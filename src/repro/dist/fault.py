"""Fault handling for long-running launches: straggler + heartbeat tracking.

The training loop is synchronous (one pjit step == one global barrier), so a
single slow host stretches every step.  :class:`StragglerMonitor` keeps an
exponential moving average of step wall-time and flags steps that exceed
``straggler_factor`` x the baseline; the accounting (count, excess seconds)
is what a fleet controller uses to decide when re-scheduling a host is
cheaper than riding out the slowdown.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class FaultConfig:
    """Knobs for straggler detection and liveness timeouts."""

    straggler_factor: float = 2.5  # step is a straggler above factor * EWMA
    warmup_steps: int = 5  # compile/first-touch steps never flagged
    ewma_alpha: float = 0.1  # baseline smoothing (per observed step)
    heartbeat_timeout_s: float = 300.0  # liveness: max silence between beats
    max_consecutive_stragglers: int = 10  # sustained slowdown => reschedule

    def __post_init__(self):
        if self.straggler_factor <= 1.0:
            raise ValueError("straggler_factor must exceed 1.0")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")


class StragglerMonitor:
    """EWMA-based step-time watchdog with excess-time accounting."""

    def __init__(self, config: Optional[FaultConfig] = None):
        self.config = config or FaultConfig()
        self.baseline_s: Optional[float] = None  # EWMA of non-straggler steps
        self.n_observed = 0
        self.n_stragglers = 0
        self.consecutive_stragglers = 0
        self.excess_s = 0.0  # total time above the straggler threshold
        self.last_flagged_step: Optional[int] = None
        self._last_heartbeat: Optional[float] = None

    def observe(self, step: int, duration_s: float) -> bool:
        """Record one step's wall time; returns True if it straggled.

        Straggler steps do NOT update the baseline — a run of slow steps
        must not normalize the slowdown away.
        """
        self.n_observed += 1
        self._last_heartbeat = time.monotonic()
        cfg = self.config
        in_warmup = self.n_observed <= cfg.warmup_steps
        threshold = (
            None if self.baseline_s is None else cfg.straggler_factor * self.baseline_s
        )
        straggled = (
            not in_warmup and threshold is not None and duration_s > threshold
        )
        if straggled:
            self.n_stragglers += 1
            self.consecutive_stragglers += 1
            self.excess_s += duration_s - threshold
            self.last_flagged_step = step
        else:
            self.consecutive_stragglers = 0
            # warmup steps (compile, first touch — routinely 100x steady
            # state) must not seed the baseline, or the inflated threshold
            # masks real stragglers for ~1/ewma_alpha steps afterwards
            if in_warmup:
                return False
            if self.baseline_s is None:
                self.baseline_s = float(duration_s)
            else:
                a = cfg.ewma_alpha
                self.baseline_s = (1 - a) * self.baseline_s + a * float(duration_s)
        return straggled

    def heartbeat(self) -> None:
        """Record liveness outside the step loop (data stalls, checkpoints)."""
        self._last_heartbeat = time.monotonic()

    def seconds_since_heartbeat(self) -> Optional[float]:
        if self._last_heartbeat is None:
            return None
        return time.monotonic() - self._last_heartbeat

    def heartbeat_expired(self) -> bool:
        since = self.seconds_since_heartbeat()
        return since is not None and since > self.config.heartbeat_timeout_s

    def should_reschedule(self) -> bool:
        """Sustained slowdown: the host is sick, not momentarily noisy."""
        return self.consecutive_stragglers >= self.config.max_consecutive_stragglers

    @property
    def straggler_ratio(self) -> float:
        return self.n_stragglers / max(self.n_observed, 1)
