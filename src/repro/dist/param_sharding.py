"""Pytree sharding resolvers: params, optimizer state, batches, KV caches.

Each resolver walks a pytree of ``ShapeDtypeStruct``s (or arrays) and returns
a matching pytree of ``NamedSharding``s for ``jax.jit(in_shardings=...)``.
Resolution is *name-based*: the last dict key on a leaf's path selects a
logical-axis tuple for the leaf's trailing dims (leading dims are the scanned
layer stack and stay replicated), then ``logical_to_spec`` maps it onto the
mesh with the usual divisibility / axis-reuse drops.

FSDP (ZeRO-3): architectures above :data:`FSDP_THRESHOLD` parameters
additionally shard the weight dims that are replicated under pure TP — the
``"embed"`` (d_model) dim of every matmul weight and the ``"moe_ff"`` expert
hidden dim — over the "data" axis.  Below the threshold those dims stay
replicated and "data" carries only the batch.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from .sharding import ShardingRules, default_rules, logical_to_spec

# Parameter count above which params/moments get ZeRO-3 sharded over "data".
# 20B: the same boundary the launchers use to drop optimizer moments to bf16
# — kimi-k2 (1T) and jamba (398B) land above, every dense <=14B arch below.
FSDP_THRESHOLD = 2e10

# Logical axes for the *trailing* dims of each named weight.  "embed" /
# "moe_ff" resolve to None under pure TP and to "data" under FSDP.
_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "enc_pos": (None, "embed"),
    "dec_pos": (None, "embed"),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
    # dense MLP
    "w_gate": ("embed", "ff"),
    "w_up": ("embed", "ff"),
    "w_down": ("ff", "embed"),
    # MoE router (expert weights handled by _MOE_AXES)
    "router": ("embed", None),
    # Mamba
    "w_in": ("embed", "ff"),
    "conv_w": (None, "ff"),
    "w_bc": ("ff", None),
    "w_dt": ("ff", None),
    "A_log": ("ff", None),
    "w_out": ("ff", "embed"),
    # RWKV
    "w_r": ("embed", "ff"),
    "w_k": ("embed", "ff"),
    "w_v": ("embed", "ff"),
    "w_g": ("embed", "ff"),
    "w_o": ("ff", "embed"),
    "w_ck": ("embed", "ff"),
    "w_cv": ("ff", "embed"),
    "w_cr": ("embed", "ff"),
    "w_lora_a": ("embed", None),
    "w_lora_b": (None, "embed"),
}

# Expert-parallel weights (E, d, f) / (E, f, d): experts over "model", the
# hidden dim over "data" under FSDP (the F~data layout moe_forward's decode
# path matches with shard(h, ..., "fsdp")).  The d_model dim must stay
# replicated here — giving it "embed" would consume the "data" axis first
# and the axis-reuse drop would silently replicate F instead.
_MOE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("experts", None, "moe_ff"),
    "w_up": ("experts", None, "moe_ff"),
    "w_down": ("experts", "moe_ff", None),
    "router": ("embed", None),
}

# Decode-cache leaves: (logical axes for trailing dims, right-aligned).
_CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "k_scale": ("batch", "kv_seq", "kv_heads"),
    "v_scale": ("batch", "kv_seq", "kv_heads"),
    "cross_k": ("batch", None, "kv_heads", None),
    "cross_v": ("batch", None, "kv_heads", None),
    "tm_x": ("batch", None),
    "tm_s": ("batch", None, None, None),
    "cm_x": ("batch", None),
    "conv": ("batch", None, "ff"),
    "ssm": ("batch", "ff", None),
    "pos": (),
}


def _path_names(path) -> Tuple[str, ...]:
    """Dict-key names along a tree path (attr/sequence keys skipped)."""
    names = []
    for entry in path:
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            names.append(key)
        else:
            name = getattr(entry, "name", None)
            if isinstance(name, str):
                names.append(name)
    return tuple(names)


def is_fsdp(cfg) -> bool:
    """Strictly above the threshold: the boundary arch stays pure TP/DP."""
    return cfg.param_count() > FSDP_THRESHOLD


def _rules_for(cfg, mesh, rules: Optional[ShardingRules]) -> ShardingRules:
    if rules is None:
        rules = default_rules(multi_pod="pod" in mesh.axis_names)
    if cfg is not None and is_fsdp(cfg):
        rules = rules.with_overrides(embed="data", moe_ff="data")
    return rules


def _aligned_spec(axes: Sequence[Optional[str]], leaf, rules, sizes):
    """Right-align trailing-dim axes; leading (stacked) dims replicate."""
    ndim = len(leaf.shape)
    if len(axes) > ndim:  # leaf smaller than the table entry: replicate
        axes = ()
    full = (None,) * (ndim - len(axes)) + tuple(axes)
    return logical_to_spec(full, rules, sizes, leaf.shape)


def param_shardings(cfg, params_shape: Any, mesh, rules: Optional[ShardingRules] = None):
    """NamedSharding pytree for a (possibly layer-stacked) parameter tree."""
    rules = _rules_for(cfg, mesh, rules)
    sizes = dict(mesh.shape)

    def resolve(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        table = _MOE_AXES if "moe" in names[:-1] else _PARAM_AXES
        axes = table.get(name, ())
        return NamedSharding(mesh, _aligned_spec(axes, leaf, rules, sizes))

    return jax.tree_util.tree_map_with_path(resolve, params_shape)


def state_shardings(cfg, state_shape: Any, mesh, rules: Optional[ShardingRules] = None):
    """Shardings for a TrainState: moments follow their parameters.

    Works because the optimizer mirrors the parameter tree leaf-for-leaf, so
    the same name-based resolution applies; non-parameter leaves (step
    counters, scalars) fall through to replicated.
    """
    return param_shardings(cfg, state_shape, mesh, rules)


def batch_shardings(mesh, specs: Any, rules: Optional[ShardingRules] = None):
    """Data-parallel input shardings: leading dim over "batch", rest replicated."""
    rules = _rules_for(None, mesh, rules)
    sizes = dict(mesh.shape)

    def resolve(leaf):
        ndim = len(leaf.shape)
        axes = ("batch",) + (None,) * (ndim - 1) if ndim else ()
        return NamedSharding(mesh, logical_to_spec(axes, rules, sizes, leaf.shape))

    return jax.tree_util.tree_map(resolve, specs)


def cache_shardings(cfg, cache_shape: Any, mesh, rules: Optional[ShardingRules] = None):
    """Decode-cache shardings: batch-sharded KV/SSM state, replicated pos.

    Cache leaves carry stacked leading layer dims (``(L, B, ...)`` or
    ``(n_blocks, period-1, B, ...)``); the name table right-aligns onto the
    trailing dims so the batch dim is found regardless of stack depth.
    """
    rules = _rules_for(cfg, mesh, rules)
    sizes = dict(mesh.shape)

    def resolve(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        axes = _CACHE_AXES.get(name, ())
        return NamedSharding(mesh, _aligned_spec(axes, leaf, rules, sizes))

    return jax.tree_util.tree_map_with_path(resolve, cache_shape)
