"""Distributed-execution helpers: logical sharding, pytree resolvers, faults.

Split by concern:

  * :mod:`repro.dist.sharding` — the context-managed (mesh, rules) registry
    and the :func:`~repro.dist.sharding.shard` activation annotation.
  * :mod:`repro.dist.param_sharding` — name-based pytree resolvers producing
    ``NamedSharding`` trees for params / optimizer state / batches / caches,
    with ZeRO-3 above :data:`~repro.dist.param_sharding.FSDP_THRESHOLD`.
  * :mod:`repro.dist.fault` — straggler / heartbeat monitoring for launches.
"""

from .fault import FaultConfig, StragglerMonitor
from .param_sharding import (
    FSDP_THRESHOLD,
    batch_shardings,
    cache_shardings,
    is_fsdp,
    param_shardings,
    state_shardings,
)
from .sharding import (
    ShardingRules,
    current_mesh,
    current_rules,
    default_rules,
    logical_to_spec,
    named_sharding,
    shard,
    use_sharding,
)

__all__ = [
    "FSDP_THRESHOLD",
    "FaultConfig",
    "ShardingRules",
    "StragglerMonitor",
    "batch_shardings",
    "cache_shardings",
    "current_mesh",
    "current_rules",
    "default_rules",
    "is_fsdp",
    "logical_to_spec",
    "named_sharding",
    "param_shardings",
    "shard",
    "state_shardings",
    "use_sharding",
]
