"""Context-managed mesh / logical-axis registry over ``jax.sharding``.

Model code never names mesh axes directly.  It annotates activations with
*logical* axis names (``"batch"``, ``"heads"``, ``"ff"``, ...) via
:func:`shard`; a :class:`ShardingRules` table maps logical names to physical
mesh axes, and :func:`use_sharding` installs a ``(mesh, rules)`` pair on a
context stack.  Off-context (plain CPU tests, eager debugging) every
annotation is a no-op, so the same model code runs unsharded.

Resolution drops a logical axis instead of failing when

  * the rules map it to ``None`` (explicitly replicated),
  * the mesh doesn't carry the mapped axis (e.g. single-pod mesh with
    multi-pod rules),
  * the dimension isn't divisible by the mapped axes' total size (smoke
    configs on test meshes), or
  * the mesh axis is already consumed by an earlier dimension of the same
    array (a PartitionSpec may use each mesh axis once).

This mirrors how production GSPMD codebases treat logical annotations: hints,
never hard constraints on toy shapes.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class ShardingRules:
    """Immutable logical-name -> mesh-axis table (``None`` = replicated)."""

    rules: Mapping[str, Axis]

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        """Physical mesh axes for one logical name (possibly empty)."""
        if logical is None:
            return ()
        mapped = self.rules.get(logical)
        if mapped is None:
            return ()
        return (mapped,) if isinstance(mapped, str) else tuple(mapped)

    def with_overrides(self, **overrides: Axis) -> "ShardingRules":
        """New table with some logical names remapped (overrides win)."""
        merged = dict(self.rules)
        merged.update(overrides)
        return ShardingRules(rules=merged)


def default_rules(*, multi_pod: bool = False) -> ShardingRules:
    """The production mapping onto a ("data", "model") / pod mesh.

    DP over "data" (spanning pods when ``multi_pod``), TP over "model" for
    heads / hidden / vocab / experts, ZeRO-3 ("fsdp") over "data".  GQA KV
    heads and the KV sequence dim stay replicated: KV heads are few and the
    decode cache is batch-sharded already.  "embed" / "moe_ff" are the
    weight dims that FSDP resolution remaps to "data" above
    ``FSDP_THRESHOLD`` (see param_sharding) — replicated by default.
    """
    return ShardingRules(
        rules={
            "batch": ("pod", "data") if multi_pod else "data",
            # independent per-tenant caches (cachesim.fleet): embarrassingly
            # parallel over the fleet, so they ride the data axis
            "tenants": "data",
            "fsdp": "data",
            "heads": "model",
            "kv_heads": None,
            "kv_seq": None,
            "ff": "model",
            "vocab": "model",
            "experts": "model",
            "embed": None,
            "moe_ff": None,
        }
    )


class _Context(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _Context()


def current_mesh():
    """Innermost active mesh, or None outside any use_sharding context."""
    return _CTX.stack[-1][0] if _CTX.stack else None


def current_rules() -> Optional[ShardingRules]:
    """Innermost active rules, or None outside any use_sharding context."""
    return _CTX.stack[-1][1] if _CTX.stack else None


@contextmanager
def use_sharding(mesh, rules: ShardingRules):
    """Install (mesh, rules) for the dynamic extent of the block. Nests."""
    _CTX.stack.append((mesh, rules))
    try:
        yield (mesh, rules)
    finally:
        _CTX.stack.pop()


def logical_to_spec(
    axes: Sequence[Optional[str]],
    rules: ShardingRules,
    axis_sizes: Mapping[str, int],
    shape: Optional[Sequence[int]] = None,
) -> PartitionSpec:
    """Resolve per-dim logical names to a PartitionSpec.

    ``axis_sizes`` is the mesh's name -> size mapping (``mesh.shape``); pass
    ``shape`` to drop axes that don't divide the corresponding dimension.
    """
    used: set = set()
    out = []
    for d, logical in enumerate(axes):
        mapped = tuple(
            a for a in rules.mesh_axes(logical) if a in axis_sizes and a not in used
        )
        if mapped and shape is not None:
            size = 1
            for a in mapped:
                size *= axis_sizes[a]
            if size == 0 or shape[d] % size != 0:
                mapped = ()
        if not mapped:
            out.append(None)
            continue
        used.update(mapped)
        out.append(mapped[0] if len(mapped) == 1 else mapped)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names; no-op off-context.

    One name (or None) per dimension of ``x``.
    """
    # arity is validated even off-context so plain CPU tests catch it
    if len(axes) != x.ndim:
        raise ValueError(
            f"shard() got {len(axes)} axis names for a rank-{x.ndim} array"
        )
    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return x
    spec = logical_to_spec(axes, rules, dict(mesh.shape), x.shape)
    if not spec:  # fully replicated constraint adds nothing
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(
    mesh,
    axes: Sequence[Optional[str]],
    rules: Optional[ShardingRules] = None,
    shape: Optional[Sequence[int]] = None,
) -> NamedSharding:
    """NamedSharding from logical names (helper for the pytree resolvers)."""
    if rules is None:
        rules = default_rules(multi_pod="pod" in mesh.axis_names)
    return NamedSharding(mesh, logical_to_spec(axes, rules, dict(mesh.shape), shape))
