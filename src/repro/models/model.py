"""Unified model assembly for all 10 assigned architectures.

Families:
  dense / moe / vlm — decoder-only LM, homogeneous layers, lax.scan over the
      stacked per-layer params (+ optional jax.checkpoint remat).
  ssm (rwkv6)       — RWKV-6 time-mix + channel-mix stack, O(1) decode state.
  hybrid (jamba)    — scan over "super-blocks" of `attn_period` layers
      (attn_period-1 Mamba + 1 attention; MoE every `moe_every`).
  encdec (whisper)  — encoder stack + decoder stack with cross-attention;
      the audio conv frontend is stubbed (precomputed frame embeddings).

Public API (used by train/serve/launch):
  init_params(cfg, key)
  forward_train(cfg, params, batch)           -> (loss, metrics)
  prefill(cfg, params, batch, max_len)        -> (logits, cache)
  decode_step(cfg, params, cache, tokens, pos)-> (logits, cache)
  input_specs(cfg, shape)                     -> pytree of ShapeDtypeStruct
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import shard

from .attention import (
    attention_decode,
    attention_forward,
    init_attention,
    project_cross_kv,
)
from .common import dtype_of, embed_init, rmsnorm, rmsnorm_init, softmax_cross_entropy
from .mamba import init_mamba, mamba_forward
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .rwkv import init_rwkv_block, rwkv_block_fwd

VOCAB_PAD = 256

# parameters kept in float32 even under bf16 compute (routing / SSM dynamics)
_F32_KEEP = ("router", "A_log", "dt_bias", "w0", "u", "D")


def cast_params_for_compute(cfg: ArchConfig, params):
    """Cast weights to the compute dtype (mixed-precision forward), keeping
    numerically sensitive leaves (router logits, SSM dynamics) in float32."""
    cdt = dtype_of(cfg.compute_dtype)
    if cdt == jnp.float32:
        return params

    def cast(path, leaf):
        name = str(getattr(path[-1], "key", path[-1])) if path else ""
        if name in _F32_KEEP:
            return leaf
        if leaf.dtype == jnp.float32:
            return leaf.astype(cdt)
        return leaf

    return jax.tree_util.tree_map_with_path(cast, params)


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


def _is_moe_layer(cfg: ArchConfig, layer: int) -> bool:
    return cfg.n_experts > 0 and (layer % cfg.moe_every) == cfg.moe_offset


def _is_attn_layer(cfg: ArchConfig, layer: int) -> bool:
    if cfg.family == "ssm":
        return False
    if cfg.attn_period == 0:
        return True
    return (layer % cfg.attn_period) == (cfg.attn_period - 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_decoder_layer(cfg: ArchConfig, layer_idx: int, key, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if _is_attn_layer(cfg, layer_idx):
        p["attn"] = init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    if _is_moe_layer(cfg, layer_idx):
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype)
    return p


def init_params(cfg: ArchConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    pv = padded_vocab(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], pv, cfg.d_model, dtype),
        "lm_head": embed_init(keys[1], pv, cfg.d_model, dtype).T,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }

    if cfg.family == "ssm":
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["blocks"] = jax.vmap(
            lambda k: init_rwkv_block(k, cfg, dtype)
        )(lkeys)
        return params

    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[2], cfg.n_encoder_layers)
        dkeys = jax.random.split(keys[3], cfg.n_layers)

        def enc_layer(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": rmsnorm_init(cfg.d_model, dtype),
                "ln2": rmsnorm_init(cfg.d_model, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
            }

        def dec_layer(k):
            ks = jax.random.split(k, 3)
            return {
                "ln1": rmsnorm_init(cfg.d_model, dtype),
                "ln2": rmsnorm_init(cfg.d_model, dtype),
                "ln3": rmsnorm_init(cfg.d_model, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "cross": init_attention(ks[1], cfg, dtype),
                "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
            }

        params["encoder"] = jax.vmap(enc_layer)(ekeys)
        params["blocks"] = jax.vmap(dec_layer)(dkeys)
        params["enc_pos"] = embed_init(keys[4], cfg.n_audio_frames, cfg.d_model, dtype)
        # sized for the largest assigned decoder shape (prefill/decode_32k)
        params["dec_pos"] = embed_init(keys[5], 32_768, cfg.d_model, dtype)
        params["enc_final_norm"] = rmsnorm_init(cfg.d_model, dtype)
        return params

    if cfg.family == "hybrid":
        period = cfg.attn_period
        n_blocks = cfg.n_layers // period
        bkeys = jax.random.split(keys[2], n_blocks)

        def super_block(k):
            lks = jax.random.split(k, period)
            return [
                _init_decoder_layer(cfg, i, lks[i], dtype) for i in range(period)
            ]

        params["blocks"] = jax.vmap(super_block)(bkeys)
        return params

    # dense / moe / vlm: homogeneous decoder layers
    lkeys = jax.random.split(keys[2], cfg.n_layers)
    params["blocks"] = jax.vmap(
        lambda k: _init_decoder_layer(cfg, cfg.moe_offset, k, dtype)
    )(lkeys)
    if cfg.family == "vlm":
        params["img_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _is_fsdp_arch(cfg) -> bool:
    from repro.dist.param_sharding import FSDP_THRESHOLD

    return cfg.param_count() > FSDP_THRESHOLD


def _decoder_layer_fwd(cfg, layer_idx, p, x, positions, aux_acc, cache=None, pos=None):
    """One decoder layer; cache-aware. Returns (x, aux_acc, new_layer_cache)."""
    from jax.ad_checkpoint import checkpoint_name

    new_cache = {}
    h = rmsnorm(x, p["ln1"])
    # §Perf H5b: at decode on FSDP archs, shard the activation's d_model dim
    # over "data" so every weight matmul contracts locally against its
    # data-sharded weight slice and emits a tiny (B,1,out) psum — instead of
    # ZeRO-3 all-gathering GB-scale weights per layer for one token.
    decode_fsdp = cache is not None and _is_fsdp_arch(cfg)
    if decode_fsdp:
        h = shard(h, None, None, "fsdp")
    if _is_attn_layer(cfg, layer_idx):
        if cache is None:
            h = attention_forward(p["attn"], h, cfg, positions, causal=True)
        else:
            h, kv = attention_decode(p["attn"], h, cache["kv"], pos, cfg)
            new_cache["kv"] = kv
    else:
        if cache is None:
            h, _state = mamba_forward(p["mamba"], h, cfg)
        else:
            h, state = mamba_forward(p["mamba"], h, cfg, state=cache["ssm"])
            new_cache["ssm"] = state
    # §Perf H1b: the block outputs sit just past the TP all-reduce; saving
    # them means the remat recompute never re-issues those collectives
    h = checkpoint_name(h, "tp_block_out")
    x = x + h
    h = rmsnorm(x, p["ln2"])
    if decode_fsdp:
        h = shard(h, None, None, "fsdp")
    if _is_moe_layer(cfg, layer_idx):
        h, aux = moe_forward(p["moe"], h, cfg)
        aux_acc = {
            "load_balance_loss": aux_acc["load_balance_loss"] + aux["load_balance_loss"],
            "router_z_loss": aux_acc["router_z_loss"] + aux["router_z_loss"],
        }
    else:
        h = mlp_forward(p["mlp"], h, cfg.mlp_activation)
    h = checkpoint_name(h, "tp_block_out")
    x = x + h
    return x, aux_acc, new_cache



def _remat(cfg, fn):
    """Wrap a scan body per the configured remat policy (§Perf H1b)."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "save_tp":
        policy = jax.checkpoint_policies.save_only_these_names("tp_block_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)

def _zero_aux():
    return {
        "load_balance_loss": jnp.zeros((), jnp.float32),
        "router_z_loss": jnp.zeros((), jnp.float32),
    }


def _stack_forward(cfg: ArchConfig, params, x, positions):
    """Scan the layer stack over a full sequence (train / prefill, no cache)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = x.astype(cdt)

    if cfg.family == "ssm":

        def block(carry, p):
            x, aux = carry
            x, _state = rwkv_block_fwd(p, x, cfg)
            return (x, aux), None

        fn = _remat(cfg, block)
        (x, aux), _ = jax.lax.scan(fn, (x, _zero_aux()), params["blocks"])
        return x, _zero_aux()

    if cfg.family == "hybrid":
        period = cfg.attn_period

        def sblock_list(carry, bp):
            x, aux = carry
            for i in range(period):
                x, aux, _ = _decoder_layer_fwd(cfg, i, bp[i], x, positions, aux)
            return (x, aux), None

        fn = _remat(cfg, sblock_list)
        (x, aux), _ = jax.lax.scan(fn, (x, _zero_aux()), params["blocks"])
        return x, aux

    # homogeneous decoder stack
    def block(carry, p):
        x, aux = carry
        x, aux, _ = _decoder_layer_fwd(cfg, cfg.moe_offset, p, x, positions, aux)
        return (x, aux), None

    fn = _remat(cfg, block)
    (x, aux), _ = jax.lax.scan(fn, (x, _zero_aux()), params["blocks"])
    return x, aux


def _encoder_forward(cfg, params, frames):
    """Whisper encoder over stubbed frame embeddings (B, T, D)."""
    cdt = dtype_of(cfg.compute_dtype)
    T = frames.shape[1]
    x = frames.astype(cdt) + params["enc_pos"][:T].astype(cdt)
    positions = jnp.broadcast_to(jnp.arange(T), frames.shape[:2])

    def block(x, p):
        h = rmsnorm(x, p["ln1"])
        h = attention_forward(p["attn"], h, cfg, positions, causal=False)
        x = x + h
        h = rmsnorm(x, p["ln2"])
        x = x + mlp_forward(p["mlp"], h, cfg.mlp_activation)
        return x, None

    fn = _remat(cfg, block)
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return rmsnorm(x, params["enc_final_norm"])


def _decoder_encdec_forward(cfg, params, tokens, enc_out):
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cdt) + params["dec_pos"][:S].astype(cdt)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def block(x, p):
        h = rmsnorm(x, p["ln1"])
        h = attention_forward(p["attn"], h, cfg, positions, causal=True)
        x = x + h
        h = rmsnorm(x, p["ln2"])
        ckv = project_cross_kv(p["cross"], enc_out, cfg)
        h = attention_forward(p["cross"], h, cfg, positions, causal=False, kv=ckv)
        x = x + h
        h = rmsnorm(x, p["ln3"])
        x = x + mlp_forward(p["mlp"], h, cfg.mlp_activation)
        return x, None

    fn = _remat(cfg, block)
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return x


def _logits(cfg, params, x):
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "vocab")
    pv, v = logits.shape[-1], cfg.vocab_size
    if pv != v:  # mask vocab padding
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(pv) < v, logits, neg)
    return logits


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------
def forward_train(cfg: ArchConfig, params, batch: Dict) -> Tuple[jax.Array, Dict]:
    """batch: tokens (B,S), labels (B,S) [, frames | image_embeds]."""
    cdt = dtype_of(cfg.compute_dtype)
    params = cast_params_for_compute(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape

    if cfg.family == "encdec":
        enc_out = _encoder_forward(cfg, params, batch["frames"])
        x = _decoder_encdec_forward(cfg, params, tokens, enc_out)
        logits = _logits(cfg, params, x)
        loss, lse = softmax_cross_entropy(logits, batch["labels"])
        return loss, {"nll": loss, "lse": lse}

    x = params["embed"][tokens].astype(cdt)
    x = shard(x, "batch", None, None)
    loss_mask = None
    if cfg.family == "vlm":
        img = rmsnorm(batch["image_embeds"].astype(cdt), params["img_norm"])
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
        loss_mask = jnp.concatenate(
            [jnp.zeros((B, n_img)), jnp.ones((B, S))], axis=1
        )
        labels = jnp.concatenate(
            [jnp.zeros((B, n_img), batch["labels"].dtype), batch["labels"]], axis=1
        )
    else:
        labels = batch["labels"]

    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, aux = _stack_forward(cfg, params, x, positions)
    logits = _logits(cfg, params, x)
    loss, lse = softmax_cross_entropy(logits, labels, mask=loss_mask)
    metrics = {"nll": loss, "lse": lse}
    if cfg.n_experts:
        loss = loss + 0.01 * aux["load_balance_loss"] + 1e-3 * aux["router_z_loss"]
        metrics.update(aux)
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    """Decode-state pytree, stacked per block for scan."""
    cdt = dtype_of(cfg.compute_dtype)
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv_head_dim
        L = cfg.n_layers
        return {
            "tm_x": jnp.zeros((L, batch, cfg.d_model), cdt),
            "tm_s": jnp.zeros((L, batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
            "cm_x": jnp.zeros((L, batch, cfg.d_model), cdt),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        period = cfg.attn_period
        nb = cfg.n_layers // period
        d_in = cfg.ssm_expand * cfg.d_model
        return {
            "kv": {
                "k": jnp.zeros((nb, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt),
                "v": jnp.zeros((nb, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt),
            },
            "conv": jnp.zeros((nb, period - 1, batch, cfg.ssm_conv_width - 1, d_in), cdt),
            "ssm": jnp.zeros((nb, period - 1, batch, d_in, cfg.ssm_state_dim), jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "kv": {
                "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt),
            },
            "cross_k": jnp.zeros(
                (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads, cfg.head_dim), cdt
            ),
            "cross_v": jnp.zeros(
                (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads, cfg.head_dim), cdt
            ),
            "pos": jnp.zeros((), jnp.int32),
        }
    # dense / moe / vlm
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        kv = {
            "k": jnp.zeros((L, batch, max_len, kvh, hd), jnp.int8),
            "v": jnp.zeros((L, batch, max_len, kvh, hd), jnp.int8),
            "k_scale": jnp.zeros((L, batch, max_len, kvh), jnp.float32),
            "v_scale": jnp.zeros((L, batch, max_len, kvh), jnp.float32),
        }
    else:
        kv = {
            "k": jnp.zeros((L, batch, max_len, kvh, hd), cdt),
            "v": jnp.zeros((L, batch, max_len, kvh, hd), cdt),
        }
    return {"kv": kv, "pos": jnp.zeros((), jnp.int32)}


def decode_step(
    cfg: ArchConfig, params, cache: Dict, tokens: jax.Array
) -> Tuple[jax.Array, Dict]:
    """One decode step: tokens (B,) -> logits (B, V_padded); updates cache."""
    cdt = dtype_of(cfg.compute_dtype)
    params = cast_params_for_compute(cfg, params)
    pos = cache["pos"]
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cdt)  # (B,1,D)

    if cfg.family == "ssm":

        def block(x, inp):
            p, tm_x, tm_s, cm_x = inp
            x, (tm_x2, tm_s2, cm_x2) = rwkv_block_fwd(
                p, x, cfg, state=(tm_x, tm_s, cm_x)
            )
            return x, (tm_x2, tm_s2, cm_x2)

        x, (tm_x, tm_s, cm_x) = jax.lax.scan(
            block, x, (params["blocks"], cache["tm_x"], cache["tm_s"], cache["cm_x"])
        )
        new_cache = {"tm_x": tm_x, "tm_s": tm_s, "cm_x": cm_x, "pos": pos + 1}
        logits = _logits(cfg, params, x)[:, 0]
        return logits, new_cache

    if cfg.family == "hybrid":
        period = cfg.attn_period

        def sblock(x, inp):
            bp, kv, conv, ssm = inp
            new_conv, new_ssm = [], []
            new_kv = kv
            m = 0
            for i in range(period):
                p_i = bp[i]
                lc = (
                    {"kv": new_kv}
                    if _is_attn_layer(cfg, i)
                    else {"ssm": (conv[m], ssm[m])}
                )
                x, _, out_c = _decoder_layer_fwd(
                    cfg, i, p_i, x, None, _zero_aux(), cache=lc, pos=pos
                )
                if _is_attn_layer(cfg, i):
                    new_kv = out_c["kv"]
                else:
                    cst, hst = out_c["ssm"]
                    new_conv.append(cst)
                    new_ssm.append(hst)
                    m += 1
            return x, (new_kv, jnp.stack(new_conv), jnp.stack(new_ssm))

        x, (kv, conv, ssm) = jax.lax.scan(
            sblock, x, (params["blocks"], cache["kv"], cache["conv"], cache["ssm"])
        )
        new_cache = {"kv": kv, "conv": conv, "ssm": ssm, "pos": pos + 1}
        logits = _logits(cfg, params, x)[:, 0]
        return logits, new_cache

    if cfg.family == "encdec":
        positions = jnp.broadcast_to(pos, (B, 1))
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(cdt)

        def block(x, inp):
            p, kv, ck, cv = inp
            h = rmsnorm(x, p["ln1"])
            h, kv2 = attention_decode(p["attn"], h, kv, pos, cfg)
            x = x + h
            h = rmsnorm(x, p["ln2"])
            h = attention_forward(
                p["cross"], h, cfg, positions, causal=False, kv=(ck, cv)
            )
            x = x + h
            h = rmsnorm(x, p["ln3"])
            x = x + mlp_forward(p["mlp"], h, cfg.mlp_activation)
            return x, kv2

        x, kv = jax.lax.scan(
            block,
            x,
            (params["blocks"], cache["kv"], cache["cross_k"], cache["cross_v"]),
        )
        new_cache = dict(cache, kv=kv, pos=pos + 1)
        logits = _logits(cfg, params, x)[:, 0]
        return logits, new_cache

    # dense / moe / vlm
    def block(carry, inp):
        x, aux = carry
        p, kv = inp
        x, aux, out_c = _decoder_layer_fwd(
            cfg, cfg.moe_offset, p, x, None, aux, cache={"kv": kv}, pos=pos
        )
        return (x, aux), out_c["kv"]

    (x, _aux), kv = jax.lax.scan(
        block, (x, _zero_aux()), (params["blocks"], cache["kv"])
    )
    new_cache = {"kv": kv, "pos": pos + 1}
    logits = _logits(cfg, params, x)[:, 0]
    return logits, new_cache


def prefill(
    cfg: ArchConfig, params, batch: Dict, max_len: int
) -> Tuple[jax.Array, Dict]:
    """Run the full prompt, build the decode cache, return last-token logits.

    Implemented as full-sequence forward + recomputed per-layer KV write (the
    production path would fuse these; equality with decode_step is tested).
    """
    cdt = dtype_of(cfg.compute_dtype)
    params = cast_params_for_compute(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)

    if cfg.family == "ssm":
        x = params["embed"][tokens].astype(cdt)

        def block(x, p):
            x, (tx, ts, cx) = rwkv_block_fwd(p, x, cfg)
            return x, (tx, ts, cx)

        fn = _remat(cfg, block)
        x, (tm_x, tm_s, cm_x) = jax.lax.scan(fn, x, params["blocks"])
        cache = {
            "tm_x": tm_x,
            "tm_s": tm_s,
            "cm_x": cm_x,
            "pos": jnp.asarray(S, jnp.int32),
        }
        logits = _logits(cfg, params, x[:, -1:])[:, 0]
        return logits, cache

    if cfg.family == "encdec":
        enc_out = _encoder_forward(cfg, params, batch["frames"])

        def cross_kv(p):
            return project_cross_kv(p["cross"], enc_out, cfg)

        ck, cv = jax.lax.map(cross_kv, params["blocks"])
        cache["cross_k"] = ck
        cache["cross_v"] = cv
        logits = _decoder_encdec_forward_with_cache(
            cfg, params, tokens, enc_out, cache, max_len
        )
        return logits, cache

    # dense / moe / vlm / hybrid: step-by-step via decode on the last token
    # after a full forward that fills KV (simple + testable implementation).
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cdt)
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = rmsnorm(batch["image_embeds"].astype(cdt), params["img_norm"])
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if cfg.family == "hybrid":
        logits, cache = _hybrid_prefill(cfg, params, x, positions, cache, max_len)
        return logits, cache

    from .attention import _project_qkv, _quantize_kv, flash_attention

    def _pad_kv(k, pad):
        return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt)

    pad = max_len - S
    int8_kv = cfg.kv_cache_dtype == "int8"

    def block(carry, p):
        x = carry
        h = rmsnorm(x, p["ln1"])
        q, k, v = _project_qkv(p["attn"], h, cfg, positions)
        attn = flash_attention(q, k, v, causal=True)
        attn = attn.reshape(B, x.shape[1], cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
        x = x + attn
        h = rmsnorm(x, p["ln2"])
        if "moe" in p:
            h, _ = moe_forward(p["moe"], h, cfg)
        else:
            h = mlp_forward(p["mlp"], h, cfg.mlp_activation)
        x = x + h
        if int8_kv:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            out = (
                jnp.pad(kq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                jnp.pad(vq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                jnp.pad(ks, ((0, 0), (0, pad), (0, 0))),
                jnp.pad(vs, ((0, 0), (0, pad), (0, 0))),
            )
        else:
            out = (_pad_kv(k, pad), _pad_kv(v, pad))
        return x, out

    fn = _remat(cfg, block)
    x, kv_out = jax.lax.scan(fn, x, params["blocks"])
    if int8_kv:
        cache["kv"] = {
            "k": kv_out[0], "v": kv_out[1],
            "k_scale": kv_out[2], "v_scale": kv_out[3],
        }
    else:
        cache["kv"] = {"k": kv_out[0], "v": kv_out[1]}
    cache["pos"] = jnp.asarray(S, jnp.int32)
    logits = _logits(cfg, params, x[:, -1:])[:, 0]
    return logits, cache


def _hybrid_prefill(cfg, params, x, positions, cache, max_len):
    """Jamba prefill: scan over super-blocks, harvesting KV + SSM states."""
    period = cfg.attn_period
    B, S = x.shape[:2]
    cdt = dtype_of(cfg.compute_dtype)
    from .attention import _project_qkv, flash_attention

    pad = max_len - S

    def sblock(x, bp):
        bconv, bssm, kvs = [], [], None
        for i in range(period):
            p = bp[i]
            h = rmsnorm(x, p["ln1"])
            if _is_attn_layer(cfg, i):
                q, k, v = _project_qkv(p["attn"], h, cfg, positions)
                attn = flash_attention(q, k, v, causal=True)
                attn = (
                    attn.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
                )
                x = x + attn
                kvs = (
                    jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt),
                    jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt),
                )
            else:
                h2, (cst, hst) = mamba_forward(p["mamba"], h, cfg)
                x = x + h2
                bconv.append(cst)
                bssm.append(hst)
            h = rmsnorm(x, p["ln2"])
            if "moe" in p:
                h, _ = moe_forward(p["moe"], h, cfg)
            else:
                h = mlp_forward(p["mlp"], h, cfg.mlp_activation)
            x = x + h
        return x, (kvs[0], kvs[1], jnp.stack(bconv), jnp.stack(bssm))

    fn = _remat(cfg, sblock)
    x, (kv_k, kv_v, convs, ssms) = jax.lax.scan(fn, x, params["blocks"])
    cache = {
        "kv": {"k": kv_k, "v": kv_v},
        "conv": convs,
        "ssm": ssms,
        "pos": jnp.asarray(S, jnp.int32),
    }
    logits = _logits(cfg, params, x[:, -1:])[:, 0]
    return logits, cache


def _decoder_encdec_forward_with_cache(cfg, params, tokens, enc_out, cache, max_len):
    """Whisper decoder prefill: fills self-attn KV; returns last logits."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cdt) + params["dec_pos"][:S].astype(cdt)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    from .attention import _project_qkv, flash_attention

    pad = max_len - S

    def block(x, inp):
        p, ck, cv = inp
        h = rmsnorm(x, p["ln1"])
        q, k, v = _project_qkv(p["attn"], h, cfg, positions)
        attn = flash_attention(q, k, v, causal=True)
        x = x + attn.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
        h = rmsnorm(x, p["ln2"])
        h = attention_forward(p["cross"], h, cfg, positions, causal=False, kv=(ck, cv))
        x = x + h
        h = rmsnorm(x, p["ln3"])
        x = x + mlp_forward(p["mlp"], h, cfg.mlp_activation)
        kpad = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt)
        vpad = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt)
        return x, (kpad, vpad)

    fn = _remat(cfg, block)
    x, (kv_k, kv_v) = jax.lax.scan(
        fn, x, (params["blocks"], cache["cross_k"], cache["cross_v"])
    )
    cache["kv"] = {"k": kv_k, "v": kv_v}
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return _logits(cfg, params, x[:, -1:])[:, 0]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins for the dry-run)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """Shape/dtype stand-ins for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        text_s = S - cfg.n_image_tokens if cfg.family == "vlm" else S
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, text_s), i32),
            "labels": jax.ShapeDtypeStruct((B, text_s), i32),
        }
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model), dtype_of(cfg.compute_dtype)
            )
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), dtype_of(cfg.compute_dtype)
            )
        return specs
    if shape.kind == "prefill":
        text_s = S - cfg.n_image_tokens if cfg.family == "vlm" else S
        specs = {"tokens": jax.ShapeDtypeStruct((B, text_s), i32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model), dtype_of(cfg.compute_dtype)
            )
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), dtype_of(cfg.compute_dtype)
            )
        return specs
    # decode: one new token given a cache of length S
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "cache": cache,
    }
