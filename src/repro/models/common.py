"""Shared model components: norms, RoPE, embeddings, initializers, loss."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp



def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[
        name
    ]


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(dim: int, dtype):
    return jnp.ones((dim,), dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,  # (..., S, H, D)
    positions: jax.Array,  # (..., S)
    theta: float,
) -> jax.Array:
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


def softmax_cross_entropy(
    logits: jax.Array,  # (B, S, V) — may be sharded on V
    labels: jax.Array,  # (B, S) int32
    mask: Optional[jax.Array] = None,  # (B, S) 1=count
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, jax.Array]:
    """Mean next-token loss (+ z-loss for logit drift control at scale)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll), jnp.mean(lse)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, jnp.sum(lse * mask) / denom


def shift_tokens(x: jax.Array) -> jax.Array:
    """x_{t-1} with zero at t=0 (token-shift used by RWKV)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
