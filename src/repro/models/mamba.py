"""Mamba (selective SSM) block — the non-attention layer of Jamba.

h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t + D x_t
with input-dependent (dt, B, C) and a causal depthwise conv front.  Training
scans over time; decoding carries (conv window, h) as O(1) state.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

from .common import dense_init


def init_mamba(key, cfg, dtype) -> Dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, d_in), jnp.float32) * 0.1).astype(dtype),
        "w_bc": dense_init(ks[2], d_in, 2 * n, dtype),
        "w_dt": dense_init(ks[3], d_in, d_in, dtype, scale=0.01),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))
        ),
        "D": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[4], d_in, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv; x (B, S, C), w (W, C). Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state  # (B, W-1, C)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return y, xp[:, -(W - 1) :, :]


def mamba_forward(
    p: Dict, x: jax.Array, cfg, state: Tuple = None
) -> Tuple[jax.Array, Tuple]:
    """x: (B, S, D); state=(conv_state, h) for decode, None for training."""
    B, S, D = x.shape
    n = cfg.ssm_state_dim
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_in) each
    xin = shard(xin, "batch", None, "ff")
    conv_state = None if state is None else state[0]
    xin, conv_state_new = _causal_conv(xin, p["conv_w"], conv_state)
    xin = jax.nn.silu(xin)

    bc = xin @ p["w_bc"]
    Bmat, Cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,S,n)
    dt = jax.nn.softplus((xin @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (d_in, n)

    xin_f = xin.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * A[None, None])  # (B,S,d_in,n)
    drive = (dt * xin_f)[..., None] * Bmat[:, :, None, :]  # (B,S,d_in,n)

    h0 = (
        jnp.zeros((B, xin.shape[-1], n), jnp.float32) if state is None else state[1]
    )

    def step(h, inp):
        dec, drv, c = inp  # (B,d_in,n), (B,d_in,n), (B,n)
        h_new = dec * h + drv
        y = jnp.einsum("bdn,bn->bd", h_new, c)
        return h_new, y

    decs = jnp.moveaxis(decay, 1, 0)
    drvs = jnp.moveaxis(drive, 1, 0)
    cs = jnp.moveaxis(Cmat, 1, 0)
    h_final, ys = jax.lax.scan(step, h0, (decs, drvs, cs))
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,d_in)
    y = y + p["D"] * xin_f
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return shard(out, "batch", None, None), (conv_state_new, h_final)
