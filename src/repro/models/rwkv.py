"""RWKV-6 ("Finch") — attention-free token mixing with data-dependent decay.

Per head (head dim n): state S in R^{n x n};  for each step t:

    a_t = k_t (outer) v_t
    y_t = r_t @ (S_{t-1} + diag(u) a_t)
    S_t = diag(w_t) S_{t-1} + a_t

with the *data-dependent* per-channel decay  w_t = exp(-exp(w0 + lora(x_t)))
(the Finch contribution vs RWKV-5's static decay).  Training uses a
lax.scan over time; decoding carries (shift-token, S) as O(1) state — there is
no KV cache, which is why the long_500k shape runs on this family.

Channel mixing is the standard RWKV squared-ReLU gated MLP with token shift.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

from .common import dense_init, rmsnorm, rmsnorm_init, shift_tokens

_LORA_RANK = 64


def init_rwkv_block(key, cfg, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    n_heads = d // hd
    ks = jax.random.split(key, 12)
    return {
        "ln1": rmsnorm_init(d, dtype),
        "ln2": rmsnorm_init(d, dtype),
        # time mix
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_g": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype),
        "w0": (jax.random.normal(ks[5], (d,), jnp.float32) * 0.1 - 6.0).astype(
            jnp.float32
        ),
        "w_lora_a": dense_init(ks[6], d, _LORA_RANK, dtype),
        "w_lora_b": dense_init(ks[7], _LORA_RANK, d, dtype, scale=0.01),
        "u": (jax.random.normal(ks[8], (n_heads, hd), jnp.float32) * 0.1).astype(
            jnp.float32
        ),
        "ln_x": rmsnorm_init(d, dtype),
        # channel mix
        "mu_ck": jnp.full((d,), 0.5, dtype),
        "mu_cr": jnp.full((d,), 0.5, dtype),
        "w_ck": dense_init(ks[9], d, cfg.d_ff, dtype),
        "w_cv": dense_init(ks[10], cfg.d_ff, d, dtype),
        "w_cr": dense_init(ks[11], d, d, dtype),
    }


def _decay(p, xw: jax.Array) -> jax.Array:
    """Data-dependent decay w_t in (0,1): exp(-exp(w0 + lora(x)))."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))


def _wkv_scan(r, k, v, w, u, state0):
    """r,k,v,w: (B, S, H, n); u: (H, n); state0: (B, H, n, n)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, n)
        a = jnp.einsum("bhi,bhj->bhij", k_t, v_t)  # (B,H,n,n)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * a)
        S_new = w_t[..., None] * S + a
        return S_new, y

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, (rs, ks_, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state  # (B,S,H,n), final state


def rwkv_time_mix(
    p: Dict, x: jax.Array, cfg, state: Tuple = None
) -> Tuple[jax.Array, Tuple]:
    """x: (B, S, D). state=(last_x, S) for decode; None for training."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    if state is None:
        x_prev = shift_tokens(x)
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    else:
        last_x, S0 = state
        x_prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)

    def lerp(mu):
        return x * mu + x_prev * (1 - mu)

    r = (lerp(p["mu_r"]) @ p["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (lerp(p["mu_k"]) @ p["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (lerp(p["mu_v"]) @ p["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["w_g"])
    w = _decay(p, lerp(p["mu_w"])).reshape(B, S, H, hd)

    y, S_final = _wkv_scan(r, k, v, w, p["u"], S0)
    y = y.reshape(B, S, D).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"]) * g
    out = y @ p["w_o"]
    return shard(out, "batch", None, None), (x[:, -1, :], S_final)


def rwkv_channel_mix(
    p: Dict, x: jax.Array, state: jax.Array = None
) -> Tuple[jax.Array, jax.Array]:
    """Squared-ReLU gated channel mixing with token shift."""
    if state is None:
        x_prev = shift_tokens(x)
    else:
        x_prev = jnp.concatenate([state[:, None, :], x[:, :-1, :]], axis=1)
    xk = x * p["mu_ck"] + x_prev * (1 - p["mu_ck"])
    xr = x * p["mu_cr"] + x_prev * (1 - p["mu_cr"])
    k = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    k = shard(k, "batch", None, "ff")
    out = jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])
    return out, x[:, -1, :]


def rwkv_block_fwd(p, x, cfg, state=None):
    """Full RWKV block (pre-norm time-mix + pre-norm channel-mix).

    state = (tm_x, tm_s, cm_x) or None (training). Returns (x, new_state).
    """
    from .common import rmsnorm as _rms

    tm_state = None if state is None else (state[0], state[1])
    h, (tm_x, tm_s) = rwkv_time_mix(p, _rms(x, p["ln1"]), cfg, state=tm_state)
    x = x + h
    cm_state = None if state is None else state[2]
    h2, cm_x = rwkv_channel_mix(p, _rms(x, p["ln2"]), state=cm_state)
    x = x + h2
    return x, (tm_x, tm_s, cm_x)
