"""Dense MLP blocks: SwiGLU / GeGLU / plain-GELU."""

from __future__ import annotations

from typing import Dict

import jax

from repro.dist.sharding import shard

from .common import dense_init


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    if activation == "gelu":
        return {
            "w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        }
    raise ValueError(f"unknown activation {activation}")


def mlp_forward(p: Dict, x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard(h, "batch", None, "ff")
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"])
    h = shard(h, "batch", None, "ff")
    return h @ p["w_down"]
