"""Mixture-of-Experts layer: top-k routing, capacity buckets, EP sharding.

Production-style dispatch (no dense one-hot (T, E, Cap) tensor):

  1. router logits -> top-k (gates, expert ids) per token
  2. sort the (T*k,) assignment list by expert; rank-in-expert via the sorted
     segment offsets (O(Tk log Tk), no (Tk x E) buffer)
  3. scatter tokens into an (E, capacity, D) buffer (dropped beyond capacity)
  4. per-expert SwiGLU via batched einsum, experts sharded over "model" (EP)
  5. gather back + combine with gates

Aux losses: switch-style load-balance + router z-loss, returned to the caller.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

from .common import dense_init


def init_moe(key, cfg, dtype) -> Dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (e, f, d), jnp.float32) * (1.0 / jnp.sqrt(f))
        ).astype(dtype),
    }


def moe_forward(
    p: Dict, x: jax.Array, cfg
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (B, S, D), aux {load_balance_loss, router_z_loss}."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)  # (T, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # --- aux losses ---
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    # fraction of tokens routed per expert (scatter-add; no (T,K,E) one-hot)
    ce = (
        jnp.zeros(E, jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * K)
    )
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }

    # §Perf H2'': for SMALL experts (granite: E*F = 16k), dispatch/combine
    # communication dwarfs the matmuls — evaluate the mixture DENSELY (every
    # expert on every token, weighted by the top-k gates).  ~E/K overcompute
    # but zero dispatch collectives; profitable whenever E*F is below a dense
    # d_ff-equivalent threshold.  (The first H2' attempt — capacity sharded
    # over data — was REFUTED: GSPMD cannot prove scatter locality and
    # replicates + all-reduces the buffer; see EXPERIMENTS.md §Perf.)
    if E * cfg.expert_ff <= 32_768:
        gates_full = jnp.zeros((T, E), jnp.float32).at[
            jnp.arange(T)[:, None], eidx
        ].set(gates)
        xe = xt.astype(x.dtype)
        hd = jax.nn.silu(jnp.einsum("td,edf->tef", xe, p["w_gate"])) * jnp.einsum(
            "td,edf->tef", xe, p["w_up"]
        )
        hd = hd * gates_full.astype(x.dtype)[:, :, None]
        out = jnp.einsum("tef,efd->td", hd, p["w_down"]).reshape(B, S, D)
        return shard(out, "batch", None, None), aux

    # Perf H2''': per-data-shard capacity slicing.  Tokens are reshaped to
    # (dp, T/dp, D) with dp = the batch-sharding degree, so the dispatch
    # buffer (dp, E, cap', D) carries an explicit leading dim that GSPMD can
    # align with the token sharding -- the scatter/gather become LOCAL per
    # data shard and the only MoE communication left is the EP/ZeRO-3 weight
    # movement.  (Replicated-buffer variants generate (E, cap, D)-sized
    # all-reduces per layer: 15.8 GiB x 61 layers on kimi -- measured, see
    # EXPERIMENTS.md Perf.  Annotating h/out with F~data was refuted twice:
    # 14+ TiB/step of all-reduce.)  Capacity is enforced per shard, as in
    # production EP systems.
    # Perf H5: the optimal MoE comm strategy is SHAPE-DEPENDENT.  At small
    # token counts (decode / tiny prefill) the dispatch buffer is tiny, so
    # within-expert TP over "data" with an activation psum (refuted at train
    # scale, where cap is huge) beats ZeRO-3 weight gathers by ~100x:
    # kimi decode psum = (24, 3, 7168) x 61 layers = 63 MB vs 260 GB of
    # per-layer expert-weight all-gathers.
    small_batch = T * K <= 16_384
    dp = 1 if small_batch else _batch_sharding_degree()
    while dp > 1 and T % dp:
        dp //= 2
    tp = T // dp
    capacity = int(max(1, round(tp * K / E * cfg.capacity_factor)))

    def one_slice(x_s, gates_s, eidx_s):
        # x_s: (tp, D); gates_s/eidx_s: (tp, K)
        flat_e = eidx_s.reshape(-1)
        flat_gate = gates_s.reshape(-1)
        flat_tok = jnp.arange(tp * K) // K
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
        rank_sorted = jnp.arange(tp * K) - seg_start[sorted_e]
        keep_sorted = rank_sorted < capacity
        rows = jnp.where(keep_sorted, sorted_e, 0)
        cols = jnp.where(keep_sorted, rank_sorted, 0)
        vals = x_s[flat_tok[order]] * keep_sorted[:, None].astype(x_s.dtype)
        buf = jnp.zeros((E, capacity, D), x_s.dtype).at[rows, cols].add(vals)
        inv = jnp.argsort(order)
        rank_flat = rank_sorted[inv]
        keep_flat = keep_sorted[inv]
        return buf, (flat_e, rank_flat, keep_flat, flat_gate)

    xs = xt.reshape(dp, tp, D)
    buf, meta = jax.vmap(one_slice)(
        xs, gates.reshape(dp, tp, K), eidx.reshape(dp, tp, K)
    )
    batch_ax = None if small_batch else "batch"
    buf = shard(buf, batch_ax, "experts", None, None)

    # per-expert SwiGLU (experts sharded over "model"; under FSDP the
    # F-sharded weights are ZeRO-3-gathered -- the storage price at 1T scale)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", buf, p["w_up"]
    )
    if small_batch:
        # within-expert TP: hidden dim follows the F~data weight layout; the
        # down-projection emits a tiny (dp, E, cap, D) psum instead of
        # gathering the expert weights
        h = shard(h, None, "experts", None, "fsdp")
    else:
        h = shard(h, "batch", "experts", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = shard(out_buf, batch_ax, "experts", None, None)

    def combine_slice(out_b, meta_s):
        flat_e, rank_flat, keep_flat, flat_gate = meta_s
        slot = out_b[flat_e, jnp.minimum(rank_flat, capacity - 1)]
        slot = slot * keep_flat[:, None].astype(slot.dtype)
        comb = (slot * flat_gate[:, None].astype(slot.dtype)).reshape(tp, K, D)
        return jnp.sum(comb, axis=1)

    out = jax.vmap(combine_slice)(out_buf, meta).reshape(T, D)
    out = out.reshape(B, S, D)
    return shard(out, "batch", None, None), aux


def _batch_sharding_degree() -> int:
    """Product of mesh axes the 'batch' logical axis maps to (1 off-mesh)."""
    from repro.dist.sharding import current_mesh, current_rules

    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return 1
    mapped = rules.rules.get("batch")
    if mapped is None:
        return 1
    axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
    deg = 1
    for a in axes:
        deg *= mesh.shape.get(a, 1)
    return deg
