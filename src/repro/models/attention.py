"""GQA/MQA attention: chunked-flash training path + KV-cache decode path.

The training/prefill path is a pure-jnp flash formulation (online softmax over
KV chunks inside a scan over Q chunks) so 32k-token prefill never materializes
an (S x S) score matrix; the decode path attends one token over a cached KV —
optionally via the Pallas flash-decode kernel (repro.kernels.decode_attention).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

from .common import apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30

# Backend switch for full-sequence causal attention: the pure-jnp chunked
# flash (default; shardable via GSPMD) or the Pallas flash_prefill kernel
# (TPU drop-in; validated in interpret mode on CPU). Toggle via
# set_pallas_prefill(True) — parity is tested in tests/models.
_PALLAS_PREFILL = False


def set_pallas_prefill(enabled: bool) -> None:
    global _PALLAS_PREFILL
    _PALLAS_PREFILL = bool(enabled)


def init_attention(key, cfg, dtype) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (x @ p["wk"]).reshape(B, S, kv, hd)
    v = (x @ p["wv"]).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    causal: bool,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked online-softmax attention in pure jnp (no S x T buffer).

    §Perf H1 (EXPERIMENTS.md): the head dim stays FLAT (B, S, H, D) end to
    end, sharded over "model" when H divides; GQA is realized by broadcasting
    each KV head to its q-group *inside* the kv-chunk loop.  Since k/v heads
    are replicated, the broadcast+slice is local to every shard: the kv-loop
    carries (m, l, acc) stay H-sharded and no per-iteration collectives are
    generated (the (Hkv, g) reshape of the baseline forced a layer-wide
    reshard of q/scores/acc every chunk).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if _PALLAS_PREFILL and causal and q_offset == 0 and S == T:
        from repro.kernels.flash_prefill.ops import flash_prefill

        return flash_prefill(q, k, v, interpret=True)
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    s_pad = (-S) % qc
    t_pad = (-T) % kc
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    n_q, n_k = qp.shape[1] // qc, kp.shape[1] // kc

    q5 = qp.reshape(B, n_q, qc, H, D).astype(jnp.float32)
    k5 = kp.reshape(B, n_k, kc, Hkv, D).astype(jnp.float32)
    v5 = vp.reshape(B, n_k, kc, Hkv, D).astype(jnp.float32)
    q5 = shard(q5, "batch", None, None, "heads", None)

    def _expand_kv(blk):  # (B, kc, Hkv, D) -> (B, kc, H, D), local per shard
        out = jnp.broadcast_to(
            blk[:, :, :, None, :], (B, kc, Hkv, g, D)
        ).reshape(B, kc, H, D)
        return shard(out, "batch", None, "heads", None)

    def q_block(qi, q_blk):
        # q_blk: (B, qc, H, D)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_block(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_rep = _expand_kv(k_blk)
            v_rep = _expand_kv(v_blk)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_rep) * scale
            s = shard(s, "batch", "heads", None, None)
            k_pos = ki * kc + jnp.arange(kc)
            valid = (k_pos < T)[None, :]  # mask the T-padding keys
            if causal:
                mask = (k_pos[None, :] <= q_pos[:, None]) & valid
            else:
                mask = jnp.broadcast_to(valid, (qc, kc))
            s = jnp.where(mask[None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = alpha[..., None] * acc + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_rep
            )
            acc_new = shard(acc_new, "batch", "heads", None, None)
            return (m_new, l_new, acc_new), None

        m0 = shard(jnp.full((B, H, qc), NEG_INF, jnp.float32),
                   "batch", "heads", None)
        l0 = shard(jnp.zeros((B, H, qc), jnp.float32), "batch", "heads", None)
        a0 = shard(jnp.zeros((B, H, qc, D), jnp.float32),
                   "batch", "heads", None, None)
        ks = jnp.arange(n_k)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (ks, jnp.moveaxis(k5, 1, 0), jnp.moveaxis(v5, 1, 0))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, H, qc, D)
        return jnp.moveaxis(out, 2, 1)  # (B, qc, H, D)

    outs = jax.lax.map(
        lambda args: q_block(args[0], args[1]),
        (jnp.arange(n_q), jnp.moveaxis(q5, 1, 0)),
    )  # (n_q, B, qc, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_q * qc, H, D)
    return out[:, :S].astype(q.dtype)


def attention_forward(
    p: Dict,
    x: jax.Array,  # (B, S, d_model)
    cfg,
    positions: jax.Array,  # (B, S)
    causal: bool = True,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn KV override
) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    B, S, _ = x.shape
    if kv is None:
        q, k, v = _project_qkv(p, x, cfg, positions)
    else:
        h, hd = cfg.n_heads, cfg.head_dim
        q = (x @ p["wq"]).reshape(B, S, h, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k, v = kv
    out = flash_attention(q, k, v, causal=causal)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return shard(out @ p["wo"], "batch", None, None)


def project_cross_kv(p: Dict, enc: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Encoder-output K/V for cross-attention (computed once per utterance)."""
    B, T, _ = enc.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc @ p["wk"]).reshape(B, T, kvh, hd)
    v = (enc @ p["wv"]).reshape(B, T, kvh, hd)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    return k, v


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    if getattr(cfg, "kv_cache_dtype", "compute") == "int8":
        return {
            "k": jnp.zeros((batch, max_len, kvh, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, kvh, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, kvh), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, kvh), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
    }


def _quantize_kv(x: jax.Array):
    """Per-token-per-head symmetric int8: x ~ (B, S, Hkv, D)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def attention_decode(
    p: Dict,
    x: jax.Array,  # (B, 1, d_model)
    cache: Dict,  # {"k": (B, S, Hkv, D), "v": ...}
    position: jax.Array,  # () or (B,) current index
    cfg,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Dict]:
    """One-token decode over the KV cache; returns (out, updated cache)."""
    B = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos_b = jnp.broadcast_to(jnp.asarray(position), (B,))
    q = (x @ p["wq"]).reshape(B, 1, h, hd)
    k = (x @ p["wk"]).reshape(B, 1, kvh, hd)
    v = (x @ p["wv"]).reshape(B, 1, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = apply_rope(k, pos_b[:, None], cfg.rope_theta)

    quantized = "k_scale" in cache
    new_cache = {}
    if quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ck_q = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, position, axis=1)
        cv_q = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, position, axis=1)
        ks_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, position, axis=1
        )
        vs_c = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, position, axis=1
        )
        ck_q = shard(ck_q, "batch", "kv_seq", "kv_heads", None)
        cv_q = shard(cv_q, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck_q, "v": cv_q, "k_scale": ks_c, "v_scale": vs_c}
        # dequantize for the attention math (reads 1B + scale vs 2B per elem)
        ck = ck_q.astype(jnp.float32) * ks_c[..., None]
        cv = cv_q.astype(jnp.float32) * vs_c[..., None]
        ck = ck.astype(x.dtype)
        cv = cv.astype(x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), position, axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), position, axis=1
        )
        ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}
    lengths = pos_b + 1

    if use_pallas:
        from repro.kernels.decode_attention.ops import decode_attention

        out = decode_attention(q[:, 0], ck, cv, lengths.astype(jnp.int32))
    else:
        S = ck.shape[1]
        g = h // kvh
        qg = q.reshape(B, kvh, g, hd).astype(jnp.float32)
        scale = 1.0 / math.sqrt(hd)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, ck.astype(jnp.float32)) * scale
        mask = jnp.arange(S)[None, :] < lengths[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w, cv.astype(jnp.float32))
        out = out.reshape(B, h, hd)

    out = out.reshape(B, 1, h * hd).astype(x.dtype)
    return out @ p["wo"], new_cache
