"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP frontend STUBBED
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

input_specs() provides precomputed patch embeddings (B, n_image_tokens,
d_model); the CLIP tower is out of scope per the assignment.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    mlp_activation="swiglu", rope_theta=10_000.0,
    n_image_tokens=256,
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)

SMOKE = ArchConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="swiglu",
    n_image_tokens=16,
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
