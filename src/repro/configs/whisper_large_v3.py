"""whisper-large-v3 — encoder-decoder, conv audio frontend STUBBED
[arXiv:2212.04356; unverified].

input_specs() provides precomputed frame embeddings (B, 1500, d_model) — the
conv1d+GELU frontend is out of scope per the assignment.  32 encoder + 32
decoder layers, MHA (kv=20 == heads).
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    mlp_activation="gelu", rope_theta=0.0,  # learned positions in whisper
    n_encoder_layers=32, n_audio_frames=1500,
    source="arXiv:2212.04356; unverified",
)

SMOKE = ArchConfig(
    name="whisper-large-v3-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="gelu", rope_theta=0.0,
    n_encoder_layers=2, n_audio_frames=60,
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
