"""mistral-nemo-12b — dense, GQA kv=8, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    mlp_activation="swiglu", rope_theta=1_000_000.0,  # 128k context
    kv_cache_dtype="int8",  # Perf H3: halves decode KV traffic (hillclimbed cell)
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
)

SMOKE = ArchConfig(
    name="mistral-nemo-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="swiglu",
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
