"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

One attention layer per 8 (attn_period=8, the 1:7 interleave); MoE on every
other layer (moe_every=2) which reproduces the published ~398B total params.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    mlp_activation="swiglu", rope_theta=10_000.0,
    n_experts=16, experts_per_token=2, moe_d_ff=24576, moe_every=2, moe_offset=1,
    attn_period=8, ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
    param_dtype="bfloat16",  # Perf: halves ZeRO-3 gather + grad-AR volume at the 0.4-1T scale
    source="arXiv:2403.19887; hf",
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="swiglu",
    n_experts=4, experts_per_token=2, moe_d_ff=128, moe_every=2, moe_offset=1,
    capacity_factor=4.0,  # drop-free at smoke scale
    attn_period=4, ssm_state_dim=8, ssm_conv_width=4, ssm_expand=2,
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
