"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` (exact published dims)
plus a reduced ``smoke()`` variant for CPU tests.  Input shapes are the four
assigned workloads; ``cells()`` enumerates the (arch x shape) dry-run grid,
honouring the mandated skips (DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention details ---
    mlp_activation: str = "swiglu"  # swiglu | geglu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden dim (0 -> use d_ff)
    moe_every: int = 1  # MoE on layers with index % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (jamba): one attention layer per `attn_period`, rest Mamba ---
    attn_period: int = 0  # 0 => pure attention (or pure ssm for family=ssm)
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # --- rwkv ---
    rwkv_head_dim: int = 64
    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # stubbed conv frontend output length
    # --- vlm ---
    n_image_tokens: int = 0
    # --- numerics / training ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # "full" recomputes everything in the backward (re-running the TP
    # all-reduces); "save_tp" checkpoints the post-collective block outputs
    # so recompute never re-issues collectives (§Perf H1b: -1/3 AR volume)
    remat_policy: str = "save_tp"
    # "compute" stores KV in compute_dtype; "int8" stores per-token-per-head
    # symmetric-quantized KV (halves decode HBM traffic — §Perf H3)
    kv_cache_dtype: str = "compute"
    # --- notes (provenance) ---
    source: str = ""

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k applies."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * 2  # in + out (untied)
        att = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
        att += self.n_heads * self.head_dim * d
        dense_mlp = 3 * d * self.d_ff
        total = emb
        for layer in range(self.n_layers):
            if self.family == "ssm":
                d_in = self.ssm_expand * d
                total += 2 * d * d_in + d_in * d + 3 * d * self.d_ff
                continue
            is_attn = (
                self.attn_period == 0 or (layer % self.attn_period) == (self.attn_period - 1)
            )
            if is_attn:
                total += att
            else:  # mamba layer
                d_in = self.ssm_expand * d
                total += 2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state_dim + 1)
            is_moe = (
                self.n_experts > 0 and (layer % self.moe_every) == self.moe_offset
            )
            if is_moe:
                total += self.n_experts * 3 * d * self.expert_ff + d * self.n_experts
            else:
                total += dense_mlp
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (att + dense_mlp)
            total += self.n_layers * att  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = len(
            [
                l
                for l in range(self.n_layers)
                if (l % self.moe_every) == self.moe_offset
            ]
        )
        all_e = n_moe_layers * self.n_experts * 3 * self.d_model * self.expert_ff
        act_e = n_moe_layers * self.experts_per_token * 3 * self.d_model * self.expert_ff
        return full - all_e + act_e


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}
_SMOKE: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_smoke(name: str) -> ArchConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Mandated skip rules (DESIGN.md §5)."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "long_500k needs sub-quadratic attention (skip: full-attention arch)"
    return True, ""


def cells() -> List[Tuple[str, str]]:
    """All runnable (arch, shape) dry-run cells."""
    _ensure_loaded()
    out = []
    for a in list_archs():
        arch = get_arch(a)
        for s, shp in SHAPES.items():
            ok, _ = shape_applicable(arch, shp)
            if ok:
                out.append((a, s))
    return out


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        gemma_7b,
        glm4_9b,
        granite_moe_1b_a400m,
        jamba_1p5_large,
        kimi_k2,
        mistral_nemo_12b,
        phi3_vision,
        qwen3_14b,
        rwkv6_1b6,
        whisper_large_v3,
    )
