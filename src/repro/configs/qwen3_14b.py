"""qwen3-14b — dense, qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B family; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936,
    mlp_activation="swiglu", qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-14B; hf",
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256,
    mlp_activation="swiglu", qk_norm=True,
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
