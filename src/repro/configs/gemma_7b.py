"""gemma-7b — dense, GeGLU, head_dim=256, GQA kv=16 [arXiv:2403.08295; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    mlp_activation="geglu", rope_theta=10_000.0,
    source="arXiv:2403.08295; hf",
)

SMOKE = ArchConfig(
    name="gemma-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="geglu",
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
