"""granite-moe-1b-a400m — MoE 32e top-8, GQA kv=8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    mlp_activation="swiglu", rope_theta=10_000.0,
    n_experts=32, experts_per_token=8, moe_d_ff=512, moe_every=1,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE = ArchConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    mlp_activation="swiglu",
    n_experts=4, experts_per_token=2, moe_d_ff=64, moe_every=1,
    capacity_factor=4.0,  # drop-free at smoke scale
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
