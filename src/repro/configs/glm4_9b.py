"""glm4-9b — dense, RoPE, GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552,
    mlp_activation="swiglu", rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b; hf",
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_activation="swiglu",
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
