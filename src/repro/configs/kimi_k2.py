"""kimi-k2-1t-a32b — trillion-param MoE 384e top-8 [arXiv:2501.kimi2; unverified].

Hardware adaptation (DESIGN.md §3): the paper table gives d_model=7168 with
64 heads (head_dim 112); we round head_dim up to 128 for MXU lane alignment —
the projection widths become 64*128=8192 (vs 7168), noted in EXPERIMENTS.md.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    mlp_activation="swiglu", rope_theta=50_000.0,
    n_experts=384, experts_per_token=8, moe_d_ff=2048, moe_every=1,
    capacity_factor=1.0,
    param_dtype="bfloat16",  # Perf: halves ZeRO-3 gather + grad-AR volume at the 0.4-1T scale
    source="arXiv:2501.kimi2 (paper-table); unverified",
)

SMOKE = ArchConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    mlp_activation="swiglu",
    n_experts=8, experts_per_token=2, moe_d_ff=64, moe_every=1,
    capacity_factor=8.0,  # drop-free at smoke scale
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
