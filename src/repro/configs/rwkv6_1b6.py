"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=7168, vocab_size=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892; unverified",
)

SMOKE = ArchConfig(
    name="rwkv6-1.6b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=128, vocab_size=256,
    rwkv_head_dim=16,
    param_dtype="float32", compute_dtype="float32",
)

register(FULL, SMOKE)
