"""Tracelab: real-trace ingestion + out-of-core streaming replay.

The paper's headline experiment runs the log-complexity OGB policy on
real-world traces with *millions of requests and items* — the regime the
prior regret-guaranteed policies could not reach.  This package is the
bridge from the generator-fed replay stack to that regime:

* :mod:`~repro.cachesim.tracelab.loaders` — streaming readers for the
  standard on-disk request-trace formats (CSV/TSV key-value traces à la
  the twitter cache-trace, whitespace ``timestamp id size`` CDN logs, raw
  binary uint32/uint64 id streams).  Chunked iteration: the full trace is
  never materialized.
* :mod:`~repro.cachesim.tracelab.catalog` — :class:`CatalogRemap`, the
  streaming sparse-raw-id -> dense ``0..N-1`` remapper (first-seen order,
  configurable out-of-catalog policy).
* :mod:`~repro.cachesim.tracelab.synth` — the stats-matched workload
  synthesizer: :func:`fit_profile` measures a real (or sampled) trace,
  :func:`synthesize_chunks` emits arbitrarily long traces with matching
  popularity skew, reuse-distance profile and popularity drift, in fixed
  memory — so CI and benchmarks exercise "real-trace-shaped" workloads at
  T >= 1e7 without shipping datasets.
* :mod:`~repro.cachesim.tracelab.stream` — :func:`run_stream`, the
  out-of-core replay driver: any registered
  :class:`~repro.cachesim.api.PolicyDef` over any chunk iterator, layered
  on the resumable ``api.run(carry=...)`` contract, in memory independent
  of the trace length, with windowed hit-ratio and time-varying-OPT
  ("dynamic regret" proxy) accumulation.
"""

from repro.cachesim.tracelab.catalog import CatalogRemap
from repro.cachesim.tracelab.loaders import (
    TRACE_FORMATS,
    load_trace,
    open_trace,
    sniff_format,
    write_trace,
)
from repro.cachesim.tracelab.stream import StreamFault, run_stream
from repro.cachesim.tracelab.synth import (
    TraceProfile,
    fit_profile,
    synthesize,
    synthesize_chunks,
    synthesize_sizes,
    tenant_streams,
)

__all__ = [
    "CatalogRemap",
    "TRACE_FORMATS",
    "TraceProfile",
    "fit_profile",
    "load_trace",
    "open_trace",
    "run_stream",
    "StreamFault",
    "sniff_format",
    "synthesize",
    "synthesize_chunks",
    "synthesize_sizes",
    "tenant_streams",
    "write_trace",
]
