"""Out-of-core streaming replay: any PolicyDef over any chunk iterator.

:func:`run_stream` is the third execution surface next to ``api.run`` and
``api.sweep`` — except it is *not* a third engine: it re-batches an
arbitrary chunk iterator (a trace-file loader, a catalog remapper, the
workload synthesizer, a live request tap) into fixed-shape segments and
replays each one through the resumable ``api.run(carry=...)`` contract.
Peak memory is O(segment + policy state), independent of the trace
length, and the replayed dynamics are **bit-exact** equal to a one-shot
in-memory ``api.run`` over the concatenated trace — whatever the incoming
chunking (PR-4's streaming tests are the foundation; the tracelab
differential sweep extends them to the ingestion path).

Fixed-shape segments matter: ``api.run`` memoizes compiled executables on
the chunk shape, so a multi-gigabyte stream costs two compilations (the
steady-state segment and the tail), not one per chunk.

**The async double-buffered pipeline (default).**  The synchronous loop —
load a chunk, step the device, repeat — leaves the device idle during
host I/O and the host idle during device replay.  With ``prefetch >= 1``
the stream runs as a pipeline instead:

* a background ingest thread pulls chunks from the source and re-batches
  them into segments, keeping up to ``prefetch`` assembled segments ahead
  of the device;
* the main thread dispatches segment ``k`` *without blocking*
  (``api.run(block=False)`` — the carry chains through JAX's async
  dispatch), then runs the host-side dynamic-OPT/stats pass for segment
  ``k`` while the device scans it and the ingest thread reads ``k+1``;
* ``jax.block_until_ready`` happens only at the consume point, when a
  segment's results are folded into the accumulators.

The pipeline is **bit-exact** with the synchronous path — same segment
re-batching, same carry chain, same dynamic-OPT windows — only the
:class:`~repro.cachesim.results.StreamResult` timing split
(``ingest_seconds`` / ``device_seconds`` / ``host_seconds``) tells them
apart.  ``prefetch=0`` falls back to the fully synchronous loop.

When the chunk source *raises* mid-stream, the pipeline degrades
gracefully: in-flight device work is drained, accumulated results are
packaged (resumable carry included), and a :class:`StreamFault` pinning
the stream position — requests ingested, requests replayed, segments
dispatched — is raised from the original error.  A source that merely
*stalls* just idles the pipeline: the device drains its queue and the
stream resumes when chunks flow again.
"""

from __future__ import annotations

# the ingest thread is the SOLE writer of these _StreamState counters;
# the main thread reads them only after joining (single-writer contract)
# reprolint: thread-owned(t_ingested, ingest_seconds, t_dropped)

import os
import queue
import threading
import time
from collections import deque
from typing import Any, Iterable, Iterator, Optional, Union

import numpy as np

import jax

from repro.cachesim import api
from repro.cachesim.results import StreamResult
from repro.core.regret import best_static_hits

#: default steady-state segment length (requests per device dispatch)
DEFAULT_SEGMENT = 131_072

#: default pipeline depth (segments assembled/dispatched ahead of the
#: consume point); override per call with ``prefetch=`` or process-wide
#: with ``REPRO_STREAM_PREFETCH`` (0 = synchronous)
DEFAULT_PREFETCH = 2


class StreamFault(RuntimeError):
    """The chunk source failed mid-stream.

    Raised by :func:`run_stream` *after* the in-flight device work has
    been drained, so the attributes pin the exact stream position:

    - ``t_ingested``: requests successfully pulled from the source,
    - ``t_replayed``: requests whose segments were dispatched and drained,
    - ``n_segments``: device dispatches completed,
    - ``partial``: a :class:`~repro.cachesim.results.StreamResult` over the
      replayed prefix (resumable via its ``carry``), or ``None`` when the
      fault hit before one full window replayed.

    The original source exception is chained as ``__cause__``.
    """

    def __init__(
        self,
        message: str,
        *,
        t_ingested: int = 0,
        t_replayed: int = 0,
        n_segments: int = 0,
        partial: Optional[StreamResult] = None,
    ):
        super().__init__(message)
        self.t_ingested = int(t_ingested)
        self.t_replayed = int(t_replayed)
        self.n_segments = int(n_segments)
        self.partial = partial


class _SourceError(Exception):
    """Internal marker: the *source iterator* raised (vs our own
    validation, which must surface unwrapped)."""

    def __init__(self, cause: BaseException):
        super().__init__(str(cause))
        self.cause = cause


_DONE = object()  # ingest-thread sentinel: source exhausted


def _as_chunks(
    chunks: Union[np.ndarray, Iterable[np.ndarray]],
) -> Iterator[np.ndarray]:
    if isinstance(chunks, np.ndarray):
        yield chunks
        return
    for c in chunks:
        yield np.asarray(c)


def _default_prefetch() -> int:
    return int(os.environ.get("REPRO_STREAM_PREFETCH", DEFAULT_PREFETCH))


class _StreamState:
    """Mutable accumulators shared by the sync and async drivers.

    The ingest-side counters (``t_ingested``, ``ingest_seconds``,
    ``t_dropped``) are written only by whichever thread runs the segment
    assembly; the replay-side accumulators only by the main thread."""

    def __init__(self):
        self.reward, self.hits, self.aux, self.occupancy = [], [], [], []
        self.byte_hits: list = []
        self.bytes_total = 0.0
        self.dyn_opt: list = []
        self.opt_buf: list = []
        self.opt_buffered = 0
        self.n_segments = 0
        self.t_used = 0
        self.t_ingested = 0
        self.t_dropped = 0
        self.extras: dict = {}
        self.ingest_seconds = 0.0
        self.device_seconds = 0.0
        self.host_seconds = 0.0


def _assemble_segments(
    source,
    segment_len: int,
    window: int,
    catalog_size: Optional[int],
    st: _StreamState,
) -> Iterator[np.ndarray]:
    """Re-batch raw source chunks into window-aligned segments.

    Yields steady-state ``segment_len`` segments, then one final
    window-aligned tail (``st.t_dropped`` records the sub-window
    remainder).  Time spent *inside the source* accrues to
    ``st.ingest_seconds``; source exceptions are wrapped in
    :class:`_SourceError` so the driver can tell a failing loader apart
    from a validation bug."""
    it = _as_chunks(source)
    buf: list = []
    buffered = 0
    while True:
        t0 = time.perf_counter()
        try:
            chunk = next(it)
        except StopIteration:
            st.ingest_seconds += time.perf_counter() - t0
            break
        except Exception as e:  # the source failed, not us
            st.ingest_seconds += time.perf_counter() - t0
            raise _SourceError(e) from e
        st.ingest_seconds += time.perf_counter() - t0
        chunk = np.asarray(chunk, dtype=np.int64).ravel()
        if chunk.size == 0:
            continue
        if catalog_size is not None and not (
            0 <= int(chunk.min()) and int(chunk.max()) < catalog_size
        ):
            # an out-of-range dense id would be silently clamped by the
            # device gather (aliasing item N-1) — corrupt results, no error
            raise ValueError(
                f"stream ids must be dense in [0, {catalog_size}): got "
                f"[{int(chunk.min())}, {int(chunk.max())}] — route raw "
                "traces through CatalogRemap (with max_items=catalog_size) "
                "first"
            )
        st.t_ingested += chunk.size
        buf.append(chunk)
        buffered += chunk.size
        while buffered >= segment_len:
            merged = np.concatenate(buf) if len(buf) > 1 else buf[0]
            yield merged[:segment_len]
            rest = merged[segment_len:]
            buf = [rest] if rest.size else []
            buffered = rest.size
    # tail: whole windows replay as one final (differently shaped) segment
    if buffered:
        merged = np.concatenate(buf) if len(buf) > 1 else buf[0]
        aligned = (buffered // window) * window
        st.t_dropped = buffered - aligned
        if aligned:
            yield merged[:aligned]


def run_stream(
    pd: "api.PolicyDef",
    chunks: Union[np.ndarray, Iterable[np.ndarray]],
    catalog_size: Optional[int] = None,
    capacity: Optional[int] = None,
    *,
    window: int = 1000,
    segment_len: Optional[int] = None,
    carry: Any = None,
    seed: int = 0,
    eta: Optional[float] = None,
    horizon: Optional[int] = None,
    n_slots: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    opt_window: Optional[int] = None,
    keep_carry: bool = True,
    name: Optional[str] = None,
    prefetch: Optional[int] = None,
) -> StreamResult:
    """Replay a chunk iterator through one policy in fixed memory.

    ``chunks`` yields 1-D int arrays of dense ids in ``[0, catalog_size)``
    (route raw traces through
    :class:`~repro.cachesim.tracelab.catalog.CatalogRemap` first).  They
    are re-buffered into ``segment_len``-request segments (rounded down to
    a multiple of ``window``; the incoming chunking never changes the
    replayed dynamics) and each segment resumes the previous one's carry
    via ``api.run(carry=...)``.  A trailing remainder shorter than one
    ``window`` is dropped — exactly like the one-shot ``api.run`` — and
    reported as ``t_dropped``.

    ``horizon`` is the *planned* total stream length and is required on a
    fresh (non-resumed) stream: it seeds horizon-tuned policies (FTPL's
    noise scale, OGB/OMD's ``eta=None`` resolution via ``pd.default_eta``)
    and a stream cannot know its own length up front.  For bit-exact
    parity with a one-shot ``api.run`` over the same trace, pass the same
    ``horizon``/``eta``/``seed``.

    ``opt_window`` (a multiple of ``window``; rounded up) additionally
    computes the hindsight-optimal *per-window* static allocation on the
    host while the stream passes by — the time-varying comparator behind
    :attr:`~repro.cachesim.results.StreamResult.dynamic_regret`.  The
    final window covers the replayed remainder (shorter than
    ``opt_window`` when the stream length is not a multiple), so the
    windows together cover every replayed request.

    ``prefetch`` sets the pipeline depth: with the default (``2``, or the
    ``REPRO_STREAM_PREFETCH`` env var) a background thread ingests and
    assembles up to ``prefetch`` segments ahead while the device scans
    and the host runs the dynamic-OPT pass — the async double-buffered
    mode.  ``prefetch=0`` is the fully synchronous fallback (load, step,
    repeat).  Both modes produce **bit-identical** results; only the
    :class:`~repro.cachesim.results.StreamResult` timing split differs.
    If the chunk source raises mid-stream, in-flight work is drained and
    a :class:`StreamFault` (with the stream position and a resumable
    ``partial`` result) is raised from the source error.

    Pass ``carry=`` to resume a previous stream's final carry; as with
    ``api.run``, the carry holds every policy parameter, so
    ``seed``/``eta``/``horizon``/``n_slots``/``costs`` must not be
    re-passed (``sizes`` may be: it also drives the host-side byte
    accounting).

    ``sizes``/``costs`` are per-*item* arrays passed through to
    ``api.run`` — sized policies shape decisions with them and results
    gain ``byte_hits``/``bytes_total`` (ingest per-request sizes with
    ``open_trace(..., with_sizes=True)`` + ``CatalogRemap.item_sizes``).
    """
    if window <= 0:
        raise ValueError(f"window must be positive, got {window}")
    if segment_len is None:
        segment_len = max(window, (DEFAULT_SEGMENT // window) * window)
    else:
        segment_len = max(window, (int(segment_len) // window) * window)
    if opt_window is not None:
        if capacity is None:
            raise ValueError("opt_window needs capacity")
        opt_window = max(1, -(-int(opt_window) // window)) * window
    if prefetch is None:
        prefetch = _default_prefetch()
    prefetch = max(0, int(prefetch))

    resumed = carry is not None
    if not resumed:
        if catalog_size is None or capacity is None:
            raise ValueError(
                "run_stream() needs catalog_size and capacity (or carry=)"
            )
        if horizon is None:
            # a one-shot api.run can default horizon to the trace length; a
            # stream cannot know its own length, and letting horizon-tuned
            # policies (FTPL's noise scale, eta=None resolution) silently
            # tune to the *first segment* length would break the bit-exact
            # parity with the one-shot replay
            raise ValueError(
                "run_stream() needs horizon= (the planned total stream "
                "length): a stream cannot infer it, and horizon-tuned "
                "policies would otherwise mis-tune to the first segment"
            )
        if eta is None and pd.default_eta is not None:
            eta = pd.default_eta(
                int(catalog_size), int(capacity), int(horizon), window
            )
    elif (
        eta is not None
        or horizon is not None
        or n_slots is not None
        or seed != 0
        or costs is not None
    ):
        raise ValueError(
            "run_stream(carry=...) resumes with the carry's parameters; do "
            "not pass seed/eta/horizon/n_slots/costs alongside a carry"
        )

    st = _StreamState()
    t0_wall = time.perf_counter()

    def _dispatch(seg: np.ndarray, block: bool):
        """One ``api.run`` over a segment (first call initializes)."""
        nonlocal carry
        run_kw = dict(
            window=window, track_opt=False, name=name, sizes=sizes,
            block=block,
        )
        if carry is None:
            res = api.run(
                pd, seg, catalog_size, capacity, seed=seed, eta=eta,
                horizon=horizon, n_slots=n_slots, costs=costs, **run_kw,
            )
            st.extras.update(res.extras)
        else:
            res = api.run(pd, seg, capacity=capacity, carry=carry, **run_kw)
        carry = res.carry
        st.device_seconds += res.wall_seconds
        return res

    def _host_pass(seg: np.ndarray):
        """Dynamic-OPT accounting over a segment's ids (host-only: it needs
        the request ids, not the device results — which is what lets it
        overlap the device scan in the async pipeline)."""
        if opt_window is None:
            return
        t0 = time.perf_counter()
        st.opt_buf.append(seg)
        st.opt_buffered += len(seg)
        while st.opt_buffered >= opt_window:
            merged = (
                np.concatenate(st.opt_buf)
                if len(st.opt_buf) > 1
                else st.opt_buf[0]
            )
            st.dyn_opt.append(
                float(best_static_hits(merged[:opt_window], int(capacity)))
            )
            rest = merged[opt_window:]
            st.opt_buf[:] = [rest] if rest.size else []
            st.opt_buffered = rest.size
        st.host_seconds += time.perf_counter() - t0

    def _consume(res):
        """Fold one segment's (possibly in-flight) results into the
        accumulators — the only place the pipeline blocks on the device."""
        t0 = time.perf_counter()
        jax.block_until_ready(
            (res.reward, res.hits, res.aux, res.occupancy)
        )
        st.device_seconds += time.perf_counter() - t0
        t0 = time.perf_counter()
        st.reward.append(np.asarray(res.reward, np.float64))
        st.hits.append(np.asarray(res.hits, np.int64))
        st.aux.append(np.asarray(res.aux, np.float64))
        st.occupancy.append(np.asarray(res.occupancy, np.float64))
        if res.byte_hits is not None:
            st.byte_hits.append(np.asarray(res.byte_hits, np.float64))
        st.bytes_total += res.bytes_total
        st.n_segments += 1
        st.t_used += res.T
        st.host_seconds += time.perf_counter() - t0

    def _flush_dyn_opt_tail():
        """The replayed remainder shorter than one opt_window still gets a
        (final, shorter) dynamic-OPT window — without it the end of every
        stream would be invisible to the dynamic-regret comparator."""
        if opt_window is None or not st.opt_buffered:
            return
        t0 = time.perf_counter()
        merged = (
            np.concatenate(st.opt_buf)
            if len(st.opt_buf) > 1
            else st.opt_buf[0]
        )
        st.dyn_opt.append(float(best_static_hits(merged, int(capacity))))
        st.opt_buf.clear()
        st.opt_buffered = 0
        st.host_seconds += time.perf_counter() - t0

    def _result() -> StreamResult:
        return StreamResult(
            name=name or pd.name,
            kind=pd.kind,
            T=st.t_used,
            window=window,
            capacity=int(capacity) if capacity is not None else -1,
            reward=np.concatenate(st.reward),
            hits=np.concatenate(st.hits),
            aux=np.concatenate(st.aux),
            occupancy=np.concatenate(st.occupancy),
            opt_hits=0.0,
            carry=carry if keep_carry else None,
            wall_seconds=time.perf_counter() - t0_wall,
            extras=st.extras,
            byte_hits=(
                np.concatenate(st.byte_hits)
                if len(st.byte_hits) == st.n_segments and st.n_segments
                else None
            ),
            bytes_total=st.bytes_total,
            dyn_opt_hits=(
                np.asarray(st.dyn_opt, np.float64)
                if opt_window is not None
                else None
            ),
            dyn_opt_window=opt_window or 0,
            n_segments=st.n_segments,
            t_dropped=st.t_dropped,
            ingest_seconds=st.ingest_seconds,
            device_seconds=st.device_seconds,
            host_seconds=st.host_seconds,
            prefetch=prefetch,
        )

    def _fault(err: _SourceError, pending=None) -> StreamFault:
        """Drain in-flight work, package the replayed prefix, and build the
        position-pinned fault to raise from the source error."""
        for res in pending or ():
            _consume(res)
        _flush_dyn_opt_tail()
        partial = _result() if st.t_used else None
        return StreamFault(
            f"chunk source failed after {st.t_ingested} ingested / "
            f"{st.t_used} replayed requests "
            f"({st.n_segments} segments): {err.cause!r}",
            t_ingested=st.t_ingested,
            t_replayed=st.t_used,
            n_segments=st.n_segments,
            partial=partial,
        )

    if prefetch == 0:
        # ---- synchronous fallback: load, step, repeat --------------------
        segs = _assemble_segments(
            chunks, segment_len, window, catalog_size, st
        )
        while True:
            try:
                seg = next(segs)
            except StopIteration:
                break
            except _SourceError as e:
                raise _fault(e) from e.cause
            res = _dispatch(seg, block=True)
            _host_pass(seg)
            _consume(res)
    else:
        # ---- async double-buffered pipeline ------------------------------
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def _put(item) -> bool:
            # bounded put that aborts when the consumer has bailed, so the
            # ingest thread can never hang on a dead pipeline
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def _ingest():
            try:
                for seg in _assemble_segments(
                    chunks, segment_len, window, catalog_size, st
                ):
                    if not _put(seg):
                        return
                _put(_DONE)
            except BaseException as e:  # reprolint: allow(broad-except) forwarded; classified by main
                _put(e)  # (source fault vs validation error)

        worker = threading.Thread(
            target=_ingest, name="run_stream-ingest", daemon=True
        )
        worker.start()
        pending: deque = deque()  # dispatched, not yet consumed
        try:
            while True:
                item = q.get()
                if item is _DONE:
                    break
                if isinstance(item, _SourceError):
                    raise _fault(item, pending) from item.cause
                if isinstance(item, BaseException):
                    for res in pending:  # drain before re-raising
                        _consume(res)
                    pending.clear()
                    raise item
                res = _dispatch(item, block=False)
                pending.append(res)
                _host_pass(item)  # overlaps the device scan just dispatched
                while len(pending) > prefetch:
                    _consume(pending.popleft())
            while pending:
                _consume(pending.popleft())
        finally:
            stop.set()
            worker.join(timeout=5.0)

    _flush_dyn_opt_tail()

    if st.t_used == 0:
        raise ValueError(
            f"stream shorter than one window ({st.t_dropped} < {window})"
        )

    return _result()
