"""Out-of-core streaming replay: any PolicyDef over any chunk iterator.

:func:`run_stream` is the third execution surface next to ``api.run`` and
``api.sweep`` — except it is *not* a third engine: it re-batches an
arbitrary chunk iterator (a trace-file loader, a catalog remapper, the
workload synthesizer, a live request tap) into fixed-shape segments and
replays each one through the resumable ``api.run(carry=...)`` contract.
Peak memory is O(segment + policy state), independent of the trace
length, and the replayed dynamics are **bit-exact** equal to a one-shot
in-memory ``api.run`` over the concatenated trace — whatever the incoming
chunking (PR-4's streaming tests are the foundation; the tracelab
differential sweep extends them to the ingestion path).

Fixed-shape segments matter: ``api.run`` memoizes compiled executables on
the chunk shape, so a multi-gigabyte stream costs two compilations (the
steady-state segment and the tail), not one per chunk.
"""

from __future__ import annotations

import time
from typing import Any, Iterable, Iterator, Optional, Union

import numpy as np

from repro.cachesim import api
from repro.cachesim.results import StreamResult
from repro.core.regret import best_static_hits

#: default steady-state segment length (requests per device dispatch)
DEFAULT_SEGMENT = 131_072


def _as_chunks(
    chunks: Union[np.ndarray, Iterable[np.ndarray]],
) -> Iterator[np.ndarray]:
    if isinstance(chunks, np.ndarray):
        yield chunks
        return
    for c in chunks:
        yield np.asarray(c)


def run_stream(
    pd: "api.PolicyDef",
    chunks: Union[np.ndarray, Iterable[np.ndarray]],
    catalog_size: Optional[int] = None,
    capacity: Optional[int] = None,
    *,
    window: int = 1000,
    segment_len: Optional[int] = None,
    carry: Any = None,
    seed: int = 0,
    eta: Optional[float] = None,
    horizon: Optional[int] = None,
    n_slots: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    opt_window: Optional[int] = None,
    keep_carry: bool = True,
    name: Optional[str] = None,
) -> StreamResult:
    """Replay a chunk iterator through one policy in fixed memory.

    ``chunks`` yields 1-D int arrays of dense ids in ``[0, catalog_size)``
    (route raw traces through
    :class:`~repro.cachesim.tracelab.catalog.CatalogRemap` first).  They
    are re-buffered into ``segment_len``-request segments (rounded down to
    a multiple of ``window``; the incoming chunking never changes the
    replayed dynamics) and each segment resumes the previous one's carry
    via ``api.run(carry=...)``.  A trailing remainder shorter than one
    ``window`` is dropped — exactly like the one-shot ``api.run`` — and
    reported as ``t_dropped``.

    ``horizon`` is the *planned* total stream length and is required on a
    fresh (non-resumed) stream: it seeds horizon-tuned policies (FTPL's
    noise scale, OGB/OMD's ``eta=None`` resolution via ``pd.default_eta``)
    and a stream cannot know its own length up front.  For bit-exact
    parity with a one-shot ``api.run`` over the same trace, pass the same
    ``horizon``/``eta``/``seed``.

    ``opt_window`` (a multiple of ``window``; rounded up) additionally
    computes the hindsight-optimal *per-window* static allocation on the
    host while the stream passes by — the time-varying comparator behind
    :attr:`~repro.cachesim.results.StreamResult.dynamic_regret`.

    Pass ``carry=`` to resume a previous stream's final carry; as with
    ``api.run``, the carry holds every policy parameter, so
    ``seed``/``eta``/``horizon``/``n_slots``/``costs`` must not be
    re-passed (``sizes`` may be: it also drives the host-side byte
    accounting).

    ``sizes``/``costs`` are per-*item* arrays passed through to
    ``api.run`` — sized policies shape decisions with them and results
    gain ``byte_hits``/``bytes_total`` (ingest per-request sizes with
    ``open_trace(..., with_sizes=True)`` + ``CatalogRemap.item_sizes``).
    """
    if window <= 0:
        raise ValueError(f"window must be positive, got {window}")
    if segment_len is None:
        segment_len = max(window, (DEFAULT_SEGMENT // window) * window)
    else:
        segment_len = max(window, (int(segment_len) // window) * window)
    if opt_window is not None:
        if capacity is None:
            raise ValueError("opt_window needs capacity")
        opt_window = max(1, -(-int(opt_window) // window)) * window

    resumed = carry is not None
    if not resumed:
        if catalog_size is None or capacity is None:
            raise ValueError(
                "run_stream() needs catalog_size and capacity (or carry=)"
            )
        if horizon is None:
            # a one-shot api.run can default horizon to the trace length; a
            # stream cannot know its own length, and letting horizon-tuned
            # policies (FTPL's noise scale, eta=None resolution) silently
            # tune to the *first segment* length would break the bit-exact
            # parity with the one-shot replay
            raise ValueError(
                "run_stream() needs horizon= (the planned total stream "
                "length): a stream cannot infer it, and horizon-tuned "
                "policies would otherwise mis-tune to the first segment"
            )
        if eta is None and pd.default_eta is not None:
            eta = pd.default_eta(
                int(catalog_size), int(capacity), int(horizon), window
            )
    elif (
        eta is not None
        or horizon is not None
        or n_slots is not None
        or seed != 0
        or costs is not None
    ):
        raise ValueError(
            "run_stream(carry=...) resumes with the carry's parameters; do "
            "not pass seed/eta/horizon/n_slots/costs alongside a carry"
        )

    reward, hits, aux, occupancy = [], [], [], []
    byte_hits: list = []
    bytes_total = 0.0
    dyn_opt: list = []
    opt_buf: list = []
    opt_buffered = 0
    n_segments = 0
    t_used = 0
    extras: dict = {}

    t0 = time.perf_counter()

    def _flush_segment(seg: np.ndarray):
        nonlocal carry, n_segments, t_used, opt_buffered, bytes_total
        run_kw = dict(window=window, track_opt=False, name=name, sizes=sizes)
        if carry is None:
            res = api.run(
                pd, seg, catalog_size, capacity, seed=seed, eta=eta,
                horizon=horizon, n_slots=n_slots, costs=costs, **run_kw,
            )
            extras.update(res.extras)
        else:
            res = api.run(pd, seg, capacity=capacity, carry=carry, **run_kw)
        carry = res.carry
        reward.append(res.reward)
        hits.append(res.hits)
        aux.append(res.aux)
        occupancy.append(res.occupancy)
        if res.byte_hits is not None:
            byte_hits.append(res.byte_hits)
        bytes_total += res.bytes_total
        n_segments += 1
        t_used += res.T
        if opt_window is not None:
            opt_buf.append(seg)
            opt_buffered += len(seg)
            while opt_buffered >= opt_window:
                merged = np.concatenate(opt_buf) if len(opt_buf) > 1 else (
                    opt_buf[0]
                )
                dyn_opt.append(
                    float(best_static_hits(merged[:opt_window], int(capacity)))
                )
                rest = merged[opt_window:]
                opt_buf[:] = [rest] if rest.size else []
                opt_buffered = rest.size

    buf: list = []
    buffered = 0
    for chunk in _as_chunks(chunks):
        chunk = np.asarray(chunk, dtype=np.int64).ravel()
        if chunk.size == 0:
            continue
        if catalog_size is not None and not (
            0 <= int(chunk.min()) and int(chunk.max()) < catalog_size
        ):
            # an out-of-range dense id would be silently clamped by the
            # device gather (aliasing item N-1) — corrupt results, no error
            raise ValueError(
                f"stream ids must be dense in [0, {catalog_size}): got "
                f"[{int(chunk.min())}, {int(chunk.max())}] — route raw "
                "traces through CatalogRemap (with max_items=catalog_size) "
                "first"
            )
        buf.append(chunk)
        buffered += chunk.size
        while buffered >= segment_len:
            merged = np.concatenate(buf) if len(buf) > 1 else buf[0]
            _flush_segment(merged[:segment_len])
            rest = merged[segment_len:]
            buf = [rest] if rest.size else []
            buffered = rest.size
    # tail: whole windows replay as one final (differently shaped) segment
    t_dropped = 0
    if buffered:
        merged = np.concatenate(buf) if len(buf) > 1 else buf[0]
        aligned = (buffered // window) * window
        if aligned:
            _flush_segment(merged[:aligned])
        t_dropped = buffered - aligned
    wall = time.perf_counter() - t0

    if t_used == 0:
        raise ValueError(
            f"stream shorter than one window ({t_dropped} < {window})"
        )

    return StreamResult(
        name=name or pd.name,
        kind=pd.kind,
        T=t_used,
        window=window,
        capacity=int(capacity) if capacity is not None else -1,
        reward=np.concatenate(reward),
        hits=np.concatenate(hits),
        aux=np.concatenate(aux),
        occupancy=np.concatenate(occupancy),
        opt_hits=0.0,
        carry=carry if keep_carry else None,
        wall_seconds=wall,
        extras=extras,
        byte_hits=(
            np.concatenate(byte_hits)
            if len(byte_hits) == n_segments and n_segments
            else None
        ),
        bytes_total=bytes_total,
        dyn_opt_hits=(
            np.asarray(dyn_opt, np.float64) if opt_window is not None else None
        ),
        dyn_opt_window=opt_window or 0,
        n_segments=n_segments,
        t_dropped=t_dropped,
    )
