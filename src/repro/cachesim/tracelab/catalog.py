"""Streaming raw-id -> dense-id catalog remapping.

Raw logs carry sparse 64-bit ids (hashes, block addresses, anonymized
keys); the replay engines want a dense catalog ``0..N-1`` so policy state
is plain arrays.  :class:`CatalogRemap` performs that densification as a
streaming pass: ids are assigned in **first-seen order**, chunk by chunk,
so the mapping is a pure function of the request stream (and therefore
independent of how the stream is chunked).
"""

from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Optional

import numpy as np

#: table sentinels (dense ids are >= 0)
_UNSEEN = -2
_DROPPED = -1


class CatalogRemap:
    """Sparse raw ids -> dense ``0..N-1``, first-seen order, streaming.

    ``max_items`` bounds the dense catalog; once it is full, a raw id never
    seen before follows ``overflow``:

    * ``"raise"`` (default) — fail loudly; the caller sized the catalog.
    * ``"drop"``  — remove those requests from the stream (they can never
      be cache hits for an N-bounded policy anyway); ``dropped`` counts.
    * ``"clamp"`` — map them all onto the reserved last dense id
      ``max_items - 1`` (a shared "everything else" bucket; that id is
      never assigned to a real item).

    ``apply(chunk)`` remaps one chunk; ``remap(chunks)`` lifts it over an
    iterator.  ``len(remap)`` is the dense catalog size so far, and
    ``raw_ids[d]`` recovers the raw id behind dense id ``d``.

    Sized traces: ``apply(chunk, sizes=...)`` additionally records each
    item's size (bytes) the first time a sized request for it is seen, so
    the mapping stays a pure function of the request stream (chunking
    cannot change which size wins).  ``item_sizes`` densifies them to a
    ``(len(self),)`` array for the policy engines; ids never observed with
    a size (and the clamp bucket) read the unit default ``1.0``.
    """

    def __init__(
        self, max_items: Optional[int] = None, overflow: str = "raise"
    ):
        if overflow not in ("raise", "drop", "clamp"):
            raise ValueError(
                f"overflow must be 'raise'/'drop'/'clamp', got {overflow!r}"
            )
        if max_items is not None and max_items < (
            2 if overflow == "clamp" else 1
        ):
            raise ValueError(f"max_items too small: {max_items}")
        self.max_items = max_items
        self.overflow = overflow
        self.dropped = 0  # requests removed under overflow="drop"
        self.clamped = 0  # requests folded into the bucket under "clamp"
        self._table: Dict[int, int] = {}
        self._raw: List[int] = []  # dense -> raw, first-seen order
        self._sizes: Dict[int, float] = {}  # dense -> first-seen size
        #: reserved bucket id under "clamp" (assigned lazily on first spill)
        self._bucket: Optional[int] = None

    def __len__(self) -> int:
        n = len(self._raw)
        return n + (1 if self._bucket is not None else 0)

    @property
    def raw_ids(self) -> np.ndarray:
        """Raw id behind each dense id (the clamp bucket, if any, reads -1)."""
        out = np.asarray(self._raw, dtype=np.int64)
        if self._bucket is not None:
            out = np.concatenate([out, np.asarray([-1], np.int64)])
        return out

    def _capacity_left(self) -> bool:
        if self.max_items is None:
            return True
        cap = self.max_items - (1 if self.overflow == "clamp" else 0)
        return len(self._raw) < cap

    @property
    def item_sizes(self) -> np.ndarray:
        """Per-dense-id sizes (bytes), unit default for never-sized ids."""
        out = np.ones(len(self), np.float64)
        for d, s in self._sizes.items():
            out[d] = s
        return out

    def apply(
        self, chunk: np.ndarray, sizes: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Remap one chunk of raw ids to dense ids (possibly shorter under
        ``overflow="drop"``); ``sizes`` records per-item first-seen sizes."""
        chunk = np.asarray(chunk, dtype=np.int64)
        if chunk.ndim != 1:
            raise ValueError("CatalogRemap.apply expects a 1-D id chunk")
        if sizes is not None:
            sizes = np.asarray(sizes, np.float64)
            if sizes.shape != chunk.shape:
                raise ValueError(
                    f"sizes shape {sizes.shape} != chunk shape {chunk.shape}"
                )
        if chunk.size == 0:
            return chunk.copy()
        # per-chunk vectorization: resolve each distinct raw id once
        uniq, first_idx, inv = np.unique(
            chunk, return_index=True, return_inverse=True
        )
        vals = np.fromiter(
            (self._table.get(k, _UNSEEN) for k in uniq.tolist()),
            dtype=np.int64,
            count=len(uniq),
        )
        new = np.flatnonzero(vals == _UNSEEN)
        if new.size:
            # assign dense ids in order of first appearance *in the stream*
            for j in new[np.argsort(first_idx[new], kind="stable")]:
                raw = int(uniq[j])
                if self._capacity_left():
                    dense = len(self._raw)
                    self._raw.append(raw)
                    self._table[raw] = dense
                elif self.overflow == "raise":
                    raise ValueError(
                        f"catalog overflow: {raw} is the "
                        f"{len(self._raw) + 1}-th distinct id but "
                        f"max_items={self.max_items}"
                    )
                elif self.overflow == "drop":
                    # NOT recorded in the table: once the catalog is full
                    # every unseen id drops, and remembering each one would
                    # make memory O(distinct raw ids) — unbounded on hashed
                    # out-of-core streams, the exact case drop exists for
                    dense = _DROPPED
                else:  # clamp — same reasoning, the bucket is a constant
                    if self._bucket is None:
                        self._bucket = self.max_items - 1
                    dense = self._bucket
                vals[j] = dense
        if sizes is not None:
            # first-seen-size rule, in stream order (first_idx), skipping
            # dropped requests and the shared clamp bucket
            for j in np.argsort(first_idx, kind="stable"):
                d = int(vals[j])
                if d >= 0 and d != self._bucket and d not in self._sizes:
                    self._sizes[d] = float(sizes[first_idx[j]])
        mapped = vals[inv]
        if self.overflow == "drop":
            keep = mapped >= 0
            self.dropped += int(chunk.size - keep.sum())
            mapped = mapped[keep]
        elif self._bucket is not None:
            self.clamped += int(np.sum(mapped == self._bucket))
        return mapped

    def remap(self, chunks: Iterable) -> Iterator[np.ndarray]:
        """Lift :meth:`apply` over a chunk iterator (skips emptied chunks).

        Accepts plain id chunks or the ``(ids, sizes)`` pairs yielded by
        ``open_trace(..., with_sizes=True)`` — sizes are recorded into
        :attr:`item_sizes` and the densified id chunks are yielded."""
        for chunk in chunks:
            if isinstance(chunk, tuple):
                out = self.apply(chunk[0], sizes=chunk[1])
            else:
                out = self.apply(chunk)
            if out.size:
                yield out


def remap_trace(trace: np.ndarray, **kw) -> np.ndarray:
    """One-shot convenience: densify a whole in-memory trace."""
    return CatalogRemap(**kw).apply(np.asarray(trace))
