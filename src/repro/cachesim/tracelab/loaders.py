"""Streaming loaders for on-disk request traces.

Every loader yields ``np.ndarray[int64]`` chunks of raw item ids and never
materializes the full trace — ingestion memory is ``O(chunk_size)``
regardless of file length.  Raw ids are whatever the log recorded (sparse,
gappy, 64-bit); densification is a separate streaming pass
(:class:`repro.cachesim.tracelab.catalog.CatalogRemap`).

Supported formats (``TRACE_FORMATS``):

==========  ==================================================================
``csv``     comma-separated key-value trace à la the twitter cache-trace
            (``timestamp,key,...``; the key column is ``id_col``, default 1).
``tsv``     the same with tab separation.
``cdn``     whitespace-separated CDN/storage log lines ``timestamp id size``
            (any >= 2 fields; the id column is ``id_col``, default 1).
``bin32``   raw little-endian uint32 id stream, no header.
``bin64``   raw little-endian uint64 id stream, no header.
==========  ==================================================================

Malformed text lines follow ``on_bad``: ``"raise"`` (default) fails with the
file/line position, ``"skip"`` drops the line.  Ids that don't fit a
non-negative int64 always raise (an overflowed id would silently alias
another item after remapping).  Non-integer keys (hashed/anonymized traces)
are supported via ``key_mode="hash"`` — a stable 64-bit BLAKE2b digest.
"""

from __future__ import annotations

import hashlib
import os
from typing import Iterator, Optional

import numpy as np

DEFAULT_CHUNK = 1 << 16

_INT64_MAX = np.iinfo(np.int64).max

#: format name -> (kind, default options) — the loader dispatch table.
#: ``size_col`` is where the object size (bytes) lives: the twitter-style
#: csv puts ``value_size`` fourth (``timestamp,key,key_size,value_size``),
#: the tsv/cdn logs put it right after the id (``timestamp id size``).
TRACE_FORMATS = {
    "csv": {"delimiter": ",", "id_col": 1, "size_col": 3},
    "tsv": {"delimiter": "\t", "id_col": 1, "size_col": 2},
    "cdn": {"delimiter": None, "id_col": 1, "size_col": 2},  # None = any ws
    "bin32": {"dtype": np.uint32},
    "bin64": {"dtype": np.uint64},
}

#: file-extension -> format (``.bin`` is deliberately absent: a bare ``.bin``
#: is ambiguous between u32/u64 and must be named explicitly)
_EXTENSIONS = {
    ".csv": "csv",
    ".tsv": "tsv",
    ".txt": "cdn",
    ".log": "cdn",
    ".trace": "cdn",
    ".u32": "bin32",
    ".bin32": "bin32",
    ".u64": "bin64",
    ".bin64": "bin64",
}


def sniff_format(path: str) -> str:
    """Infer the trace format from the file extension."""
    ext = os.path.splitext(path)[1].lower()
    if ext in _EXTENSIONS:
        return _EXTENSIONS[ext]
    raise ValueError(
        f"cannot infer trace format from {path!r} (extension {ext!r}); "
        f"pass format= one of {sorted(TRACE_FORMATS)}"
    )


def _hash_key(raw: str) -> int:
    """Stable non-negative int64 digest for anonymized string keys."""
    d = hashlib.blake2b(raw.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(d, "big") >> 1  # keep it in [0, 2**63)


def _parse_id(raw: str, key_mode: str) -> int:
    if key_mode == "hash":
        return _hash_key(raw)
    v = int(raw)  # ValueError on non-integer keys -> handled as a bad line
    if v < 0:
        raise ValueError(f"negative item id {v}")
    if v > _INT64_MAX:
        raise OverflowError(f"item id {v} overflows int64")
    return v


def _iter_text(
    path: str,
    delimiter: Optional[str],
    id_col: int,
    chunk_size: int,
    on_bad: str,
    header: str,
    key_mode: str,
    size_col: Optional[int] = None,
) -> Iterator:
    if on_bad not in ("raise", "skip"):
        raise ValueError(f"on_bad must be 'raise' or 'skip', got {on_bad!r}")
    if header not in ("auto", "none", "skip"):
        raise ValueError(f"header must be 'auto'/'none'/'skip', got {header!r}")
    if key_mode == "hash" and header == "auto":
        # auto-detection works by the header failing to parse — but hash
        # mode parses *every* string, so a header row would be silently
        # ingested as a phantom first-seen item
        raise ValueError(
            "key_mode='hash' hashes any string, so a header row cannot be "
            "auto-detected; pass header='skip' (or 'none' for headerless "
            "files) explicitly"
        )
    buf: list = []
    sbuf: list = []
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            if lineno == 1 and header == "skip":
                continue
            parts = line.split(delimiter)
            bad = None
            need = id_col if size_col is None else max(id_col, size_col)
            if len(parts) <= need:
                bad = (
                    f"{len(parts)} field(s), id column is {id_col}"
                    if len(parts) <= id_col
                    else f"{len(parts)} field(s), size column is {size_col}"
                )
            else:
                try:
                    v = _parse_id(parts[id_col], key_mode)
                except OverflowError as e:
                    # an overflowed id is never skippable: after remapping it
                    # would silently alias another item
                    raise ValueError(f"{path}:{lineno}: {e}") from None
                except ValueError as e:
                    bad = str(e) or f"unparseable id {parts[id_col]!r}"
                if bad is None and size_col is not None:
                    try:
                        sz = float(parts[size_col])
                    except ValueError:
                        sz = float("nan")
                    if not (sz > 0.0 and np.isfinite(sz)):
                        bad = f"unparseable size {parts[size_col]!r}"
            if bad is not None:
                if lineno == 1 and header == "auto":
                    continue  # a header row is the one expected bad first line
                if on_bad == "raise":
                    raise ValueError(f"{path}:{lineno}: bad trace line ({bad})")
                continue
            buf.append(v)
            if size_col is not None:
                sbuf.append(sz)
            if len(buf) >= chunk_size:
                ids = np.asarray(buf, dtype=np.int64)
                if size_col is not None:
                    yield ids, np.asarray(sbuf, dtype=np.float64)
                    sbuf = []
                else:
                    yield ids
                buf = []
    if buf:
        ids = np.asarray(buf, dtype=np.int64)
        if size_col is not None:
            yield ids, np.asarray(sbuf, dtype=np.float64)
        else:
            yield ids


def _iter_binary(
    path: str, dtype: np.dtype, chunk_size: int
) -> Iterator[np.ndarray]:
    dtype = np.dtype(dtype)
    size = os.path.getsize(path)
    if size % dtype.itemsize:
        raise ValueError(
            f"{path}: truncated binary trace — {size} bytes is not a "
            f"multiple of the {dtype.itemsize}-byte record size"
        )
    with open(path, "rb") as f:
        while True:
            a = np.fromfile(f, dtype=dtype, count=chunk_size)
            if a.size == 0:
                break
            if dtype == np.uint64 and a.max() > np.uint64(_INT64_MAX):
                raise ValueError(
                    f"{path}: item id {int(a.max())} overflows int64"
                )
            yield a.astype(np.int64)


def open_trace(
    path: str,
    format: Optional[str] = None,
    *,
    chunk_size: int = DEFAULT_CHUNK,
    id_col: Optional[int] = None,
    on_bad: str = "raise",
    header: str = "auto",
    key_mode: str = "int",
    with_sizes: bool = False,
    size_col: Optional[int] = None,
) -> Iterator:
    """Open an on-disk trace as a chunk iterator of raw int64 ids.

    ``format`` defaults to :func:`sniff_format` on the extension.  Text
    formats take ``id_col`` (which column holds the item id), ``on_bad``
    (``"raise"``/``"skip"`` for malformed lines), ``header`` (``"auto"``
    tolerates one unparseable first line, ``"skip"`` always drops it,
    ``"none"`` treats it as data) and ``key_mode`` (``"int"`` or ``"hash"``
    for anonymized string keys).  Chunk boundaries never change the loaded
    stream: any ``chunk_size`` concatenates to the same trace.

    ``with_sizes=True`` additionally parses the per-request object size
    (bytes) from each format's size column (``size_col`` overrides; see
    ``TRACE_FORMATS``) and yields ``(ids, sizes)`` pairs — ``sizes`` is
    float64, validated positive and finite, with malformed sizes following
    ``on_bad`` like any other bad line.  The CDN/storage logs carry real
    sizes in exactly this column; dropping it silently was a bug — a
    byte-hit evaluation on a "loaded" CDN trace was actually unit-size.
    Binary formats carry ids only and reject ``with_sizes``.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    fmt = format or sniff_format(path)
    if fmt not in TRACE_FORMATS:
        raise ValueError(
            f"unknown trace format {fmt!r}; have {sorted(TRACE_FORMATS)}"
        )
    opts = TRACE_FORMATS[fmt]
    if "dtype" in opts:
        if key_mode != "int":
            raise ValueError("key_mode applies to text formats only")
        if with_sizes:
            raise ValueError(
                f"format {fmt!r} is a raw id stream with no size column; "
                "with_sizes needs a text format (csv/tsv/cdn)"
            )
        return _iter_binary(path, opts["dtype"], chunk_size)
    return _iter_text(
        path,
        opts["delimiter"],
        id_col if id_col is not None else opts["id_col"],
        chunk_size,
        on_bad,
        header,
        key_mode,
        size_col=(
            (size_col if size_col is not None else opts["size_col"])
            if with_sizes
            else None
        ),
    )


def load_trace(path: str, format: Optional[str] = None, **kw):
    """One-shot load: :func:`open_trace` chunks concatenated (small files /
    tests; streaming callers should keep the iterator).  With
    ``with_sizes=True`` returns an ``(ids, sizes)`` pair instead of ids."""
    chunks = list(open_trace(path, format, **kw))
    if kw.get("with_sizes"):
        if not chunks:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        return (
            np.concatenate([c[0] for c in chunks]),
            np.concatenate([c[1] for c in chunks]),
        )
    if not chunks:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(chunks)


def write_trace(
    path: str, ids, format: Optional[str] = None, *, sizes=None
) -> str:
    """Write ids to ``path`` in any supported format (fixtures/round-trips).

    Text formats get a synthetic ``timestamp`` column and a ``size`` column
    — per-request ``sizes`` when given (preserved bit-for-float through a
    ``with_sizes=True`` round-trip; integral values are written as
    integers), else the unit-size placeholder ``1``.  Binary formats carry
    ids only and reject ``sizes``.  ``bin32`` rejects ids that don't fit
    uint32 rather than silently wrapping.
    """
    ids = np.asarray(ids, dtype=np.int64)
    if ids.ndim != 1:
        raise ValueError("write_trace expects a 1-D id array")
    if ids.size and ids.min() < 0:
        raise ValueError("negative item ids")
    if sizes is not None:
        sizes = np.asarray(sizes, np.float64)
        if sizes.shape != ids.shape:
            raise ValueError(
                f"sizes shape {sizes.shape} != ids shape {ids.shape}"
            )
        if sizes.size and not (
            np.all(np.isfinite(sizes)) and float(sizes.min()) > 0.0
        ):
            raise ValueError("sizes must be finite and > 0")
    fmt = format or sniff_format(path)
    if fmt in ("bin32", "bin64"):
        if sizes is not None:
            raise ValueError(
                f"format {fmt!r} is a raw id stream and cannot carry sizes"
            )
        if fmt == "bin32":
            if ids.size and ids.max() > np.iinfo(np.uint32).max:
                raise ValueError("id overflows uint32; use bin64")
            ids.astype(np.uint32).tofile(path)
        else:
            ids.astype(np.uint64).tofile(path)
    elif fmt in ("csv", "tsv", "cdn"):
        sep = {"csv": ",", "tsv": "\t", "cdn": " "}[fmt]
        pad = sep + "0" if fmt == "csv" else ""  # csv size col is 4th
        with open(path, "w", encoding="utf-8") as f:
            for t, v in enumerate(ids.tolist()):
                if sizes is None:
                    s = "1"
                else:
                    sz = float(sizes[t])
                    s = str(int(sz)) if sz == int(sz) else repr(sz)
                f.write(f"{t}{sep}{v}{pad}{sep}{s}\n")
    else:
        raise ValueError(
            f"unknown trace format {fmt!r}; have {sorted(TRACE_FORMATS)}"
        )
    return path
