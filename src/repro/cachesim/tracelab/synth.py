"""Stats-matched workload synthesizer: fit a real trace, emit look-alikes.

Real cache traces are network-gated in this environment (and too big to
ship in a repo anyway), but the paper's empirical regime — millions of
requests over millions of items — still has to be exercised by CI and
benchmarks.  :func:`fit_profile` measures the §B.2 statistics of a real
(or sampled) trace — popularity skew, one-shot/burst composition,
reuse-distance profile, popularity drift — and :func:`synthesize_chunks`
emits arbitrarily long traces matching them:

* **popularity skew** — base requests draw ranks from the fitted
  rank-quantile CDF (an empirical generalization of the Zipf fit), mapped
  through a per-phase rank permutation;
* **drift** — the permutation is re-drawn every ``drift_phase`` requests
  (estimated from the decorrelation scale of segment popularity vectors);
* **reuse-distance / lifetime profile** — the short-distance mass that an
  independent-reference model cannot produce is matched by an explicit
  overlay of one-shot items and short-lived bursts at the fitted rates
  (the same mechanism behind :func:`repro.cachesim.traces.bursty`).

Generation is **blockwise-deterministic**: block ``b`` of the stream is a
pure function of ``(profile, catalog, seed, b)``, so any chunk size yields
the same trace, memory is O(block + catalog) regardless of T, and a
T=1e7+ stream needs no materialization.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from repro.cachesim.traces import reuse_distances, trace_stats

#: fixed internal generation block — chunk-size invariance comes from here
BLOCK = 8192

_POP_BINS = 64
_REUSE_SAMPLE = 200_000
_DRIFT_SIM_THRESHOLD = 0.5
#: popularity-rank bins of the fitted size--popularity joint
_SIZE_BINS = 8


@dataclass(frozen=True)
class TraceProfile:
    """The fitted statistics :func:`synthesize_chunks` reproduces.

    Rank bins are stored as *fractions* of the base catalog so a profile
    fitted on a sampled trace scales to any synthesis catalog size.
    """

    catalog: int  # suggested synthesis catalog (source distinct items)
    pop_cdf: np.ndarray  # (K,) cumulative base-request mass per rank bin
    pop_bins: np.ndarray  # (K+1,) rank-bin edges as fractions in [0, 1]
    base_item_frac: float  # share of distinct items that are base items
    oneshot_frac: float  # share of requests to items requested exactly once
    burst_frac: float  # share of requests to short-lived multi-use items
    burst_len_mean: float  # mean requests per burst item
    burst_span: int  # lifetime bound defining "short-lived"
    drift_phase: int  # requests per popularity phase (0 = stationary)
    source_T: int
    reuse_q: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64)
    )  # source reuse-distance quantiles (calibration reference)
    #: size--popularity joint: per popularity-rank bin, the lognormal
    #: (log-mean, log-std) of item sizes in that bin.  Empty = unsized fit.
    size_logmu: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64)
    )  # (J,) log-mean item size per rank bin
    size_logsd: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64)
    )  # (J,) log-std item size per rank bin
    size_rank_bins: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64)
    )  # (J+1,) rank-bin edges as fractions in [0, 1]


def _segment_drift_phase(trace: np.ndarray) -> int:
    """Decorrelation scale of segment popularity vectors (0 = stationary).

    The finest even split whose consecutive-segment popularity cosine
    similarity drops below ``_DRIFT_SIM_THRESHOLD`` names the phase
    length; a stationary trace stays similar at every scale.
    """
    t = len(trace)
    if t < 4096:
        return 0
    _, inv = np.unique(trace, return_inverse=True)
    u = int(inv.max()) + 1
    for n_seg in (16, 8, 4, 2):
        seg = t // n_seg
        counts = np.stack(
            [
                np.bincount(inv[i * seg : (i + 1) * seg], minlength=u)
                for i in range(n_seg)
            ]
        ).astype(np.float64)
        norms = np.linalg.norm(counts, axis=1)
        sims = (counts[1:] * counts[:-1]).sum(axis=1) / np.maximum(
            norms[1:] * norms[:-1], 1e-12
        )
        if float(np.mean(sims)) < _DRIFT_SIM_THRESHOLD:
            return seg
    return 0


def _fit_size_joint(trace: np.ndarray, sizes: np.ndarray):
    """Lognormal item-size fit per popularity-rank bin.

    An item's size is its first-seen request size; items are ranked by
    request count (descending, stable) and grouped into ``_SIZE_BINS``
    log-spaced rank bins — dense at the head, where size--popularity
    correlation (small-hot vs large-cold CDN objects) matters most."""
    sizes = np.asarray(sizes, np.float64)
    if sizes.shape != trace.shape:
        raise ValueError(
            f"sizes shape {sizes.shape} != trace shape {trace.shape}"
        )
    if not (np.all(np.isfinite(sizes)) and float(sizes.min()) > 0.0):
        raise ValueError("sizes must be finite and > 0")
    _, first_idx, cnt = np.unique(
        trace, return_index=True, return_counts=True
    )
    item_logsz = np.log(sizes[first_idx])
    order = np.argsort(-cnt, kind="stable")
    ranked = item_logsz[order]
    u = len(ranked)
    j = min(_SIZE_BINS, u)
    edges = np.unique(
        np.round(np.geomspace(1, u, j + 1) - 1).astype(np.int64)
    )
    if len(edges) < 2:
        edges = np.asarray([0, u], dtype=np.int64)
    edges[0], edges[-1] = 0, u
    mu = np.empty(len(edges) - 1)
    sd = np.empty(len(edges) - 1)
    for q in range(len(edges) - 1):
        seg = ranked[edges[q] : max(edges[q + 1], edges[q] + 1)]
        if seg.size == 0:  # guard: geomspace edge collisions are deduped
            seg = ranked[-1:]
        mu[q] = float(seg.mean())
        sd[q] = float(seg.std())
    return mu, sd, edges.astype(np.float64) / u


def fit_profile(
    trace: np.ndarray,
    *,
    sizes: Optional[np.ndarray] = None,
    burst_span: int = 100,
    bins: int = _POP_BINS,
) -> TraceProfile:
    """Measure the synthesis statistics of a trace (sparse raw ids are fine
    — everything routes through the sparse-safe :func:`trace_stats`).

    ``sizes`` (per-request bytes, e.g. from ``open_trace(...,
    with_sizes=True)``) additionally fits the size--popularity joint, which
    :func:`synthesize_sizes` reproduces for the synthesized catalog."""
    trace = np.asarray(trace, dtype=np.int64)
    t_len = len(trace)
    if t_len == 0:
        raise ValueError("cannot fit a profile on an empty trace")
    stats = trace_stats(trace)
    counts = stats.max_hits + 1  # requests per distinct item
    oneshot = counts == 1
    bursty = (~oneshot) & (stats.lifetimes < burst_span)
    base = ~(oneshot | bursty)

    oneshot_frac = float(counts[oneshot].sum()) / t_len
    burst_requests = int(counts[bursty].sum())
    burst_frac = burst_requests / t_len
    burst_len_mean = (
        float(counts[bursty].mean()) if burst_requests else 2.0
    )

    base_counts = np.sort(counts[base])[::-1].astype(np.float64)
    if base_counts.size == 0:
        # degenerate (everything one-shot): a flat one-bin base
        base_counts = np.asarray([1.0])
    u_base = len(base_counts)
    probs = base_counts / base_counts.sum()
    # log-spaced rank-bin edges: dense near the head where the mass lives
    k = min(bins, u_base)
    edges = np.unique(
        np.round(
            np.geomspace(1, u_base, k + 1) - 1
        ).astype(np.int64)
    )
    if len(edges) < 2:
        edges = np.asarray([0, u_base], dtype=np.int64)
    edges[0], edges[-1] = 0, u_base
    cum = np.concatenate([[0.0], np.cumsum(probs)])
    pop_cdf = cum[edges[1:]] - cum[edges[:-1]]
    pop_cdf = np.cumsum(pop_cdf)
    pop_cdf /= pop_cdf[-1]

    sample = trace[:_REUSE_SAMPLE]
    rd = reuse_distances(sample)
    reuse_q = (
        np.quantile(rd, [0.25, 0.5, 0.75, 0.9]).astype(np.float64)
        if rd.size
        else np.empty(0, np.float64)
    )

    if sizes is not None:
        s_mu, s_sd, s_bins = _fit_size_joint(trace, sizes)
    else:
        s_mu = s_sd = s_bins = np.empty(0, np.float64)

    return TraceProfile(
        catalog=int(stats.unique),
        pop_cdf=pop_cdf,
        pop_bins=edges.astype(np.float64) / u_base,
        base_item_frac=float(base.sum()) / max(stats.unique, 1),
        oneshot_frac=oneshot_frac,
        burst_frac=burst_frac,
        burst_len_mean=burst_len_mean,
        burst_span=burst_span,
        drift_phase=_segment_drift_phase(trace),
        source_T=t_len,
        reuse_q=reuse_q,
        size_logmu=s_mu,
        size_logsd=s_sd,
        size_rank_bins=s_bins,
    )


def _base_split(profile: TraceProfile, catalog: int) -> int:
    """Base/overlay catalog split: overlay needs a pool of short-lived ids;
    tiny catalogs (< 8) give everything to the base popularity model."""
    n_base = catalog
    if catalog >= 8 and profile.base_item_frac < 1.0:
        n_base = int(np.clip(
            round(catalog * max(profile.base_item_frac, 0.05)),
            1,
            catalog - 1,
        ))
    return n_base


def _phase_perm(n_base: int, seed: int, phase: int) -> np.ndarray:
    """The rank->item permutation for one popularity phase (pure function
    of (seed, phase) so any block can regenerate it)."""
    rng = np.random.default_rng([seed, 0x5A5A, phase])
    return rng.permutation(n_base)


def _gen_block(
    profile: TraceProfile,
    catalog: int,
    n_base: int,
    seed: int,
    b: int,
    length: int,
    perm_cache: dict,
) -> np.ndarray:
    """Block ``b`` of the stream: deterministic in (profile, catalog, seed, b).

    The full ``BLOCK`` draws are always generated and then truncated to
    ``length``, so a shorter synthesis is an exact *prefix* of a longer
    one — T only ever truncates the stream, never reshuffles it."""
    rng = np.random.default_rng([seed, 0xB10C, b])
    pos0 = b * BLOCK

    # --- base traffic: rank-CDF draws through the per-phase permutation
    u = rng.random(BLOCK)
    j = np.searchsorted(profile.pop_cdf, u, side="right")
    j = np.minimum(j, len(profile.pop_cdf) - 1)
    lo = profile.pop_bins[j] * n_base
    hi = profile.pop_bins[j + 1] * n_base
    ranks = np.minimum(
        (lo + rng.random(BLOCK) * np.maximum(hi - lo, 1.0)).astype(np.int64),
        n_base - 1,
    )
    if profile.drift_phase > 0:
        out = np.empty(BLOCK, dtype=np.int64)
        pos = pos0
        done = 0
        while done < BLOCK:
            phase = pos // profile.drift_phase
            take = min(
                BLOCK - done, (phase + 1) * profile.drift_phase - pos
            )
            if phase not in perm_cache:
                if len(perm_cache) > 2:
                    perm_cache.clear()
                perm_cache[phase] = _phase_perm(n_base, seed, phase)
            perm = perm_cache[phase]
            out[done : done + take] = perm[ranks[done : done + take]]
            done += take
            pos += take
        ids = out
    else:
        if 0 not in perm_cache:
            perm_cache[0] = _phase_perm(n_base, seed, 0)
        ids = perm_cache[0][ranks]

    # --- overlay: one-shot items and short-lived bursts from the tail pool
    pool = catalog - n_base
    if pool > 0:
        pool_off = (b * (BLOCK // 2 + 1)) % pool
        fresh = 0

        def _fresh_ids(k: int) -> np.ndarray:
            nonlocal fresh
            out = n_base + (pool_off + fresh + np.arange(k)) % pool
            fresh += k
            return out

        n_one = rng.binomial(BLOCK, min(profile.oneshot_frac, 1.0))
        if n_one:
            at = rng.choice(BLOCK, size=n_one, replace=False)
            ids[at] = _fresh_ids(n_one)
        if profile.burst_frac > 0:
            span = min(profile.burst_span, BLOCK)
            n_bursts = rng.poisson(
                BLOCK * profile.burst_frac / max(profile.burst_len_mean, 1.0)
            )
            for _ in range(int(n_bursts)):
                k = 1 + rng.geometric(
                    1.0 / max(profile.burst_len_mean - 1.0, 1.0)
                )
                k = int(min(k, span))
                start = int(rng.integers(0, max(BLOCK - span, 1)))
                at = start + rng.choice(span, size=k, replace=False)
                ids[at] = _fresh_ids(1)[0]
    return ids[:length]


def synthesize_chunks(
    profile: TraceProfile,
    T: int,
    *,
    catalog: Optional[int] = None,
    seed: int = 0,
    chunk_size: int = 65536,
) -> Iterator[np.ndarray]:
    """Stream ``T`` synthesized requests in ``chunk_size`` pieces.

    Fixed memory: O(``chunk_size`` + ``catalog``), independent of ``T``.
    The stream content depends only on ``(profile, catalog, seed)`` — any
    ``chunk_size`` concatenates to the same trace.
    """
    if T < 0:
        raise ValueError(f"T must be >= 0, got {T}")
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    catalog = int(catalog if catalog is not None else profile.catalog)
    if catalog < 1:
        raise ValueError(f"catalog must be >= 1, got {catalog}")
    n_base = _base_split(profile, catalog)

    perm_cache: dict = {}
    buf: list = []
    buffered = 0
    for b in range(-(-T // BLOCK)):  # ceil(T / BLOCK) blocks
        length = min(BLOCK, T - b * BLOCK)
        buf.append(
            _gen_block(profile, catalog, n_base, seed, b, length, perm_cache)
        )
        buffered += length
        while buffered >= chunk_size:
            merged = np.concatenate(buf) if len(buf) > 1 else buf[0]
            yield merged[:chunk_size]
            rest = merged[chunk_size:]
            buf = [rest] if rest.size else []
            buffered = rest.size
    if buffered:
        yield np.concatenate(buf) if len(buf) > 1 else buf[0]


def synthesize_sizes(
    profile: TraceProfile,
    *,
    catalog: Optional[int] = None,
    seed: int = 0,
) -> np.ndarray:
    """Per-item sizes (bytes) reproducing the fitted size--popularity joint.

    Returns a ``(catalog,)`` array aligned with the item ids that
    :func:`synthesize_chunks` emits for the same ``(profile, catalog,
    seed)``: each popularity rank draws from its rank bin's fitted
    lognormal, and ranks map to item ids through the phase-0 base
    permutation (under drift, later phases re-rank items while their sizes
    stay fixed — sizes are a per-object property).  Overlay-pool items
    (one-shots/bursts) draw from the tail bin.  An unsized profile yields
    unit sizes, so the pairing is always safe to use."""
    catalog = int(catalog if catalog is not None else profile.catalog)
    if catalog < 1:
        raise ValueError(f"catalog must be >= 1, got {catalog}")
    if profile.size_logmu.size == 0:
        return np.ones(catalog, np.float64)
    rng = np.random.default_rng([seed, 0x512E])
    frac = (np.arange(catalog, dtype=np.float64) + 0.5) / catalog
    q = np.clip(
        np.searchsorted(profile.size_rank_bins, frac, side="right") - 1,
        0,
        len(profile.size_logmu) - 1,
    )
    by_rank = np.exp(
        profile.size_logmu[q] + profile.size_logsd[q] * rng.standard_normal(
            catalog
        )
    )
    n_base = _base_split(profile, catalog)
    out = np.empty(catalog, np.float64)
    out[_phase_perm(n_base, seed, 0)] = by_rank[:n_base]
    out[n_base:] = by_rank[n_base:]
    return out


def synthesize(
    profile: TraceProfile,
    T: int,
    *,
    catalog: Optional[int] = None,
    seed: int = 0,
) -> np.ndarray:
    """Materialized convenience wrapper over :func:`synthesize_chunks`."""
    chunks = list(
        synthesize_chunks(profile, T, catalog=catalog, seed=seed)
    )
    if not chunks:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(chunks)


def tenant_streams(
    profile: TraceProfile,
    n_tenants: int,
    T: int,
    *,
    catalog: Optional[int] = None,
    base_seed: int = 0,
    chunk_size: int = 65536,
) -> list:
    """E stats-matched per-tenant chunk streams for ``cachesim.fleet``.

    Tenant ``e`` synthesizes an independent ``T``-request stream from the
    same fitted profile with seed ``base_seed + e`` — the fleet ingestion
    shape (statistically matched tenants, decorrelated request sequences).
    Each entry is a fresh :func:`synthesize_chunks` iterator, so the list
    plugs straight into ``run_fleet_stream(sources=...)`` in fixed memory.
    """
    if n_tenants <= 0:
        raise ValueError(f"n_tenants must be positive (got {n_tenants})")
    return [
        synthesize_chunks(
            profile, T, catalog=catalog, seed=base_seed + e,
            chunk_size=chunk_size,
        )
        for e in range(n_tenants)
    ]
