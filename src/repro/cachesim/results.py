"""Shared result types for the unified run/sweep engine.

One home for the host-side views every execution path returns:

* :class:`RunResult` — one policy replayed over one trace (any kind: the
  fractional gradient policies and the discrete automata share it).  The
  legacy names (``ReplayMetrics``, ``EngineResult``) are aliases.
* :class:`SweepResult` — a stacked (capacities x seeds x etas) grid run in
  one vmapped dispatch.  Legacy ``ReplaySweepResult`` / ``EngineSweepResult``
  are aliases.
* :class:`StreamResult` — a :class:`RunResult` accumulated out-of-core by
  :func:`repro.cachesim.tracelab.stream.run_stream`, extended with the
  windowed time-varying-OPT ("dynamic regret") accounting.
* :class:`HitStatsMixin` — the single implementation of ``hit_ratio`` and
  ``us_per_request``, also mixed into the per-request simulator's
  :class:`repro.cachesim.simulator.SimResult`.

Field conventions: per-chunk arrays are shaped ``(M,)`` (runs) or ``(R, M)``
(sweeps, one row per combo); ``reward`` is the fractional pre-update reward
(equal to ``hits`` for the integral automata), ``aux`` holds the per-chunk
projection threshold (tau for OGB, lambda for OMD, 0 for automata).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


def find_combo(combos: "List[Dict[str, float]]", **match) -> int:
    """Row index of the sweep combo matching all given key/values."""
    for r, combo in enumerate(combos):
        if all(combo.get(k) == v for k, v in match.items()):
            return r
    raise KeyError(f"no combo matching {match}")


class HitStatsMixin:
    """The one implementation of the scalar throughput/quality ratios."""

    @property
    def hit_ratio(self) -> float:
        return float(np.sum(self.hits)) / max(self.T, 1)

    @property
    def byte_hit_ratio(self) -> float:
        """Bytes served from cache over bytes requested (sized runs).

        Falls back to the object hit ratio for unsized runs (every object
        one byte), so callers can read it unconditionally."""
        bh = getattr(self, "byte_hits", None)
        bt = float(getattr(self, "bytes_total", 0.0) or 0.0)
        if bh is None or bt <= 0.0:
            return self.hit_ratio
        return float(np.sum(bh)) / bt

    @property
    def us_per_request(self) -> float:
        return 1e6 * self.wall_seconds / max(self.T, 1)


@dataclass
class RunResult(HitStatsMixin):
    """Host-side view of one policy replay (single final fetch).

    ``carry`` is the final device carry — pass it back to
    :func:`repro.cachesim.api.run` to resume the replay on the next trace
    chunk (the streaming contract; note the carry is *donated* on resume,
    so hand it off rather than keeping references).
    """

    name: str
    kind: str
    T: int  # requests actually replayed (num_chunks * window)
    window: int  # requests per chunk (the OGB/OMD update batch B)
    capacity: int
    reward: np.ndarray  # (M,) per-chunk fractional reward (== hits if integral)
    hits: np.ndarray  # (M,) per-chunk integral hits
    aux: np.ndarray  # (M,) per-chunk projection threshold (tau / lambda)
    occupancy: np.ndarray  # (M,) per-chunk cached mass / item count
    opt_hits: float = 0.0  # hindsight static-OPT reward over the replayed prefix
    carry: Any = None  # final device carry (resumable)
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)
    byte_hits: Optional[np.ndarray] = None  # (M,) per-chunk byte hits (sized)
    bytes_total: float = 0.0  # total bytes requested (sized runs, else 0)

    # legacy spellings (ReplayMetrics / EngineResult)
    @property
    def batch(self) -> int:
        return self.window

    @property
    def frac_reward(self) -> np.ndarray:
        return self.reward

    @property
    def taus(self) -> np.ndarray:
        return self.aux

    @property
    def final_f(self) -> Optional[np.ndarray]:
        f = getattr(self.carry, "f", None)
        return None if f is None else np.asarray(f)

    @property
    def frac_hit_ratio(self) -> float:
        return float(self.reward.sum()) / max(self.T, 1)

    @property
    def regret(self) -> float:
        """Hindsight regret of the fractional (OCO) reward."""
        return self.opt_hits - float(self.reward.sum())

    @property
    def integral_regret(self) -> float:
        return self.opt_hits - float(self.hits.sum())

    def windowed_hit_ratio(self, window: int) -> np.ndarray:
        """Hit ratio per non-overlapping window (rounded to whole chunks)."""
        per = max(window // self.window, 1)
        m = (len(self.hits) // per) * per
        if m == 0:
            return np.array([self.hit_ratio])
        return self.hits[:m].reshape(-1, per).sum(axis=1) / (per * self.window)

    def windowed_frac_ratio(self, window: int) -> np.ndarray:
        per = max(window // self.window, 1)
        m = (len(self.reward) // per) * per
        if m == 0:
            return np.array([self.frac_hit_ratio])
        return self.reward[:m].reshape(-1, per).sum(axis=1) / (
            per * self.window
        )


@dataclass
class StreamResult(RunResult):
    """A :class:`RunResult` accumulated out-of-core by
    :func:`repro.cachesim.tracelab.stream.run_stream`.

    Per-chunk arrays are concatenated across stream segments (so every
    inherited windowed/ratio view works unchanged); on top of them the
    stream tracks the **time-varying OPT proxy**: ``dyn_opt_hits[k]`` is
    the hindsight-optimal static allocation recomputed for the ``k``-th
    ``dyn_opt_window``-request window alone (the final window may be a
    shorter remainder — see :attr:`dyn_opt_lens` — so together the windows
    cover every replayed request).  Summed, that is the comparator of the
    *dynamic* regret notion (an adversary allowed to re-pick its cache
    every window) — a strictly harder bar than the static OPT in
    ``opt_hits``.

    **Timing split:** ``wall_seconds`` stays the total wall clock of the
    stream (back-compat).  The component clocks attribute it:
    ``ingest_seconds`` is time spent waiting on the chunk source,
    ``device_seconds`` is dispatch plus time blocked on device results,
    and ``host_seconds`` is the segment re-batching + dynamic-OPT
    accounting.  On the synchronous path (``prefetch=0``) the components
    sum to roughly ``wall_seconds``; on the async pipeline they *overlap*,
    so their sum can exceed the wall clock — that surplus is the measured
    overlap win.
    """

    dyn_opt_hits: Optional[np.ndarray] = None  # (K,) per-window OPT hits
    dyn_opt_window: int = 0  # requests per dynamic-OPT window (0 = off)
    n_segments: int = 0  # device dispatches the stream took
    t_dropped: int = 0  # trailing requests short of one window, not replayed
    ingest_seconds: float = 0.0  # time waiting on the chunk source
    device_seconds: float = 0.0  # dispatch + time blocked on device results
    host_seconds: float = 0.0  # re-batching + dynamic-OPT host accounting
    prefetch: int = 0  # pipeline depth the stream ran with (0 = synchronous)

    @property
    def dyn_opt_lens(self) -> np.ndarray:
        """Requests covered by each dynamic-OPT window.

        All windows are ``dyn_opt_window`` long except the last, which
        covers the replayed remainder (the flush that keeps
        ``sum(dyn_opt_lens) == T``)."""
        if self.dyn_opt_hits is None:
            raise ValueError("run_stream(..., opt_window=...) was not set")
        k = len(self.dyn_opt_hits)
        lens = np.full(k, self.dyn_opt_window, np.int64)
        if k:
            lens[-1] = self.T - (k - 1) * self.dyn_opt_window
        return lens

    @property
    def dynamic_opt_total(self) -> float:
        """Total hits of the per-window re-optimized comparator."""
        if self.dyn_opt_hits is None:
            raise ValueError("run_stream(..., opt_window=...) was not set")
        return float(np.sum(self.dyn_opt_hits))

    @property
    def dynamic_regret(self) -> float:
        """Fractional-reward regret vs the time-varying OPT proxy, over the
        prefix the dynamic windows cover (== every replayed request)."""
        total = self.dynamic_opt_total  # raises cleanly when not tracked
        covered = int(self.dyn_opt_lens.sum())
        chunks = covered // max(self.window, 1)
        return total - float(self.reward[:chunks].sum())

    def dyn_opt_ratio(self) -> np.ndarray:
        """Per-window hit ratio of the time-varying OPT proxy."""
        lens = self.dyn_opt_lens  # raises cleanly when not tracked
        return self.dyn_opt_hits / np.maximum(lens, 1)


@dataclass
class SweepResult:
    """Stacked replays over a parameter grid (single vmapped dispatch).

    ``combos[r]`` names row ``r``: always ``capacity`` and ``seed``, plus
    ``eta`` for the fractional policies; :meth:`row` looks rows up by any
    subset of those keys.
    """

    kind: str
    combos: List[Dict[str, float]]
    T: int
    window: int
    reward: np.ndarray  # (R, M)
    hits: np.ndarray  # (R, M)
    aux: np.ndarray  # (R, M)
    occupancy: np.ndarray  # (R, M)
    opt_hits: np.ndarray  # (R,) hindsight static-OPT per combo (host-side)
    wall_seconds: float = 0.0
    byte_hits: Optional[np.ndarray] = None  # (R, M) per-chunk byte hits
    bytes_total: float = 0.0  # total bytes requested (sized runs, else 0)

    @property
    def batch(self) -> int:
        return self.window

    @property
    def byte_hit_ratios(self) -> np.ndarray:
        """Per-combo byte hit ratio (falls back to object ratio unsized)."""
        if self.byte_hits is None or self.bytes_total <= 0.0:
            return self.hit_ratios
        return self.byte_hits.sum(axis=1) / self.bytes_total

    @property
    def frac_reward(self) -> np.ndarray:
        return self.reward

    @property
    def taus(self) -> np.ndarray:
        return self.aux

    @property
    def hit_ratios(self) -> np.ndarray:
        return self.hits.sum(axis=1) / max(self.T, 1)

    @property
    def frac_hit_ratios(self) -> np.ndarray:
        return self.reward.sum(axis=1) / max(self.T, 1)

    @property
    def regrets(self) -> np.ndarray:
        return self.opt_hits - self.reward.sum(axis=1)

    def row(self, **match) -> int:
        return find_combo(self.combos, **match)


@dataclass
class FleetResult:
    """Host-side view of one multi-tenant fleet replay.

    E independent per-tenant caches stepped in lockstep by one vmapped,
    donated-carry scan (``api._fleet_jit``): every per-chunk observable
    gains a leading tenant axis, so ``reward``/``hits``/``aux``/
    ``occupancy`` are ``(E, M)`` and the scalar ratios aggregate over the
    whole fleet.  ``T`` is the number of requests replayed *per tenant*
    (the fleet steps in lockstep, so it is shared); ``carry`` is the
    final tenant-stacked carry — pass it back to ``run_fleet(carry=...)``
    to resume every tenant mid-stream in one call.
    """

    name: str
    kind: str
    n_tenants: int
    T: int  # requests replayed PER TENANT (num_chunks * window)
    window: int
    capacities: np.ndarray  # (E,)
    seeds: np.ndarray  # (E,) (-1 on resumed runs: seeds live in the carry)
    etas: Optional[np.ndarray]  # (E,) resolved per-tenant eta, fractional only
    reward: np.ndarray  # (E, M)
    hits: np.ndarray  # (E, M)
    aux: np.ndarray  # (E, M)
    occupancy: np.ndarray  # (E, M)
    opt_hits: np.ndarray  # (E,) per-tenant hindsight static OPT (0 if untracked)
    carry: Any = None  # final tenant-stacked device carry (resumable)
    wall_seconds: float = 0.0
    byte_hits: Optional[np.ndarray] = None  # (E, M) sized runs only
    bytes_total: Optional[np.ndarray] = None  # (E,) bytes requested per tenant
    n_segments: int = 1  # dispatches (1 for in-memory run_fleet)
    t_dropped: int = 0  # unreplayed tail requests across the fleet (stream)
    prefetch: int = 0

    @property
    def total_requests(self) -> int:
        """Requests replayed across the whole fleet (E * T)."""
        return self.n_tenants * self.T

    @property
    def tenant_hit_ratios(self) -> np.ndarray:
        """(E,) integral hit ratio of each tenant."""
        return self.hits.sum(axis=1) / max(self.T, 1)

    @property
    def tenant_frac_ratios(self) -> np.ndarray:
        """(E,) fractional (OCO) reward ratio of each tenant."""
        return self.reward.sum(axis=1) / max(self.T, 1)

    @property
    def regrets(self) -> np.ndarray:
        """(E,) per-tenant hindsight regret of the fractional reward."""
        return self.opt_hits - self.reward.sum(axis=1)

    @property
    def hit_ratio(self) -> float:
        """Aggregate hit ratio over every request the fleet served."""
        return float(self.hits.sum()) / max(self.total_requests, 1)

    @property
    def hit_ratio_mean(self) -> float:
        return float(self.tenant_hit_ratios.mean())

    @property
    def hit_ratio_p5(self) -> float:
        """5th-percentile tenant hit ratio — the tail tenants SLOs live on."""
        return float(np.percentile(self.tenant_hit_ratios, 5.0))

    @property
    def hit_ratio_p95(self) -> float:
        return float(np.percentile(self.tenant_hit_ratios, 95.0))

    @property
    def byte_hit_ratio(self) -> float:
        """Fleet-aggregate byte hit ratio (object ratio when unsized)."""
        if self.byte_hits is None or self.bytes_total is None:
            return self.hit_ratio
        bt = float(np.sum(self.bytes_total))
        if bt <= 0.0:
            return self.hit_ratio
        return float(np.sum(self.byte_hits)) / bt

    @property
    def us_per_request(self) -> float:
        """Aggregate dispatch cost per request across the fleet."""
        return 1e6 * self.wall_seconds / max(self.total_requests, 1)

    @property
    def requests_per_second(self) -> float:
        return self.total_requests / max(self.wall_seconds, 1e-12)


@dataclass
class EdgeFleetResult:
    """Two-level edge->origin replay: E edge caches, one shared origin.

    ``edges`` is the fleet replay of the per-edge request streams;
    ``origin`` is the streamed replay of the deterministic interleave of
    every edge miss (arrival-position major, edge index minor).
    ``origin_requests`` counts every edge miss handed to the origin tier —
    the origin replays its window-aligned prefix of them (its ``T``).
    """

    edges: "FleetResult"
    origin: Any  # StreamResult of the origin cache over the miss stream
    origin_requests: int

    @property
    def edge_hit_ratio(self) -> float:
        return self.edges.hit_ratio

    @property
    def origin_hit_ratio(self) -> float:
        return self.origin.hit_ratio

    @property
    def end_to_end_hit_ratio(self) -> float:
        """Requests served by either tier over all edge-arriving requests."""
        total = self.edges.total_requests
        return float(self.edges.hits.sum() + self.origin.hits.sum()) / max(
            total, 1
        )
