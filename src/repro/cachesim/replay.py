"""Device-resident whole-trace OGB_cl replay — one ``lax.scan``, zero host syncs.

The per-batch driver (``for batch: ogb_batch_update(...)``) pays a Python
dispatch + host round-trip per batch and a cold ~50-sweep bisection per
projection — at paper scale (millions of requests over million-item catalogs)
the harness reintroduces exactly the per-step overhead the paper's O(log N)
policy removes.  This engine compiles the *entire* replay into a single
``jax.lax.scan`` over ``(num_chunks, B)`` request chunks with a donated
carry, accumulating on device:

* fractional reward  sum_t f[r_t] (pre-update, OCO order),
* integral hits under coordinated Poisson or Madow sampling,
* per-chunk occupancy and projection threshold tau,
* the whole-trace request histogram, from which the hindsight-OPT reward
  (top-C counts) and hence regret are computed — still on device.

Nothing crosses the host boundary until the final metrics fetch.

The projection is *warm-started*: with a feasible pre-step state the per-chunk
threshold provably lies in [0, eta * B], and the previous chunk's tau seeds a
bracketed-Newton root-find (:func:`repro.jaxcache.fractional.
capped_simplex_project_warm`) that needs single-digit catalog sweeps instead
of ~50 cold bisection sweeps.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.jaxcache.fractional import (
    DEFAULT_BISECT_ITERS,
    DEFAULT_WARM_SWEEPS,
    capped_simplex_project,
    capped_simplex_project_warm,
    madow_sample_jax,
    permanent_random_numbers,
    warm_bracket_hi,
)


class ReplayCarry(NamedTuple):
    """Scan carry: donated, lives on device for the whole replay."""

    f: jax.Array  # (N,) float32 fractional state
    tau: jax.Array  # () float32 previous chunk's projection threshold
    counts: jax.Array  # (N,) float32 whole-trace histogram (hindsight OPT)

    @staticmethod
    def create(catalog_size: int, capacity: int) -> "ReplayCarry":
        return ReplayCarry(
            f=jnp.full(catalog_size, capacity / catalog_size, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            counts=jnp.zeros(catalog_size, jnp.float32),
        )


@functools.lru_cache(maxsize=64)
def make_replay_fn(
    catalog_size: int,
    capacity: int,
    batch: int,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    track_opt: bool = True,
):
    """Build the jitted whole-trace replay.

    Returns ``replay(carry, chunks, eta, p, us) -> (carry', opt_hits, ys)``
    where ``chunks`` is (M, B) int32, ``p`` the (N,) permanent random numbers
    (Poisson sampling), ``us`` the (M,) Madow offsets (pass size-0 arrays for
    the unused one) and ``ys`` stacks per-chunk (reward, hits, tau,
    occupancy).  The carry is donated: call with a fresh ``ReplayCarry``.

    Memoized on its (hashable) configuration so repeat calls — e.g.
    ``replay_trace`` in a sweep — reuse the same jitted function and hence
    XLA's compilation cache instead of re-tracing every time.
    """
    if sample not in ("poisson", "madow", "none"):
        raise ValueError(f"unknown sample mode {sample!r}")
    if projection not in ("warm", "bisect"):
        raise ValueError(f"unknown projection mode {projection!r}")
    cap_f = float(capacity)

    def step(eta, p, carry, xs):
        f, tau_prev, counts_tot = carry
        ids, u = xs
        fi = f[ids]
        reward = jnp.sum(fi)
        if sample == "poisson":
            # hits only need the requested coordinates: B-sized gathers, not
            # an N-sized mask; occupancy is the one remaining catalog pass
            hits = jnp.sum((fi >= p[ids]).astype(jnp.int32))
            occ = jnp.sum((f >= p).astype(jnp.float32))
        elif sample == "madow":
            cached = madow_sample_jax(f, u, capacity)
            hits = jnp.sum(cached[ids].astype(jnp.int32))
            occ = jnp.sum(cached.astype(jnp.float32))
        else:
            hits = jnp.zeros((), jnp.int32)
            occ = jnp.sum(f)
        # gradient step as a B-element scatter-add (duplicates accumulate);
        # avoids materializing a dense (N,) counts histogram per chunk
        y = f.at[ids].add(eta)
        if projection == "warm":
            hi = warm_bracket_hi(eta * jnp.float32(batch))
            f_new, tau = capped_simplex_project_warm(
                y, cap_f, jnp.float32(0.0), hi, tau_prev, sweeps
            )
        else:
            f_new, tau = capped_simplex_project(y, cap_f, iters)
        if track_opt:
            counts_tot = counts_tot.at[ids].add(1.0)
        return (
            ReplayCarry(f_new, tau, counts_tot),
            (reward, hits, tau, occ),
        )

    def replay(carry, chunks, eta, p, us):
        m = chunks.shape[0]
        if us.shape[0] != m:
            us = jnp.zeros((m,), jnp.float32)
        carry, ys = jax.lax.scan(
            lambda c, x: step(eta, p, c, x), carry, (chunks, us)
        )
        if track_opt:
            opt = jnp.sum(jax.lax.top_k(carry.counts, capacity)[0])
        else:
            opt = jnp.zeros((), jnp.float32)
        return carry, opt, ys

    return jax.jit(replay, donate_argnums=(0,))


@dataclass
class ReplayMetrics:
    """Host-side view of one replay (everything fetched in a single sync)."""

    name: str
    T: int  # requests actually replayed (num_chunks * batch)
    batch: int
    capacity: int
    frac_reward: np.ndarray  # (M,) per-chunk fractional reward
    hits: np.ndarray  # (M,) per-chunk integral hits
    taus: np.ndarray  # (M,) per-chunk projection threshold
    occupancy: np.ndarray  # (M,) per-chunk sampled-cache size
    opt_hits: float  # hindsight static-OPT reward over the replayed prefix
    final_f: Optional[np.ndarray] = None
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def hit_ratio(self) -> float:
        return float(self.hits.sum()) / max(self.T, 1)

    @property
    def frac_hit_ratio(self) -> float:
        return float(self.frac_reward.sum()) / max(self.T, 1)

    @property
    def regret(self) -> float:
        """Hindsight regret of the fractional (OCO) reward."""
        return self.opt_hits - float(self.frac_reward.sum())

    @property
    def integral_regret(self) -> float:
        return self.opt_hits - float(self.hits.sum())

    @property
    def us_per_request(self) -> float:
        return 1e6 * self.wall_seconds / max(self.T, 1)

    def windowed_hit_ratio(self, window: int) -> np.ndarray:
        """Hit ratio per non-overlapping window (rounded to whole chunks)."""
        per = max(window // self.batch, 1)
        m = (len(self.hits) // per) * per
        if m == 0:
            return np.array([self.hit_ratio])
        return self.hits[:m].reshape(-1, per).sum(axis=1) / (per * self.batch)

    def windowed_frac_ratio(self, window: int) -> np.ndarray:
        per = max(window // self.batch, 1)
        m = (len(self.frac_reward) // per) * per
        if m == 0:
            return np.array([self.frac_hit_ratio])
        return self.frac_reward[:m].reshape(-1, per).sum(axis=1) / (
            per * self.batch
        )


def replay_trace(
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    batch: int,
    eta: Optional[float] = None,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    seed: int = 0,
    track_opt: bool = True,
    keep_final_f: bool = False,
    name: str = "OGB_scan",
) -> ReplayMetrics:
    """Replay a whole trace through the scan-compiled OGB_cl engine.

    The trace is reshaped into ``(T // batch, batch)`` chunks (a trailing
    partial chunk is dropped, matching the per-batch driver).  ``eta`` defaults
    to the Theorem 3.1 tuning for the replayed horizon.
    """
    from repro.core.ogb import theoretical_eta  # cheap, avoids a cycle at import

    n_chunks = len(trace) // batch
    if n_chunks == 0:
        raise ValueError(f"trace shorter than one batch ({len(trace)} < {batch})")
    t_used = n_chunks * batch
    if eta is None:
        eta = theoretical_eta(capacity, catalog_size, t_used, 1)
    chunks = jnp.asarray(
        np.asarray(trace[:t_used]).reshape(n_chunks, batch), jnp.int32
    )

    key = jax.random.key(seed)
    k_p, k_u = jax.random.split(key)
    p = (
        permanent_random_numbers(k_p, catalog_size)
        if sample == "poisson"
        else jnp.zeros((0,), jnp.float32)
    )
    us = (
        jax.random.uniform(k_u, (n_chunks,), jnp.float32)
        if sample == "madow"
        else jnp.zeros((0,), jnp.float32)
    )

    fn = make_replay_fn(
        catalog_size,
        capacity,
        batch,
        sample=sample,
        projection=projection,
        sweeps=sweeps,
        iters=iters,
        track_opt=track_opt,
    )
    carry = ReplayCarry.create(catalog_size, capacity)
    t0 = time.perf_counter()
    carry, opt, (reward, hits, taus, occ) = fn(
        carry, chunks, jnp.float32(eta), p, us
    )
    jax.block_until_ready((carry.f, opt, reward, hits, taus, occ))
    wall = time.perf_counter() - t0

    return ReplayMetrics(
        name=name,
        T=t_used,
        batch=batch,
        capacity=capacity,
        frac_reward=np.asarray(reward, np.float64),
        hits=np.asarray(hits, np.int64),
        taus=np.asarray(taus, np.float64),
        occupancy=np.asarray(occ, np.float64),
        opt_hits=float(opt),
        final_f=np.asarray(carry.f) if keep_final_f else None,
        wall_seconds=wall,
        extras={"eta": float(eta), "sweeps": float(sweeps)},
    )
