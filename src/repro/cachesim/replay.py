"""Device-resident whole-trace OGB_cl replay — one ``lax.scan``, zero host syncs.

The per-batch driver (``for batch: ogb_batch_update(...)``) pays a Python
dispatch + host round-trip per batch and a cold ~50-sweep bisection per
projection — at paper scale (millions of requests over million-item catalogs)
the harness reintroduces exactly the per-step overhead the paper's O(log N)
policy removes.  This module owns the *raw* OGB_cl scan step (gradient
scatter-add + warm-started capped-simplex projection) and the low-level
whole-trace ``make_replay_fn`` builder used by the throughput benchmark.

The projection is *warm-started*: with a feasible pre-step state the per-chunk
threshold provably lies in [0, eta * B], and the previous chunk's tau seeds a
bracketed-Newton root-find (:func:`repro.jaxcache.fractional.
capped_simplex_project_warm`) that needs single-digit catalog sweeps instead
of ~50 cold bisection sweeps.

The public entry points (``replay_trace`` / ``sweep_replay``) are deprecated
thin wrappers over the unified policy engine — use
:func:`repro.cachesim.api.run` / :func:`repro.cachesim.api.sweep` with
``policy_def("ogb")`` instead; the OGB policy is registered there through the
same step built here, so the replayed dynamics are identical.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.results import RunResult, SweepResult
from repro.jaxcache.fractional import (
    DEFAULT_BISECT_ITERS,
    DEFAULT_WARM_SWEEPS,
    capped_simplex_project,
    capped_simplex_project_warm,
    madow_sample_jax,
    permanent_random_numbers,
    warm_bracket_hi,
)

#: legacy names — the five result dataclasses are unified in
#: :mod:`repro.cachesim.results`
ReplayMetrics = RunResult
ReplaySweepResult = SweepResult


def sampling_keys(seed: int, catalog_size: int, sample: str) -> tuple:
    """Seed-derived ``(p, k_u)``: the permanent random numbers for Poisson
    sampling (size-0 when unused) and the key that drives Madow offsets.
    THE one seed derivation — both the unified api carries and the legacy
    per-trace arrays build on it, so the Poisson stream cannot desync
    between the two paths (the goldens pin it)."""
    k_p, k_u = jax.random.split(jax.random.key(seed))
    p = (
        permanent_random_numbers(k_p, catalog_size)
        if sample == "poisson"
        else jnp.zeros((0,), jnp.float32)
    )
    return p, k_u


#: sampling modes that draw a per-chunk Madow offset u from the carried key
MADOW_SAMPLES = ("madow", "madow_tree")


def sample_chunk_metrics(sample: str, capacity, f, ids, p, u):
    """(reward, hits, occupancy) for one request chunk at the pre-update
    state ``f`` (OCO order).  The one definition of the Poisson / Madow /
    fractional hit-accounting conventions, shared by the OGB and OMD scan
    engines so they cannot drift.

    ``madow_tree`` is the O(C log N) form of ``madow``: the same systematic
    sample drawn by prefix-tree descent
    (:func:`repro.kernels.prefix_tree.madow_sample_tree`) instead of an
    O(N) cumsum + mask — an equally valid draw from the same marginals, but
    not the bit-identical sample set (float32 tree sums associate
    differently), so the committed goldens stay on ``madow``."""
    fi = f[ids]
    reward = jnp.sum(fi)
    if sample == "poisson":
        # hits only need the requested coordinates: B-sized gathers, not an
        # N-sized mask; occupancy is the one remaining catalog pass
        hits = jnp.sum((fi >= p[ids]).astype(jnp.int32))
        occ = jnp.sum((f >= p).astype(jnp.float32))
    elif sample == "madow":
        cached = madow_sample_jax(f, u, capacity)
        hits = jnp.sum(cached[ids].astype(jnp.int32))
        occ = jnp.sum(cached.astype(jnp.float32))
    elif sample == "madow_tree":
        from repro.kernels.prefix_tree import madow_sample_tree

        sel = madow_sample_tree(f, u, capacity)  # (C,) ascending leaf ids
        pos = jnp.searchsorted(sel, ids)
        cached = sel[jnp.minimum(pos, capacity - 1)] == ids
        hits = jnp.sum(cached.astype(jnp.int32))
        occ = jnp.float32(capacity)
    else:
        hits = jnp.zeros((), jnp.int32)
        occ = jnp.sum(f)
    return reward, hits, occ


def opt_hits_by_combo(
    trace_prefix: np.ndarray, combos: "List[Dict[str, float]]"
) -> np.ndarray:
    """Hindsight static-OPT per combo, computed host-side once per capacity
    (OPT depends only on the trace histogram and C)."""
    from repro.core.regret import best_static_hits

    opt_by_c = {
        c: float(best_static_hits(trace_prefix, c))
        for c in set(int(combo["capacity"]) for combo in combos)
    }
    return np.asarray([opt_by_c[int(c["capacity"])] for c in combos])


class ReplayCarry(NamedTuple):
    """Scan carry: donated, lives on device for the whole replay."""

    f: jax.Array  # (N,) float32 fractional state
    tau: jax.Array  # () float32 previous chunk's projection threshold
    counts: jax.Array  # (N,) float32 whole-trace histogram (hindsight OPT)

    @staticmethod
    def create(catalog_size: int, capacity: int) -> "ReplayCarry":
        return ReplayCarry(
            f=jnp.full(catalog_size, capacity / catalog_size, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            counts=jnp.zeros(catalog_size, jnp.float32),
        )


def _make_ogb_step(
    sample: str,
    projection: str,
    sweeps: int,
    iters: int,
    track_opt: bool,
    madow_capacity: Optional[int] = None,
):
    """The per-chunk OGB_cl update, with *traced* eta and capacity.

    Shared by :func:`make_replay_fn` (capacity baked in as a constant) and
    the unified policy engine (:mod:`repro.cachesim.api`, capacity vmapped
    over a grid).  The chunk size B is read off ``ids.shape`` (static under
    scan); ``madow_capacity`` must be the static C when ``sample == "madow"``
    (Madow needs a static sample count).
    """
    if sample not in ("poisson", "madow", "madow_tree", "none"):
        raise ValueError(f"unknown sample mode {sample!r}")
    if projection not in ("warm", "bisect"):
        raise ValueError(f"unknown projection mode {projection!r}")
    if sample in MADOW_SAMPLES and madow_capacity is None:
        raise ValueError("madow sampling needs a static capacity")

    def step(eta, p, cap, carry, xs):
        f, tau_prev, counts_tot = carry
        ids, u = xs
        reward, hits, occ = sample_chunk_metrics(
            sample, madow_capacity, f, ids, p, u
        )
        # gradient step as a B-element scatter-add (duplicates accumulate);
        # avoids materializing a dense (N,) counts histogram per chunk
        y = f.at[ids].add(eta)
        if projection == "warm":
            hi = warm_bracket_hi(eta * jnp.float32(ids.shape[0]))
            f_new, tau = capped_simplex_project_warm(
                y, cap, jnp.float32(0.0), hi, tau_prev, sweeps
            )
        else:
            f_new, tau = capped_simplex_project(y, cap, iters)
        if track_opt:
            counts_tot = counts_tot.at[ids].add(1.0)
        return (
            ReplayCarry(f_new, tau, counts_tot),
            (reward, hits, tau, occ),
        )

    return step


@functools.lru_cache(maxsize=64)
def make_replay_fn(
    catalog_size: int,
    capacity: int,
    batch: int,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    track_opt: bool = True,
):
    """Build the jitted whole-trace replay.

    Returns ``replay(carry, chunks, eta, p, us) -> (carry', opt_hits, ys)``
    where ``chunks`` is (M, B) int32, ``p`` the (N,) permanent random numbers
    (Poisson sampling), ``us`` the (M,) Madow offsets (pass size-0 arrays for
    the unused one) and ``ys`` stacks per-chunk (reward, hits, tau,
    occupancy).  The carry is donated: call with a fresh ``ReplayCarry``.

    Memoized on its (hashable) configuration so repeat calls — e.g. the
    throughput benchmark's repeated timings — reuse the same jitted function
    and hence XLA's compilation cache instead of re-tracing every time.
    """
    cap_f = float(capacity)
    step = _make_ogb_step(
        sample, projection, sweeps, iters, track_opt,
        madow_capacity=capacity,
    )

    def replay(carry, chunks, eta, p, us):
        m = chunks.shape[0]
        if us.shape[0] != m:
            us = jnp.zeros((m,), jnp.float32)
        carry, ys = jax.lax.scan(
            lambda c, x: step(eta, p, jnp.float32(cap_f), c, x),
            carry,
            (chunks, us),
        )
        if track_opt:
            opt = jnp.sum(jax.lax.top_k(carry.counts, capacity)[0])
        else:
            opt = jnp.zeros((), jnp.float32)
        return carry, opt, ys

    return jax.jit(replay, donate_argnums=(0,))


def replay_trace(
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    batch: int,
    eta: Optional[float] = None,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    seed: int = 0,
    track_opt: bool = True,
    keep_final_f: bool = False,
    name: str = "OGB_scan",
) -> RunResult:
    """Replay a whole trace through the scan-compiled OGB_cl engine.

    .. deprecated::
        Use ``api.run(api.policy_def("ogb", ...), trace, N, C, window=batch)``
        (:mod:`repro.cachesim.api`).  This wrapper forwards there and keeps
        the legacy signature/result shape.  Poisson and fractional replays
        are numerically identical to the pre-unification engine; under
        ``sample="madow"`` the per-chunk offsets are now counter-derived
        from the carried key (the streaming-resume requirement), so madow
        hit *samples* come from a different — equally valid — random stream.
    """
    warnings.warn(
        "replay_trace is deprecated; use repro.cachesim.api.run("
        "policy_def('ogb'), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.cachesim import api

    opts = dict(sample=sample, projection=projection, sweeps=sweeps, iters=iters)
    if sample == "madow":
        opts["madow_capacity"] = int(capacity)
    res = api.run(
        api.policy_def("ogb", **opts),
        trace,
        catalog_size,
        capacity,
        window=batch,
        eta=eta,
        seed=seed,
        track_opt=track_opt,
        keep_carry=keep_final_f,  # legacy footprint: final state is opt-in
        name=name,
    )
    res.extras["sweeps"] = float(sweeps)
    return res


def sweep_replay(
    trace: np.ndarray,
    catalog_size: int,
    capacities: Sequence[int],
    etas: Sequence[Optional[float]] = (None,),
    seeds: Sequence[int] = (0,),
    batch: int = 1000,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    track_opt: bool = True,
) -> SweepResult:
    """Run the whole (seeds x etas x capacities) OGB grid in one dispatch.

    .. deprecated::
        Use ``api.sweep(api.policy_def("ogb", ...), trace, N, capacities,
        etas=..., seeds=..., window=batch)`` (:mod:`repro.cachesim.api`).
    """
    warnings.warn(
        "sweep_replay is deprecated; use repro.cachesim.api.sweep("
        "policy_def('ogb'), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.cachesim import api

    opts = dict(sample=sample, projection=projection, sweeps=sweeps, iters=iters)
    if sample == "madow":
        if len(set(int(c) for c in capacities)) > 1:
            raise ValueError(
                "madow sweeps need a single capacity (static sample count); "
                "use sample='poisson' for capacity grids"
            )
        opts["madow_capacity"] = int(capacities[0])
    return api.sweep(
        api.policy_def("ogb", **opts),
        trace,
        catalog_size,
        capacities,
        etas=etas,
        seeds=seeds,
        window=batch,
        track_opt=track_opt,
    )
