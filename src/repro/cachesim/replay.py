"""Device-resident whole-trace OGB_cl replay — one ``lax.scan``, zero host syncs.

The per-batch driver (``for batch: ogb_batch_update(...)``) pays a Python
dispatch + host round-trip per batch and a cold ~50-sweep bisection per
projection — at paper scale (millions of requests over million-item catalogs)
the harness reintroduces exactly the per-step overhead the paper's O(log N)
policy removes.  This engine compiles the *entire* replay into a single
``jax.lax.scan`` over ``(num_chunks, B)`` request chunks with a donated
carry, accumulating on device:

* fractional reward  sum_t f[r_t] (pre-update, OCO order),
* integral hits under coordinated Poisson or Madow sampling,
* per-chunk occupancy and projection threshold tau,
* the whole-trace request histogram, from which the hindsight-OPT reward
  (top-C counts) and hence regret are computed — still on device.

Nothing crosses the host boundary until the final metrics fetch.

The projection is *warm-started*: with a feasible pre-step state the per-chunk
threshold provably lies in [0, eta * B], and the previous chunk's tau seeds a
bracketed-Newton root-find (:func:`repro.jaxcache.fractional.
capped_simplex_project_warm`) that needs single-digit catalog sweeps instead
of ~50 cold bisection sweeps.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.jaxcache.fractional import (
    DEFAULT_BISECT_ITERS,
    DEFAULT_WARM_SWEEPS,
    capped_simplex_project,
    capped_simplex_project_warm,
    madow_sample_jax,
    permanent_random_numbers,
    warm_bracket_hi,
)


def sampling_arrays(
    seed: int, catalog_size: int, m: int, sample: str
) -> tuple:
    """Seed-derived (p, us): permanent random numbers for Poisson sampling
    and per-chunk Madow offsets.  The one derivation every replay flavor
    (OGB scan, OMD engine, vmapped sweeps) shares — size-0 placeholders for
    the unused mode."""
    k_p, k_u = jax.random.split(jax.random.key(seed))
    p = (
        permanent_random_numbers(k_p, catalog_size)
        if sample == "poisson"
        else jnp.zeros((0,), jnp.float32)
    )
    us = (
        jax.random.uniform(k_u, (m,), jnp.float32)
        if sample == "madow"
        else jnp.zeros((0,), jnp.float32)
    )
    return p, us


def sample_chunk_metrics(sample: str, capacity, f, ids, p, u):
    """(reward, hits, occupancy) for one request chunk at the pre-update
    state ``f`` (OCO order).  The one definition of the Poisson / Madow /
    fractional hit-accounting conventions, shared by the OGB and OMD scan
    engines so they cannot drift."""
    fi = f[ids]
    reward = jnp.sum(fi)
    if sample == "poisson":
        # hits only need the requested coordinates: B-sized gathers, not an
        # N-sized mask; occupancy is the one remaining catalog pass
        hits = jnp.sum((fi >= p[ids]).astype(jnp.int32))
        occ = jnp.sum((f >= p).astype(jnp.float32))
    elif sample == "madow":
        cached = madow_sample_jax(f, u, capacity)
        hits = jnp.sum(cached[ids].astype(jnp.int32))
        occ = jnp.sum(cached.astype(jnp.float32))
    else:
        hits = jnp.zeros((), jnp.int32)
        occ = jnp.sum(f)
    return reward, hits, occ


def find_combo(combos: "List[Dict[str, float]]", **match) -> int:
    """Row index of the sweep combo matching all given key/values."""
    for r, combo in enumerate(combos):
        if all(combo.get(k) == v for k, v in match.items()):
            return r
    raise KeyError(f"no combo matching {match}")


def opt_hits_by_combo(
    trace_prefix: np.ndarray, combos: "List[Dict[str, float]]"
) -> np.ndarray:
    """Hindsight static-OPT per combo, computed host-side once per capacity
    (OPT depends only on the trace histogram and C)."""
    from repro.core.regret import best_static_hits

    opt_by_c = {
        c: float(best_static_hits(trace_prefix, c))
        for c in set(int(combo["capacity"]) for combo in combos)
    }
    return np.asarray([opt_by_c[int(c["capacity"])] for c in combos])


class ReplayCarry(NamedTuple):
    """Scan carry: donated, lives on device for the whole replay."""

    f: jax.Array  # (N,) float32 fractional state
    tau: jax.Array  # () float32 previous chunk's projection threshold
    counts: jax.Array  # (N,) float32 whole-trace histogram (hindsight OPT)

    @staticmethod
    def create(catalog_size: int, capacity: int) -> "ReplayCarry":
        return ReplayCarry(
            f=jnp.full(catalog_size, capacity / catalog_size, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            counts=jnp.zeros(catalog_size, jnp.float32),
        )


def _make_ogb_step(
    batch: int,
    sample: str,
    projection: str,
    sweeps: int,
    iters: int,
    track_opt: bool,
    madow_capacity: Optional[int] = None,
):
    """The per-chunk OGB_cl update, with a *traced* capacity.

    Shared by :func:`make_replay_fn` (capacity baked in as a constant) and
    :func:`sweep_replay` (capacity vmapped over a grid).  ``madow_capacity``
    must be the static C when ``sample == "madow"`` (Madow needs a static
    sample count); the other modes treat capacity as data.
    """
    if sample not in ("poisson", "madow", "none"):
        raise ValueError(f"unknown sample mode {sample!r}")
    if projection not in ("warm", "bisect"):
        raise ValueError(f"unknown projection mode {projection!r}")
    if sample == "madow" and madow_capacity is None:
        raise ValueError("madow sampling needs a static capacity")

    def step(eta, p, cap, carry, xs):
        f, tau_prev, counts_tot = carry
        ids, u = xs
        reward, hits, occ = sample_chunk_metrics(
            sample, madow_capacity, f, ids, p, u
        )
        # gradient step as a B-element scatter-add (duplicates accumulate);
        # avoids materializing a dense (N,) counts histogram per chunk
        y = f.at[ids].add(eta)
        if projection == "warm":
            hi = warm_bracket_hi(eta * jnp.float32(batch))
            f_new, tau = capped_simplex_project_warm(
                y, cap, jnp.float32(0.0), hi, tau_prev, sweeps
            )
        else:
            f_new, tau = capped_simplex_project(y, cap, iters)
        if track_opt:
            counts_tot = counts_tot.at[ids].add(1.0)
        return (
            ReplayCarry(f_new, tau, counts_tot),
            (reward, hits, tau, occ),
        )

    return step


@functools.lru_cache(maxsize=64)
def make_replay_fn(
    catalog_size: int,
    capacity: int,
    batch: int,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    track_opt: bool = True,
):
    """Build the jitted whole-trace replay.

    Returns ``replay(carry, chunks, eta, p, us) -> (carry', opt_hits, ys)``
    where ``chunks`` is (M, B) int32, ``p`` the (N,) permanent random numbers
    (Poisson sampling), ``us`` the (M,) Madow offsets (pass size-0 arrays for
    the unused one) and ``ys`` stacks per-chunk (reward, hits, tau,
    occupancy).  The carry is donated: call with a fresh ``ReplayCarry``.

    Memoized on its (hashable) configuration so repeat calls — e.g.
    ``replay_trace`` in a sweep — reuse the same jitted function and hence
    XLA's compilation cache instead of re-tracing every time.
    """
    cap_f = float(capacity)
    step = _make_ogb_step(
        batch, sample, projection, sweeps, iters, track_opt,
        madow_capacity=capacity,
    )

    def replay(carry, chunks, eta, p, us):
        m = chunks.shape[0]
        if us.shape[0] != m:
            us = jnp.zeros((m,), jnp.float32)
        carry, ys = jax.lax.scan(
            lambda c, x: step(eta, p, jnp.float32(cap_f), c, x),
            carry,
            (chunks, us),
        )
        if track_opt:
            opt = jnp.sum(jax.lax.top_k(carry.counts, capacity)[0])
        else:
            opt = jnp.zeros((), jnp.float32)
        return carry, opt, ys

    return jax.jit(replay, donate_argnums=(0,))


@dataclass
class ReplayMetrics:
    """Host-side view of one replay (everything fetched in a single sync)."""

    name: str
    T: int  # requests actually replayed (num_chunks * batch)
    batch: int
    capacity: int
    frac_reward: np.ndarray  # (M,) per-chunk fractional reward
    hits: np.ndarray  # (M,) per-chunk integral hits
    taus: np.ndarray  # (M,) per-chunk projection threshold
    occupancy: np.ndarray  # (M,) per-chunk sampled-cache size
    opt_hits: float  # hindsight static-OPT reward over the replayed prefix
    final_f: Optional[np.ndarray] = None
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def hit_ratio(self) -> float:
        return float(self.hits.sum()) / max(self.T, 1)

    @property
    def frac_hit_ratio(self) -> float:
        return float(self.frac_reward.sum()) / max(self.T, 1)

    @property
    def regret(self) -> float:
        """Hindsight regret of the fractional (OCO) reward."""
        return self.opt_hits - float(self.frac_reward.sum())

    @property
    def integral_regret(self) -> float:
        return self.opt_hits - float(self.hits.sum())

    @property
    def us_per_request(self) -> float:
        return 1e6 * self.wall_seconds / max(self.T, 1)

    def windowed_hit_ratio(self, window: int) -> np.ndarray:
        """Hit ratio per non-overlapping window (rounded to whole chunks)."""
        per = max(window // self.batch, 1)
        m = (len(self.hits) // per) * per
        if m == 0:
            return np.array([self.hit_ratio])
        return self.hits[:m].reshape(-1, per).sum(axis=1) / (per * self.batch)

    def windowed_frac_ratio(self, window: int) -> np.ndarray:
        per = max(window // self.batch, 1)
        m = (len(self.frac_reward) // per) * per
        if m == 0:
            return np.array([self.frac_hit_ratio])
        return self.frac_reward[:m].reshape(-1, per).sum(axis=1) / (
            per * self.batch
        )


def replay_trace(
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    batch: int,
    eta: Optional[float] = None,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    seed: int = 0,
    track_opt: bool = True,
    keep_final_f: bool = False,
    name: str = "OGB_scan",
) -> ReplayMetrics:
    """Replay a whole trace through the scan-compiled OGB_cl engine.

    The trace is reshaped into ``(T // batch, batch)`` chunks (a trailing
    partial chunk is dropped, matching the per-batch driver).  ``eta`` defaults
    to the Theorem 3.1 tuning for the replayed horizon.
    """
    from repro.core.ogb import theoretical_eta  # cheap, avoids a cycle at import

    n_chunks = len(trace) // batch
    if n_chunks == 0:
        raise ValueError(f"trace shorter than one batch ({len(trace)} < {batch})")
    t_used = n_chunks * batch
    if eta is None:
        eta = theoretical_eta(capacity, catalog_size, t_used, 1)
    chunks = jnp.asarray(
        np.asarray(trace[:t_used]).reshape(n_chunks, batch), jnp.int32
    )

    p, us = sampling_arrays(seed, catalog_size, n_chunks, sample)

    fn = make_replay_fn(
        catalog_size,
        capacity,
        batch,
        sample=sample,
        projection=projection,
        sweeps=sweeps,
        iters=iters,
        track_opt=track_opt,
    )
    carry = ReplayCarry.create(catalog_size, capacity)
    t0 = time.perf_counter()
    carry, opt, (reward, hits, taus, occ) = fn(
        carry, chunks, jnp.float32(eta), p, us
    )
    jax.block_until_ready((carry.f, opt, reward, hits, taus, occ))
    wall = time.perf_counter() - t0

    return ReplayMetrics(
        name=name,
        T=t_used,
        batch=batch,
        capacity=capacity,
        frac_reward=np.asarray(reward, np.float64),
        hits=np.asarray(hits, np.int64),
        taus=np.asarray(taus, np.float64),
        occupancy=np.asarray(occ, np.float64),
        opt_hits=float(opt),
        final_f=np.asarray(carry.f) if keep_final_f else None,
        wall_seconds=wall,
        extras={"eta": float(eta), "sweeps": float(sweeps)},
    )


# ---------------------------------------------------------------------------
# vmapped scenario sweeps: (seeds x etas x capacities) in one device dispatch
# ---------------------------------------------------------------------------
@dataclass
class ReplaySweepResult:
    """Stacked OGB replays over a parameter grid (single final fetch)."""

    combos: List[Dict[str, float]]  # [{"capacity", "eta", "seed"}, ...]
    T: int
    batch: int
    frac_reward: np.ndarray  # (R, M)
    hits: np.ndarray  # (R, M)
    taus: np.ndarray  # (R, M)
    occupancy: np.ndarray  # (R, M)
    opt_hits: np.ndarray  # (R,) hindsight static-OPT per combo (host-side)
    wall_seconds: float = 0.0

    @property
    def hit_ratios(self) -> np.ndarray:
        return self.hits.sum(axis=1) / max(self.T, 1)

    @property
    def frac_hit_ratios(self) -> np.ndarray:
        return self.frac_reward.sum(axis=1) / max(self.T, 1)

    @property
    def regrets(self) -> np.ndarray:
        return self.opt_hits - self.frac_reward.sum(axis=1)

    def row(self, **match) -> int:
        return find_combo(self.combos, **match)


def sweep_replay(
    trace: np.ndarray,
    catalog_size: int,
    capacities: Sequence[int],
    etas: Sequence[Optional[float]] = (None,),
    seeds: Sequence[int] = (0,),
    batch: int = 1000,
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    track_opt: bool = True,
) -> ReplaySweepResult:
    """Run the whole (seeds x etas x capacities) OGB grid in one dispatch.

    Stacks one :class:`ReplayCarry` per combo and ``vmap``s the scan replay
    over the stack with the trace broadcast — the entire grid costs one
    compile + one device round-trip.  ``eta=None`` entries resolve to the
    Theorem 3.1 tuning for that combo's capacity.  OPT is computed host-side
    per capacity (it only depends on the trace histogram), so the device
    carries no per-combo count arrays beyond the shared replay state.
    """
    from repro.core.ogb import theoretical_eta

    m = len(trace) // batch
    if m == 0:
        raise ValueError(f"trace shorter than one batch ({len(trace)} < {batch})")
    t_used = m * batch
    chunks = jnp.asarray(
        np.asarray(trace[:t_used]).reshape(m, batch), jnp.int32
    )
    combos = [
        {
            "capacity": int(C),
            # eta=None resolves exactly like replay_trace's default (B=1
            # Theorem 3.1 tuning) so default-tuned sweep rows reproduce
            # default-tuned single replays
            "eta": float(
                eta
                if eta is not None
                else theoretical_eta(int(C), catalog_size, t_used, 1)
            ),
            "seed": int(s),
        }
        for s in seeds
        for eta in etas
        for C in capacities
    ]
    carry = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[ReplayCarry.create(catalog_size, c["capacity"]) for c in combos],
    )
    eta_arr = jnp.asarray([c["eta"] for c in combos], jnp.float32)
    cap_arr = jnp.asarray([c["capacity"] for c in combos], jnp.float32)
    per_combo = [
        sampling_arrays(c["seed"], catalog_size, m, sample) for c in combos
    ]
    if sample == "poisson":
        p = jnp.stack([pc[0] for pc in per_combo])
    else:
        p = jnp.zeros((len(combos), 1), jnp.float32)
    if sample == "madow":
        us = jnp.stack([pc[1] for pc in per_combo])
        if len(set(c["capacity"] for c in combos)) > 1:
            raise ValueError(
                "madow sweeps need a single capacity (static sample count); "
                "use sample='poisson' for capacity grids"
            )
        madow_capacity = int(capacities[0])
    else:
        us = jnp.zeros((len(combos), m), jnp.float32)
        madow_capacity = None
    step = _make_ogb_step(
        batch, sample, projection, sweeps, iters, track_opt=False,
        madow_capacity=madow_capacity,
    )

    def one(carry, eta, cap, p, us):
        return jax.lax.scan(
            lambda c, x: step(eta, p, cap, c, x), carry, (chunks, us)
        )

    vrun = jax.jit(
        jax.vmap(one, in_axes=(0, 0, 0, 0, 0)), donate_argnums=(0,)
    )
    compiled = vrun.lower(carry, eta_arr, cap_arr, p, us).compile()
    t0 = time.perf_counter()
    _carry, (reward, hits, taus, occ) = compiled(carry, eta_arr, cap_arr, p, us)
    jax.block_until_ready((reward, hits, taus, occ))
    wall = time.perf_counter() - t0
    opt = (
        opt_hits_by_combo(np.asarray(trace[:t_used]), combos)
        if track_opt
        else np.zeros(len(combos))
    )
    return ReplaySweepResult(
        combos=combos,
        T=t_used,
        batch=batch,
        frac_reward=np.asarray(reward, np.float64),
        hits=np.asarray(hits, np.int64),
        taus=np.asarray(taus, np.float64),
        occupancy=np.asarray(occ, np.float64),
        opt_hits=opt,
        wall_seconds=wall,
    )
