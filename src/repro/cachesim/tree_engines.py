"""Tree-backed device engines: the O(log N) automata of the paper.

The dense automata in :mod:`repro.cachesim.engines` pay O(C) vector work
per request (slot-wide compares and argmins) and the fractional replay pays
O(N) per chunk.  This module re-implements the eviction machinery on the
packed radix trees of :mod:`repro.kernels.prefix_tree`, turning the
per-request cost into O(R log_R ·) scatter/gather paths while staying
**bit-exact** against the dense steps (the differential tests in
``tests/cachesim/test_tree_policies.py`` compare hit sequences request by
request).

Three engines:

* **tree-LRU** — chunk-batched *reuse distance*: a request hits iff the
  number of distinct items since its previous occurrence is at most C-1,
  which is exactly LRU.  Marks (last occurrences) live on a ring of
  positions with a radix-16 count tree over them; a chunk of W requests is
  resolved with two batched prefix queries plus a (W, W) in-chunk dominance
  term, and the tree moves each distinct item's mark once per chunk.  When
  the ring fills, a rank-compaction keeps only the newest ``capacity``
  marks — exact, because a reuse window reaching past those marks already
  contains >= capacity distinct items (a certain miss either way), and
  dropped items re-enter as first-seen misses, which they would be.
* **tree-LFU / tree-FTPL** — per-request automata whose victim search is a
  lexicographic (hi, lo) min-tree over slots: (frequency, tick) for LFU,
  (sortable perturbed score, item id) for FTPL — the same eviction keys and
  tie-breaks as the dense steps, so hit sequences agree bit for bit.  All
  writes are *delayed* one request (applied at the start of the next step)
  so no gather reads a just-scattered array — the anti-dependency would
  otherwise force a full-array copy per request.

Per-chunk steps keep their pending writes in the **inner** scan carry and
flush them before returning, so the outer carry is window-independent —
the streaming/resume contract of :mod:`repro.cachesim.api` (two chunked
runs replay one full run bit for bit) holds for any window split.

FIFO stays dense: its eviction order is insertion time, which reuse
distances cannot express, and its O(C) step is already cheap.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ftpl import ftpl_initial_top_c, ftpl_noise, theoretical_zeta
from repro.kernels.prefix_tree import ops as pt

_I32_MAX = np.int32(np.iinfo(np.int32).max)

#: kinds with a tree-backed implementation (impl="tree" in the API layer)
TREE_ENGINE_KINDS = ("lru", "lfu", "ftpl")

#: radix of the position tree (LRU ring) — 16 lanes keep the sibling
#: gathers one vector register wide while the ring tree stays 4 levels deep
RING_RADIX = 16
#: radix of the slot min-trees (LFU/FTPL) — 64-wide groups make catalogs of
#: thousands of slots two levels deep
SLOT_RADIX = 64
#: sub-chunk width cap for the reuse-distance engine: the (W, W) in-chunk
#: dominance term is brute-force, and past ~128 it stops being free
MAX_SUBCHUNK = 128


# ---------------------------------------------------------------------------
# carries
# ---------------------------------------------------------------------------
class TreeLRUCarry(NamedTuple):
    """Reuse-distance LRU state (window-independent; pends live inner-scan)."""

    tree: jax.Array  # (TOT,) int32 packed radix-16 mark-count tree
    last: jax.Array  # (N+1,) int32 item -> ring position of last occurrence
    pos: jax.Array  # () int32 next free ring position
    nseen: jax.Array  # () int32 distinct items seen (occupancy = min(, cap))
    cap: jax.Array  # () int32 capacity (traced: sweeps stack it)


class TreeLFUCarry(NamedTuple):
    imap: jax.Array  # (N+1,) int32 item -> slot (-1 out; N is scratch)
    counts: jax.Array  # (N,) int32 perfect-LFU counters
    slots: jax.Array  # (K,) int32 slot -> item (-1 empty, -2 inactive)
    tree_hi: jax.Array  # (TOT,) int32 min-tree over slot frequencies
    tree_lo: jax.Array  # (TOT,) int32 min-tree over slot ticks
    t: jax.Array  # () int32


class TreeFTPLCarry(NamedTuple):
    imap: jax.Array  # (N+1,) int32 item -> slot (-1 out; N is scratch)
    counts: jax.Array  # (N,) int32 request counters
    noise: jax.Array  # (N,) float32 one-shot perturbation (constant)
    slots: jax.Array  # (K,) int32 slot -> item (-2 inactive)
    tree_hi: jax.Array  # (TOT,) int32 min-tree over sortable scores
    tree_lo: jax.Array  # (TOT,) int32 min-tree over slot item ids


# ---------------------------------------------------------------------------
# geometry helpers
# ---------------------------------------------------------------------------
def ring_size(n_slots: int) -> int:
    """Ring length: power of two with >= 4x slack over the kept-mark count
    (compaction keeps at most ``capacity`` marks).  The floor is generous —
    each compaction pays an argsort over the catalog, so headroom buys
    throughput directly (8192 vs 65536 measured 2x on the bench trace) and
    the tree costs only ~level-sum(m) int32s."""
    m = 65536
    while m < 4 * int(n_slots):
        m *= 2
    return m


def _pick_subchunk(window: int) -> int:
    """Largest divisor of ``window`` that is <= MAX_SUBCHUNK, preferring
    16-aligned widths (aligned sub-chunks take the cheap grouped-insert
    path: ~2x fewer scatter elements per request)."""
    best, best_aligned = 1, 1
    for d in range(1, min(window, MAX_SUBCHUNK) + 1):
        if window % d == 0:
            best = d
            if d % RING_RADIX == 0:
                best_aligned = d
    return best_aligned if best_aligned > 1 else best


# ---------------------------------------------------------------------------
# tree-LRU: chunk-batched reuse distance
# ---------------------------------------------------------------------------
def init_tree_lru_carry(catalog_size: int, capacity: int,
                        n_slots: Optional[int] = None,
                        ring: Optional[int] = None) -> TreeLRUCarry:
    k = int(n_slots) if n_slots else int(capacity)
    m = int(ring) if ring else ring_size(k)
    if m & (m - 1) or m < 4 * k:
        raise ValueError(
            f"ring must be a power of two >= 4 * n_slots, got {m} for {k}"
        )
    return TreeLRUCarry(
        tree=jnp.zeros(pt.tree_storage(m, RING_RADIX), jnp.int32),
        last=jnp.full(catalog_size + 1, -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        nseen=jnp.zeros((), jnp.int32),
        cap=jnp.int32(capacity),
    )


@functools.lru_cache(maxsize=None)
def make_lru_tree_chunk(catalog_size: int, m: int,
                        return_flags: bool = False):
    """Chunk step ``(carry, ids(window,)) -> (carry, (hits, occ))`` for the
    reuse-distance engine; the sub-chunk width W is derived from the traced
    chunk shape, so one factory serves every window.  ``return_flags=True``
    replaces the hit count with the (window,) per-request flags (the inner
    scan's (n_sub, w) flag rows, flattened back to request order)."""
    radix = RING_RADIX
    sh = radix.bit_length() - 1
    offs = pt.tree_offsets(m, radix)
    nlev = len(offs)

    def compact(tree, last, pos, cap):
        # rank-remap marks to [0, kept); drop all but the newest `cap`
        # marks (exact: a reuse window reaching past them holds >= cap
        # marks, a certain miss, and dropped items re-enter as first-seen)
        nmarks = jnp.sum(last >= 0, dtype=jnp.int32)
        kept = jnp.minimum(nmarks, cap)
        key = jnp.where(last >= 0, last, _I32_MAX)
        order = jnp.argsort(key)
        ranks = jnp.zeros_like(key).at[order].set(
            jnp.arange(key.shape[0], dtype=jnp.int32)
        )
        newrank = ranks - (nmarks - kept)
        newlast = jnp.where((last >= 0) & (newrank >= 0), newrank, -1)
        leaf = (jnp.arange(m, dtype=jnp.int32) < kept).astype(jnp.int32)
        tree = pt.tree_build(leaf, radix)
        npos = (kept + radix - 1) & ~(radix - 1)  # 16-aligned restart
        return tree, newlast, npos

    def chunk(carry, ids):
        window = ids.shape[0]
        # compaction runs (at most) once per *chunk*, not per sub-chunk:
        # after it, pos <= aligned(cap) <= m/4 + 16, so a whole window of
        # inserts fits.  Keeping the cond out of the inner scan matters
        # under vmap (sweeps), where a batched cond executes both branches
        # — per sub-chunk that would pay the argsort every step.
        if window > 3 * (m // 4) - RING_RADIX:
            raise ValueError(
                f"window {window} too large for ring {m}; pass a larger "
                f"ring= to init (need window <= 3*ring/4 - {RING_RADIX})"
            )
        w = _pick_subchunk(window)
        aligned = w % radix == 0
        if aligned:
            npend = w * nlev + w + (nlev - 1) * (w // radix)
        else:
            npend = w * nlev * 2
        eye = jnp.eye(w, dtype=bool)
        lanes = jnp.arange(w, dtype=jnp.int32)

        def substep(st, sub_ids):
            tree, last, pos, nseen, cap, pn, pd, pli, plv = st
            # delayed writes: apply the previous sub-chunk's tree deltas
            # and mark moves before reading anything
            tree = tree.at[pn].add(pd)
            last = last.at[pli].max(plv)
            kpos = pos + lanes
            lastg = last[sub_ids]
            eq = sub_ids[None, :] == sub_ids[:, None]
            lower = kpos[None, :] < kpos[:, None]
            prev_in = jnp.max(jnp.where(eq & lower, kpos[None, :], -1), axis=1)
            prevp = jnp.where(prev_in >= 0, prev_in, lastg)
            islast = ~jnp.any(eq & ~lower & ~eye, axis=1)
            # d(i) = tree marks in (prev(i), chunk start) + in-chunk firsts
            # in (prev(i), i) — the dominance term, brute (W, W)
            base = pt.tree_prefix(
                tree, m, radix, jnp.full((1,), pos - 1, jnp.int32)
            )[0]
            dpre = base - pt.tree_prefix(
                tree, m, radix, jnp.minimum(prevp, pos - 1)
            )
            dom = (
                (prevp[None, :] <= prevp[:, None])
                & (kpos[None, :] > prevp[:, None])
                & lower
            )
            d = dpre + jnp.sum(dom, axis=1, dtype=jnp.int32)
            hit = (prevp >= 0) & (d <= cap - 1)
            nseen = nseen + jnp.sum(prevp < 0, dtype=jnp.int32)

            # plan next sub-chunk's writes: remove pre-chunk marks that
            # moved, insert marks at last in-chunk occurrences
            rm = jnp.where((lastg >= 0) & (prev_in < 0), lastg, -1)
            rm_nodes, rm_deltas, node = [], [], rm
            for l in range(nlev):
                ok = rm >= 0
                rm_nodes.append(jnp.where(ok, offs[l] + node, 0))
                rm_deltas.append(jnp.where(ok, jnp.int32(-1), 0))
                node = node >> sh
            ins = islast.astype(jnp.int32)
            if aligned:
                # leaf groups are complete (pos and W both 16-aligned):
                # exact level-1 deltas via reshape; higher levels scatter
                # the same (W/16,) deltas at ancestor nodes (duplicate
                # indices accumulate across group boundaries)
                g1 = ins.reshape(-1, radix).sum(1, dtype=jnp.int32)
                gids = (pos >> sh) + jnp.arange(g1.shape[0], dtype=jnp.int32)
                node = gids
                ins_nodes, ins_deltas = [], []
                for l in range(1, nlev):
                    ins_nodes.append(offs[l] + node)
                    ins_deltas.append(g1)
                    node = node >> sh
                pn = jnp.concatenate([*rm_nodes, kpos] + ins_nodes)
                pd = jnp.concatenate([*rm_deltas, ins] + ins_deltas)
            else:
                node = kpos
                ins_nodes, ins_deltas = [], []
                for l in range(nlev):
                    ins_nodes.append(offs[l] + node)
                    ins_deltas.append(ins)
                    node = node >> sh
                pn = jnp.concatenate(rm_nodes + ins_nodes)
                pd = jnp.concatenate(rm_deltas + ins_deltas)
            pli, plv = sub_ids, kpos
            st = (tree, last, pos + w, nseen, cap, pn, pd, pli, plv)
            return st, hit

        tree, last, pos = jax.lax.cond(
            carry.pos + window > m,
            lambda a: compact(a[0], a[1], a[2], carry.cap),
            lambda a: a,
            (carry.tree, carry.last, carry.pos),
        )
        # pend arrays are inner-scan state only, flushed before returning,
        # so the outer carry does not depend on the window split
        st = (
            tree, last, pos, carry.nseen, carry.cap,
            jnp.zeros(npend, jnp.int32), jnp.zeros(npend, jnp.int32),
            jnp.zeros(w, jnp.int32), jnp.full(w, -1, jnp.int32),
        )
        st, hits = jax.lax.scan(substep, st, ids.reshape(-1, w))
        tree, last, pos, nseen, cap, pn, pd, pli, plv = st
        tree = tree.at[pn].add(pd)
        last = last.at[pli].max(plv)
        out = TreeLRUCarry(tree, last, pos, nseen, cap)
        if return_flags:
            return out, (hits.reshape(-1), jnp.minimum(nseen, cap))
        nhits = jnp.sum(hits.astype(jnp.int32))
        return out, (nhits, jnp.minimum(nseen, cap))

    return chunk


# ---------------------------------------------------------------------------
# tree-LFU / tree-FTPL: delayed-write min-pair automata
# ---------------------------------------------------------------------------
def _slot_tot(k: int) -> int:
    return pt.tree_storage(k, SLOT_RADIX)


def init_tree_lfu_carry(catalog_size: int, capacity: int,
                        n_slots: Optional[int] = None) -> TreeLFUCarry:
    k = int(n_slots) if n_slots else int(capacity)
    c = int(capacity)
    hi = np.full(k, _I32_MAX, np.int32)
    lo = np.full(k, _I32_MAX, np.int32)
    hi[:c] = -1  # empty slots: freq -1 sorts below any real frequency
    lo[:c] = -1
    th, tl = pt.minpair_build(jnp.asarray(hi), jnp.asarray(lo), SLOT_RADIX)
    slots = np.full(k, -2, np.int32)
    slots[:c] = -1
    return TreeLFUCarry(
        imap=jnp.full(catalog_size + 1, -1, jnp.int32),
        counts=jnp.zeros(catalog_size, jnp.int32),
        slots=jnp.asarray(slots),
        tree_hi=th,
        tree_lo=tl,
        t=jnp.zeros((), jnp.int32),
    )


def init_tree_ftpl_carry(catalog_size: int, capacity: int,
                         n_slots: Optional[int] = None, *, seed: int = 0,
                         zeta: Optional[float] = None,
                         horizon: Optional[int] = None) -> TreeFTPLCarry:
    k = int(n_slots) if n_slots else int(capacity)
    c = int(capacity)
    if zeta is None:
        if horizon is None:
            raise ValueError("ftpl needs zeta or horizon")
        zeta = theoretical_zeta(c, catalog_size, horizon)
    noise = ftpl_noise(catalog_size, zeta, seed=seed)
    top = ftpl_initial_top_c(noise, c).astype(np.int32)
    slots = np.full(k, -2, np.int32)
    slots[:c] = top
    imap = np.full(catalog_size + 1, -1, np.int32)
    imap[top] = np.arange(c, dtype=np.int32)
    hi = np.full(k, _I32_MAX, np.int32)
    lo = np.full(k, _I32_MAX, np.int32)
    hi[:c] = np.asarray(
        pt.sortable_f32(jnp.asarray(noise[top], jnp.float32))
    )
    lo[:c] = top
    th, tl = pt.minpair_build(jnp.asarray(hi), jnp.asarray(lo), SLOT_RADIX)
    return TreeFTPLCarry(
        imap=jnp.asarray(imap),
        counts=jnp.zeros(catalog_size, jnp.int32),
        noise=jnp.asarray(noise),
        slots=jnp.asarray(slots),
        tree_hi=th,
        tree_lo=tl,
    )


def _wrap_pend_chunk(substep, pack, unpack, return_flags: bool = False):
    """Build ``chunk(carry, ids)`` from a delayed-write per-request substep:
    pending writes ride the inner carry and are flushed before returning.
    ``return_flags=True`` emits the per-request hit flags instead of their
    sum (the sized runs weight each hit by the requested item's bytes)."""

    def chunk(carry, ids):
        st = pack(carry)
        st, hits = jax.lax.scan(substep, st, ids)
        carry = unpack(st)
        if return_flags:
            return carry, hits
        return carry, jnp.sum(hits.astype(jnp.int32))

    return chunk


@functools.lru_cache(maxsize=None)
def make_lfu_tree_chunk(catalog_size: int, k: int,
                        return_flags: bool = False):
    n = catalog_size
    radix = SLOT_RADIX
    offs = pt.tree_offsets(k, radix)

    def substep(st, j):
        (imap, counts, slots, th, tl, t,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)

        slot = imap[j]
        hit = slot >= 0
        f = counts[j] + 1  # the dense step increments before keying
        root_hi, _ = pt.minpair_root(th, tl, k, radix)
        victim = pt.minpair_argmin(th, tl, k, radix).astype(jnp.int32)
        idx = jnp.where(hit, slot, victim)
        # admission: the newcomer must match the victim's frequency
        write = jnp.logical_or(hit, f >= root_hi)
        old = slots[idx]
        new_hi = jnp.where(write, f, th[idx])  # no-op plan when not writing
        new_lo = jnp.where(write, t, tl[idx])
        pti, pth, ptl = pt.minpair_update_plan(th, tl, k, radix, idx,
                                               new_hi, new_lo)
        pci, pcd = j, jnp.int32(1)
        psi = idx
        psv = jnp.where(write, j, old)
        mo = jnp.where(write & (old >= 0) & (old != j), old, n)  # n: scratch
        mj = jnp.where(write, j, n)
        pii = jnp.stack([mo, mj])
        piv = jnp.stack([jnp.int32(-1), idx])
        st = (imap, counts, slots, th, tl, t + 1,
              pci, pcd, pii, piv, psi, psv, pti, pth, ptl)
        return st, hit

    def pack(c: TreeLFUCarry):
        return (
            c.imap, c.counts, c.slots, c.tree_hi, c.tree_lo, c.t,
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.full(2, n, jnp.int32), jnp.full(2, -1, jnp.int32),
            jnp.zeros((), jnp.int32), c.slots[0],
            jnp.asarray(offs, jnp.int32), c.tree_hi[jnp.asarray(offs)],
            c.tree_lo[jnp.asarray(offs)],
        )

    def unpack(st):
        (imap, counts, slots, th, tl, t,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)
        return TreeLFUCarry(imap, counts, slots, th, tl, t)

    return _wrap_pend_chunk(substep, pack, unpack, return_flags)


@functools.lru_cache(maxsize=None)
def make_ftpl_tree_chunk(catalog_size: int, k: int,
                         return_flags: bool = False):
    n = catalog_size
    radix = SLOT_RADIX
    offs = pt.tree_offsets(k, radix)

    def substep(st, j):
        (imap, counts, noise, slots, th, tl,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)

        slot = imap[j]
        hit = slot >= 0
        s = (counts[j] + 1).astype(jnp.float32) + noise[j]
        skey = pt.sortable_f32(s)
        root_hi, _ = pt.minpair_root(th, tl, k, radix)
        victim = pt.minpair_argmin(th, tl, k, radix).astype(jnp.int32)
        # strict >, like the dense step; sortable_f32 preserves float order
        swap = jnp.logical_and(~hit, skey > root_hi)
        idx = jnp.where(hit, slot, victim)
        upd = jnp.logical_or(hit, swap)  # a hit refreshes its slot's score
        old = slots[idx]
        new_hi = jnp.where(upd, skey, th[idx])
        new_lo = jnp.where(upd, j, tl[idx])
        pti, pth, ptl = pt.minpair_update_plan(th, tl, k, radix, idx,
                                               new_hi, new_lo)
        pci, pcd = j, jnp.int32(1)
        psi = idx
        psv = jnp.where(upd, j, old)
        mo = jnp.where(swap & (old >= 0), old, n)  # n: scratch index
        mj = jnp.where(swap, j, n)
        pii = jnp.stack([mo, mj])
        piv = jnp.stack([jnp.int32(-1), idx])
        st = (imap, counts, noise, slots, th, tl,
              pci, pcd, pii, piv, psi, psv, pti, pth, ptl)
        return st, hit

    def pack(c: TreeFTPLCarry):
        return (
            c.imap, c.counts, c.noise, c.slots, c.tree_hi, c.tree_lo,
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.full(2, n, jnp.int32), jnp.full(2, -1, jnp.int32),
            jnp.zeros((), jnp.int32), c.slots[0],
            jnp.asarray(offs, jnp.int32), c.tree_hi[jnp.asarray(offs)],
            c.tree_lo[jnp.asarray(offs)],
        )

    def unpack(st):
        (imap, counts, noise, slots, th, tl,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)
        return TreeFTPLCarry(imap, counts, noise, slots, th, tl)

    return _wrap_pend_chunk(substep, pack, unpack, return_flags)


# ---------------------------------------------------------------------------
# tree-GDS: GreedyDual-Size on the min-pair eviction trees
# ---------------------------------------------------------------------------
class TreeGDSCarry(NamedTuple):
    """GreedyDual-Size (Cao & Irani 1997) automaton state.

    Size-normalized eviction keys: every resident item carries a priority
    H_i = L + cost_i / size_i where L is the global inflation value (the
    last evicted item's H), so small/costly objects survive longer.  The
    victim search is the same lexicographic min-pair tree as LFU/FTPL with
    (sortable H, item id) keys — the id tie-break matches the host oracle's
    sorted-store ``(key, item)`` ordering.  Capacity is slot-based (like
    the host ``core.policies.GDS``); sizes shape the *priorities* and the
    byte-hit accounting, not the occupancy constraint.
    """

    imap: jax.Array  # (N+1,) int32 item -> slot (-1 out; N is scratch)
    hval: jax.Array  # (K,) float32 slot -> current H (reads L back as float)
    L: jax.Array  # () float32 global inflation value
    prio: jax.Array  # (N,) float32 per-item cost_i / size_i increments
    szs: jax.Array  # (N,) float32 per-item sizes (byte accounting; 1 = unit)
    slots: jax.Array  # (K,) int32 slot -> item (-1 empty, -2 inactive)
    tree_hi: jax.Array  # (TOT,) int32 min-tree over sortable H
    tree_lo: jax.Array  # (TOT,) int32 min-tree over slot item ids


def init_tree_gds_carry(
    catalog_size: int,
    capacity: int,
    n_slots: Optional[int] = None,
    *,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
) -> TreeGDSCarry:
    n = int(catalog_size)
    k = int(n_slots) if n_slots else int(capacity)
    c = int(capacity)
    s = np.ones(n, np.float32) if sizes is None else np.asarray(
        sizes, np.float32
    )
    w = np.ones(n, np.float32) if costs is None else np.asarray(
        costs, np.float32
    )
    if s.shape != (n,) or w.shape != (n,):
        raise ValueError(f"sizes/costs must be ({n},) arrays")
    if not (np.all(np.isfinite(s)) and s.min() > 0.0):
        raise ValueError("gds sizes must be finite and > 0")
    if not (np.all(np.isfinite(w)) and w.min() > 0.0):
        raise ValueError("gds costs must be finite and > 0")
    hi = np.full(k, _I32_MAX, np.int32)
    lo = np.full(k, _I32_MAX, np.int32)
    hi[:c] = -1  # empty slots sort below any real H (sortable(H>0) > 0)
    lo[:c] = -1
    th, tl = pt.minpair_build(jnp.asarray(hi), jnp.asarray(lo), SLOT_RADIX)
    slots = np.full(k, -2, np.int32)
    slots[:c] = -1
    return TreeGDSCarry(
        imap=jnp.full(n + 1, -1, jnp.int32),
        hval=jnp.zeros(k, jnp.float32),
        L=jnp.zeros((), jnp.float32),
        prio=jnp.asarray(w / s),
        szs=jnp.asarray(s),
        slots=jnp.asarray(slots),
        tree_hi=th,
        tree_lo=tl,
    )


@functools.lru_cache(maxsize=None)
def make_gds_tree_chunk(catalog_size: int, k: int,
                        return_flags: bool = False):
    n = catalog_size
    radix = SLOT_RADIX
    offs = pt.tree_offsets(k, radix)

    def substep(st, j):
        (imap, hval, L, prio, szs, slots, th, tl,
         pii, piv, psi, psv, phi, phv, pti, pth, ptl) = st
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        hval = hval.at[phi].set(phv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)

        slot = imap[j]
        hit = slot >= 0
        victim = pt.minpair_argmin(th, tl, k, radix).astype(jnp.int32)
        idx = jnp.where(hit, slot, victim)
        old = slots[idx]
        # host order: evict first (L <- H_min of a *real* victim), then
        # key the newcomer off the updated L.  Empty-slot fills and hits
        # leave L unchanged.
        evict = jnp.logical_and(~hit, old >= 0)
        L = jnp.where(evict, hval[idx], L)
        h = L + prio[j]
        pti, pth, ptl = pt.minpair_update_plan(
            th, tl, k, radix, idx, pt.sortable_f32(h), j
        )
        psi, psv = idx, j
        phi, phv = idx, h
        mo = jnp.where(evict, old, n)  # n: scratch index
        pii = jnp.stack([mo, j])
        piv = jnp.stack([jnp.int32(-1), idx])
        st = (imap, hval, L, prio, szs, slots, th, tl,
              pii, piv, psi, psv, phi, phv, pti, pth, ptl)
        return st, hit

    def pack(c: TreeGDSCarry):
        return (
            c.imap, c.hval, c.L, c.prio, c.szs, c.slots,
            c.tree_hi, c.tree_lo,
            jnp.full(2, n, jnp.int32), jnp.full(2, -1, jnp.int32),
            jnp.zeros((), jnp.int32), c.slots[0],
            jnp.zeros((), jnp.int32), c.hval[0],
            jnp.asarray(offs, jnp.int32), c.tree_hi[jnp.asarray(offs)],
            c.tree_lo[jnp.asarray(offs)],
        )

    def unpack(st):
        (imap, hval, L, prio, szs, slots, th, tl,
         pii, piv, psi, psv, phi, phv, pti, pth, ptl) = st
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        hval = hval.at[phi].set(phv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)
        return TreeGDSCarry(imap, hval, L, prio, szs, slots, th, tl)

    return _wrap_pend_chunk(substep, pack, unpack, return_flags)


# ---------------------------------------------------------------------------
# lazy bucketized OGB: O(B log V) per chunk, independent of the catalog size
# ---------------------------------------------------------------------------
#: bucket count of the value histogram the lazy projection solves over
OGB_TREE_BUCKETS = 65536
#: radix of the bucket count/sum trees
OGB_TREE_RADIX = 64
#: bisection iterations of the per-chunk threshold solve
OGB_TREE_ITERS = 30
#: grid headroom factor: the value grid spans ~2*GAIN chunk-updates of rho
#: growth before a re-anchor pass is needed
OGB_TREE_GAIN = 8.0


class OGBTreeCarry(NamedTuple):
    """Lazy OGB state: absolute accumulated values + cumulative threshold.

    The dense replay projects the whole catalog every chunk.  Here the
    state is the *unprojected* accumulation ``y`` with ``f = clip(y - rho,
    0, 1)`` implicit, and the per-chunk projection becomes a scalar solve
    of ``mass(rho) = sum_b cnt_b * clip(mean_b - rho, 0, 1) = C`` over a
    V-bucket histogram of ``y`` kept in packed radix trees — the chunk
    touches O(B log V) tree nodes, never the catalog.
    """

    y: jax.Array  # (N,) float32 accumulated values (f = clip(y - rho, 0, 1))
    rho: jax.Array  # () float32 cumulative projection threshold
    eta: jax.Array  # () float32
    cap: jax.Array  # () float32
    p: jax.Array  # (N,) float32 permanent random numbers, or (0,)
    w: jax.Array  # () float32 bucket width of the value grid
    scratch: jax.Array  # (N,) int32 first-occurrence dedup scratch (I32_MAX)
    ycnt: jax.Array  # (TOT,) float32 bucket-count tree over y
    ysum: jax.Array  # (TOT,) float32 bucket-sum tree over y
    dcnt: jax.Array  # (TOT,) float32 bucket-count tree over y - p, or (0,)


def _ogb_bucket(x, wv, v: int):
    """Grid bucket of value ``x``: the grid covers [-1, v*w - 1) so both y
    (>= 0) and y - p (> -1) share it."""
    b = jnp.floor((x + 1.0) / wv).astype(jnp.int32)
    return jnp.clip(b, 0, v - 1)


def init_ogb_tree_carry(
    catalog_size: int,
    capacity: int,
    *,
    eta: float,
    seed: int = 0,
    sample: str = "poisson",
    buckets: int = OGB_TREE_BUCKETS,
    radix: int = OGB_TREE_RADIX,
    batch_hint: int = 4096,
) -> OGBTreeCarry:
    """Initial carry at the uniform feasible state f = C/N.

    ``batch_hint`` sizes the value grid: headroom for ~2*OGB_TREE_GAIN
    chunks of worst-case rho growth (eta*B per chunk) between re-anchor
    passes.  A larger actual window than the hint is still correct — the
    re-anchor trigger watches the real chunk size — it just re-anchors
    more often."""
    from repro.cachesim.replay import sampling_keys

    n, v = int(catalog_size), int(buckets)
    span = 1.0 + 2.0 * OGB_TREE_GAIN * max(1.0, float(eta) * batch_hint)
    wv = (span + 1.0) / v
    y0 = float(capacity) / n
    p, _ = sampling_keys(seed, n, sample)
    b0 = int(np.clip(np.floor((y0 + 1.0) / wv), 0, v - 1))
    cnt_leaf = np.zeros(v, np.float32)
    cnt_leaf[b0] = n
    sum_leaf = np.zeros(v, np.float32)
    sum_leaf[b0] = n * y0
    ycnt = pt.tree_build(jnp.asarray(cnt_leaf), radix)
    ysum = pt.tree_build(jnp.asarray(sum_leaf), radix)
    if sample == "poisson":
        d0 = y0 - np.asarray(p, np.float64)
        db = np.clip(np.floor((d0 + 1.0) / wv), 0, v - 1).astype(np.int64)
        dcnt = pt.tree_build(
            jnp.asarray(np.bincount(db, minlength=v), jnp.float32), radix
        )
    else:
        dcnt = jnp.zeros((0,), jnp.float32)
    return OGBTreeCarry(
        y=jnp.full(n, y0, jnp.float32),
        rho=jnp.zeros((), jnp.float32),
        eta=jnp.float32(eta),
        cap=jnp.float32(capacity),
        p=p,
        w=jnp.float32(wv),
        scratch=jnp.full(n, _I32_MAX, jnp.int32),
        ycnt=ycnt,
        ysum=ysum,
        dcnt=dcnt,
    )


@functools.lru_cache(maxsize=None)
def make_ogb_tree_chunk(catalog_size: int, v: int, radix: int, sample: str,
                        iters: int = OGB_TREE_ITERS):
    """Per-chunk lazy OGB step ``(carry, ids) -> (carry, (reward, hits,
    dtau, occ))``.

    Exactness notes (vs the dense chained projection):

    * the gradient step, hit accounting and reward are exact (B gathers of
      ``clip(y - rho, 0, 1)``);
    * the threshold solve uses the bucket mean-clip mass — exact except for
      the <= 2 buckets straddling ``rho`` and ``rho + 1``, so rho carries
      an O(bucket width) quantization;
    * the upper clip ``y <- min(y, 1 + rho)`` is applied to an item only
      when it is touched, so an item far above the cap decays a little
      later than in the dense replay (bounded by its last chunk's eta
      mass).  The differential test bounds the combined drift.
    """
    poisson = sample == "poisson"

    def mass_at(ycnt, ysum, wv, total, t):
        """sum_b cnt_b * clip(mean_b - t, 0, 1) via O(log V) tree reads."""
        k0 = _ogb_bucket(t, wv, v)
        k1 = _ogb_bucket(t + 1.0, wv, v)
        qc = pt.tree_prefix(ycnt, v, radix, jnp.stack([k0, k1]))
        qs = pt.tree_prefix(ysum, v, radix, jnp.stack([k0, k1]))
        cb = jnp.stack([ycnt[k0], ycnt[k1]])
        sb = jnp.stack([ysum[k0], ysum[k1]])
        # buckets above k1 are entirely past t+1: full mass
        above = total - qc[1]
        # buckets strictly between k0 and k1 lie in the linear clip region
        mid_c = qc[1] - cb[1] - qc[0]
        mid_s = qs[1] - sb[1] - qs[0]
        mid = mid_s - t * mid_c
        # boundary buckets: mean-clip approximation
        mean = jnp.where(cb > 0, sb / jnp.maximum(cb, 1.0), 0.0)
        bnd = cb * jnp.clip(mean - t, 0.0, 1.0)
        return above + mid + bnd[0] + jnp.where(k1 > k0, bnd[1], 0.0)

    def chunk(carry, ids):
        b = ids.shape[0]
        y, rho, eta, cap = carry.y, carry.rho, carry.eta, carry.cap
        p, wv, scratch = carry.p, carry.w, carry.scratch
        ycnt, ysum, dcnt = carry.ycnt, carry.ysum, carry.dcnt
        lanes = jnp.arange(b, dtype=jnp.int32)

        # --- metrics at the pre-update state (OCO order), O(B) gathers ---
        fi = jnp.clip(y[ids] - rho, 0.0, 1.0)
        reward = jnp.sum(fi)
        if poisson:
            hits = jnp.sum((fi >= p[ids]).astype(jnp.int32))
            # occupancy #{y - p >= rho} from the d-tree: suffix count above
            # rho's bucket (quantized at the boundary bucket)
            dtot = pt.tree_total(dcnt, v, radix)
            occ = dtot - pt.tree_prefix(
                dcnt, v, radix, _ogb_bucket(rho, wv, v)[None]
            )[0]
        else:
            hits = jnp.zeros((), jnp.int32)
            occ = cap

        # --- first-occurrence mask (dedup without sorting) ---
        a = scratch.at[ids].min(lanes)
        first = a[ids] == lanes
        scratch = a.at[ids].set(_I32_MAX)  # restore

        # --- gradient step: upper-clip touched items, add eta per request ---
        yold = y[ids]
        y = y.at[ids].min(1.0 + rho)
        y = y.at[ids].add(eta)
        ynew = y[ids]

        # --- move touched items between buckets (one per distinct item) ---
        bo = jnp.where(first, _ogb_bucket(yold, wv, v), -1)
        bn = jnp.where(first, _ogb_bucket(ynew, wv, v), -1)
        didx = jnp.concatenate([bo, bn])
        ones = jnp.ones(b, jnp.float32)
        ycnt = pt.tree_update(ycnt, v, radix, didx,
                              jnp.concatenate([-ones, ones]))
        ysum = pt.tree_update(
            ysum, v, radix, didx,
            jnp.concatenate([
                jnp.where(first, -yold, 0.0), jnp.where(first, ynew, 0.0)
            ]),
        )
        if poisson:
            do = jnp.where(first, _ogb_bucket(yold - p[ids], wv, v), -1)
            dn = jnp.where(first, _ogb_bucket(ynew - p[ids], wv, v), -1)
            dcnt = pt.tree_update(dcnt, v, radix,
                                  jnp.concatenate([do, dn]),
                                  jnp.concatenate([-ones, ones]))

        # --- scalar threshold solve: bisect on the warm bracket ---
        total = pt.tree_total(ycnt, v, radix)
        # rho* - rho <= eta*B (chained-projection bound); the 4w floor keeps
        # the bracket wider than the mass quantization when eta*B < w
        hi0 = rho + jnp.maximum(eta * jnp.float32(b), 4.0 * wv)

        def bis(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            m = mass_at(ycnt, ysum, wv, total, mid)
            return jnp.where(m >= cap, mid, lo), jnp.where(m >= cap, hi, mid)

        rho_new, _ = jax.lax.fori_loop(0, iters, bis, (rho, hi0))

        # --- re-anchor when the next chunk could outgrow the value grid ---
        gridtop = wv * jnp.float32(v) - 1.0

        def reanchor(args):
            y, rho_new, ycnt, ysum, dcnt = args
            y = jnp.clip(y - rho_new, 0.0, 1.0)
            by = _ogb_bucket(y, wv, v)
            onesn = jnp.ones_like(y)
            cl = jnp.zeros(v, jnp.float32).at[by].add(onesn)
            sl = jnp.zeros(v, jnp.float32).at[by].add(y)
            ycnt = pt.tree_build(cl, radix)
            ysum = pt.tree_build(sl, radix)
            if poisson:
                dl = jnp.zeros(v, jnp.float32).at[
                    _ogb_bucket(y - p, wv, v)
                ].add(onesn)
                dcnt = pt.tree_build(dl, radix)
            return y, jnp.float32(0.0), ycnt, ysum, dcnt

        y, rho_out, ycnt, ysum, dcnt = jax.lax.cond(
            1.0 + rho_new + eta * jnp.float32(b) >= gridtop - wv,
            reanchor,
            lambda args: args,
            (y, rho_new, ycnt, ysum, dcnt),
        )
        out = carry._replace(y=y, rho=rho_out, scratch=scratch,
                             ycnt=ycnt, ysum=ysum, dcnt=dcnt)
        return out, (reward, hits, rho_new - rho, occ)

    return chunk


# ---------------------------------------------------------------------------
# sized OGB: per-size-class bucket trees, O(K * B log V) per chunk
# ---------------------------------------------------------------------------
#: default number of size (slab) classes the sized tree flavor quantizes to
SIZED_OGB_CLASSES = 16


class SizedOGBTreeCarry(NamedTuple):
    """Lazy *weighted* OGB state over K size classes (paper §8 setting).

    The knapsack-relaxed projection onto {f : sum_i s_i f_i = C} is
    f_i = clip(y_i - s_k * rho, 0, 1) for item i in size class k — the
    uniform-subtraction trick generalizes per class, so the unit-size
    bucket-histogram solve becomes K stacked histograms, one per slab
    class, each with a class-scaled bucket width w_k = s_k * wb (uniform
    rho resolution across classes).  A chunk touches O(K * B log V) tree
    nodes; the catalog is only visited on re-anchor.

    Sizes/costs are pre-normalized by the mean slab size (``sref``), so
    uniform sizes reduce to the unit ``ogb_tree`` dynamics at the same
    eta; byte outputs are scaled back by ``sref``.
    """

    y: jax.Array  # (N,) float32 accumulated values
    rho: jax.Array  # () float32 cumulative base multiplier
    eta: jax.Array  # () float32
    cap: jax.Array  # () float32 capacity in normalized bytes
    cls: jax.Array  # (N,) int32 item -> size class
    s: jax.Array  # (K,) float32 normalized class sizes
    wts: jax.Array  # (N,) float32 normalized gradient weights (costs)
    sref: jax.Array  # () float32 bytes per normalized size unit
    wmax: jax.Array  # () float32 max gradient weight (re-anchor headroom)
    p: jax.Array  # (N,) float32 permanent random numbers, or (0,)
    wb: jax.Array  # () float32 base bucket width (class k: s_k * wb)
    scratch: jax.Array  # (N,) int32 first-occurrence dedup scratch
    ycnt: jax.Array  # (K, TOT) float32 per-class bucket-count trees
    ysum: jax.Array  # (K, TOT) float32 per-class bucket-sum trees
    dcnt: jax.Array  # (K, TOT) float32 trees over y - p, or (0, TOT)


def _stacked_tree_update(trees, v: int, radix: int, rows, idx, delta):
    """Batched point update on stacked per-class trees ``(K, TOT)``:
    add ``delta[q]`` along the ancestor path of leaf ``idx[q]`` in the
    class-``rows[q]`` tree; ``idx < 0`` entries are skipped."""
    kk, tot = trees.shape
    offs = pt.tree_offsets(v, radix)
    sh = radix.bit_length() - 1
    ok = idx >= 0
    node = jnp.where(ok, idx, 0)
    row = jnp.where(ok, rows, 0) * tot
    nodes, deltas = [], []
    zero = jnp.zeros((), delta.dtype)
    for off in offs:
        nodes.append(row + off + node)
        deltas.append(jnp.where(ok, delta, zero))
        node = node >> sh
    flat = trees.reshape(-1).at[jnp.concatenate(nodes)].add(
        jnp.concatenate(deltas)
    )
    return flat.reshape(kk, tot)


def init_sized_ogb_tree_carry(
    catalog_size: int,
    capacity: float,
    *,
    sizes: np.ndarray,
    costs: Optional[np.ndarray] = None,
    eta: float,
    seed: int = 0,
    sample: str = "poisson",
    classes: int = SIZED_OGB_CLASSES,
    buckets: int = OGB_TREE_BUCKETS,
    radix: int = OGB_TREE_RADIX,
    batch_hint: int = 4096,
) -> SizedOGBTreeCarry:
    """Initial carry at the uniform feasible state f = C / sum_i s_i.

    ``sizes`` (bytes) are quantized to at most ``classes`` slab sizes
    (exact when there are that few distinct sizes — see
    :func:`repro.core.ogb_sized.size_classes`); ``costs`` default to the
    (quantized) sizes, i.e. byte-weighted rewards w_{t,i} = s_i."""
    from repro.cachesim.replay import sampling_keys
    from repro.core.ogb_sized import size_classes

    n, v = int(catalog_size), int(buckets)
    s_cls, cls = size_classes(sizes, classes)  # validates sizes > 0
    if not np.isfinite(capacity) or capacity <= 0:
        raise ValueError(f"capacity must be finite and > 0: {capacity!r}")
    sref = float(np.mean(s_cls[cls]))
    s_n = (s_cls / sref).astype(np.float64)  # normalized class sizes
    sq = s_n[cls]  # (N,) normalized per-item size
    if costs is None:
        w = sq.copy()
    else:
        w = np.asarray(costs, np.float64) / sref
        if w.shape != (n,):
            raise ValueError(f"costs must be a ({n},) array")
        if not (np.all(np.isfinite(w)) and w.min() > 0.0):
            raise ValueError("costs must be finite and > 0")
    cap_n = float(capacity) / sref
    total_s = float(np.sum(sq))
    if cap_n >= total_s:
        raise ValueError(
            f"capacity {capacity} holds the whole catalog "
            f"({sref * total_s:.0f} bytes); caching is trivial"
        )
    f0 = cap_n / total_s  # uniform feasible: sum_i s_i * f0 = cap_n
    wmax = float(np.max(w))
    smin = float(np.min(s_n))
    # base grid width: class-k grids span s_k * wb * v, sized so the
    # smallest class clears ~2*GAIN chunks of worst-case rho growth
    wb = (2.0 / smin + 2.0 * OGB_TREE_GAIN
          * max(1.0, float(eta) * batch_hint * wmax)) / v
    p, _ = sampling_keys(seed, n, sample)
    kk = len(s_n)
    w_k = s_n * wb  # per-class bucket widths
    by = np.clip(
        np.floor((f0 + 1.0) / w_k[cls]), 0, v - 1
    ).astype(np.int64)
    flatb = cls.astype(np.int64) * v + by
    cnt_leaf = np.bincount(flatb, minlength=kk * v).reshape(kk, v)
    sum_leaf = (cnt_leaf * f0).astype(np.float32)
    build = jax.vmap(lambda leaf: pt.tree_build(leaf, radix))
    ycnt = build(jnp.asarray(cnt_leaf, jnp.float32))
    ysum = build(jnp.asarray(sum_leaf))
    if sample == "poisson":
        d0 = f0 - np.asarray(p, np.float64)
        db = np.clip(np.floor((d0 + 1.0) / w_k[cls]), 0, v - 1).astype(
            np.int64
        )
        dl = np.bincount(
            cls.astype(np.int64) * v + db, minlength=kk * v
        ).reshape(kk, v)
        dcnt = build(jnp.asarray(dl, jnp.float32))
    else:
        dcnt = jnp.zeros((0, pt.tree_storage(v, radix)), jnp.float32)
    return SizedOGBTreeCarry(
        y=jnp.full(n, f0, jnp.float32),
        rho=jnp.zeros((), jnp.float32),
        eta=jnp.float32(eta),
        cap=jnp.float32(cap_n),
        cls=jnp.asarray(cls, jnp.int32),
        s=jnp.asarray(s_n, jnp.float32),
        wts=jnp.asarray(w, jnp.float32),
        sref=jnp.float32(sref),
        wmax=jnp.float32(wmax),
        p=p,
        wb=jnp.float32(wb),
        scratch=jnp.full(n, _I32_MAX, jnp.int32),
        ycnt=ycnt,
        ysum=ysum,
        dcnt=dcnt,
    )


@functools.lru_cache(maxsize=None)
def make_sized_ogb_tree_chunk(catalog_size: int, kk: int, v: int, radix: int,
                              sample: str, iters: int = OGB_TREE_ITERS):
    """Per-chunk sized lazy OGB step ``(carry, ids) -> (carry, (reward,
    hits, byte_hits, drho, occ_bytes))``.

    The scalar solve finds the base multiplier rho with

        sum_k s_k * m_k(s_k * rho) = C,   m_k = class-k mean-clip bucket mass

    by warm-bracketed safeguarded Newton: each iteration reads 2 prefix
    sums per class (O(K log V)), the slope is sum_k s_k^2 * (interior
    count)_k, and the bisection bracket [rho, wb * v] guards the Newton
    proposals.  Same quantization caveats as the unit ``ogb_tree``, with
    the bucket width scaled per class so rho resolution is uniform."""
    poisson = sample == "poisson"

    def class_mass(ycnt_k, ysum_k, wv_k, t_k):
        """(mass, interior count) of one class at class-threshold t_k."""
        k0 = _ogb_bucket(t_k, wv_k, v)
        k1 = _ogb_bucket(t_k + 1.0, wv_k, v)
        total = pt.tree_total(ycnt_k, v, radix)
        qc = pt.tree_prefix(ycnt_k, v, radix, jnp.stack([k0, k1]))
        qs = pt.tree_prefix(ysum_k, v, radix, jnp.stack([k0, k1]))
        cb = jnp.stack([ycnt_k[k0], ycnt_k[k1]])
        sb = jnp.stack([ysum_k[k0], ysum_k[k1]])
        above = total - qc[1]
        mid_c = qc[1] - cb[1] - qc[0]
        mid_s = qs[1] - sb[1] - qs[0]
        mid = mid_s - t_k * mid_c
        mean = jnp.where(cb > 0, sb / jnp.maximum(cb, 1.0), 0.0)
        bclip = jnp.clip(mean - t_k, 0.0, 1.0)
        bnd = cb * bclip
        bint = jnp.where((bclip > 0.0) & (bclip < 1.0), cb, 0.0)
        mass = above + mid + bnd[0] + jnp.where(k1 > k0, bnd[1], 0.0)
        interior = mid_c + bint[0] + jnp.where(k1 > k0, bint[1], 0.0)
        return mass, interior

    vclass_mass = jax.vmap(class_mass, in_axes=(0, 0, 0, 0))

    def chunk(carry, ids):
        b = ids.shape[0]
        y, rho, eta, cap = carry.y, carry.rho, carry.eta, carry.cap
        cls, s, wts, sref = carry.cls, carry.s, carry.wts, carry.sref
        p, wb, scratch = carry.p, carry.wb, carry.scratch
        ycnt, ysum, dcnt = carry.ycnt, carry.ysum, carry.dcnt
        lanes = jnp.arange(b, dtype=jnp.int32)
        w_k = s * wb  # (K,) per-class bucket widths

        cj = cls[ids]
        sj = s[cj]
        wj = wts[ids]

        # --- metrics at the pre-update state (OCO order) ---
        fi = jnp.clip(y[ids] - sj * rho, 0.0, 1.0)
        reward = jnp.sum(wj * fi)
        if poisson:
            hflag = fi >= p[ids]
            hits = jnp.sum(hflag.astype(jnp.int32))
            byte_hits = jnp.sum(jnp.where(hflag, sj, 0.0)) * sref
            # byte occupancy: per-class suffix counts of y - p above the
            # class threshold s_k * rho, weighted by class bytes
            dtots = jax.vmap(lambda tr: pt.tree_total(tr, v, radix))(dcnt)
            dpre = jax.vmap(
                lambda tr, q: pt.tree_prefix(tr, v, radix, q[None])[0]
            )(dcnt, _ogb_bucket(s * rho, w_k, v))
            occ = jnp.sum(s * (dtots - dpre)) * sref
        else:
            hits = jnp.zeros((), jnp.int32)
            byte_hits = jnp.zeros((), jnp.float32)
            occ = cap * sref

        # --- first-occurrence mask (dedup without sorting) ---
        a = scratch.at[ids].min(lanes)
        first = a[ids] == lanes
        scratch = a.at[ids].set(_I32_MAX)

        # --- gradient step: upper-clip touched, add eta * w_j per request ---
        yold = y[ids]
        y = y.at[ids].min(1.0 + sj * rho)
        y = y.at[ids].add(eta * wj)
        ynew = y[ids]

        # --- move touched items between their class buckets ---
        wvj = w_k[cj]
        bo = jnp.where(first, _ogb_bucket(yold, wvj, v), -1)
        bn = jnp.where(first, _ogb_bucket(ynew, wvj, v), -1)
        rows2 = jnp.concatenate([cj, cj])
        didx = jnp.concatenate([bo, bn])
        ones = jnp.ones(b, jnp.float32)
        ycnt = _stacked_tree_update(ycnt, v, radix, rows2, didx,
                                    jnp.concatenate([-ones, ones]))
        ysum = _stacked_tree_update(
            ysum, v, radix, rows2, didx,
            jnp.concatenate([
                jnp.where(first, -yold, 0.0), jnp.where(first, ynew, 0.0)
            ]),
        )
        if poisson:
            do = jnp.where(first, _ogb_bucket(yold - p[ids], wvj, v), -1)
            dn = jnp.where(first, _ogb_bucket(ynew - p[ids], wvj, v), -1)
            dcnt = _stacked_tree_update(dcnt, v, radix, rows2,
                                        jnp.concatenate([do, dn]),
                                        jnp.concatenate([-ones, ones]))

        # --- threshold solve: warm-bracketed safeguarded Newton on rho ---
        gridtop = wb * jnp.float32(v)

        def sweep_iter(_, state):
            lo, hi, t = state
            masses, interior = vclass_mass(ycnt, ysum, w_k, s * t)
            mass = jnp.sum(s * masses)
            slope = jnp.sum(s * s * interior)
            too_much = mass >= cap
            lo = jnp.where(too_much, t, lo)
            hi = jnp.where(too_much, hi, t)
            t_newton = t + (mass - cap) / jnp.maximum(slope, 1e-12)
            t_mid = 0.5 * (lo + hi)
            ok = jnp.logical_and(
                slope > 0.0,
                jnp.logical_and(t_newton > lo, t_newton < hi),
            )
            return lo, hi, jnp.where(ok, t_newton, t_mid)

        rho_new, _, _ = jax.lax.fori_loop(
            0, iters, sweep_iter, (rho, gridtop, rho)
        )

        # --- re-anchor when any class could outgrow its value grid ---
        def reanchor(args):
            y, rho_new, ycnt, ysum, dcnt = args
            scl = s[cls]
            y = jnp.clip(y - scl * rho_new, 0.0, 1.0)
            wcl = w_k[cls]
            by = _ogb_bucket(y, wcl, v)
            onesn = jnp.ones_like(y)
            cl = jnp.zeros((kk, v), jnp.float32).at[cls, by].add(onesn)
            sl = jnp.zeros((kk, v), jnp.float32).at[cls, by].add(y)
            build = jax.vmap(lambda leaf: pt.tree_build(leaf, radix))
            ycnt = build(cl)
            ysum = build(sl)
            if poisson:
                dl = jnp.zeros((kk, v), jnp.float32).at[
                    cls, _ogb_bucket(y - p, wcl, v)
                ].add(onesn)
                dcnt = build(dl)
            return y, jnp.float32(0.0), ycnt, ysum, dcnt

        trig = jnp.any(
            1.0 + s * rho_new + eta * carry.wmax * jnp.float32(b)
            >= w_k * jnp.float32(v) - 1.0 - w_k
        )
        y, rho_out, ycnt, ysum, dcnt = jax.lax.cond(
            trig, reanchor, lambda args: args, (y, rho_new, ycnt, ysum, dcnt)
        )
        out = carry._replace(y=y, rho=rho_out, scratch=scratch,
                             ycnt=ycnt, ysum=ysum, dcnt=dcnt)
        return out, (reward, hits, byte_hits, rho_new - rho, occ)

    return chunk


# ---------------------------------------------------------------------------
# unified entry points (mirrors engines.init_engine_carry / _STEPS)
# ---------------------------------------------------------------------------
def init_tree_engine_carry(
    kind: str,
    catalog_size: int,
    capacity: int,
    *,
    n_slots: Optional[int] = None,
    seed: int = 0,
    zeta: Optional[float] = None,
    horizon: Optional[int] = None,
    ring: Optional[int] = None,
):
    if kind == "lru":
        return init_tree_lru_carry(catalog_size, capacity, n_slots, ring)
    if kind == "lfu":
        return init_tree_lfu_carry(catalog_size, capacity, n_slots)
    if kind == "ftpl":
        return init_tree_ftpl_carry(catalog_size, capacity, n_slots,
                                    seed=seed, zeta=zeta, horizon=horizon)
    if kind == "gds":
        return init_tree_gds_carry(catalog_size, capacity, n_slots)
    raise ValueError(
        f"unknown tree engine kind {kind!r} (have {TREE_ENGINE_KINDS})"
    )


def make_tree_chunk(kind: str, carry, return_flags: bool = False):
    """Chunk step ``(carry, ids) -> (carry, (hits, occupancy))`` matching
    the given carry's static geometry.  ``return_flags=True`` yields the
    (window,) per-request hit flags instead of the chunk sum, so sized
    callers can weight each hit by the requested item's bytes."""
    if kind == "lru":
        m = pt.leaves_for_storage(carry.tree.shape[0], RING_RADIX)
        inner = make_lru_tree_chunk(carry.last.shape[0] - 1, m,
                                    return_flags)

        def chunk(c, ids):
            c, (hits, occ) = inner(c, ids)
            return c, (hits, occ)

        return chunk
    if kind == "lfu":
        inner = make_lfu_tree_chunk(carry.imap.shape[0] - 1,
                                    carry.slots.shape[0], return_flags)
    elif kind == "ftpl":
        inner = make_ftpl_tree_chunk(carry.imap.shape[0] - 1,
                                     carry.slots.shape[0], return_flags)
    elif kind == "gds":
        inner = make_gds_tree_chunk(carry.imap.shape[0] - 1,
                                    carry.slots.shape[0], return_flags)
    else:
        raise ValueError(f"unknown tree engine kind {kind!r}")

    def chunk(c, ids):
        c, hits = inner(c, ids)
        occ = jnp.sum((c.slots >= 0).astype(jnp.int32))
        return c, (hits, occ)

    return chunk
