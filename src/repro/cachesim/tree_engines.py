"""Tree-backed device engines: the O(log N) automata of the paper.

The dense automata in :mod:`repro.cachesim.engines` pay O(C) vector work
per request (slot-wide compares and argmins) and the fractional replay pays
O(N) per chunk.  This module re-implements the eviction machinery on the
packed radix trees of :mod:`repro.kernels.prefix_tree`, turning the
per-request cost into O(R log_R ·) scatter/gather paths while staying
**bit-exact** against the dense steps (the differential tests in
``tests/cachesim/test_tree_policies.py`` compare hit sequences request by
request).

Three engines:

* **tree-LRU** — chunk-batched *reuse distance*: a request hits iff the
  number of distinct items since its previous occurrence is at most C-1,
  which is exactly LRU.  Marks (last occurrences) live on a ring of
  positions with a radix-16 count tree over them; a chunk of W requests is
  resolved with two batched prefix queries plus a (W, W) in-chunk dominance
  term, and the tree moves each distinct item's mark once per chunk.  When
  the ring fills, a rank-compaction keeps only the newest ``capacity``
  marks — exact, because a reuse window reaching past those marks already
  contains >= capacity distinct items (a certain miss either way), and
  dropped items re-enter as first-seen misses, which they would be.
* **tree-LFU / tree-FTPL** — per-request automata whose victim search is a
  lexicographic (hi, lo) min-tree over slots: (frequency, tick) for LFU,
  (sortable perturbed score, item id) for FTPL — the same eviction keys and
  tie-breaks as the dense steps, so hit sequences agree bit for bit.  All
  writes are *delayed* one request (applied at the start of the next step)
  so no gather reads a just-scattered array — the anti-dependency would
  otherwise force a full-array copy per request.

Per-chunk steps keep their pending writes in the **inner** scan carry and
flush them before returning, so the outer carry is window-independent —
the streaming/resume contract of :mod:`repro.cachesim.api` (two chunked
runs replay one full run bit for bit) holds for any window split.

FIFO stays dense: its eviction order is insertion time, which reuse
distances cannot express, and its O(C) step is already cheap.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ftpl import ftpl_initial_top_c, ftpl_noise, theoretical_zeta
from repro.kernels.prefix_tree import ops as pt

_I32_MAX = np.int32(np.iinfo(np.int32).max)

#: kinds with a tree-backed implementation (impl="tree" in the API layer)
TREE_ENGINE_KINDS = ("lru", "lfu", "ftpl")

#: radix of the position tree (LRU ring) — 16 lanes keep the sibling
#: gathers one vector register wide while the ring tree stays 4 levels deep
RING_RADIX = 16
#: radix of the slot min-trees (LFU/FTPL) — 64-wide groups make catalogs of
#: thousands of slots two levels deep
SLOT_RADIX = 64
#: sub-chunk width cap for the reuse-distance engine: the (W, W) in-chunk
#: dominance term is brute-force, and past ~128 it stops being free
MAX_SUBCHUNK = 128


# ---------------------------------------------------------------------------
# carries
# ---------------------------------------------------------------------------
class TreeLRUCarry(NamedTuple):
    """Reuse-distance LRU state (window-independent; pends live inner-scan)."""

    tree: jax.Array  # (TOT,) int32 packed radix-16 mark-count tree
    last: jax.Array  # (N+1,) int32 item -> ring position of last occurrence
    pos: jax.Array  # () int32 next free ring position
    nseen: jax.Array  # () int32 distinct items seen (occupancy = min(, cap))
    cap: jax.Array  # () int32 capacity (traced: sweeps stack it)


class TreeLFUCarry(NamedTuple):
    imap: jax.Array  # (N+1,) int32 item -> slot (-1 out; N is scratch)
    counts: jax.Array  # (N,) int32 perfect-LFU counters
    slots: jax.Array  # (K,) int32 slot -> item (-1 empty, -2 inactive)
    tree_hi: jax.Array  # (TOT,) int32 min-tree over slot frequencies
    tree_lo: jax.Array  # (TOT,) int32 min-tree over slot ticks
    t: jax.Array  # () int32


class TreeFTPLCarry(NamedTuple):
    imap: jax.Array  # (N+1,) int32 item -> slot (-1 out; N is scratch)
    counts: jax.Array  # (N,) int32 request counters
    noise: jax.Array  # (N,) float32 one-shot perturbation (constant)
    slots: jax.Array  # (K,) int32 slot -> item (-2 inactive)
    tree_hi: jax.Array  # (TOT,) int32 min-tree over sortable scores
    tree_lo: jax.Array  # (TOT,) int32 min-tree over slot item ids


# ---------------------------------------------------------------------------
# geometry helpers
# ---------------------------------------------------------------------------
def ring_size(n_slots: int) -> int:
    """Ring length: power of two with >= 4x slack over the kept-mark count
    (compaction keeps at most ``capacity`` marks).  The floor is generous —
    each compaction pays an argsort over the catalog, so headroom buys
    throughput directly (8192 vs 65536 measured 2x on the bench trace) and
    the tree costs only ~level-sum(m) int32s."""
    m = 65536
    while m < 4 * int(n_slots):
        m *= 2
    return m


def _pick_subchunk(window: int) -> int:
    """Largest divisor of ``window`` that is <= MAX_SUBCHUNK, preferring
    16-aligned widths (aligned sub-chunks take the cheap grouped-insert
    path: ~2x fewer scatter elements per request)."""
    best, best_aligned = 1, 1
    for d in range(1, min(window, MAX_SUBCHUNK) + 1):
        if window % d == 0:
            best = d
            if d % RING_RADIX == 0:
                best_aligned = d
    return best_aligned if best_aligned > 1 else best


# ---------------------------------------------------------------------------
# tree-LRU: chunk-batched reuse distance
# ---------------------------------------------------------------------------
def init_tree_lru_carry(catalog_size: int, capacity: int,
                        n_slots: Optional[int] = None,
                        ring: Optional[int] = None) -> TreeLRUCarry:
    k = int(n_slots) if n_slots else int(capacity)
    m = int(ring) if ring else ring_size(k)
    if m & (m - 1) or m < 4 * k:
        raise ValueError(
            f"ring must be a power of two >= 4 * n_slots, got {m} for {k}"
        )
    return TreeLRUCarry(
        tree=jnp.zeros(pt.tree_storage(m, RING_RADIX), jnp.int32),
        last=jnp.full(catalog_size + 1, -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        nseen=jnp.zeros((), jnp.int32),
        cap=jnp.int32(capacity),
    )


@functools.lru_cache(maxsize=None)
def make_lru_tree_chunk(catalog_size: int, m: int):
    """Chunk step ``(carry, ids(window,)) -> (carry, (hits, occ))`` for the
    reuse-distance engine; the sub-chunk width W is derived from the traced
    chunk shape, so one factory serves every window."""
    radix = RING_RADIX
    sh = radix.bit_length() - 1
    offs = pt.tree_offsets(m, radix)
    sizes = pt.tree_sizes(m, radix)
    nlev = len(offs)

    def compact(tree, last, pos, cap):
        # rank-remap marks to [0, kept); drop all but the newest `cap`
        # marks (exact: a reuse window reaching past them holds >= cap
        # marks, a certain miss, and dropped items re-enter as first-seen)
        nmarks = jnp.sum(last >= 0, dtype=jnp.int32)
        kept = jnp.minimum(nmarks, cap)
        key = jnp.where(last >= 0, last, _I32_MAX)
        order = jnp.argsort(key)
        ranks = jnp.zeros_like(key).at[order].set(
            jnp.arange(key.shape[0], dtype=jnp.int32)
        )
        newrank = ranks - (nmarks - kept)
        newlast = jnp.where((last >= 0) & (newrank >= 0), newrank, -1)
        leaf = (jnp.arange(m, dtype=jnp.int32) < kept).astype(jnp.int32)
        tree = pt.tree_build(leaf, radix)
        npos = (kept + radix - 1) & ~(radix - 1)  # 16-aligned restart
        return tree, newlast, npos

    def chunk(carry, ids):
        window = ids.shape[0]
        # compaction runs (at most) once per *chunk*, not per sub-chunk:
        # after it, pos <= aligned(cap) <= m/4 + 16, so a whole window of
        # inserts fits.  Keeping the cond out of the inner scan matters
        # under vmap (sweeps), where a batched cond executes both branches
        # — per sub-chunk that would pay the argsort every step.
        if window > 3 * (m // 4) - RING_RADIX:
            raise ValueError(
                f"window {window} too large for ring {m}; pass a larger "
                f"ring= to init (need window <= 3*ring/4 - {RING_RADIX})"
            )
        w = _pick_subchunk(window)
        aligned = w % radix == 0
        if aligned:
            npend = w * nlev + w + (nlev - 1) * (w // radix)
        else:
            npend = w * nlev * 2
        eye = jnp.eye(w, dtype=bool)
        lanes = jnp.arange(w, dtype=jnp.int32)

        def substep(st, sub_ids):
            tree, last, pos, nseen, cap, pn, pd, pli, plv = st
            # delayed writes: apply the previous sub-chunk's tree deltas
            # and mark moves before reading anything
            tree = tree.at[pn].add(pd)
            last = last.at[pli].max(plv)
            kpos = pos + lanes
            lastg = last[sub_ids]
            eq = sub_ids[None, :] == sub_ids[:, None]
            lower = kpos[None, :] < kpos[:, None]
            prev_in = jnp.max(jnp.where(eq & lower, kpos[None, :], -1), axis=1)
            prevp = jnp.where(prev_in >= 0, prev_in, lastg)
            islast = ~jnp.any(eq & ~lower & ~eye, axis=1)
            # d(i) = tree marks in (prev(i), chunk start) + in-chunk firsts
            # in (prev(i), i) — the dominance term, brute (W, W)
            base = pt.tree_prefix(
                tree, m, radix, jnp.full((1,), pos - 1, jnp.int32)
            )[0]
            dpre = base - pt.tree_prefix(
                tree, m, radix, jnp.minimum(prevp, pos - 1)
            )
            dom = (
                (prevp[None, :] <= prevp[:, None])
                & (kpos[None, :] > prevp[:, None])
                & lower
            )
            d = dpre + jnp.sum(dom, axis=1, dtype=jnp.int32)
            hit = (prevp >= 0) & (d <= cap - 1)
            nseen = nseen + jnp.sum(prevp < 0, dtype=jnp.int32)

            # plan next sub-chunk's writes: remove pre-chunk marks that
            # moved, insert marks at last in-chunk occurrences
            rm = jnp.where((lastg >= 0) & (prev_in < 0), lastg, -1)
            rm_nodes, rm_deltas, node = [], [], rm
            for l in range(nlev):
                ok = rm >= 0
                rm_nodes.append(jnp.where(ok, offs[l] + node, 0))
                rm_deltas.append(jnp.where(ok, jnp.int32(-1), 0))
                node = node >> sh
            ins = islast.astype(jnp.int32)
            if aligned:
                # leaf groups are complete (pos and W both 16-aligned):
                # exact level-1 deltas via reshape; higher levels scatter
                # the same (W/16,) deltas at ancestor nodes (duplicate
                # indices accumulate across group boundaries)
                g1 = ins.reshape(-1, radix).sum(1, dtype=jnp.int32)
                gids = (pos >> sh) + jnp.arange(g1.shape[0], dtype=jnp.int32)
                node = gids
                ins_nodes, ins_deltas = [], []
                for l in range(1, nlev):
                    ins_nodes.append(offs[l] + node)
                    ins_deltas.append(g1)
                    node = node >> sh
                pn = jnp.concatenate([*rm_nodes, kpos] + ins_nodes)
                pd = jnp.concatenate([*rm_deltas, ins] + ins_deltas)
            else:
                node = kpos
                ins_nodes, ins_deltas = [], []
                for l in range(nlev):
                    ins_nodes.append(offs[l] + node)
                    ins_deltas.append(ins)
                    node = node >> sh
                pn = jnp.concatenate(rm_nodes + ins_nodes)
                pd = jnp.concatenate(rm_deltas + ins_deltas)
            pli, plv = sub_ids, kpos
            st = (tree, last, pos + w, nseen, cap, pn, pd, pli, plv)
            return st, hit

        tree, last, pos = jax.lax.cond(
            carry.pos + window > m,
            lambda a: compact(a[0], a[1], a[2], carry.cap),
            lambda a: a,
            (carry.tree, carry.last, carry.pos),
        )
        # pend arrays are inner-scan state only, flushed before returning,
        # so the outer carry does not depend on the window split
        st = (
            tree, last, pos, carry.nseen, carry.cap,
            jnp.zeros(npend, jnp.int32), jnp.zeros(npend, jnp.int32),
            jnp.zeros(w, jnp.int32), jnp.full(w, -1, jnp.int32),
        )
        st, hits = jax.lax.scan(substep, st, ids.reshape(-1, w))
        tree, last, pos, nseen, cap, pn, pd, pli, plv = st
        tree = tree.at[pn].add(pd)
        last = last.at[pli].max(plv)
        out = TreeLRUCarry(tree, last, pos, nseen, cap)
        nhits = jnp.sum(hits.astype(jnp.int32))
        return out, (nhits, jnp.minimum(nseen, cap))

    return chunk


# ---------------------------------------------------------------------------
# tree-LFU / tree-FTPL: delayed-write min-pair automata
# ---------------------------------------------------------------------------
def _slot_tot(k: int) -> int:
    return pt.tree_storage(k, SLOT_RADIX)


def init_tree_lfu_carry(catalog_size: int, capacity: int,
                        n_slots: Optional[int] = None) -> TreeLFUCarry:
    k = int(n_slots) if n_slots else int(capacity)
    c = int(capacity)
    hi = np.full(k, _I32_MAX, np.int32)
    lo = np.full(k, _I32_MAX, np.int32)
    hi[:c] = -1  # empty slots: freq -1 sorts below any real frequency
    lo[:c] = -1
    th, tl = pt.minpair_build(jnp.asarray(hi), jnp.asarray(lo), SLOT_RADIX)
    slots = np.full(k, -2, np.int32)
    slots[:c] = -1
    return TreeLFUCarry(
        imap=jnp.full(catalog_size + 1, -1, jnp.int32),
        counts=jnp.zeros(catalog_size, jnp.int32),
        slots=jnp.asarray(slots),
        tree_hi=th,
        tree_lo=tl,
        t=jnp.zeros((), jnp.int32),
    )


def init_tree_ftpl_carry(catalog_size: int, capacity: int,
                         n_slots: Optional[int] = None, *, seed: int = 0,
                         zeta: Optional[float] = None,
                         horizon: Optional[int] = None) -> TreeFTPLCarry:
    k = int(n_slots) if n_slots else int(capacity)
    c = int(capacity)
    if zeta is None:
        if horizon is None:
            raise ValueError("ftpl needs zeta or horizon")
        zeta = theoretical_zeta(c, catalog_size, horizon)
    noise = ftpl_noise(catalog_size, zeta, seed=seed)
    top = ftpl_initial_top_c(noise, c).astype(np.int32)
    slots = np.full(k, -2, np.int32)
    slots[:c] = top
    imap = np.full(catalog_size + 1, -1, np.int32)
    imap[top] = np.arange(c, dtype=np.int32)
    hi = np.full(k, _I32_MAX, np.int32)
    lo = np.full(k, _I32_MAX, np.int32)
    hi[:c] = np.asarray(
        pt.sortable_f32(jnp.asarray(noise[top], jnp.float32))
    )
    lo[:c] = top
    th, tl = pt.minpair_build(jnp.asarray(hi), jnp.asarray(lo), SLOT_RADIX)
    return TreeFTPLCarry(
        imap=jnp.asarray(imap),
        counts=jnp.zeros(catalog_size, jnp.int32),
        noise=jnp.asarray(noise),
        slots=jnp.asarray(slots),
        tree_hi=th,
        tree_lo=tl,
    )


def _wrap_pend_chunk(substep, pack, unpack):
    """Build ``chunk(carry, ids)`` from a delayed-write per-request substep:
    pending writes ride the inner carry and are flushed before returning."""

    def chunk(carry, ids):
        st = pack(carry)
        st, hits = jax.lax.scan(substep, st, ids)
        carry = unpack(st)
        return carry, jnp.sum(hits.astype(jnp.int32))

    return chunk


@functools.lru_cache(maxsize=None)
def make_lfu_tree_chunk(catalog_size: int, k: int):
    n = catalog_size
    radix = SLOT_RADIX
    offs = pt.tree_offsets(k, radix)

    def substep(st, j):
        (imap, counts, slots, th, tl, t,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)

        slot = imap[j]
        hit = slot >= 0
        f = counts[j] + 1  # the dense step increments before keying
        root_hi, _ = pt.minpair_root(th, tl, k, radix)
        victim = pt.minpair_argmin(th, tl, k, radix).astype(jnp.int32)
        idx = jnp.where(hit, slot, victim)
        # admission: the newcomer must match the victim's frequency
        write = jnp.logical_or(hit, f >= root_hi)
        old = slots[idx]
        new_hi = jnp.where(write, f, th[idx])  # no-op plan when not writing
        new_lo = jnp.where(write, t, tl[idx])
        pti, pth, ptl = pt.minpair_update_plan(th, tl, k, radix, idx,
                                               new_hi, new_lo)
        pci, pcd = j, jnp.int32(1)
        psi = idx
        psv = jnp.where(write, j, old)
        mo = jnp.where(write & (old >= 0) & (old != j), old, n)  # n: scratch
        mj = jnp.where(write, j, n)
        pii = jnp.stack([mo, mj])
        piv = jnp.stack([jnp.int32(-1), idx])
        st = (imap, counts, slots, th, tl, t + 1,
              pci, pcd, pii, piv, psi, psv, pti, pth, ptl)
        return st, hit

    def pack(c: TreeLFUCarry):
        return (
            c.imap, c.counts, c.slots, c.tree_hi, c.tree_lo, c.t,
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.full(2, n, jnp.int32), jnp.full(2, -1, jnp.int32),
            jnp.zeros((), jnp.int32), c.slots[0],
            jnp.asarray(offs, jnp.int32), c.tree_hi[jnp.asarray(offs)],
            c.tree_lo[jnp.asarray(offs)],
        )

    def unpack(st):
        (imap, counts, slots, th, tl, t,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)
        return TreeLFUCarry(imap, counts, slots, th, tl, t)

    return _wrap_pend_chunk(substep, pack, unpack)


@functools.lru_cache(maxsize=None)
def make_ftpl_tree_chunk(catalog_size: int, k: int):
    n = catalog_size
    radix = SLOT_RADIX
    offs = pt.tree_offsets(k, radix)

    def substep(st, j):
        (imap, counts, noise, slots, th, tl,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)

        slot = imap[j]
        hit = slot >= 0
        s = (counts[j] + 1).astype(jnp.float32) + noise[j]
        skey = pt.sortable_f32(s)
        root_hi, _ = pt.minpair_root(th, tl, k, radix)
        victim = pt.minpair_argmin(th, tl, k, radix).astype(jnp.int32)
        # strict >, like the dense step; sortable_f32 preserves float order
        swap = jnp.logical_and(~hit, skey > root_hi)
        idx = jnp.where(hit, slot, victim)
        upd = jnp.logical_or(hit, swap)  # a hit refreshes its slot's score
        old = slots[idx]
        new_hi = jnp.where(upd, skey, th[idx])
        new_lo = jnp.where(upd, j, tl[idx])
        pti, pth, ptl = pt.minpair_update_plan(th, tl, k, radix, idx,
                                               new_hi, new_lo)
        pci, pcd = j, jnp.int32(1)
        psi = idx
        psv = jnp.where(upd, j, old)
        mo = jnp.where(swap & (old >= 0), old, n)  # n: scratch index
        mj = jnp.where(swap, j, n)
        pii = jnp.stack([mo, mj])
        piv = jnp.stack([jnp.int32(-1), idx])
        st = (imap, counts, noise, slots, th, tl,
              pci, pcd, pii, piv, psi, psv, pti, pth, ptl)
        return st, hit

    def pack(c: TreeFTPLCarry):
        return (
            c.imap, c.counts, c.noise, c.slots, c.tree_hi, c.tree_lo,
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.full(2, n, jnp.int32), jnp.full(2, -1, jnp.int32),
            jnp.zeros((), jnp.int32), c.slots[0],
            jnp.asarray(offs, jnp.int32), c.tree_hi[jnp.asarray(offs)],
            c.tree_lo[jnp.asarray(offs)],
        )

    def unpack(st):
        (imap, counts, noise, slots, th, tl,
         pci, pcd, pii, piv, psi, psv, pti, pth, ptl) = st
        counts = counts.at[pci].add(pcd)
        imap = imap.at[pii].set(piv)
        slots = slots.at[psi].set(psv)
        th = th.at[pti].set(pth)
        tl = tl.at[pti].set(ptl)
        return TreeFTPLCarry(imap, counts, noise, slots, th, tl)

    return _wrap_pend_chunk(substep, pack, unpack)


# ---------------------------------------------------------------------------
# lazy bucketized OGB: O(B log V) per chunk, independent of the catalog size
# ---------------------------------------------------------------------------
#: bucket count of the value histogram the lazy projection solves over
OGB_TREE_BUCKETS = 65536
#: radix of the bucket count/sum trees
OGB_TREE_RADIX = 64
#: bisection iterations of the per-chunk threshold solve
OGB_TREE_ITERS = 30
#: grid headroom factor: the value grid spans ~2*GAIN chunk-updates of rho
#: growth before a re-anchor pass is needed
OGB_TREE_GAIN = 8.0


class OGBTreeCarry(NamedTuple):
    """Lazy OGB state: absolute accumulated values + cumulative threshold.

    The dense replay projects the whole catalog every chunk.  Here the
    state is the *unprojected* accumulation ``y`` with ``f = clip(y - rho,
    0, 1)`` implicit, and the per-chunk projection becomes a scalar solve
    of ``mass(rho) = sum_b cnt_b * clip(mean_b - rho, 0, 1) = C`` over a
    V-bucket histogram of ``y`` kept in packed radix trees — the chunk
    touches O(B log V) tree nodes, never the catalog.
    """

    y: jax.Array  # (N,) float32 accumulated values (f = clip(y - rho, 0, 1))
    rho: jax.Array  # () float32 cumulative projection threshold
    eta: jax.Array  # () float32
    cap: jax.Array  # () float32
    p: jax.Array  # (N,) float32 permanent random numbers, or (0,)
    w: jax.Array  # () float32 bucket width of the value grid
    scratch: jax.Array  # (N,) int32 first-occurrence dedup scratch (I32_MAX)
    ycnt: jax.Array  # (TOT,) float32 bucket-count tree over y
    ysum: jax.Array  # (TOT,) float32 bucket-sum tree over y
    dcnt: jax.Array  # (TOT,) float32 bucket-count tree over y - p, or (0,)


def _ogb_bucket(x, wv, v: int):
    """Grid bucket of value ``x``: the grid covers [-1, v*w - 1) so both y
    (>= 0) and y - p (> -1) share it."""
    b = jnp.floor((x + 1.0) / wv).astype(jnp.int32)
    return jnp.clip(b, 0, v - 1)


def init_ogb_tree_carry(
    catalog_size: int,
    capacity: int,
    *,
    eta: float,
    seed: int = 0,
    sample: str = "poisson",
    buckets: int = OGB_TREE_BUCKETS,
    radix: int = OGB_TREE_RADIX,
    batch_hint: int = 4096,
) -> OGBTreeCarry:
    """Initial carry at the uniform feasible state f = C/N.

    ``batch_hint`` sizes the value grid: headroom for ~2*OGB_TREE_GAIN
    chunks of worst-case rho growth (eta*B per chunk) between re-anchor
    passes.  A larger actual window than the hint is still correct — the
    re-anchor trigger watches the real chunk size — it just re-anchors
    more often."""
    from repro.cachesim.replay import sampling_keys

    n, v = int(catalog_size), int(buckets)
    span = 1.0 + 2.0 * OGB_TREE_GAIN * max(1.0, float(eta) * batch_hint)
    wv = (span + 1.0) / v
    y0 = float(capacity) / n
    p, _ = sampling_keys(seed, n, sample)
    b0 = int(np.clip(np.floor((y0 + 1.0) / wv), 0, v - 1))
    cnt_leaf = np.zeros(v, np.float32)
    cnt_leaf[b0] = n
    sum_leaf = np.zeros(v, np.float32)
    sum_leaf[b0] = n * y0
    ycnt = pt.tree_build(jnp.asarray(cnt_leaf), radix)
    ysum = pt.tree_build(jnp.asarray(sum_leaf), radix)
    if sample == "poisson":
        d0 = y0 - np.asarray(p, np.float64)
        db = np.clip(np.floor((d0 + 1.0) / wv), 0, v - 1).astype(np.int64)
        dcnt = pt.tree_build(
            jnp.asarray(np.bincount(db, minlength=v), jnp.float32), radix
        )
    else:
        dcnt = jnp.zeros((0,), jnp.float32)
    return OGBTreeCarry(
        y=jnp.full(n, y0, jnp.float32),
        rho=jnp.zeros((), jnp.float32),
        eta=jnp.float32(eta),
        cap=jnp.float32(capacity),
        p=p,
        w=jnp.float32(wv),
        scratch=jnp.full(n, _I32_MAX, jnp.int32),
        ycnt=ycnt,
        ysum=ysum,
        dcnt=dcnt,
    )


@functools.lru_cache(maxsize=None)
def make_ogb_tree_chunk(catalog_size: int, v: int, radix: int, sample: str,
                        iters: int = OGB_TREE_ITERS):
    """Per-chunk lazy OGB step ``(carry, ids) -> (carry, (reward, hits,
    dtau, occ))``.

    Exactness notes (vs the dense chained projection):

    * the gradient step, hit accounting and reward are exact (B gathers of
      ``clip(y - rho, 0, 1)``);
    * the threshold solve uses the bucket mean-clip mass — exact except for
      the <= 2 buckets straddling ``rho`` and ``rho + 1``, so rho carries
      an O(bucket width) quantization;
    * the upper clip ``y <- min(y, 1 + rho)`` is applied to an item only
      when it is touched, so an item far above the cap decays a little
      later than in the dense replay (bounded by its last chunk's eta
      mass).  The differential test bounds the combined drift.
    """
    poisson = sample == "poisson"

    def mass_at(ycnt, ysum, wv, total, t):
        """sum_b cnt_b * clip(mean_b - t, 0, 1) via O(log V) tree reads."""
        k0 = _ogb_bucket(t, wv, v)
        k1 = _ogb_bucket(t + 1.0, wv, v)
        qc = pt.tree_prefix(ycnt, v, radix, jnp.stack([k0, k1]))
        qs = pt.tree_prefix(ysum, v, radix, jnp.stack([k0, k1]))
        cb = jnp.stack([ycnt[k0], ycnt[k1]])
        sb = jnp.stack([ysum[k0], ysum[k1]])
        # buckets above k1 are entirely past t+1: full mass
        above = total - qc[1]
        # buckets strictly between k0 and k1 lie in the linear clip region
        mid_c = qc[1] - cb[1] - qc[0]
        mid_s = qs[1] - sb[1] - qs[0]
        mid = mid_s - t * mid_c
        # boundary buckets: mean-clip approximation
        mean = jnp.where(cb > 0, sb / jnp.maximum(cb, 1.0), 0.0)
        bnd = cb * jnp.clip(mean - t, 0.0, 1.0)
        return above + mid + bnd[0] + jnp.where(k1 > k0, bnd[1], 0.0)

    def chunk(carry, ids):
        b = ids.shape[0]
        y, rho, eta, cap = carry.y, carry.rho, carry.eta, carry.cap
        p, wv, scratch = carry.p, carry.w, carry.scratch
        ycnt, ysum, dcnt = carry.ycnt, carry.ysum, carry.dcnt
        lanes = jnp.arange(b, dtype=jnp.int32)

        # --- metrics at the pre-update state (OCO order), O(B) gathers ---
        fi = jnp.clip(y[ids] - rho, 0.0, 1.0)
        reward = jnp.sum(fi)
        if poisson:
            hits = jnp.sum((fi >= p[ids]).astype(jnp.int32))
            # occupancy #{y - p >= rho} from the d-tree: suffix count above
            # rho's bucket (quantized at the boundary bucket)
            dtot = pt.tree_total(dcnt, v, radix)
            occ = dtot - pt.tree_prefix(
                dcnt, v, radix, _ogb_bucket(rho, wv, v)[None]
            )[0]
        else:
            hits = jnp.zeros((), jnp.int32)
            occ = cap

        # --- first-occurrence mask (dedup without sorting) ---
        a = scratch.at[ids].min(lanes)
        first = a[ids] == lanes
        scratch = a.at[ids].set(_I32_MAX)  # restore

        # --- gradient step: upper-clip touched items, add eta per request ---
        yold = y[ids]
        y = y.at[ids].min(1.0 + rho)
        y = y.at[ids].add(eta)
        ynew = y[ids]

        # --- move touched items between buckets (one per distinct item) ---
        bo = jnp.where(first, _ogb_bucket(yold, wv, v), -1)
        bn = jnp.where(first, _ogb_bucket(ynew, wv, v), -1)
        didx = jnp.concatenate([bo, bn])
        ones = jnp.ones(b, jnp.float32)
        ycnt = pt.tree_update(ycnt, v, radix, didx,
                              jnp.concatenate([-ones, ones]))
        ysum = pt.tree_update(
            ysum, v, radix, didx,
            jnp.concatenate([
                jnp.where(first, -yold, 0.0), jnp.where(first, ynew, 0.0)
            ]),
        )
        if poisson:
            do = jnp.where(first, _ogb_bucket(yold - p[ids], wv, v), -1)
            dn = jnp.where(first, _ogb_bucket(ynew - p[ids], wv, v), -1)
            dcnt = pt.tree_update(dcnt, v, radix,
                                  jnp.concatenate([do, dn]),
                                  jnp.concatenate([-ones, ones]))

        # --- scalar threshold solve: bisect on the warm bracket ---
        total = pt.tree_total(ycnt, v, radix)
        # rho* - rho <= eta*B (chained-projection bound); the 4w floor keeps
        # the bracket wider than the mass quantization when eta*B < w
        hi0 = rho + jnp.maximum(eta * jnp.float32(b), 4.0 * wv)

        def bis(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            m = mass_at(ycnt, ysum, wv, total, mid)
            return jnp.where(m >= cap, mid, lo), jnp.where(m >= cap, hi, mid)

        rho_new, _ = jax.lax.fori_loop(0, iters, bis, (rho, hi0))

        # --- re-anchor when the next chunk could outgrow the value grid ---
        gridtop = wv * jnp.float32(v) - 1.0

        def reanchor(args):
            y, rho_new, ycnt, ysum, dcnt = args
            y = jnp.clip(y - rho_new, 0.0, 1.0)
            by = _ogb_bucket(y, wv, v)
            onesn = jnp.ones_like(y)
            cl = jnp.zeros(v, jnp.float32).at[by].add(onesn)
            sl = jnp.zeros(v, jnp.float32).at[by].add(y)
            ycnt = pt.tree_build(cl, radix)
            ysum = pt.tree_build(sl, radix)
            if poisson:
                dl = jnp.zeros(v, jnp.float32).at[
                    _ogb_bucket(y - p, wv, v)
                ].add(onesn)
                dcnt = pt.tree_build(dl, radix)
            return y, jnp.float32(0.0), ycnt, ysum, dcnt

        y, rho_out, ycnt, ysum, dcnt = jax.lax.cond(
            1.0 + rho_new + eta * jnp.float32(b) >= gridtop - wv,
            reanchor,
            lambda args: args,
            (y, rho_new, ycnt, ysum, dcnt),
        )
        out = carry._replace(y=y, rho=rho_out, scratch=scratch,
                             ycnt=ycnt, ysum=ysum, dcnt=dcnt)
        return out, (reward, hits, rho_new - rho, occ)

    return chunk


# ---------------------------------------------------------------------------
# unified entry points (mirrors engines.init_engine_carry / _STEPS)
# ---------------------------------------------------------------------------
def init_tree_engine_carry(
    kind: str,
    catalog_size: int,
    capacity: int,
    *,
    n_slots: Optional[int] = None,
    seed: int = 0,
    zeta: Optional[float] = None,
    horizon: Optional[int] = None,
    ring: Optional[int] = None,
):
    if kind == "lru":
        return init_tree_lru_carry(catalog_size, capacity, n_slots, ring)
    if kind == "lfu":
        return init_tree_lfu_carry(catalog_size, capacity, n_slots)
    if kind == "ftpl":
        return init_tree_ftpl_carry(catalog_size, capacity, n_slots,
                                    seed=seed, zeta=zeta, horizon=horizon)
    raise ValueError(
        f"unknown tree engine kind {kind!r} (have {TREE_ENGINE_KINDS})"
    )


def make_tree_chunk(kind: str, carry):
    """Chunk step ``(carry, ids) -> (carry, (hits, occupancy))`` matching
    the given carry's static geometry."""
    if kind == "lru":
        m = pt.leaves_for_storage(carry.tree.shape[0], RING_RADIX)
        inner = make_lru_tree_chunk(carry.last.shape[0] - 1, m)

        def chunk(c, ids):
            c, (hits, occ) = inner(c, ids)
            return c, (hits, occ)

        return chunk
    if kind == "lfu":
        inner = make_lfu_tree_chunk(carry.imap.shape[0] - 1,
                                    carry.slots.shape[0])
    elif kind == "ftpl":
        inner = make_ftpl_tree_chunk(carry.imap.shape[0] - 1,
                                     carry.slots.shape[0])
    else:
        raise ValueError(f"unknown tree engine kind {kind!r}")

    def chunk(c, ids):
        c, hits = inner(c, ids)
        occ = jnp.sum((c.slots >= 0).astype(jnp.int32))
        return c, (hits, occ)

    return chunk
