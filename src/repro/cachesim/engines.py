"""Device-resident baseline policy steps — classic policies as scan automata.

The paper's comparison baselines (LRU / FIFO / LFU, the no-regret FTPL of
Bhattacharjee et al. and OMD of Si Salem et al.) were host-side per-request
Python loops (:mod:`repro.core.policies` driven by
:func:`repro.cachesim.simulator.simulate`), which caps every comparison figure
at toy scale while OGB alone rides the ``lax.scan`` replay engine
(:mod:`repro.cachesim.replay`).  This module gives each baseline the same
device-resident treatment:

* **LRU / FIFO** — fixed-size slot arrays ``(slots, stamps)``: membership is a
  C-wide compare, the victim is ``argmin(stamps)`` (last-use time for LRU,
  insertion time for FIFO).  Bit-exact vs the OrderedDict policies: the hit
  sequence depends only on the membership set, which is fully determined by
  the timestamp map.
* **LFU** — perfect-frequency counters over the catalog plus slot arrays with
  the Python policy's exact ``(freq, tick)`` eviction key and "admit only if
  the newcomer's frequency beats the victim's" rule, via a two-stage argmin
  (min frequency, then min tick).
* **FTPL** — perturbed counters ``count + noise`` with top-C membership
  maintained by single-swap eviction.  The noise is the *same float32 grid*
  the host policy uses (:func:`repro.core.ftpl.ftpl_noise`), and scores are
  float32 IEEE adds on both sides, so agreement is bit-exact, not approximate.
* **OMD** — negative-entropy mirror descent (multiplicative weights with a
  KL projection onto the capped simplex), sharing the warm-bracket idea of
  :func:`repro.jaxcache.fractional.capped_simplex_project_warm`: after the
  log-weight step the threshold provably lies in ``[0, eta * B]``, and a few
  safeguarded Newton sweeps replace a cold bisection.

This module owns the raw per-request/per-chunk step functions and carries;
the execution layer lives in :mod:`repro.cachesim.api`, where every kind is
registered as a :class:`~repro.cachesim.api.PolicyDef` and replayed/swept by
the one generic engine.  The legacy entry points here (``run_engine`` /
``run_omd`` / ``sweep_engine``) are deprecated thin wrappers over that API.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.replay import sample_chunk_metrics
from repro.cachesim.results import RunResult, SweepResult
from repro.core.ftpl import ftpl_initial_top_c, ftpl_noise, theoretical_zeta
from repro.jaxcache.fractional import warm_bracket_hi

_I32_MAX = np.int32(np.iinfo(np.int32).max)
_I32_MIN = np.int32(np.iinfo(np.int32).min)

#: kinds compiled by this module as discrete slot automata
ENGINE_KINDS = ("lru", "fifo", "lfu", "ftpl")
DEFAULT_OMD_SWEEPS = 10

#: legacy names — the five result dataclasses are unified in
#: :mod:`repro.cachesim.results`
EngineResult = RunResult
EngineSweepResult = SweepResult


# ---------------------------------------------------------------------------
# carries — ReplayCarry-style NamedTuples of fixed-shape device arrays
# ---------------------------------------------------------------------------
class SlotCarry(NamedTuple):
    """LRU / FIFO state: C slots with an eviction timestamp each.

    Slot ids: ``-1`` empty (fillable), ``-2`` inactive (capacity padding for
    vmapped sweeps over capacities; never matched, never evicted into).
    """

    slots: jax.Array  # (K,) int32 item ids
    stamps: jax.Array  # (K,) int32; empty = -1, inactive = INT32_MAX
    t: jax.Array  # () int32 request clock


class LFUCarry(NamedTuple):
    slots: jax.Array  # (K,) int32 item ids (-1 empty, -2 inactive)
    ticks: jax.Array  # (K,) int32 tie-break clock; inactive = INT32_MAX
    counts: jax.Array  # (N,) int32 perfect-LFU counters
    t: jax.Array  # () int32


class FTPLCarry(NamedTuple):
    slots: jax.Array  # (K,) int32 item ids (-2 inactive; always C cached)
    counts: jax.Array  # (N,) int32 request counters
    noise: jax.Array  # (N,) float32 one-shot perturbation (constant)


class OMDCarry(NamedTuple):
    """Normalized log-weight state: f = min(1, exp(w)) is always feasible."""

    f: jax.Array  # (N,) float32 fractional cache state
    w: jax.Array  # (N,) float32 log-weights, renormalized every chunk
    lam: jax.Array  # () float32 last chunk's KL-projection threshold
    counts: jax.Array  # (N,) float32 whole-trace histogram (hindsight OPT)


def _padded(active: np.ndarray, n_slots: int, inactive_val: int) -> jnp.ndarray:
    pad = n_slots - len(active)
    if pad < 0:
        raise ValueError(f"n_slots {n_slots} < capacity {len(active)}")
    return jnp.asarray(
        np.concatenate([active, np.full(pad, inactive_val, active.dtype)])
    )


def init_engine_carry(
    kind: str,
    catalog_size: int,
    capacity: int,
    *,
    n_slots: Optional[int] = None,
    seed: int = 0,
    zeta: Optional[float] = None,
    horizon: Optional[int] = None,
):
    """Build the initial carry for one automaton.

    ``n_slots`` > capacity pads with inactive slots so carries for different
    capacities share a shape (the vmapped-sweep requirement).
    """
    K = int(n_slots) if n_slots else int(capacity)
    C = int(capacity)
    if kind in ("lru", "fifo"):
        return SlotCarry(
            slots=_padded(np.full(C, -1, np.int32), K, -2),
            stamps=_padded(np.full(C, -1, np.int32), K, _I32_MAX),
            t=jnp.zeros((), jnp.int32),
        )
    if kind == "lfu":
        return LFUCarry(
            slots=_padded(np.full(C, -1, np.int32), K, -2),
            ticks=_padded(np.full(C, -1, np.int32), K, _I32_MAX),
            counts=jnp.zeros(catalog_size, jnp.int32),
            t=jnp.zeros((), jnp.int32),
        )
    if kind == "ftpl":
        if zeta is None:
            if horizon is None:
                raise ValueError("ftpl needs zeta or horizon")
            zeta = theoretical_zeta(C, catalog_size, horizon)
        noise = ftpl_noise(catalog_size, zeta, seed=seed)
        top = ftpl_initial_top_c(noise, C).astype(np.int32)
        return FTPLCarry(
            slots=_padded(top, K, -2),
            counts=jnp.zeros(catalog_size, jnp.int32),
            noise=jnp.asarray(noise),
        )
    raise ValueError(f"unknown engine kind {kind!r} (have {ENGINE_KINDS})")


# ---------------------------------------------------------------------------
# per-request steps — each mirrors its core/policies.py counterpart exactly
# ---------------------------------------------------------------------------
def _lru_step(carry: SlotCarry, j):
    slots, stamps, t = carry
    match = slots == j
    hit = jnp.any(match)
    # one fused pass: a matching slot outranks every timestamp, so argmin is
    # the hit slot on a hit and the oldest (or first empty) slot on a miss
    idx = jnp.argmin(jnp.where(match, _I32_MIN, stamps))
    slots = slots.at[idx].set(j)  # no-op on hit (slot already holds j)
    stamps = stamps.at[idx].set(t)  # refresh-on-hit == LRU
    return SlotCarry(slots, stamps, t + 1), hit


def _fifo_step(carry: SlotCarry, j):
    slots, stamps, t = carry
    match = slots == j
    hit = jnp.any(match)
    idx = jnp.argmin(jnp.where(match, _I32_MIN, stamps))
    # FIFO never refreshes: on a hit both writes are no-ops
    slots = slots.at[idx].set(j)
    stamps = stamps.at[idx].set(jnp.where(hit, stamps[idx], t))
    return SlotCarry(slots, stamps, t + 1), hit


def _lfu_step(carry: LFUCarry, j):
    slots, ticks, counts, t = carry
    counts = counts.at[j].add(1)
    f = counts[j]
    match = slots == j
    hit = jnp.any(match)
    # per-slot eviction key (freq, tick): empty slots (-1) sort below any real
    # frequency >= 1 so they fill first; inactive slots (-2) sort above all
    sf = jnp.where(
        slots >= 0,
        counts[jnp.maximum(slots, 0)],
        jnp.where(slots == -1, jnp.int32(-1), _I32_MAX),
    )
    minf = jnp.min(sf)
    victim = jnp.argmin(jnp.where(sf == minf, ticks, _I32_MAX))
    idx = jnp.where(hit, jnp.argmax(match), victim)
    # admission: the newcomer must match the victim's frequency (policies.LFU)
    write = jnp.logical_or(hit, f >= minf)
    slots = slots.at[idx].set(jnp.where(write, j, slots[idx]))
    ticks = ticks.at[idx].set(jnp.where(write, t, ticks[idx]))
    return LFUCarry(slots, ticks, counts, t + 1), hit


def _ftpl_step(carry: FTPLCarry, j):
    slots, counts, noise = carry
    counts = counts.at[j].add(1)
    s = counts[j].astype(jnp.float32) + noise[j]
    match = slots == j
    hit = jnp.any(match)
    si = jnp.maximum(slots, 0)
    sscore = jnp.where(
        slots >= 0, counts[si].astype(jnp.float32) + noise[si], jnp.inf
    )
    mins = jnp.min(sscore)
    # ties break by item id, matching the host policy's (score, item) store
    victim = jnp.argmin(jnp.where(sscore == mins, slots, _I32_MAX))
    swap = jnp.logical_and(~hit, s > mins)  # strict >, like the host policy
    slots = slots.at[victim].set(jnp.where(swap, j, slots[victim]))
    return FTPLCarry(slots, counts, noise), hit


def _occ_slots(carry) -> jax.Array:
    return jnp.sum((carry.slots >= 0).astype(jnp.int32))


_STEPS = {
    "lru": _lru_step,
    "fifo": _fifo_step,
    "lfu": _lfu_step,
    "ftpl": _ftpl_step,
}


def make_engine_run(kind: str):
    """Unjitted whole-trace automaton: ``run(carry, chunks) -> (carry, ys)``.

    ``chunks`` is (M, W) int32; ``ys`` stacks per-chunk (hits, occupancy).
    Kept unjitted so sweeps can ``vmap`` it; callers wanting a single replay
    should use :func:`make_engine_fn`.
    """
    step = _STEPS[kind]

    def run(carry, chunks):
        def outer(c, ids):
            c, hits = jax.lax.scan(step, c, ids)
            return c, (jnp.sum(hits.astype(jnp.int32)), _occ_slots(c))

        return jax.lax.scan(outer, carry, chunks)

    return run


@functools.lru_cache(maxsize=None)
def make_engine_fn(kind: str):
    """Jitted (donated-carry) form of :func:`make_engine_run`."""
    return jax.jit(make_engine_run(kind), donate_argnums=(0,))


# ---------------------------------------------------------------------------
# OMD — mirror-descent fractional step (multiplicative analogue of replay)
# ---------------------------------------------------------------------------
def _omd_project(w, cap, hi, sweeps):
    """Safeguarded-Newton KL threshold: lam with sum min(1, e^(w-lam)) = C.

    For feasible pre-step weights the root provably lies in [0, hi] where hi
    covers the added gradient mass eta*B (same invariant as
    ``warm_bracket_hi``): weights only grew, so mass(0) >= C, and every
    log-weight grew by at most eta*B, so mass(eta*B) <= C.  g is convex and
    decreasing, so Newton from the mass-excess side converges monotonically;
    the bisection midpoint safeguards the other side.
    """
    cap = jnp.float32(cap)

    def body(_, c):
        lo, hi, t = c
        e = jnp.exp(w - t)
        fcur = jnp.minimum(1.0, e)
        mass = jnp.sum(fcur)
        interior = jnp.sum(jnp.where(e < 1.0, e, 0.0))
        too_much = mass >= cap
        lo = jnp.where(too_much, t, lo)
        hi = jnp.where(too_much, hi, t)
        t_newton = t + (mass - cap) / jnp.maximum(interior, 1e-12)
        t_mid = 0.5 * (lo + hi)
        ok = jnp.logical_and(t_newton >= lo, t_newton <= hi)
        return lo, hi, jnp.where(ok, t_newton, t_mid)

    lo0 = jnp.float32(0.0)
    _lo, _hi, lam = jax.lax.fori_loop(
        0, sweeps, body, (lo0, jnp.float32(hi), lo0)
    )
    return lam


def _make_omd_step(
    sample: str,
    sweeps: int,
    track_opt: bool,
    madow_capacity: Optional[int] = None,
):
    """The per-chunk OMD update, with *traced* eta and capacity — the
    mirror-descent counterpart of :func:`repro.cachesim.replay._make_ogb_step`
    (same ``step(eta, p, cap, carry, xs)`` contract)."""
    if sample not in ("poisson", "madow", "madow_tree", "none"):
        raise ValueError(f"unknown sample mode {sample!r}")
    if sample in ("madow", "madow_tree") and madow_capacity is None:
        raise ValueError("madow sampling needs a static capacity")

    def step(eta, p, cap, carry, xs):
        f, w, _lam, counts_tot = carry
        ids, u = xs
        reward, hits, occ = sample_chunk_metrics(
            sample, madow_capacity, f, ids, p, u
        )
        w = w.at[ids].add(eta)
        lam = _omd_project(
            w, cap, warm_bracket_hi(eta * jnp.float32(ids.shape[0])), sweeps
        )
        w = w - lam  # renormalize: f = min(1, e^w) stays threshold-free
        f_new = jnp.minimum(1.0, jnp.exp(w))
        if track_opt:
            counts_tot = counts_tot.at[ids].add(1.0)
        return OMDCarry(f_new, w, lam, counts_tot), (reward, hits, lam, occ)

    return step


@functools.lru_cache(maxsize=64)
def make_omd_fn(
    catalog_size: int,
    capacity: int,
    batch: int,
    sample: str = "poisson",
    sweeps: int = DEFAULT_OMD_SWEEPS,
    track_opt: bool = True,
):
    """Jitted whole-trace OMD replay, interface-compatible with
    :func:`repro.cachesim.replay.make_replay_fn`:
    ``replay(carry, chunks, eta, p, us) -> (carry', opt_hits, ys)``.
    """
    step = _make_omd_step(sample, sweeps, track_opt, madow_capacity=capacity)
    cap_f = float(capacity)

    def replay(carry, chunks, eta, p, us):
        m = chunks.shape[0]
        if us.shape[0] != m:
            us = jnp.zeros((m,), jnp.float32)
        carry, ys = jax.lax.scan(
            lambda c, x: step(eta, p, jnp.float32(cap_f), c, x),
            carry,
            (chunks, us),
        )
        if track_opt:
            opt = jnp.sum(jax.lax.top_k(carry.counts, capacity)[0])
        else:
            opt = jnp.zeros((), jnp.float32)
        return carry, opt, ys

    return jax.jit(replay, donate_argnums=(0,))


def init_omd_carry(catalog_size: int, capacity: int) -> OMDCarry:
    f0 = capacity / catalog_size
    return OMDCarry(
        f=jnp.full(catalog_size, f0, jnp.float32),
        w=jnp.full(catalog_size, float(np.log(f0)), jnp.float32),
        lam=jnp.zeros((), jnp.float32),
        counts=jnp.zeros(catalog_size, jnp.float32),
    )


# ---------------------------------------------------------------------------
# deprecated entry points — thin wrappers over the unified policy engine
# ---------------------------------------------------------------------------
def run_engine(
    kind: str,
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    *,
    window: int = 10_000,
    seed: int = 0,
    zeta: Optional[float] = None,
    horizon: Optional[int] = None,
    name: Optional[str] = None,
) -> RunResult:
    """Replay a whole trace through one scan automaton.

    .. deprecated::
        Use ``api.run(api.policy_def(kind), trace, N, C, window=...)``
        (:mod:`repro.cachesim.api`).
    """
    warnings.warn(
        "run_engine is deprecated; use repro.cachesim.api.run("
        f"policy_def({kind!r}), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.cachesim import api

    return api.run(
        api.policy_def(kind),
        trace,
        catalog_size,
        capacity,
        window=window,
        seed=seed,
        horizon=horizon,
        track_opt=False,
        keep_carry=False,  # legacy EngineResult carried no final state
        name=name,
        zeta=zeta,
    )


def engine_hit_sequence(
    kind: str,
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    **kw,
) -> np.ndarray:
    """Per-request hit flags (window=1) — the differential-testing probe."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = run_engine(kind, trace, catalog_size, capacity, window=1, **kw)
    return res.hits.astype(bool)


def run_omd(
    trace: np.ndarray,
    catalog_size: int,
    capacity: int,
    batch: int,
    *,
    eta: Optional[float] = None,
    sample: str = "poisson",
    sweeps: int = DEFAULT_OMD_SWEEPS,
    seed: int = 0,
    track_opt: bool = True,
    keep_final_f: bool = False,
    name: str = "OMD",
) -> RunResult:
    """Replay a whole trace through the scan-compiled OMD engine.

    .. deprecated::
        Use ``api.run(api.policy_def("omd", ...), trace, N, C,
        window=batch)`` (:mod:`repro.cachesim.api`).  Under
        ``sample="madow"`` the per-chunk offsets are counter-derived from
        the carried key (see :func:`repro.cachesim.replay.replay_trace`).
    """
    warnings.warn(
        "run_omd is deprecated; use repro.cachesim.api.run("
        "policy_def('omd'), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.cachesim import api

    opts = dict(sample=sample, sweeps=sweeps)
    if sample == "madow":
        opts["madow_capacity"] = int(capacity)
    res = api.run(
        api.policy_def("omd", **opts),
        trace,
        catalog_size,
        capacity,
        window=batch,
        eta=eta,
        seed=seed,
        track_opt=track_opt,
        keep_carry=keep_final_f,  # legacy footprint: final state is opt-in
        name=name,
    )
    res.extras["sweeps"] = float(sweeps)
    return res


def sweep_engine(
    kind: str,
    trace: np.ndarray,
    catalog_size: int,
    capacities: Sequence[int],
    *,
    seeds: Sequence[int] = (0,),
    window: int = 10_000,
    zeta: Optional[float] = None,
    horizon: Optional[int] = None,
    track_opt: bool = True,
) -> SweepResult:
    """Run one automaton over a (capacity x seed) grid in a single dispatch.

    .. deprecated::
        Use ``api.sweep(api.policy_def(kind), trace, N, capacities, ...)``
        (:mod:`repro.cachesim.api`).
    """
    warnings.warn(
        "sweep_engine is deprecated; use repro.cachesim.api.sweep("
        f"policy_def({kind!r}), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.cachesim import api

    return api.sweep(
        api.policy_def(kind),
        trace,
        catalog_size,
        capacities,
        seeds=seeds,
        window=window,
        horizon=horizon,
        track_opt=track_opt,
        zeta=zeta,
    )
