"""One optax-style policy protocol behind a single run/sweep engine.

The paper's thesis is that gradient-based caching (OGB) and its no-regret
cousins (OMD, FTPL) are interchangeable points in one online-optimization
design space.  This module makes the code say so: every policy — fractional
gradient policies and discrete slot automata alike — is a

    :class:`PolicyDef`:
        ``init(catalog_size, capacity, *, seed, eta, horizon, n_slots)
        -> carry``          (a pytree ``NamedTuple`` of device arrays)
        ``step(carry, request_ids) -> (carry, StepOut)``   (pure, scannable)

and exactly one execution layer drives them all:

* :func:`run` — a single donated-carry ``lax.scan`` over the chunked trace.
  Resumable: it accepts and returns the carry, so a trace can be streamed
  chunk by chunk (the serving integration uses the same contract one step
  at a time).
* :func:`sweep` — one ``vmap``-ped dispatch over a (capacities x seeds x
  etas) grid of stacked carries, capacity-padded for the automata.

Adding a policy, a sweep axis, or a serving integration is one
registration (:func:`register_policy_def`) — not a fourth execution stack.
All per-combo parameters (eta, capacity, sampling randomness) live *in the
carry* as traced arrays, which is what makes one compiled step serve both
the single replay and the whole grid.

Hindsight static-OPT is computed host-side from the trace histogram (exact
int64, cheaper than carrying per-combo count arrays on device).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim import engines as _engines
from repro.cachesim import tree_engines as _tree_engines
from repro.cachesim.replay import (
    _make_ogb_step,
    opt_hits_by_combo,
    sampling_keys,
)
from repro.cachesim.results import RunResult, SweepResult
from repro.core.ogb import theoretical_eta
from repro.core.omd import theoretical_eta_omd
from repro.core.policies import ENGINE_DEFS, register_engine_def
from repro.core.regret import best_static_hits
from repro.kernels.capped_simplex.ops import weighted_simplex_project
from repro.jaxcache.fractional import (
    DEFAULT_BISECT_ITERS,
    DEFAULT_WARM_SWEEPS,
    capped_simplex_project,
    permanent_random_numbers,
    poisson_sample,
)

__all__ = [
    "PolicyDef",
    "StepOut",
    "RunResult",
    "SweepResult",
    "policy_def",
    "policy_def_kinds",
    "register_policy_def",
    "run",
    "sweep",
]


class StepOut(NamedTuple):
    """Per-chunk observables every policy step emits.

    ``reward`` is the *pre-update* fractional reward (OCO order) — equal to
    ``hits`` for the integral automata; ``aux`` is the projection threshold
    (tau for OGB, lambda for OMD, 0 for automata).  ``byte_hits`` is the
    size-weighted hit mass of sized runs; the default ``None`` is an empty
    pytree node, so unsized steps/carries are structurally unchanged and
    every existing golden stays bit-exact."""

    reward: jax.Array  # () float32
    hits: jax.Array  # () int32
    aux: jax.Array  # () float32
    occupancy: jax.Array  # () float32
    byte_hits: Any = None  # () float32 for sized runs, else None


@dataclass(frozen=True)
class PolicyDef:
    """An optax-style ``(init, step)`` caching policy.

    ``init`` builds the carry — a pytree ``NamedTuple`` holding the policy
    state *and* its traced parameters (eta, capacity, sampling randomness),
    so ``step`` is a pure function of ``(carry, request_ids)`` and a stack
    of carries vmaps into a parameter sweep.  ``default_eta`` resolves
    ``eta=None`` at :func:`run`/:func:`sweep` time from
    ``(catalog_size, capacity, horizon, window)``.
    """

    kind: str
    name: str  # display name used in result rows ("OGB", "LRU", ...)
    init: Callable[..., Any]
    step: Callable[[Any, jax.Array], Tuple[Any, StepOut]]
    fractional: bool = False
    default_eta: Optional[Callable[[int, int, int, int], float]] = None
    #: step consumes request-id chunks (False for gradient-vector flavors
    #: like ogb_grad, which stream dense per-item weights instead and are
    #: excluded from trace replays/scenario sweeps)
    trace_driven: bool = True


# ---------------------------------------------------------------------------
# registry — backed by the core policy table (core/policies.ENGINE_DEFS)
# ---------------------------------------------------------------------------
def register_policy_def(kind: str, factory: Callable[..., PolicyDef]) -> None:
    """Register a :class:`PolicyDef` factory under a kind string.

    ``factory(**static_options) -> PolicyDef``; static options are things
    that change the compiled step (sample mode, projection flavor, sweep
    counts) as opposed to traced parameters, which belong in the carry.
    """
    register_engine_def(kind, factory)


def policy_def_kinds() -> tuple:
    """All registered device-engine kind strings."""
    return tuple(ENGINE_DEFS)


@functools.lru_cache(maxsize=None)
def _cached_def(kind: str, options: tuple) -> PolicyDef:
    return ENGINE_DEFS[kind](**dict(options))


def policy_def(kind: str, **options) -> PolicyDef:
    """Resolve a registered kind to a (memoized) :class:`PolicyDef`.

    Memoization matters: the returned def's ``step`` identity keys the
    compiled-executable cache, so repeat calls reuse compilations.
    """
    kind = kind.lower()
    if kind not in ENGINE_DEFS:
        raise KeyError(
            f"unknown policy kind {kind!r}; registered: {sorted(ENGINE_DEFS)}"
        )
    return _cached_def(kind, tuple(sorted(options.items())))


# ---------------------------------------------------------------------------
# the one execution layer
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _scan_jit(step):
    def run_fn(carry, chunks):
        return jax.lax.scan(step, carry, chunks)

    return jax.jit(run_fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _sweep_jit(step):
    def one(carry, chunks):
        return jax.lax.scan(step, carry, chunks)

    return jax.jit(jax.vmap(one, in_axes=(0, None)), donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _fleet_jit(step):
    """Tenant-vmapped scan: stacked carries (E, ...) x chunks (E, M, W).

    Unlike :func:`_sweep_jit` (one shared trace fanned over combos), every
    tenant replays its *own* chunk stream — ``in_axes=(0, 0)``.  Memoized so
    the jitted wrapper's identity keys the executable cache."""

    def one(carry, chunks):
        return jax.lax.scan(step, carry, chunks)

    return jax.jit(jax.vmap(one, in_axes=(0, 0)), donate_argnums=(0,))


_EXEC_CACHE: dict = {}

#: observers notified once per executable-cache miss (see
#: repro.analysis.recompile.track_compiles); each gets a small info dict
_COMPILE_LISTENERS: list = []


def add_compile_listener(cb) -> None:
    """Subscribe ``cb(info: dict)`` to executable-cache misses."""
    _COMPILE_LISTENERS.append(cb)


def remove_compile_listener(cb) -> None:
    try:
        _COMPILE_LISTENERS.remove(cb)
    except ValueError:
        pass


def clear_executable_cache() -> None:
    """Drop every memoized compiled executable (tests use this to measure
    cold-path compile counts deterministically).  The jitted wrappers in
    ``_scan_jit``/``_sweep_jit``/``_fleet_jit`` stay cached, so step
    identities — and therefore cache keys — remain stable."""
    _EXEC_CACHE.clear()


def _compiled(jitted, carry, chunks):
    """AOT-compiled executable, memoized on (step, carry/chunk shapes).

    ``jit.lower().compile()`` bypasses jit's own call cache, so without this
    every :func:`run` would recompile; with it, repeated runs of the same
    shapes (goldens, parity tests, benchmark repeats) compile once."""
    key = (
        id(jitted),  # _scan_jit/_sweep_jit are memoized, so ids are stable
        chunks.shape,
        jax.tree.structure(carry),
        tuple((x.shape, str(x.dtype)) for x in jax.tree.leaves(carry)),
    )
    if key not in _EXEC_CACHE:
        _EXEC_CACHE[key] = jitted.lower(carry, chunks).compile()
        if _COMPILE_LISTENERS:
            info = {
                "name": getattr(
                    getattr(jitted, "__wrapped__", jitted),
                    "__name__",
                    "<jit>",
                ),
                "chunks_shape": tuple(chunks.shape),
                "n_carry_leaves": len(jax.tree.leaves(carry)),
            }
            for cb in list(_COMPILE_LISTENERS):
                cb(info)
    return _EXEC_CACHE[key]


def _chunked(trace: np.ndarray, window: int):
    trace = np.asarray(trace)
    m = len(trace) // window
    if m == 0:
        raise ValueError(
            f"trace shorter than one window ({len(trace)} < {window})"
        )
    t_used = m * window
    return (
        jnp.asarray(trace[:t_used].reshape(m, window), jnp.int32),
        trace[:t_used],
        t_used,
    )


def run(
    pd: PolicyDef,
    trace: np.ndarray,
    catalog_size: Optional[int] = None,
    capacity: Optional[int] = None,
    *,
    window: int = 1000,
    carry: Any = None,
    seed: int = 0,
    eta: Optional[float] = None,
    horizon: Optional[int] = None,
    n_slots: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    track_opt: bool = True,
    keep_carry: bool = True,
    name: Optional[str] = None,
    block: bool = True,
    **init_kw,
) -> RunResult:
    """Replay a whole trace through one policy: a single donated-carry scan.

    The trace is reshaped into ``(T // window, window)`` chunks (a trailing
    partial chunk is dropped); ``window`` is the OGB/OMD update batch B and
    the hit-accounting granularity for the automata.  ``eta=None`` resolves
    through ``pd.default_eta`` for the replayed horizon.

    **Streaming contract:** pass ``carry=result.carry`` from a previous call
    to resume exactly where it left off — two chunked runs replay the same
    dynamics as one full run, bit for bit.  The carry is *donated* to the
    device computation, so hand it off (references kept to a resumed-from
    carry are invalidated).  When resuming, ``catalog_size`` is not needed;
    ``capacity`` is still used for OPT/bookkeeping, and the init-time
    parameters (``seed``/``eta``/``horizon``/...) must not be passed — the
    carry already holds them.  Pass ``keep_carry=False`` when the result is
    only read for metrics: the final carry is several (N,)-sized device
    arrays, and dropping it releases that memory immediately (results
    accumulated in a sweep loop otherwise pin it for their lifetime).

    **Sized runs:** pass per-item ``sizes`` (bytes) to thread the paper's
    cost-aware setting through: sized policies (``ogb_sized``, ``gds``)
    shape their decisions with them, the automata account size-weighted
    (byte) hits, and the result gains ``byte_hits``/``bytes_total`` so
    ``byte_hit_ratio`` reflects bytes served from cache.  ``costs``
    overrides the per-item miss costs (default: the sizes).  On resume
    the carry already holds the policy-side sizes; ``sizes`` may still be
    passed for the host-side byte accounting.

    **Non-blocking dispatch:** ``block=False`` returns as soon as the scan
    is *dispatched* — the result's ``reward``/``hits``/``aux``/
    ``occupancy``/``byte_hits`` (and the carry) are still device arrays
    backed by in-flight computation, and ``wall_seconds`` measures only
    the dispatch.  Call ``jax.block_until_ready`` (then ``np.asarray``)
    at the consume point.  The async streaming pipeline
    (:func:`repro.cachesim.tracelab.stream.run_stream`) uses this to
    overlap host ingest with device replay; the returned carry can be fed
    straight back into the next ``run`` — JAX chains the dispatches.
    """
    chunks, trace_used, t_used = _chunked(trace, window)
    extras = {}
    if carry is None:
        if catalog_size is None or capacity is None:
            raise ValueError("run() needs catalog_size and capacity (or carry=)")
        if eta is None and pd.default_eta is not None:
            eta = pd.default_eta(
                int(catalog_size), int(capacity), t_used, window
            )
        sized_kw = {}
        if sizes is not None:
            sized_kw["sizes"] = np.asarray(sizes)
        if costs is not None:
            sized_kw["costs"] = np.asarray(costs)
        carry = pd.init(
            int(catalog_size),
            int(capacity),
            seed=seed,
            eta=eta,
            horizon=int(horizon) if horizon is not None else t_used,
            n_slots=n_slots,
            **sized_kw,
            **init_kw,
        )
        if eta is not None:
            extras["eta"] = float(eta)
    elif (
        eta is not None
        or horizon is not None
        or n_slots is not None
        or seed != 0
        or costs is not None
        or any(v is not None for v in init_kw.values())
    ):
        # a resumed run takes every policy parameter from the carry; a
        # silently-ignored eta or seed would mislabel sweep results
        # (sizes= stays allowed: it only drives host-side byte accounting)
        raise ValueError(
            "run(carry=...) resumes with the carry's parameters; do not "
            "pass seed/eta/horizon/n_slots/costs/init kwargs alongside a "
            "carry"
        )
    compiled = _compiled(_scan_jit(pd.step), carry, chunks)
    t0 = time.perf_counter()
    carry, out = compiled(carry, chunks)
    if block:
        jax.block_until_ready((carry, out))
    wall = time.perf_counter() - t0
    opt = (
        float(best_static_hits(trace_used, int(capacity)))
        if (track_opt and capacity is not None)
        else 0.0
    )
    bytes_total = 0.0
    if sizes is not None:
        bytes_total = float(
            np.sum(np.asarray(sizes, np.float64)[trace_used])
        )
    if block:
        reward = np.asarray(out.reward, np.float64)
        hits = np.asarray(out.hits, np.int64)
        aux = np.asarray(out.aux, np.float64)
        occupancy = np.asarray(out.occupancy, np.float64)
        byte_hits = (
            np.asarray(out.byte_hits, np.float64)
            if out.byte_hits is not None
            else None
        )
    else:
        # in-flight device arrays: np.asarray here would silently block
        reward, hits, aux, occupancy = (
            out.reward, out.hits, out.aux, out.occupancy
        )
        byte_hits = out.byte_hits
    return RunResult(
        name=name or pd.name,
        kind=pd.kind,
        T=t_used,
        window=window,
        capacity=int(capacity) if capacity is not None else -1,
        reward=reward,
        hits=hits,
        aux=aux,
        occupancy=occupancy,
        opt_hits=opt,
        carry=carry if keep_carry else None,
        wall_seconds=wall,
        extras=extras,
        byte_hits=byte_hits,
        bytes_total=bytes_total,
    )


def sweep(
    pd: PolicyDef,
    trace: np.ndarray,
    catalog_size: int,
    capacities: Sequence[int],
    *,
    etas: Sequence[Optional[float]] = (None,),
    seeds: Sequence[int] = (0,),
    window: int = 1000,
    horizon: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    track_opt: bool = True,
    **init_kw,
) -> SweepResult:
    """Run a whole (seeds x etas x capacities) grid in one vmapped dispatch.

    One carry per combo is built by ``pd.init`` (automata are padded to
    ``max(capacities)`` slots so the stacked carries share a shape), the
    stack is ``vmap``-ed over with the trace broadcast, and the entire grid
    costs one compile + one device round-trip.  ``eta=None`` entries resolve
    to ``pd.default_eta`` for that combo's capacity, so default-tuned sweep
    rows reproduce default-tuned single runs exactly.  OPT is computed
    host-side per capacity (it depends only on the trace histogram).
    """
    chunks, trace_used, t_used = _chunked(trace, window)
    if horizon is None:
        horizon = t_used
    n_slots = int(max(capacities))
    sized_kw = {}
    if sizes is not None:
        sized_kw["sizes"] = np.asarray(sizes)
    if costs is not None:
        sized_kw["costs"] = np.asarray(costs)
    combos, carries = [], []
    for s in seeds:
        for eta in etas:
            for C in capacities:
                e = eta
                if e is None and pd.default_eta is not None:
                    e = pd.default_eta(
                        int(catalog_size), int(C), t_used, window
                    )
                combo = {"capacity": int(C), "seed": int(s)}
                if pd.fractional and e is not None:
                    # ogb_sized resolves eta=None inside init (it needs the
                    # sizes); its default-tuned combos just omit the key
                    combo["eta"] = float(e)
                combos.append(combo)
                carries.append(
                    pd.init(
                        int(catalog_size),
                        int(C),
                        seed=int(s),
                        eta=e,
                        horizon=int(horizon),
                        n_slots=n_slots,
                        **sized_kw,
                        **init_kw,
                    )
                )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *carries)
    compiled = _compiled(_sweep_jit(pd.step), stacked, chunks)
    t0 = time.perf_counter()
    _carry, out = compiled(stacked, chunks)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    opt = (
        opt_hits_by_combo(trace_used, combos)
        if track_opt
        else np.zeros(len(combos))
    )
    bytes_total = 0.0
    if sizes is not None:
        bytes_total = float(
            np.sum(np.asarray(sizes, np.float64)[trace_used])
        )
    return SweepResult(
        kind=pd.kind,
        combos=combos,
        T=t_used,
        window=window,
        reward=np.asarray(out.reward, np.float64),
        hits=np.asarray(out.hits, np.int64),
        aux=np.asarray(out.aux, np.float64),
        occupancy=np.asarray(out.occupancy, np.float64),
        opt_hits=opt,
        wall_seconds=wall,
        byte_hits=(
            np.asarray(out.byte_hits, np.float64)
            if out.byte_hits is not None
            else None
        ),
        bytes_total=bytes_total,
    )


# ---------------------------------------------------------------------------
# carries for the fractional policies (state + traced params + sampling rng)
# ---------------------------------------------------------------------------
class OGBCarry(NamedTuple):
    """OGB_cl state with its per-combo parameters as traced leaves."""

    f: jax.Array  # (N,) float32 fractional state
    tau: jax.Array  # () float32 previous chunk's projection threshold
    eta: jax.Array  # () float32 learning rate
    cap: jax.Array  # () float32 capacity
    p: jax.Array  # (N,) permanent random numbers (poisson) or (0,)
    u_key: jax.Array  # (2,) uint32 key data for per-chunk Madow offsets
    t: jax.Array  # () int32 chunk counter


class SizedAutomatonCarry(NamedTuple):
    """A discrete automaton carry paired with per-item byte sizes.

    The inner automaton is size-blind (its decisions are unchanged by
    construction — same hit flags as the unsized carry); the sizes only
    weight the hit accounting, turning ``StepOut.byte_hits`` on.  The
    wrapper changes the carry pytree structure, so sized and unsized runs
    compile separately and unsized goldens stay bit-exact."""

    inner: Any  # the unchanged automaton carry (tree or dense)
    szs: jax.Array  # (N,) float32 per-item sizes (bytes)


def _sizes_array(sizes, catalog_size: int) -> jnp.ndarray:
    s = np.asarray(sizes, np.float32)
    if s.shape != (int(catalog_size),):
        raise ValueError(
            f"sizes must be a ({catalog_size},) array, got {s.shape}"
        )
    if not (np.all(np.isfinite(s)) and float(s.min()) > 0.0):
        raise ValueError("sizes must be finite and > 0")
    return jnp.asarray(s)


class SizedOGBScanCarry(NamedTuple):
    """Dense (scan-flavor) sized-OGB state: exact per-item sizes, O(N)
    weighted projection per chunk.  The differential oracle for the
    O(K log N) tree flavor.  Sizes/costs are normalized by their mean
    (``sref``) so uniform sizes reduce to the unit OGB dynamics at the
    same eta; byte outputs are scaled back by ``sref``."""

    f: jax.Array  # (N,) float32 projected fractional state
    tau: jax.Array  # () float32 last weighted-projection threshold
    eta: jax.Array  # () float32
    cap: jax.Array  # () float32 capacity in normalized bytes
    s: jax.Array  # (N,) float32 normalized exact per-item sizes
    wts: jax.Array  # (N,) float32 normalized gradient weights (costs)
    sref: jax.Array  # () float32 bytes per normalized size unit
    p: jax.Array  # (N,) float32 permanent random numbers, or (0,)
    t: jax.Array  # () int32 chunk counter


class OMDApiCarry(NamedTuple):
    """OMD log-weight state with its per-combo parameters as traced leaves."""

    f: jax.Array  # (N,) float32 fractional state
    w: jax.Array  # (N,) float32 log-weights (renormalized every chunk)
    lam: jax.Array  # () float32 last KL-projection threshold
    eta: jax.Array  # () float32
    cap: jax.Array  # () float32
    p: jax.Array  # (N,) or (0,)
    u_key: jax.Array  # (2,) uint32
    t: jax.Array  # () int32


def _sampling_init(seed: int, catalog_size: int, sample: str):
    """(p, u_key): the shared seed derivation
    (:func:`repro.cachesim.replay.sampling_keys`), with the Madow key as
    raw key data so it stacks/donates like any other carry leaf."""
    p, k_u = sampling_keys(seed, catalog_size, sample)
    return p, jax.random.key_data(k_u)


def _chunk_u(sample: str, u_key: jax.Array, t: jax.Array) -> jax.Array:
    """Per-chunk Madow offset, derived from the carried key + chunk counter
    (counter-mode so streamed/resumed runs draw the same sequence)."""
    if sample not in ("madow", "madow_tree"):
        return jnp.zeros((), jnp.float32)
    k = jax.random.fold_in(jax.random.wrap_key_data(u_key), t)
    return jax.random.uniform(k, (), jnp.float32)


_EMPTY_COUNTS = None  # lazily-created (0,) placeholder for untracked OPT


def _empty_counts():
    global _EMPTY_COUNTS
    if _EMPTY_COUNTS is None:
        _EMPTY_COUNTS = jnp.zeros((0,), jnp.float32)
    return _EMPTY_COUNTS


# ---------------------------------------------------------------------------
# policy registrations
# ---------------------------------------------------------------------------
def _ogb_def(
    sample: str = "poisson",
    projection: str = "warm",
    sweeps: int = DEFAULT_WARM_SWEEPS,
    iters: int = DEFAULT_BISECT_ITERS,
    madow_capacity: Optional[int] = None,
) -> PolicyDef:
    raw = _make_ogb_step(
        sample, projection, sweeps, iters, track_opt=False,
        madow_capacity=madow_capacity,
    )

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError(
                "ogb is unit-size; use policy_def('ogb_sized') for "
                "per-item sizes/costs"
            )
        if eta is None:
            raise ValueError("ogb init needs eta (run() resolves eta=None)")
        if sample in ("madow", "madow_tree") and int(madow_capacity) != int(
            capacity
        ):
            raise ValueError(
                f"madow needs a static capacity: policy_def('ogb', "
                f"sample={sample!r}, madow_capacity={capacity}) "
                f"(got {madow_capacity})"
            )
        p, u_key = _sampling_init(seed, catalog_size, sample)
        return OGBCarry(
            f=jnp.full(catalog_size, capacity / catalog_size, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            eta=jnp.float32(eta),
            cap=jnp.float32(capacity),
            p=p,
            u_key=u_key,
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, ids):
        u = _chunk_u(sample, carry.u_key, carry.t)
        state = (carry.f, carry.tau, _empty_counts())
        (f, tau, _), (reward, hits, tau_o, occ) = raw(
            carry.eta, carry.p, carry.cap, state, (ids, u)
        )
        carry = carry._replace(f=f, tau=tau, t=carry.t + 1)
        return carry, StepOut(reward, hits, tau_o, occ)

    return PolicyDef(
        kind="ogb",
        name="OGB",
        init=init,
        step=step,
        fractional=True,
        # Theorem 3.1 tuning at B=1, matching the legacy replay default
        default_eta=lambda N, C, T, W: theoretical_eta(C, N, T, 1),
    )


def _omd_def(
    sample: str = "poisson",
    sweeps: int = _engines.DEFAULT_OMD_SWEEPS,
    madow_capacity: Optional[int] = None,
) -> PolicyDef:
    raw = _engines._make_omd_step(
        sample, sweeps, track_opt=False, madow_capacity=madow_capacity
    )

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError(
                "omd is unit-size; use policy_def('ogb_sized') for "
                "per-item sizes/costs"
            )
        if eta is None:
            raise ValueError("omd init needs eta (run() resolves eta=None)")
        if sample in ("madow", "madow_tree") and int(madow_capacity) != int(
            capacity
        ):
            raise ValueError(
                f"madow needs a static capacity: policy_def('omd', "
                f"sample={sample!r}, madow_capacity={capacity}) "
                f"(got {madow_capacity})"
            )
        p, u_key = _sampling_init(seed, catalog_size, sample)
        f0 = capacity / catalog_size
        return OMDApiCarry(
            f=jnp.full(catalog_size, f0, jnp.float32),
            w=jnp.full(catalog_size, float(np.log(f0)), jnp.float32),
            lam=jnp.zeros((), jnp.float32),
            eta=jnp.float32(eta),
            cap=jnp.float32(capacity),
            p=p,
            u_key=u_key,
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, ids):
        u = _chunk_u(sample, carry.u_key, carry.t)
        state = (carry.f, carry.w, carry.lam, _empty_counts())
        (f, w, lam, _), (reward, hits, lam_o, occ) = raw(
            carry.eta, carry.p, carry.cap, state, (ids, u)
        )
        carry = carry._replace(f=f, w=w, lam=lam, t=carry.t + 1)
        return carry, StepOut(reward, hits, lam_o, occ)

    return PolicyDef(
        kind="omd",
        name="OMD",
        init=init,
        step=step,
        fractional=True,
        # Si Salem et al. tuning at the replay batch size (legacy default)
        default_eta=lambda N, C, T, W: theoretical_eta_omd(C, N, T, W),
    )


def _ogb_tree_def(
    sample: str = "poisson",
    buckets: int = _tree_engines.OGB_TREE_BUCKETS,
    radix: int = _tree_engines.OGB_TREE_RADIX,
    iters: int = _tree_engines.OGB_TREE_ITERS,
    batch_hint: int = 4096,
) -> PolicyDef:
    """Lazy bucketized OGB: O(B log V) per chunk instead of O(N).

    Same gradient step and hit accounting as ``ogb``; the per-chunk
    capped-simplex projection is replaced by a scalar threshold solve over
    a V-bucket histogram of the accumulated values, so per-chunk work no
    longer scales with the catalog.  Hit ratios track the dense ``ogb``
    within the histogram quantization (see the differential test); use
    ``ogb`` when bit-exact projections matter.  ``sample`` is limited to
    ``"poisson"``/``"none"`` — Madow needs the full fractional vector.
    """
    if sample not in ("poisson", "none"):
        raise ValueError(
            f"ogb_tree supports sample='poisson'|'none' (got {sample!r}); "
            "use policy_def('ogb', sample='madow_tree', ...) for Madow"
        )

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError(
                "ogb_tree is unit-size; use policy_def('ogb_sized', "
                "flavor='tree') for per-item sizes/costs"
            )
        if eta is None:
            raise ValueError(
                "ogb_tree init needs eta (run() resolves eta=None)"
            )
        return _tree_engines.init_ogb_tree_carry(
            catalog_size,
            capacity,
            eta=eta,
            seed=seed,
            sample=sample,
            buckets=buckets,
            radix=radix,
            batch_hint=batch_hint,
        )

    def step(carry, ids):
        chunk = _tree_engines.make_ogb_tree_chunk(
            carry.y.shape[0], buckets, radix, sample, iters
        )
        carry, (reward, hits, dtau, occ) = chunk(carry, ids)
        return carry, StepOut(reward, hits, dtau, occ)

    return PolicyDef(
        kind="ogb_tree",
        name="OGB_tree",
        init=init,
        step=step,
        fractional=True,
        default_eta=lambda N, C, T, W: theoretical_eta(C, N, T, 1),
    )


def _automaton_def(
    kind: str,
    zeta: Optional[float] = None,
    impl: Optional[str] = None,
) -> PolicyDef:
    """Discrete automaton PolicyDef.

    ``impl`` selects the engine implementation: ``"tree"`` (the default for
    lru/lfu/ftpl) runs the O(log) prefix-tree engines of
    :mod:`repro.cachesim.tree_engines`; ``"dense"`` is the O(C)-per-request
    slot automaton — kept as an escape hatch and as the differential-test
    oracle.  Both produce bit-identical hit sequences; only the carry
    layout differs.  FIFO has no tree form (insertion order is not a reuse
    distance) and always runs dense.

    Sized runs: ``init(..., sizes=...)`` wraps the unchanged carry in a
    :class:`SizedAutomatonCarry` — the automaton stays size-blind (identical
    decisions, slot-based capacity), but every hit is also weighted by the
    requested item's bytes so the result carries ``byte_hits``.  ``costs``
    are rejected — these automata have no cost model (use ``gds``).
    """
    if impl is None:
        impl = "tree" if kind in _tree_engines.TREE_ENGINE_KINDS else "dense"
    def_zeta = zeta

    def _reject_costs(costs):
        if costs is not None:
            raise ValueError(
                f"{kind} has no miss-cost model (costs= unsupported); "
                "use policy_def('gds') or policy_def('ogb_sized')"
            )

    if impl == "tree":
        if kind not in _tree_engines.TREE_ENGINE_KINDS:
            raise ValueError(f"no tree engine for kind {kind!r}")

        def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
                 n_slots=None, zeta=None, ring=None, sizes=None, costs=None):
            _reject_costs(costs)
            inner = _tree_engines.init_tree_engine_carry(
                kind,
                catalog_size,
                capacity,
                n_slots=n_slots,
                seed=seed,
                zeta=zeta if zeta is not None else def_zeta,
                horizon=horizon,
                ring=ring,
            )
            if sizes is None:
                return inner
            return SizedAutomatonCarry(
                inner, _sizes_array(sizes, catalog_size)
            )

        def step(carry, ids):
            # static geometry comes from the (traced) carry's shapes, so
            # one PolicyDef serves every catalog/window combination
            sized = isinstance(carry, SizedAutomatonCarry)
            inner = carry.inner if sized else carry
            chunk = _tree_engines.make_tree_chunk(
                kind, inner, return_flags=sized
            )
            inner, (hits, occ) = chunk(inner, ids)
            if not sized:
                return inner, StepOut(
                    hits.astype(jnp.float32),
                    hits,
                    jnp.zeros((), jnp.float32),
                    occ.astype(jnp.float32),
                )
            flags = hits  # (window,) per-request, aligned with ids
            hits = jnp.sum(flags.astype(jnp.int32))
            byte_hits = jnp.sum(jnp.where(flags, carry.szs[ids], 0.0))
            return SizedAutomatonCarry(inner, carry.szs), StepOut(
                hits.astype(jnp.float32),
                hits,
                jnp.zeros((), jnp.float32),
                occ.astype(jnp.float32),
                byte_hits,
            )

        return PolicyDef(kind=kind, name=kind.upper(), init=init, step=step)

    if impl != "dense":
        raise ValueError(f"unknown automaton impl {impl!r}")
    raw = _engines._STEPS[kind]

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, zeta=None, sizes=None, costs=None):
        _reject_costs(costs)
        inner = _engines.init_engine_carry(
            kind,
            catalog_size,
            capacity,
            n_slots=n_slots,
            seed=seed,
            zeta=zeta if zeta is not None else def_zeta,
            horizon=horizon,
        )
        if sizes is None:
            return inner
        return SizedAutomatonCarry(inner, _sizes_array(sizes, catalog_size))

    def step(carry, ids):
        sized = isinstance(carry, SizedAutomatonCarry)
        inner = carry.inner if sized else carry
        inner, hitflags = jax.lax.scan(raw, inner, ids)
        hits = jnp.sum(hitflags.astype(jnp.int32))
        occ = _engines._occ_slots(inner).astype(jnp.float32)
        if not sized:
            return inner, StepOut(
                hits.astype(jnp.float32),
                hits,
                jnp.zeros((), jnp.float32),
                occ,
            )
        byte_hits = jnp.sum(jnp.where(hitflags, carry.szs[ids], 0.0))
        return SizedAutomatonCarry(inner, carry.szs), StepOut(
            hits.astype(jnp.float32),
            hits,
            jnp.zeros((), jnp.float32),
            occ,
            byte_hits,
        )

    return PolicyDef(kind=kind, name=kind.upper(), init=init, step=step)


def _ogb_grad_def(iters: int = DEFAULT_BISECT_ITERS) -> PolicyDef:
    """OGB on dense gradient vectors — the serving-side flavor.

    ``step(carry, grad)`` takes a raw per-item weight vector (e.g. routed
    token counts per MoE expert), normalizes it to unit mass, and performs
    one fractional OGB update.  ``StepOut.reward`` is the weighted resident
    hit mass (pre-update, under the carried Poisson sample) and ``hits``
    the *count* of requested items resident at decision time — the same
    "hits mean hits" convention every other kind follows.  Swap-in/out
    telemetry (the paper's O(changed-mass) coordination claim) is *not* a
    hit count and is derived by the consumer from the residency-mask diff
    (:class:`repro.serve.expert_cache.OGBExpertCache` streams this one
    step at a time via the carry contract and diffs
    :func:`~repro.jaxcache.fractional.poisson_sample` masks)."""

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError("ogb_grad is unit-size (weights ride the "
                             "gradient vector); sizes/costs unsupported")
        if eta is None:
            raise ValueError("ogb_grad init needs eta")
        # legacy expert-cache stream: p drawn straight from key(seed)
        p = permanent_random_numbers(jax.random.key(seed), catalog_size)
        return OGBCarry(
            f=jnp.full(catalog_size, capacity / catalog_size, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            eta=jnp.float32(eta),
            cap=jnp.float32(capacity),
            p=p,
            u_key=jax.random.key_data(jax.random.key(seed)),
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, grad):
        total = jnp.sum(grad)
        norm = grad / jnp.maximum(total, 1.0)  # unit-mass per-step gradient
        resident = poisson_sample(carry.f, carry.p, 0)
        reward = jnp.sum(norm * resident.astype(jnp.float32))
        hits = jnp.sum(
            jnp.logical_and(grad > 0, resident).astype(jnp.int32)
        )
        y = carry.f + carry.eta * norm
        f_new, tau = capped_simplex_project(y, carry.cap, iters)
        resident_new = poisson_sample(f_new, carry.p, 0)
        carry = carry._replace(f=f_new, tau=tau, t=carry.t + 1)
        return carry, StepOut(
            reward,
            hits,
            tau,
            jnp.sum(resident_new.astype(jnp.float32)),
        )

    return PolicyDef(kind="ogb_grad", name="OGB_grad", init=init, step=step,
                     fractional=True, trace_driven=False)


def _gds_def() -> PolicyDef:
    """GreedyDual-Size: the classical size/cost-aware automaton baseline.

    Runs on the min-pair eviction trees (O(log C) per request) with
    size-normalized keys H_i = L + cost_i / size_i — differential-tested
    against the host ``core.policies.GDS`` oracle.  Unit sizes/costs
    reduce it to an LRU-like automaton (every H increment equal).  Always
    emits ``byte_hits`` (== hits when unit-size)."""

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        return _tree_engines.init_tree_gds_carry(
            int(catalog_size),
            int(capacity),
            n_slots,
            sizes=sizes,
            costs=costs,
        )

    def step(carry, ids):
        chunk = _tree_engines.make_tree_chunk("gds", carry,
                                              return_flags=True)
        carry, (flags, occ) = chunk(carry, ids)
        hits = jnp.sum(flags.astype(jnp.int32))
        byte_hits = jnp.sum(jnp.where(flags, carry.szs[ids], 0.0))
        return carry, StepOut(
            hits.astype(jnp.float32),
            hits,
            jnp.zeros((), jnp.float32),
            occ.astype(jnp.float32),
            byte_hits,
        )

    return PolicyDef(kind="gds", name="GDS", init=init, step=step)


def _ogb_sized_def(
    flavor: str = "tree",
    sample: str = "poisson",
    classes: int = _tree_engines.SIZED_OGB_CLASSES,
    buckets: int = _tree_engines.OGB_TREE_BUCKETS,
    radix: int = _tree_engines.OGB_TREE_RADIX,
    iters: int = _tree_engines.OGB_TREE_ITERS,
    proj_iters: int = DEFAULT_BISECT_ITERS,
    batch_hint: int = 4096,
) -> PolicyDef:
    """Size-aware OGB over the knapsack-relaxed feasible set (paper §8).

    ``flavor="tree"`` is the O(K * B log V) per-size-class lazy bucketized
    form; ``flavor="scan"`` is the dense O(N)-per-chunk form with *exact*
    per-item sizes and a full weighted bisection projection — the
    differential oracle for the tree flavor (both are property-tested
    against the float64 ``core.ogb_sized`` oracle).  ``init`` requires
    per-item ``sizes`` (pass ``run(..., sizes=...)``); ``costs`` default
    to the sizes (byte-weighted rewards).  ``eta=None`` resolves to the
    Theorem 3.1 rate at the byte capacity expressed in mean-object units
    — the natural reduction of the unit tuning to heterogeneous sizes.
    """
    if flavor not in ("tree", "scan"):
        raise ValueError(f"ogb_sized flavor must be 'tree'|'scan': {flavor!r}")
    if sample not in ("poisson", "none"):
        raise ValueError(
            f"ogb_sized supports sample='poisson'|'none' (got {sample!r})"
        )

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is None:
            raise ValueError(
                "ogb_sized init needs per-item sizes: run(..., sizes=...)"
            )
        n = int(catalog_size)
        s64 = np.asarray(sizes, np.float64)
        if s64.shape != (n,):
            raise ValueError(f"sizes must be a ({n},) array: {s64.shape}")
        if eta is None:
            # Theorem 3.1 tuning with the capacity in mean-object units
            c_eq = float(capacity) / float(np.mean(s64))
            eta = theoretical_eta(c_eq, n, int(horizon or 1), 1)
        if flavor == "tree":
            return _tree_engines.init_sized_ogb_tree_carry(
                n,
                float(capacity),
                sizes=s64,
                costs=costs,
                eta=float(eta),
                seed=seed,
                sample=sample,
                classes=classes,
                buckets=buckets,
                radix=radix,
                batch_hint=batch_hint,
            )
        # scan flavor: exact sizes, same mean-size normalization
        if not (np.all(np.isfinite(s64)) and float(s64.min()) > 0.0):
            raise ValueError("sizes must be finite and > 0")
        sref = float(np.mean(s64))
        s_n = s64 / sref
        if costs is None:
            w = s_n.copy()
        else:
            w = np.asarray(costs, np.float64) / sref
            if w.shape != (n,):
                raise ValueError(f"costs must be a ({n},) array")
            if not (np.all(np.isfinite(w)) and w.min() > 0.0):
                raise ValueError("costs must be finite and > 0")
        cap_n = float(capacity) / sref
        total_s = float(np.sum(s_n))
        if cap_n >= total_s:
            raise ValueError(
                f"capacity {capacity} holds the whole catalog; caching is "
                "trivial"
            )
        f0 = cap_n / total_s
        p, _ = _sampling_init(seed, n, sample)
        return SizedOGBScanCarry(
            f=jnp.full(n, f0, jnp.float32),
            tau=jnp.zeros((), jnp.float32),
            eta=jnp.float32(eta),
            cap=jnp.float32(cap_n),
            s=jnp.asarray(s_n, jnp.float32),
            wts=jnp.asarray(w, jnp.float32),
            sref=jnp.float32(sref),
            p=p,
            t=jnp.zeros((), jnp.int32),
        )

    if flavor == "tree":

        def step(carry, ids):
            chunk = _tree_engines.make_sized_ogb_tree_chunk(
                carry.y.shape[0], carry.s.shape[0], buckets, radix,
                sample, iters,
            )
            carry, (reward, hits, byte_hits, drho, occ) = chunk(carry, ids)
            return carry, StepOut(
                reward * carry.sref, hits, drho, occ, byte_hits
            )

    else:

        def step(carry, ids):
            f, s, wts, p, sref = carry.f, carry.s, carry.wts, carry.p, \
                carry.sref
            sj = s[ids]
            wj = wts[ids]
            fi = f[ids]
            reward = jnp.sum(wj * fi)  # pre-update (OCO order)
            if sample == "poisson":
                hflag = fi >= p[ids]
                hits = jnp.sum(hflag.astype(jnp.int32))
                byte_hits = jnp.sum(jnp.where(hflag, sj, 0.0)) * sref
                occ = jnp.sum(
                    jnp.where(f >= p, s, 0.0)
                ) * sref
            else:
                hits = jnp.zeros((), jnp.int32)
                byte_hits = jnp.zeros((), jnp.float32)
                occ = carry.cap * sref
            y = f.at[ids].add(carry.eta * wj)
            f_new, tau = weighted_simplex_project(
                y, s, carry.cap, proj_iters
            )
            carry = carry._replace(f=f_new, tau=tau, t=carry.t + 1)
            return carry, StepOut(
                reward * sref, hits, tau, occ, byte_hits
            )

    return PolicyDef(
        kind="ogb_sized",
        name=f"OGB_sized_{flavor}",
        init=init,
        step=step,
        fractional=True,
    )


register_policy_def("ogb", _ogb_def)
register_policy_def("ogb_tree", _ogb_tree_def)
register_policy_def("omd", _omd_def)
register_policy_def("ogb_grad", _ogb_grad_def)
register_policy_def("gds", _gds_def)
register_policy_def("ogb_sized", _ogb_sized_def)
for _kind in _engines.ENGINE_KINDS:
    register_policy_def(_kind, functools.partial(_automaton_def, _kind))
