"""Multi-tenant cache fleets: thousands of independent caches, one dispatch.

The ROADMAP north-star is heavy traffic from millions of users; this module
is the layer that claim stands on.  :func:`run_fleet` stacks E per-tenant
carries (heterogeneous capacity / eta / seed via the carried-params
contract, capacity-padded like ``api.sweep``) along a leading tenant axis
and steps the whole fleet in a single vmapped, donated-carry ``lax.scan``
— unlike ``sweep`` every tenant replays its *own* request stream
(``in_axes=(0, 0)``).  The tenant axis shards over the ``data`` mesh axis
through :mod:`repro.dist.sharding` when a mesh is active.

:func:`run_fleet_stream` feeds the same dispatch from per-tenant chunk
iterators (e.g. ``tracelab.tenant_streams``) in fixed memory, with the
async double-buffered prefetch pipeline of ``tracelab.run_stream``.

:func:`run_edge_fleet` is the two-level CDN setting of "Learning to Cache
With No Regrets" collapsed to one shared parent: E edge caches replay
their streams with per-request hit flags, and the deterministic interleave
of their misses (arrival-position major, edge index minor) becomes the
origin cache's request stream.
"""

# the ingest thread is the sole writer of the stream-position counters
# reprolint: thread-owned(t_ingested, ingest_seconds, t_dropped)

from __future__ import annotations

import functools
import queue
import threading
import time
from collections import deque
from typing import Any, Iterable, Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.regret import best_static_hits
from repro.dist import sharding as _sharding

from . import api
from . import engines as _engines
from . import tree_engines as _tree_engines
from .results import EdgeFleetResult, FleetResult
from .scenarios import get_edge_fleet_scenario
from .tracelab import stream as _stream

#: per-tenant requests per streamed dispatch (window-aligned down)
DEFAULT_FLEET_SEGMENT = 16_384


# ---------------------------------------------------------------------------
# per-tenant parameter plumbing
# ---------------------------------------------------------------------------


def _tenant_array(value, n_tenants: int, name: str, dtype=np.int64) -> np.ndarray:
    """Normalize a scalar or length-E sequence to an (E,) host array."""
    arr = np.asarray(value)
    if arr.ndim == 0:
        arr = np.full(n_tenants, arr.item())
    if arr.shape != (n_tenants,):
        raise ValueError(
            f"{name} must be a scalar or a length-{n_tenants} sequence, "
            f"got shape {arr.shape}"
        )
    return arr.astype(dtype)


def _tenant_etas(etas, n_tenants: int) -> list:
    if etas is None or isinstance(etas, (int, float)):
        return [etas] * n_tenants
    out = list(etas)
    if len(out) != n_tenants:
        raise ValueError(
            f"etas must be a scalar or length-{n_tenants} (got {len(out)})"
        )
    return out


def _tenant_chunks(traces, window: int):
    """(E, M, W) int32 device chunks + (E, t_used) host ids + t_used.

    ``traces`` is an (E, T) array or a list of equal-length 1-D arrays —
    the fleet steps in lockstep, so ragged tenants must be truncated by the
    caller (or streamed via :func:`run_fleet_stream`, which truncates to
    the shortest window-aligned tenant automatically)."""
    if isinstance(traces, np.ndarray) and traces.ndim == 2:
        rows = [np.asarray(traces[e]).ravel() for e in range(traces.shape[0])]
    else:
        rows = [np.asarray(t).ravel() for t in traces]
    if not rows:
        raise ValueError("run_fleet needs at least one tenant trace")
    t_len = len(rows[0])
    if any(len(r) != t_len for r in rows):
        raise ValueError(
            "all tenant traces must have equal length (the fleet steps in "
            "lockstep); stream ragged tenants through run_fleet_stream"
        )
    m = t_len // window
    if m == 0:
        raise ValueError(
            f"tenant traces shorter than one window ({t_len} < {window})"
        )
    t_used = m * window
    used = np.stack([r[:t_used] for r in rows])
    chunks = jnp.asarray(used.reshape(len(rows), m, window), jnp.int32)
    return chunks, used, t_used


def _build_fleet_carries(
    pd: "api.PolicyDef",
    catalog_size: int,
    caps: np.ndarray,
    seeds: np.ndarray,
    eta_list: list,
    horizons: np.ndarray,
    window: int,
    n_slots: int,
    sizes,
    costs,
    init_kw: dict,
):
    """Stacked tenant carries + the per-tenant resolved etas.

    ``eta=None`` tenants resolve ``pd.default_eta`` at **their own**
    horizon — a tenant replaying a T/E slice of a fleet workload needs the
    Theorem-3.1 rate at T/E, not at the fleet-aggregate T (which is what a
    naive ``sweep()``-style resolution at the full trace horizon would
    give it)."""
    resolved = []
    carries = []
    for t in range(len(caps)):
        e = eta_list[t]
        if e is None and pd.default_eta is not None:
            e = pd.default_eta(
                int(catalog_size), int(caps[t]), int(horizons[t]), window
            )
        resolved.append(e)
        carries.append(
            pd.init(
                int(catalog_size),
                int(caps[t]),
                seed=int(seeds[t]),
                eta=e,
                horizon=int(horizons[t]),
                n_slots=n_slots,
                sizes=sizes,
                costs=costs,
                **init_kw,
            )
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *carries)
    if any(r is not None for r in resolved):
        etas_out = np.array(
            [np.nan if r is None else float(r) for r in resolved]
        )
    else:
        etas_out = None
    return stacked, etas_out


def _reject_resume_kwargs(seeds, etas, horizons, n_slots, costs, init_kw):
    if (
        seeds is not None
        or etas is not None
        or horizons is not None
        or n_slots is not None
        or costs is not None
        or init_kw
    ):
        raise ValueError(
            "run_fleet(carry=...) resumes with the stacked carry's own "
            "parameters; do not pass seeds/etas/horizons/n_slots/costs/"
            "init kwargs alongside a carry"
        )


def _place_fleet(stacked, chunks, mesh, rules):
    """Shard the tenant axis over the mesh's data axis (if a mesh is live).

    Every carry leaf and the (E, M, W) chunk block get their leading axis
    mapped through the ``"tenants"`` logical axis of
    :func:`repro.dist.sharding.default_rules`; non-divisible tenant counts
    fall back to replication leaf-by-leaf (``logical_to_spec`` drops the
    axis), so oddball fleets still run."""
    if mesh is None:
        mesh = _sharding.current_mesh()
        rules = rules if rules is not None else _sharding.current_rules()
    if mesh is None:
        return stacked, chunks, False
    if rules is None:
        rules = _sharding.default_rules()

    def put(x):
        axes = ("tenants",) + (None,) * (x.ndim - 1)
        sh = _sharding.named_sharding(mesh, axes, rules=rules, shape=x.shape)
        return jax.device_put(x, sh)

    return jax.tree.map(put, stacked), put(chunks), True


def _opt_from_counts(counts: np.ndarray, capacity: int) -> float:
    if len(counts) <= capacity:
        return float(counts.sum())
    top = np.partition(counts, len(counts) - capacity)[len(counts) - capacity:]
    return float(top.sum())


# ---------------------------------------------------------------------------
# in-memory fleet replay
# ---------------------------------------------------------------------------


def run_fleet(
    pd: "api.PolicyDef",
    traces,
    catalog_size: Optional[int] = None,
    capacities=None,
    *,
    window: int = 1000,
    carry: Any = None,
    seeds=None,
    etas=None,
    horizons=None,
    n_slots: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    track_opt: bool = True,
    keep_carry: bool = True,
    name: Optional[str] = None,
    mesh=None,
    rules=None,
    **init_kw,
) -> FleetResult:
    """Replay E per-tenant traces through E independent caches in one dispatch.

    ``traces`` is an (E, T) array (or list of equal-length 1-D arrays); row
    ``e`` is tenant ``e``'s own request stream.  Per-tenant knobs
    (``capacities``, ``seeds``, ``etas``, ``horizons``) each accept a scalar
    or a length-E sequence; carries are padded to ``n_slots =
    max(capacities)`` exactly like ``api.sweep`` so heterogeneous
    capacities stack.  ``etas=None`` resolves ``pd.default_eta`` *per
    tenant at that tenant's horizon* (default: its own replayed length).

    Resume by passing the previous result's tenant-stacked ``carry=``
    (donated — hand it off, don't keep references).  With a live mesh
    (``mesh=`` or an ambient ``dist.sharding.use_sharding``), the tenant
    axis shards over the mesh's data axis.
    """
    if not pd.trace_driven:
        raise ValueError(
            f"policy kind {pd.kind!r} is not trace-driven; the fleet "
            "replays per-tenant request streams"
        )
    chunks, used, t_used = _tenant_chunks(traces, window)
    n_tenants = chunks.shape[0]

    if carry is None:
        if catalog_size is None or capacities is None:
            raise ValueError(
                "run_fleet() needs catalog_size and capacities (or carry=)"
            )
        caps = _tenant_array(capacities, n_tenants, "capacities")
        seed_arr = _tenant_array(
            seeds if seeds is not None else np.arange(n_tenants),
            n_tenants,
            "seeds",
        )
        hor = _tenant_array(
            horizons if horizons is not None else t_used, n_tenants, "horizons"
        )
        eta_list = _tenant_etas(etas, n_tenants)
        slots = int(n_slots) if n_slots is not None else int(caps.max())
        stacked, etas_out = _build_fleet_carries(
            pd, catalog_size, caps, seed_arr, eta_list, hor, window, slots,
            sizes, costs, init_kw,
        )
    else:
        _reject_resume_kwargs(seeds, etas, horizons, n_slots, costs, init_kw)
        stacked = carry
        lead = {int(np.shape(x)[0]) for x in jax.tree.leaves(carry)}
        if lead != {n_tenants}:
            raise ValueError(
                f"carry tenant axis {sorted(lead)} does not match "
                f"{n_tenants} tenant traces"
            )
        caps = (
            _tenant_array(capacities, n_tenants, "capacities")
            if capacities is not None
            else np.full(n_tenants, -1)
        )
        seed_arr = np.full(n_tenants, -1)
        etas_out = None

    jitted = api._fleet_jit(pd.step)
    stacked, chunks, sharded = _place_fleet(stacked, chunks, mesh, rules)
    t0 = time.perf_counter()
    if sharded:
        # jit's own call cache is sharding-aware; the AOT executable cache
        # keys only on shapes, so mixing placements must bypass it
        final, out = jitted(stacked, chunks)
    else:
        compiled = api._compiled(jitted, stacked, chunks)
        final, out = compiled(stacked, chunks)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0

    if track_opt and caps.min() >= 0:
        opt = np.array(
            [
                float(best_static_hits(used[e], int(caps[e])))
                for e in range(n_tenants)
            ]
        )
    else:
        opt = np.zeros(n_tenants)

    bytes_total = None
    if sizes is not None:
        bytes_total = np.asarray(sizes, np.float64)[used].sum(axis=1)

    return FleetResult(
        name=name or pd.name,
        kind=pd.kind,
        n_tenants=n_tenants,
        T=t_used,
        window=window,
        capacities=caps,
        seeds=seed_arr,
        etas=etas_out,
        reward=np.asarray(out.reward, np.float64),
        hits=np.asarray(out.hits, np.int64),
        aux=np.asarray(out.aux, np.float64),
        occupancy=np.asarray(out.occupancy, np.float64),
        opt_hits=opt,
        carry=final if keep_carry else None,
        wall_seconds=wall,
        byte_hits=(
            np.asarray(out.byte_hits, np.float64)
            if out.byte_hits is not None
            else None
        ),
        bytes_total=bytes_total,
    )


# ---------------------------------------------------------------------------
# streamed fleet replay (fixed memory, async prefetch)
# ---------------------------------------------------------------------------


class _FleetState:
    """Accumulators shared by the sync and async fleet-stream drivers.

    The ingest-side counters (``t_ingested``, ``ingest_seconds``,
    ``t_dropped``) are written only by whichever thread runs the segment
    assembly; the replay-side accumulators only by the main thread."""

    def __init__(self):
        self.reward: list = []
        self.hits: list = []
        self.aux: list = []
        self.occupancy: list = []
        self.byte_hits: list = []
        self.n_segments = 0
        self.t_used = 0  # per tenant
        self.t_ingested = 0  # across the fleet
        self.t_dropped = 0
        self.ingest_seconds = 0.0
        self.device_seconds = 0.0
        self.host_seconds = 0.0
        self.counts: Optional[np.ndarray] = None  # (E, N) when track_opt
        self.bytes_total: Optional[np.ndarray] = None


def _assemble_fleet_segments(
    sources: list,
    segment_len: int,
    window: int,
    catalog_size: Optional[int],
    st: _FleetState,
):
    """Lockstep (E, segment_len) blocks from E independent chunk iterators.

    Each tenant's source is buffered until every tenant can cover a full
    segment; when any source runs dry the whole fleet is truncated to the
    longest window-aligned length *every* tenant can still cover (the
    lockstep analogue of ``run_stream``'s window-aligned tail), and the
    unreplayable remainder is counted in ``t_dropped``."""
    its = [_stream._as_chunks(s) for s in sources]
    n = len(its)
    bufs: list = [[] for _ in range(n)]
    buffered = [0] * n
    done = [False] * n

    def _pull(e: int) -> None:
        t0 = time.perf_counter()
        try:
            chunk = next(its[e])
        except StopIteration:
            st.ingest_seconds += time.perf_counter() - t0
            done[e] = True
            return
        except Exception as err:  # reprolint: allow(broad-except) wrapped as _SourceError
            st.ingest_seconds += time.perf_counter() - t0
            raise _stream._SourceError(err) from err
        st.ingest_seconds += time.perf_counter() - t0
        chunk = np.asarray(chunk, dtype=np.int64).ravel()
        if chunk.size == 0:
            return
        if catalog_size is not None:
            cmin, cmax = int(chunk.min()), int(chunk.max())
            if cmin < 0 or cmax >= catalog_size:
                raise ValueError(
                    f"tenant {e} ids out of range [0, {catalog_size}): "
                    f"saw [{cmin}, {cmax}]"
                )
        st.t_ingested += chunk.size
        bufs[e].append(chunk)
        buffered[e] += chunk.size

    def _take(e: int, k: int) -> np.ndarray:
        merged = np.concatenate(bufs[e]) if len(bufs[e]) > 1 else bufs[e][0]
        rest = merged[k:]
        bufs[e][:] = [rest] if rest.size else []
        buffered[e] = int(rest.size)
        return merged[:k]

    while True:
        for e in range(n):
            while buffered[e] < segment_len and not done[e]:
                _pull(e)
        if all(b >= segment_len for b in buffered):
            yield np.stack([_take(e, segment_len) for e in range(n)])
            continue
        # tail: some tenant ran dry below one segment.  Pull the others up
        # to the best window-aligned target the dry tenants still allow.
        target = min(buffered[e] for e in range(n) if done[e])
        target = (target // window) * window
        for e in range(n):
            while buffered[e] < target and not done[e]:
                _pull(e)
        aligned = (min(buffered) // window) * window
        st.t_dropped = int(sum(buffered) - aligned * n)
        if aligned:
            yield np.stack([_take(e, aligned) for e in range(n)])
        return


def run_fleet_stream(
    pd: "api.PolicyDef",
    sources: Sequence[Union[np.ndarray, Iterable[np.ndarray]]],
    catalog_size: Optional[int] = None,
    capacities=None,
    *,
    window: int = 1000,
    segment_len: Optional[int] = None,
    carry: Any = None,
    seeds=None,
    etas=None,
    horizons=None,
    n_slots: Optional[int] = None,
    sizes: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    track_opt: bool = False,
    keep_carry: bool = True,
    name: Optional[str] = None,
    prefetch: Optional[int] = None,
) -> FleetResult:
    """Stream E per-tenant chunk iterators through the fleet in fixed memory.

    ``sources[e]`` yields tenant ``e``'s request-id chunks (any sizes —
    they are re-batched into lockstep ``(E, segment_len)`` blocks); use
    ``tracelab.tenant_streams`` for stats-matched synthetic tenants.  With
    ``prefetch > 0`` (default ``REPRO_STREAM_PREFETCH``) a daemon thread
    ingests and assembles segments while the device steps the previous
    ones — the same async double-buffered pipeline as
    ``tracelab.run_stream``, with non-blocking dispatch and at most
    ``prefetch`` segments in flight.

    Fresh fleets need ``horizons`` (the planned per-tenant stream length)
    so each tenant's ``eta=None`` resolves the Theorem-3.1 rate at its own
    horizon — a stream cannot infer its length up front.  ``track_opt``
    accumulates per-tenant request histograms at ingest and reports
    hindsight static OPT (off by default: it is O(E*N) host memory).

    On a source failure mid-stream the in-flight device work is drained
    and a :class:`~repro.cachesim.tracelab.stream.StreamFault` is raised
    whose ``partial`` holds the replayed-prefix :class:`FleetResult`
    (resumable via its ``carry``).
    """
    if window <= 0:
        raise ValueError(f"window must be positive (got {window})")
    sources = list(sources)
    n_tenants = len(sources)
    if n_tenants == 0:
        raise ValueError("run_fleet_stream needs at least one tenant source")
    if segment_len is None:
        segment_len = max(window, (DEFAULT_FLEET_SEGMENT // window) * window)
    else:
        segment_len = max(window, (int(segment_len) // window) * window)
    if prefetch is None:
        prefetch = _stream._default_prefetch()
    prefetch = max(0, int(prefetch))

    if carry is None:
        if catalog_size is None or capacities is None:
            raise ValueError(
                "run_fleet_stream() needs catalog_size and capacities "
                "(or carry=)"
            )
        if horizons is None:
            raise ValueError(
                "run_fleet_stream() needs horizons= (planned per-tenant "
                "stream length) for fresh fleets: per-tenant eta "
                "resolution cannot infer a stream's length"
            )
        caps = _tenant_array(capacities, n_tenants, "capacities")
        seed_arr = _tenant_array(
            seeds if seeds is not None else np.arange(n_tenants),
            n_tenants,
            "seeds",
        )
        hor = _tenant_array(horizons, n_tenants, "horizons")
        eta_list = _tenant_etas(etas, n_tenants)
        slots = int(n_slots) if n_slots is not None else int(caps.max())
        stacked, etas_out = _build_fleet_carries(
            pd, catalog_size, caps, seed_arr, eta_list, hor, window, slots,
            sizes, costs, {},
        )
    else:
        _reject_resume_kwargs(seeds, etas, horizons, n_slots, costs, {})
        stacked = carry
        caps = (
            _tenant_array(capacities, n_tenants, "capacities")
            if capacities is not None
            else np.full(n_tenants, -1)
        )
        seed_arr = np.full(n_tenants, -1)
        etas_out = None

    st = _FleetState()
    if track_opt:
        if catalog_size is None or caps.min() < 0:
            raise ValueError("track_opt=True needs catalog_size and capacities")
        st.counts = np.zeros((n_tenants, int(catalog_size)), np.int64)
    sizes_np = None
    if sizes is not None:
        sizes_np = np.asarray(sizes, np.float64)
        st.bytes_total = np.zeros(n_tenants, np.float64)

    jitted = api._fleet_jit(pd.step)
    t0_wall = time.perf_counter()

    def _dispatch(seg: np.ndarray, block: bool):
        """One fleet scan over an (E, seg_len) lockstep block."""
        nonlocal stacked
        chunks = jnp.asarray(
            seg.reshape(n_tenants, -1, window), jnp.int32
        )
        t0 = time.perf_counter()
        compiled = api._compiled(jitted, stacked, chunks)
        stacked, out = compiled(stacked, chunks)
        if block:
            jax.block_until_ready(out)
        st.device_seconds += time.perf_counter() - t0
        return out, seg.shape[1]

    def _host_pass(seg: np.ndarray) -> None:
        """Per-tenant OPT histograms / byte accounting (host-only, so it
        overlaps the device scan in the async pipeline)."""
        if st.counts is None and sizes_np is None:
            return
        t0 = time.perf_counter()
        for e in range(n_tenants):
            if st.counts is not None:
                st.counts[e] += np.bincount(
                    seg[e], minlength=st.counts.shape[1]
                )
            if sizes_np is not None:
                st.bytes_total[e] += float(sizes_np[seg[e]].sum())
        st.host_seconds += time.perf_counter() - t0

    def _consume(item) -> None:
        out, t_seg = item
        t0 = time.perf_counter()
        jax.block_until_ready((out.reward, out.hits, out.aux, out.occupancy))
        st.device_seconds += time.perf_counter() - t0
        t0 = time.perf_counter()
        st.reward.append(np.asarray(out.reward, np.float64))
        st.hits.append(np.asarray(out.hits, np.int64))
        st.aux.append(np.asarray(out.aux, np.float64))
        st.occupancy.append(np.asarray(out.occupancy, np.float64))
        if out.byte_hits is not None:
            st.byte_hits.append(np.asarray(out.byte_hits, np.float64))
        st.n_segments += 1
        st.t_used += t_seg
        st.host_seconds += time.perf_counter() - t0

    def _result() -> FleetResult:
        if st.counts is not None:
            opt = np.array(
                [
                    _opt_from_counts(st.counts[e], int(caps[e]))
                    for e in range(n_tenants)
                ]
            )
        else:
            opt = np.zeros(n_tenants)
        return FleetResult(
            name=name or pd.name,
            kind=pd.kind,
            n_tenants=n_tenants,
            T=st.t_used,
            window=window,
            capacities=caps,
            seeds=seed_arr,
            etas=etas_out,
            reward=np.concatenate(st.reward, axis=1),
            hits=np.concatenate(st.hits, axis=1),
            aux=np.concatenate(st.aux, axis=1),
            occupancy=np.concatenate(st.occupancy, axis=1),
            opt_hits=opt,
            carry=stacked if keep_carry else None,
            wall_seconds=time.perf_counter() - t0_wall,
            byte_hits=(
                np.concatenate(st.byte_hits, axis=1)
                if len(st.byte_hits) == st.n_segments and st.n_segments
                else None
            ),
            bytes_total=st.bytes_total,
            n_segments=st.n_segments,
            t_dropped=st.t_dropped,
            prefetch=prefetch,
        )

    def _fault(err: "_stream._SourceError", pending=None) -> "_stream.StreamFault":
        for res in pending or ():
            _consume(res)
        partial = _result() if st.t_used else None
        return _stream.StreamFault(
            f"tenant chunk source failed after {st.t_ingested} ingested / "
            f"{st.t_used} per-tenant replayed requests "
            f"({st.n_segments} segments): {err.cause!r}",
            t_ingested=st.t_ingested,
            t_replayed=st.t_used * n_tenants,
            n_segments=st.n_segments,
            partial=partial,
        )

    if prefetch == 0:
        segs = _assemble_fleet_segments(
            sources, segment_len, window, catalog_size, st
        )
        while True:
            try:
                seg = next(segs)
            except StopIteration:
                break
            except _stream._SourceError as e:
                raise _fault(e) from e.cause
            res = _dispatch(seg, block=True)
            _host_pass(seg)
            _consume(res)
    else:
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def _ingest():
            try:
                for seg in _assemble_fleet_segments(
                    sources, segment_len, window, catalog_size, st
                ):
                    if not _put(seg):
                        return
                _put(_stream._DONE)
            except BaseException as e:  # reprolint: allow(broad-except) forwarded; classified by main
                _put(e)

        worker = threading.Thread(
            target=_ingest, name="run_fleet_stream-ingest", daemon=True
        )
        worker.start()
        pending: deque = deque()
        try:
            while True:
                item = q.get()
                if item is _stream._DONE:
                    break
                if isinstance(item, _stream._SourceError):
                    raise _fault(item, pending) from item.cause
                if isinstance(item, BaseException):
                    for res in pending:
                        _consume(res)
                    pending.clear()
                    raise item
                res = _dispatch(item, block=False)
                pending.append(res)
                _host_pass(item)
                while len(pending) > prefetch:
                    _consume(pending.popleft())
            while pending:
                _consume(pending.popleft())
        finally:
            stop.set()
            worker.join(timeout=5.0)

    if st.t_used == 0:
        raise ValueError(
            f"tenant streams shorter than one window "
            f"({st.t_dropped} buffered across {n_tenants} tenants < "
            f"{window} per tenant)"
        )
    return _result()


# ---------------------------------------------------------------------------
# two-level edge -> origin fleet
# ---------------------------------------------------------------------------

#: kinds whose per-request hit flags the edge tier can expose
FLAG_KINDS = ("ogb", "omd", "lru", "lfu", "ftpl", "fifo", "gds")


@functools.lru_cache(maxsize=None)
def _flags_policy(kind: str):
    """(pd, flags_step) for the edge tier.

    ``flags_step(carry, ids) -> (carry, (StepOut, flags))`` mirrors the
    kind's registered step bit-exactly and additionally emits the
    (window,) per-request hit flags whose complement is the origin's
    request stream.  Memoized so the step identity keys the executable
    cache like every registered step."""
    pd = api.policy_def(kind)
    if kind in ("ogb", "omd"):
        # Poisson accounting: a request hits iff f[id] >= p[id] at the
        # pre-update state — the same convention as sample_chunk_metrics,
        # so sum(flags) == StepOut.hits by construction.
        def step(carry, ids):
            flags = carry.f[ids] >= carry.p[ids]
            carry, out = pd.step(carry, ids)
            return carry, (out, flags)

    elif kind in _tree_engines.TREE_ENGINE_KINDS or kind == "gds":

        def step(carry, ids):
            chunk = _tree_engines.make_tree_chunk(kind, carry,
                                                  return_flags=True)
            carry, (flags, occ) = chunk(carry, ids)
            hits = jnp.sum(flags.astype(jnp.int32))
            out = api.StepOut(
                hits.astype(jnp.float32),
                hits,
                jnp.zeros((), jnp.float32),
                occ.astype(jnp.float32),
                (
                    jnp.sum(jnp.where(flags, carry.szs[ids], 0.0))
                    if kind == "gds"
                    else None
                ),
            )
            return carry, (out, flags)

    elif kind == "fifo":
        raw = _engines._STEPS[kind]

        def step(carry, ids):
            carry, flags = jax.lax.scan(raw, carry, ids)
            hits = jnp.sum(flags.astype(jnp.int32))
            out = api.StepOut(
                hits.astype(jnp.float32),
                hits,
                jnp.zeros((), jnp.float32),
                _engines._occ_slots(carry).astype(jnp.float32),
            )
            return carry, (out, flags)

    else:
        raise ValueError(
            f"edge tier needs per-request hit flags; kind {kind!r} has "
            f"none (supported: {FLAG_KINDS})"
        )
    return pd, step


def run_edge_fleet(
    edge_kind: str,
    origin_kind: str,
    traces,
    catalog_size: int,
    edge_capacities,
    origin_capacity: int,
    *,
    window: int = 500,
    origin_window: Optional[int] = None,
    seeds=None,
    edge_etas=None,
    origin_eta: Optional[float] = None,
    origin_seed: int = 0,
    track_opt: bool = True,
    prefetch: Optional[int] = None,
    name: Optional[str] = None,
) -> EdgeFleetResult:
    """Two-level replay: E edge caches in front of one shared origin cache.

    Phase 1 replays every edge's own trace through the fleet dispatch with
    per-request hit flags.  Phase 2 interleaves the edge *misses*
    deterministically — arrival position major, edge index minor, the
    round-robin order a synchronous fleet would present to its parent —
    and streams them through the origin cache via ``tracelab.run_stream``
    (async prefetch path).  Regret accounting is per tenant at the edge
    and hindsight-static at the origin.
    """
    chunks, used, t_used = _tenant_chunks(traces, window)
    n_edges = chunks.shape[0]
    pd_edge, flags_step = _flags_policy(edge_kind)

    caps = _tenant_array(edge_capacities, n_edges, "edge_capacities")
    seed_arr = _tenant_array(
        seeds if seeds is not None else np.arange(n_edges), n_edges, "seeds"
    )
    hor = np.full(n_edges, t_used)
    eta_list = _tenant_etas(edge_etas, n_edges)
    stacked, etas_out = _build_fleet_carries(
        pd_edge, catalog_size, caps, seed_arr, eta_list, hor, window,
        int(caps.max()), None, None, {},
    )

    jitted = api._fleet_jit(flags_step)
    t0 = time.perf_counter()
    compiled = api._compiled(jitted, stacked, chunks)
    final, (out, flags) = compiled(stacked, chunks)
    jax.block_until_ready(flags)
    edge_wall = time.perf_counter() - t0

    if track_opt:
        opt = np.array(
            [
                float(best_static_hits(used[e], int(caps[e])))
                for e in range(n_edges)
            ]
        )
    else:
        opt = np.zeros(n_edges)

    edges = FleetResult(
        name=f"{name or 'edge_fleet'}/{pd_edge.name}",
        kind=pd_edge.kind,
        n_tenants=n_edges,
        T=t_used,
        window=window,
        capacities=caps,
        seeds=seed_arr,
        etas=etas_out,
        reward=np.asarray(out.reward, np.float64),
        hits=np.asarray(out.hits, np.int64),
        aux=np.asarray(out.aux, np.float64),
        occupancy=np.asarray(out.occupancy, np.float64),
        opt_hits=opt,
        carry=final,
        wall_seconds=edge_wall,
        byte_hits=(
            np.asarray(out.byte_hits, np.float64)
            if out.byte_hits is not None
            else None
        ),
    )

    # ---- phase 2: the miss interleave becomes the origin's stream --------
    flags_np = np.asarray(flags, bool)  # (E, M, W)
    ids_np = used.reshape(n_edges, -1, window)
    n_chunks = ids_np.shape[1]
    total_misses = int((~flags_np).sum())
    ow = int(origin_window) if origin_window is not None else window
    if total_misses < ow:
        raise ValueError(
            f"edge misses ({total_misses}) shorter than one origin window "
            f"({ow}); lower origin_window or raise the edge load"
        )

    def _miss_chunks():
        # arrival-position major, edge minor: transpose each (E, W) chunk
        # to (W, E) before masking, so simultaneous arrivals interleave
        # round-robin across edges — deterministic, replayable
        for k in range(n_chunks):
            miss = ~flags_np[:, k, :]
            yield ids_np[:, k, :].T[miss.T]

    pd_origin = api.policy_def(origin_kind)
    origin = _stream.run_stream(
        pd_origin,
        _miss_chunks(),
        catalog_size,
        int(origin_capacity),
        window=ow,
        seed=origin_seed,
        eta=origin_eta,
        horizon=total_misses,
        keep_carry=False,
        prefetch=prefetch,
        name=f"{name or 'edge_fleet'}/origin-{pd_origin.name}",
    )
    if track_opt:
        miss_trace = np.concatenate(list(_miss_chunks()))[: origin.T]
        origin.opt_hits = float(
            best_static_hits(miss_trace, int(origin_capacity))
        )
    return EdgeFleetResult(
        edges=edges, origin=origin, origin_requests=total_misses
    )


def run_edge_fleet_scenario(
    name: str,
    scale: str = "quick",
    *,
    prefetch: Optional[int] = None,
    track_opt: bool = True,
) -> EdgeFleetResult:
    """Run a registered ``EDGE_FLEET_SCENARIOS`` entry at the given scale."""
    sc = get_edge_fleet_scenario(name)
    n_edges, catalog, t_edge, c_edge, c_origin = sc.dims(scale)
    traces = sc.make_edge_traces(scale)
    del n_edges, t_edge  # encoded in the traces' shape
    return run_edge_fleet(
        sc.edge_policy,
        sc.origin_policy,
        traces,
        catalog,
        c_edge,
        c_origin,
        window=sc.window,
        prefetch=prefetch,
        track_opt=track_opt,
        name=sc.name,
    )
