"""Synthetic request-trace generators calibrated to the paper's traces.

The four real traces the paper uses (SNIA ``ms-ex``/``systor``, Wikipedia
``cdn``, Twitter cluster-45) are network-gated in this environment, so each
generator below is calibrated to the statistics the paper itself reports:

* ``adversarial``  — paper §2.2 / Fig 2: round-robin over the catalog with a
  fresh random permutation each round.  Any recency/frequency policy gets a
  ~0 hit ratio; OPT gets C/N; gradient policies approach OPT.
* ``zipf``         — stationary Zipf(alpha) popularity: the ``cdn`` regime
  (Fig 8 left: near-stationary, OPT >> LRU, items regularly re-requested,
  large lifetimes/reuse distances — Fig 11).
* ``shifting_zipf``— Zipf popularity re-permuted every ``phase`` requests:
  the ``ms-ex`` regime (Fig 7 left: OPT's windowed hit ratio highly variable,
  online policies must track the shifts).
* ``bursty``       — Zipf base traffic + a stream of short-lived items
  requested in concentrated bursts: the ``twitter`` regime (Fig 8 right:
  LRU > OPT; ~20% of attainable hits come from items with lifetime < 100
  requests — Fig 11 left), which is also the regime where batching (B > 1)
  hurts (Fig 10 right).
* ``scan_mix``     — looping sequential scans over disjoint ranges plus a hot
  set: the ``systor``/VDI block-storage regime (Fig 7 right).

All generators return ``np.ndarray[int64]`` of item ids in ``[0, N)`` and are
deterministic per seed.  ``trace_stats`` recomputes the paper's §B.2
lifetime / reuse-distance statistics so the calibration is checkable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


def _zipf_weights(n: int, alpha: float) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), alpha)
    return w / w.sum()


def adversarial(N: int, T: int, seed: int = 0) -> np.ndarray:
    """Round-robin with per-round random permutation (paper Fig 2)."""
    rng = np.random.default_rng(seed)
    rounds = T // N + 1
    out = np.empty(rounds * N, dtype=np.int64)
    for r in range(rounds):
        out[r * N : (r + 1) * N] = rng.permutation(N)
    return out[:T]


def zipf(N: int, T: int, alpha: float = 0.8, seed: int = 0) -> np.ndarray:
    """Stationary Zipf(alpha) — cdn-like."""
    rng = np.random.default_rng(seed)
    w = _zipf_weights(N, alpha)
    return rng.choice(N, size=T, p=w).astype(np.int64)


def shifting_zipf(
    N: int, T: int, alpha: float = 0.9, phase: int = 100_000, seed: int = 0
) -> np.ndarray:
    """Zipf with popularity ranks re-permuted every ``phase`` requests — ms-ex-like."""
    rng = np.random.default_rng(seed)
    w = _zipf_weights(N, alpha)
    out = np.empty(T, dtype=np.int64)
    t = 0
    while t < T:
        n = min(phase, T - t)
        perm = rng.permutation(N)
        draws = rng.choice(N, size=n, p=w)
        out[t : t + n] = perm[draws]
        t += n
    return out


def bursty(
    N: int,
    T: int,
    alpha: float = 0.7,
    burst_fraction: float = 0.35,
    burst_len_mean: float = 6.0,
    burst_span: int = 80,
    seed: int = 0,
) -> np.ndarray:
    """Zipf base + short-lived bursty items — twitter-like (paper §B.2).

    ``burst_fraction`` of requests go to one-shot items whose entire lifetime
    (first to last request) spans < ``burst_span`` requests; each such item is
    requested Geom(1/burst_len_mean)+1 times in a tight window.  These items
    produce hits for recency policies but not for any static allocation, and
    they lose their hits when the batch size B exceeds their lifetime.
    """
    rng = np.random.default_rng(seed)
    n_base = int(N * 0.5)
    w = _zipf_weights(n_base, alpha)
    base = rng.choice(n_base, size=T, p=w).astype(np.int64)
    out = base.copy()
    # overlay bursts on a burst_fraction of slots, ids from the upper half
    n_burst_requests = int(T * burst_fraction)
    next_burst_id = n_base
    t = 0
    placed = 0
    while placed < n_burst_requests and t < T - burst_span:
        k = 1 + rng.geometric(1.0 / burst_len_mean)
        k = int(min(k, burst_span // 2, n_burst_requests - placed))
        if k <= 0:
            break
        pos = t + np.sort(rng.choice(burst_span, size=max(k, 1), replace=False))
        item = next_burst_id
        next_burst_id += 1
        if next_burst_id >= N:
            next_burst_id = n_base
        out[pos] = item
        placed += k
        # advance so bursts tile the trace roughly uniformly
        t += max(1, int(burst_span * k / max(n_burst_requests / (T / burst_span), 1e-9) / burst_span))
        t += rng.integers(1, 4)
    return out


def scan_mix(
    N: int,
    T: int,
    hot_fraction: float = 0.55,
    hot_items: Optional[int] = None,
    scan_len: int = 2000,
    seed: int = 0,
) -> np.ndarray:
    """Hot working set + looping sequential scans — systor/VDI-like."""
    rng = np.random.default_rng(seed)
    hot_n = hot_items if hot_items is not None else max(N // 20, 1)
    w = _zipf_weights(hot_n, 1.0)
    out = np.empty(T, dtype=np.int64)
    t = 0
    scan_base = hot_n
    while t < T:
        if rng.random() < hot_fraction:
            n = min(rng.integers(50, 400), T - t)
            out[t : t + n] = rng.choice(hot_n, size=n, p=w)
        else:
            n = min(scan_len, T - t)
            start = scan_base + int(rng.integers(0, max(N - scan_base - scan_len, 1)))
            out[t : t + n] = (start + np.arange(n)) % N
        t += n
    return out


def real_like(
    N: int,
    T: int,
    source: str = "zipf",
    sample_T: Optional[int] = None,
    seed: int = 0,
    **source_kw,
) -> np.ndarray:
    """Stats-matched "real-trace-shaped" workload (tracelab synthesizer).

    Stands in for the paper's real traces without shipping datasets: a
    ``source`` trace is sampled (``sample_T`` requests, a few percent of a
    paper-scale T), its §B.2 statistics are fitted
    (:func:`repro.cachesim.tracelab.synth.fit_profile`), and a trace of
    the requested length is synthesized with matching popularity skew,
    reuse-distance profile and drift.  For out-of-core lengths use
    :func:`repro.cachesim.tracelab.synth.synthesize_chunks` directly —
    this registry entry materializes.
    """
    from repro.cachesim.tracelab.synth import fit_profile, synthesize

    if sample_T is None:
        sample_T = int(np.clip(T // 10, 2_000, 200_000))
    # the sample catalog scales with the sample so fitted per-item stats
    # (one-shot share, burst composition) survive the T extrapolation
    sample_N = max(min(N, max(sample_T // 10, 8)), 1)
    sample = TRACE_REGISTRY[source](sample_N, sample_T, seed=seed, **source_kw)
    profile = fit_profile(sample)
    return synthesize(profile, T, catalog=N, seed=seed + 1)


TRACE_REGISTRY = {
    "adversarial": adversarial,
    "zipf": zipf,
    "cdn_like": zipf,
    "shifting_zipf": shifting_zipf,
    "ms_ex_like": shifting_zipf,
    "bursty": bursty,
    "twitter_like": bursty,
    "scan_mix": scan_mix,
    "systor_like": scan_mix,
    "real_like": real_like,
}


def make_trace(kind: str, N: int, T: int, seed: int = 0, **kw) -> np.ndarray:
    return TRACE_REGISTRY[kind](N, T, seed=seed, **kw)


# ---------------------------------------------------------------------------
# paper §B.2 statistics: item lifetime and reuse distance
# ---------------------------------------------------------------------------
@dataclass
class TraceStats:
    """Per-item lifetime / attainable-hit statistics, fully vectorized.

    The array form (``items`` / ``lifetimes`` / ``max_hits``, aligned) is the
    fast path used at paper scale (T = 2e7); the dict views are materialized
    lazily for the exploratory / test surface.
    """

    catalog: int
    length: int
    unique: int
    items: np.ndarray  # (U,) item ids actually requested
    lifetimes: np.ndarray  # (U,) last - first request position
    max_hits: np.ndarray  # (U,) requests - 1 (infinite-cache hits)
    _lifetime_dict: Optional[Dict[int, int]] = None
    _max_hits_dict: Optional[Dict[int, int]] = None

    @property
    def lifetime_by_item(self) -> Dict[int, int]:
        if self._lifetime_dict is None:
            self._lifetime_dict = dict(
                zip(self.items.tolist(), self.lifetimes.tolist())
            )
        return self._lifetime_dict

    @property
    def max_hits_by_item(self) -> Dict[int, int]:
        if self._max_hits_dict is None:
            self._max_hits_dict = dict(
                zip(self.items.tolist(), self.max_hits.tolist())
            )
        return self._max_hits_dict

    def hit_share_lifetime_below(self, L: int) -> float:
        """Fraction of infinite-cache hits from items with lifetime < L
        (paper Fig 11 left)."""
        tot = int(self.max_hits.sum())
        if tot == 0:
            return 0.0
        return float(self.max_hits[self.lifetimes < L].sum()) / tot


def trace_stats(trace: np.ndarray) -> TraceStats:
    """Vectorized lifetime statistics, correct on sparse/gappy id sets.

    Ids need not be dense ``0..N-1``: raw logs (block addresses, hashed
    keys) carry sparse 64-bit ids, and allocating ``max(id)+1`` arrays for
    them would OOM long before the trace does.  Two equivalent paths:

    * **dense** (``max(id)`` comparable to the trace length) — O(T + N):
      first/last positions fall out of two fancy-index writes (assigning
      ``np.arange(T)`` at ``trace`` keeps the *last* write per item; the
      same on the reversed trace keeps the *first*);
    * **sparse** — O(T log T): ``np.unique`` compresses the id set first
      and the identical fancy-index writes run on the inverse codes.

    Both return identical results (``items`` ascending); only the memory
    scaling differs.  ``catalog`` is always ``max(id) + 1`` — a label for
    the id *space*, not an allocation size.
    """
    trace = np.asarray(trace, dtype=np.int64)
    t_len = len(trace)
    if t_len == 0:
        e = np.empty(0, np.int64)
        return TraceStats(0, 0, 0, e, e, e)
    if trace.min() < 0:
        raise ValueError("trace_stats: negative item ids")
    n = int(trace.max()) + 1
    pos = np.arange(t_len, dtype=np.int64)
    if n <= max(4 * t_len, 1 << 22):  # dense ids: O(T + N) histogram path
        counts = np.bincount(trace, minlength=n)
        last = np.full(n, -1, np.int64)
        last[trace] = pos
        first = np.full(n, -1, np.int64)
        first[trace[::-1]] = t_len - 1 - pos
        items = np.nonzero(counts)[0]
        lifetimes = last[items] - first[items]
        max_hits = counts[items] - 1
    else:  # sparse/gappy ids: compress through np.unique first
        items, inverse, counts = np.unique(
            trace, return_inverse=True, return_counts=True
        )
        u = len(items)
        last = np.full(u, -1, np.int64)
        last[inverse] = pos
        first = np.full(u, -1, np.int64)
        first[inverse[::-1]] = t_len - 1 - pos
        lifetimes = last - first
        max_hits = counts - 1
    return TraceStats(
        catalog=n,
        length=t_len,
        unique=len(items),
        items=items,
        lifetimes=lifetimes,
        max_hits=max_hits,
    )


def reuse_distances(trace: np.ndarray) -> np.ndarray:
    """Timestamp gaps between consecutive requests of the same item (Fig 11
    right), ordered by the position of the later request.

    Vectorized: a stable argsort groups each item's request positions in time
    order, so within-group diffs are exactly the reuse gaps.
    """
    trace = np.asarray(trace, dtype=np.int64)
    if len(trace) < 2:
        return np.empty(0, dtype=np.int64)
    order = np.argsort(trace, kind="stable")  # by item, time-ordered within
    same = trace[order][1:] == trace[order][:-1]
    gaps = (order[1:] - order[:-1])[same]
    at = order[1:][same]  # position of the later request
    return gaps[np.argsort(at, kind="stable")]
