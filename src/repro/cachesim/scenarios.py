"""Named experiment scenarios: trace family x (N, T, C) x policy set.

One registry maps the synthetic trace families of
:mod:`repro.cachesim.traces` — the five generator-calibrated families plus
the ``real_like`` stats-matched synthesizer family
(:mod:`repro.cachesim.tracelab.synth`) — to the paper figures they
reproduce, so every
benchmark, test and golden fixture names a scenario instead of re-stating
sizes and seeds.  Each scenario carries a ``quick`` shape (minutes on one CPU
core — CI scale) and a ``full`` shape (the paper's trace sizes, feasible now
that every baseline runs device-resident).

``run_scenario`` drives the whole policy set through the one generic
execution layer (:mod:`repro.cachesim.api`): every registered kind —
``ogb``/``omd`` (fractional, replayed at the scenario batch size) and
``lru``/``fifo``/``lfu``/``ftpl`` (slot automata, replayed at the metric
window) — is a :class:`~repro.cachesim.api.PolicyDef` run by
:func:`repro.cachesim.api.run`.  Anything unregistered (``arc``, ``gds``,
...) falls back to the host-side :func:`repro.core.policies.make_policy`
policy driven by :func:`repro.cachesim.simulator.simulate` — the slow exact
oracle, included automatically only when the trace is short enough
(``HOST_POLICY_MAX_T``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.cachesim import api
from repro.cachesim.traces import make_trace
from repro.core.regret import best_static_hits

#: host (pure-Python) policies are only simulated up to this trace length
HOST_POLICY_MAX_T = 1_000_000

#: the standard comparison set (paper Figs. 2, 7, 8)
COMPARISON_POLICIES = ("ogb", "omd", "ftpl", "lru", "lfu", "fifo", "arc")

#: discrete object-size slabs (bytes) for sized scenarios — dyadic, so the
#: float32 device byte accounting sums them exactly and the size-class
#: quantization of the sized tree engine is lossless (each slab is its own
#: class)
SIZE_SLABS = (1.0, 4.0, 16.0, 64.0)


@dataclass(frozen=True)
class Scenario:
    """One named experiment configuration.

    ``trace_kw`` values may be callables ``(N, T) -> value`` for shape-derived
    parameters (e.g. the shifting-zipf phase length).
    """

    name: str
    figure: str  # paper figure this reproduces
    claim: str  # the headline the figure substantiates
    trace: str  # TRACE_REGISTRY key
    quick: Tuple[int, int]  # (N, T) at CI scale
    full: Tuple[int, int]  # (N, T) at paper scale
    cap_div: int  # C = max(N // cap_div, 1)
    policies: Tuple[str, ...] = COMPARISON_POLICIES
    trace_kw: Tuple[Tuple[str, Any], ...] = ()
    trace_seed: int = 0
    batch: int = 1000  # OGB / OMD update batch
    sized: bool = False  # heterogeneous object sizes (see make_sizes)

    def dims(self, scale: str = "quick") -> Tuple[int, int, int]:
        """(N, T, C) at the given scale ("mini", "quick" or "full").

        "mini" is the golden-fixture scale: tiny enough for tier-1 tests,
        derived from quick so it stays in the same regime.
        """
        if scale == "mini":
            n = max(self.quick[0] // 10, 4 * self.cap_div)
            return n, max(self.quick[1] // 10, 1000), max(n // self.cap_div, 1)
        if scale not in ("quick", "full"):
            raise ValueError(f"unknown scale {scale!r}")
        n, t = self.quick if scale == "quick" else self.full
        return n, t, max(n // self.cap_div, 1)

    def make_trace(self, scale: str = "quick") -> np.ndarray:
        n, t, _ = self.dims(scale)
        kw = {
            k: (v(n, t) if callable(v) else v) for k, v in self.trace_kw
        }
        return make_trace(self.trace, n, t, seed=self.trace_seed, **kw)

    def make_sizes(self, scale: str = "quick") -> Optional[np.ndarray]:
        """Per-item sizes for a sized scenario (``None`` otherwise).

        Sizes are drawn from the discrete ``SIZE_SLABS`` by popularity-rank
        quartile, **anti-correlated** with popularity: the synthetic zipf
        families emit ids in popularity order (id 0 hottest), so the hot
        head gets the small slab and the long tail the large one — the
        CDN-like regime where maximizing object hits (cache the small hot
        head) and maximizing byte hits (spend bytes on the heavy tail)
        genuinely disagree.
        """
        if not self.sized:
            return None
        n, _, _ = self.dims(scale)
        k = len(SIZE_SLABS)
        slab = np.minimum((np.arange(n) * k) // n, k - 1)
        return np.asarray(SIZE_SLABS, np.float64)[slab]

    def byte_capacity(self, scale: str = "quick") -> Optional[int]:
        """Byte budget for byte-capacity policies (``ogb_sized``): the slot
        policies hold ``C`` objects, so ``C * mean(sizes)`` is the byte
        footprint of the same slot count under a uniform object mix."""
        sizes = self.make_sizes(scale)
        if sizes is None:
            return None
        _, _, c = self.dims(scale)
        return max(int(round(c * float(sizes.mean()))), 1)


SCENARIOS: Dict[str, Scenario] = {
    s.name: s
    for s in [
        Scenario(
            name="fig2_adversarial",
            figure="Fig. 2",
            claim="recency/frequency policies collapse on the round-robin "
            "adversary while gradient policies track OPT = C/N",
            trace="adversarial",
            quick=(1_000, 60_000),
            full=(1_000, 1_000_000),
            cap_div=4,
            trace_seed=0,
            batch=500,
        ),
        Scenario(
            name="fig7_ms_ex",
            figure="Fig. 7 (left)",
            claim="shifting popularity (ms-ex): online policies must track "
            "the phase changes; OPT's windowed ratio is highly variable",
            trace="shifting_zipf",
            quick=(20_000, 200_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            trace_kw=(("alpha", 0.9), ("phase", lambda n, t: max(t // 8, 1))),
            trace_seed=3,
        ),
        Scenario(
            name="fig7_systor",
            figure="Fig. 7 (right)",
            claim="hot set + looping scans (systor/VDI): frequency beats "
            "recency; gradient policies are robust to the scans",
            trace="scan_mix",
            quick=(20_000, 200_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            trace_seed=4,
        ),
        Scenario(
            name="fig8_cdn",
            figure="Fig. 8 (left)",
            claim="near-stationary zipf (cdn): OPT >> LRU and the no-regret "
            "policies approach OPT",
            trace="zipf",
            quick=(20_000, 200_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            trace_kw=(("alpha", 0.9),),
            trace_seed=5,
        ),
        Scenario(
            name="fig8_twitter",
            figure="Fig. 8 (right)",
            claim="bursty short-lived items (twitter): LRU beats the static "
            "OPT; OGB stays robust; FTPL degenerates to noisy LFU",
            trace="bursty",
            quick=(20_000, 200_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            trace_kw=(
                ("burst_fraction", 0.5),
                ("burst_len_mean", 8.0),
                ("burst_span", 60),
            ),
            trace_seed=6,
        ),
        Scenario(
            name="sized_cdn",
            figure="§2.2 (heterogeneous sizes) / Fig. 8 (left)",
            claim="CDN objects are not unit-size: with slab sizes "
            "anti-correlated with popularity, byte hit ratio ranks the "
            "policies differently than object hit ratio — size-blind "
            "frequency policies cache the small hot head while the "
            "size-aware gradient policy spends its byte budget where the "
            "traffic volume is",
            trace="zipf",
            quick=(20_000, 200_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            policies=("ogb_sized", "gds", "lru", "lfu", "ftpl"),
            trace_kw=(("alpha", 0.9),),
            trace_seed=13,
            sized=True,
        ),
        Scenario(
            name="real_like_cdn",
            figure="Fig. 8 (left) / §5",
            claim="synthetic zipf-calibrated stand-in for a cdn-like "
            "workload: the tracelab synthesizer is fit to a generated "
            "source (not the paper's proprietary trace), preserving its "
            "popularity skew / reuse profile so the paper-scale comparison "
            "runs without shipping any dataset",
            trace="real_like",
            quick=(20_000, 200_000),
            full=(1_000_000, 10_000_000),
            cap_div=20,
            trace_kw=(("source", "zipf"), ("alpha", 0.9)),
            trace_seed=21,
        ),
        Scenario(
            name="real_like_twitter",
            figure="Fig. 8 (right) / §5",
            claim="stats-matched stand-in for the twitter trace: short-lived "
            "bursts survive the fit, so LRU still beats the static OPT and "
            "OGB stays robust at synthesized scale",
            trace="real_like",
            quick=(20_000, 200_000),
            full=(1_000_000, 10_000_000),
            cap_div=20,
            trace_kw=(
                ("source", "bursty"),
                ("burst_fraction", 0.5),
                ("burst_len_mean", 8.0),
                ("burst_span", 60),
            ),
            trace_seed=22,
        ),
        Scenario(
            name="fig11_cdn",
            figure="Fig. 11 / §B.2",
            claim="cdn items are long-lived: almost no attainable hits come "
            "from items with lifetime < 100 requests",
            trace="zipf",
            quick=(20_000, 150_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            policies=(),
            trace_kw=(("alpha", 0.9),),
            trace_seed=11,
        ),
        Scenario(
            name="fig11_twitter",
            figure="Fig. 11 / §B.2",
            claim="twitter gets ~20% of attainable hits from items with "
            "lifetime < 100 requests — the regime where recency wins",
            trace="bursty",
            quick=(20_000, 150_000),
            full=(1_000_000, 20_000_000),
            cap_div=20,
            policies=(),
            trace_seed=12,
        ),
    ]
}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}"
        )
    return SCENARIOS[name]


@dataclass(frozen=True)
class EdgeFleetScenario:
    """Two-level CDN scenario: E edge caches in front of one shared origin.

    Each edge serves its own stream (same trace family, per-edge seed
    ``trace_seed + e``); edge misses interleave deterministically
    (arrival-position major, edge index minor) into the origin's request
    stream — the bipartite caching-network setting of "Learning to Cache
    With No Regrets" collapsed to a single shared parent, with the paper's
    no-regret policy at the origin.  The scenario only holds the shape;
    the replay driver lives in :func:`repro.cachesim.fleet.run_edge_fleet`
    (this module stays below ``fleet`` in the layering).
    """

    name: str
    figure: str
    claim: str
    trace: str
    quick: Tuple[int, int, int]  # (E, N, T_per_edge) at CI scale
    full: Tuple[int, int, int]
    edge_cap_div: int  # C_edge = max(N // edge_cap_div, 1)
    origin_cap_div: int  # C_origin = max(N // origin_cap_div, 1)
    edge_policy: str = "lru"
    origin_policy: str = "ogb"
    window: int = 500
    trace_kw: Tuple[Tuple[str, Any], ...] = ()
    trace_seed: int = 0

    def dims(self, scale: str = "quick") -> Tuple[int, int, int, int, int]:
        """(E, N, T_per_edge, C_edge, C_origin) at the given scale."""
        if scale == "mini":
            e0, n0, t0 = self.quick
            e = max(e0 // 8, 2)
            n = max(n0 // 10, 4 * self.edge_cap_div)
            t = max(t0 // 10, 4 * self.window)
        elif scale in ("quick", "full"):
            e, n, t = self.quick if scale == "quick" else self.full
        else:
            raise ValueError(f"unknown scale {scale!r}")
        return (
            e,
            n,
            t,
            max(n // self.edge_cap_div, 1),
            max(n // self.origin_cap_div, 1),
        )

    def make_edge_traces(self, scale: str = "quick") -> np.ndarray:
        """(E, T_per_edge) per-edge request streams (decorrelated seeds)."""
        e, n, t, _, _ = self.dims(scale)
        kw = {k: (v(n, t) if callable(v) else v) for k, v in self.trace_kw}
        return np.stack(
            [
                make_trace(self.trace, n, t, seed=self.trace_seed + i, **kw)
                for i in range(e)
            ]
        )


EDGE_FLEET_SCENARIOS: Dict[str, EdgeFleetScenario] = {
    s.name: s
    for s in [
        EdgeFleetScenario(
            name="edge_fleet_cdn",
            figure="ROADMAP north-star (fleet scale); PAPERS.md bipartite setting",
            claim=(
                "E per-edge LRU caches in front of one shared no-regret "
                "origin: the edges absorb each stream's hot head, and the "
                "gradient origin recovers tail hits from the miss "
                "interleave the edges cannot hold"
            ),
            trace="zipf",
            quick=(32, 4096, 25_000),
            full=(256, 100_000, 500_000),
            edge_cap_div=64,
            origin_cap_div=8,
            trace_kw=(("alpha", 0.8),),
            trace_seed=40,
        ),
    ]
}


def get_edge_fleet_scenario(name: str) -> EdgeFleetScenario:
    if name not in EDGE_FLEET_SCENARIOS:
        raise KeyError(
            f"unknown edge-fleet scenario {name!r}; "
            f"have {sorted(EDGE_FLEET_SCENARIOS)}"
        )
    return EDGE_FLEET_SCENARIOS[name]


@dataclass
class ScenarioResult:
    scenario: str
    scale: str
    N: int
    T: int
    C: int
    window: int
    rows: Dict[str, Dict[str, float]] = field(default_factory=dict)
    skipped: Tuple[str, ...] = ()

    def hit_ratio(self, policy: str) -> float:
        return self.rows[policy]["hit_ratio"]

    def byte_hit_ratio(self, policy: str) -> float:
        return self.rows[policy]["byte_hit_ratio"]

    def to_json(self) -> Dict:
        return {
            "scenario": self.scenario,
            "scale": self.scale,
            "N": self.N,
            "T": self.T,
            "C": self.C,
            "rows": self.rows,
            "skipped": list(self.skipped),
        }


def run_scenario(
    name: str,
    scale: str = "quick",
    policies: Optional[Sequence[str]] = None,
    seed: int = 0,
    window: Optional[int] = None,
    include_host: Optional[bool] = None,
    include_opt: bool = True,
    trace: Optional[np.ndarray] = None,
) -> ScenarioResult:
    """Run one scenario's policy set through the device-resident engines.

    Host-side (per-request Python) policies are skipped when the trace
    exceeds ``HOST_POLICY_MAX_T`` unless ``include_host=True`` forces them.
    Pass ``trace`` to reuse an already-generated trace (it must come from
    ``scenario.make_trace(scale)`` for the result to be meaningful), and
    ``include_opt=False`` to skip the host-side OPT(static) row when the
    caller computes OPT itself (it is an O(T) pass over the trace).
    """
    from repro.cachesim.simulator import simulate
    from repro.core.policies import make_policy

    sc = get_scenario(name)
    n, t, c = sc.dims(scale)
    if trace is None:
        trace = sc.make_trace(scale)
    w = window or max(t // 20, 1)
    batch = min(sc.batch, max(t // 20, 1))
    if include_host is None:
        include_host = t <= HOST_POLICY_MAX_T

    sizes = sc.make_sizes(scale)
    cap_bytes = sc.byte_capacity(scale)

    res = ScenarioResult(
        scenario=name, scale=scale, N=n, T=t, C=c, window=w
    )
    skipped = []
    # hindsight OPT over the batch-aligned prefix, shared by the fractional
    # regret rows and the OPT(static) row (one O(T) pass, not one per row)
    t_opt = (len(trace) // batch) * batch if sc.policies else len(trace)
    opt_hits: Optional[float] = None

    def _opt() -> float:
        nonlocal opt_hits
        if opt_hits is None:
            opt_hits = float(best_static_hits(np.asarray(trace[:t_opt]), c))
        return opt_hits

    def _engine_def(kind):
        if kind not in api.policy_def_kinds():
            return None
        pd = api.policy_def(kind)
        return pd if pd.trace_driven else None

    for kind in policies if policies is not None else sc.policies:
        pd = _engine_def(kind)
        if pd is not None and pd.fractional:
            # byte-capacity fractional policies (ogb_sized) take the byte
            # budget; unit-size fractional policies take the slot count
            m = api.run(
                pd, trace, n,
                cap_bytes if (sizes is not None and kind == "ogb_sized")
                else c,
                window=batch, seed=seed, track_opt=False, keep_carry=False,
                sizes=sizes,
            )
            row = {
                "hit_ratio": m.hit_ratio,
                "frac_hit_ratio": m.frac_hit_ratio,
                "us_per_request": m.us_per_request,
            }
            if sizes is None:
                row["regret"] = _opt() - float(m.reward.sum())
            else:
                # sized fractional reward is in bytes: regret against the
                # fractional byte-optimal static allocation
                row["byte_hit_ratio"] = m.byte_hit_ratio
                row["byte_regret"] = best_static_byte_hits(
                    np.asarray(trace[:t_opt]), sizes, float(cap_bytes)
                ) - float(m.reward.sum())
            res.rows[m.name] = row
        elif pd is not None:
            r = api.run(
                pd, trace, n, c, window=w, seed=seed, horizon=t,
                track_opt=False, keep_carry=False, sizes=sizes,
            )
            res.rows[r.name] = {
                "hit_ratio": r.hit_ratio,
                "us_per_request": r.us_per_request,
            }
            if sizes is not None:
                res.rows[r.name]["byte_hit_ratio"] = r.byte_hit_ratio
        else:  # host-side oracle policies (arc, ...)
            if not include_host:
                skipped.append(kind)
                continue
            pol = make_policy(
                kind, n, c, **({} if sizes is None else {"sizes": sizes})
            )
            sr = simulate(pol, trace, window=w, record_cum=False)
            res.rows[sr.name] = {
                "hit_ratio": sr.hit_ratio,
                "us_per_request": sr.us_per_request,
            }
    if include_opt:
        res.rows["OPT(static)"] = {
            "hit_ratio": _opt() / max(t_opt, 1)
        }
        if sizes is not None:
            tr_opt = np.asarray(trace[:t_opt])
            req_bytes = float(np.sum(sizes[tr_opt]))
            res.rows["OPT(static)"]["byte_hit_ratio"] = (
                best_static_byte_hits(tr_opt, sizes, float(cap_bytes))
                / max(req_bytes, 1.0)
            )
    res.skipped = tuple(skipped)
    return res


def best_static_byte_hits(
    trace: np.ndarray, sizes: np.ndarray, cap_bytes: float
) -> float:
    """Fractional byte-optimal static allocation's byte hits (hindsight).

    Maximize ``sum_i count_i * s_i * f_i`` subject to
    ``sum_i s_i * f_i <= cap_bytes``, ``f in [0, 1]``: every objective
    coefficient is ``count_i`` per byte allocated, so the greedy fill in
    request-count order (fractional last item) is exact — the byte-weighted
    analogue of :func:`repro.core.regret.best_static_hits`.
    """
    sizes = np.asarray(sizes, np.float64)
    cnt = np.bincount(
        np.asarray(trace), minlength=len(sizes)
    ).astype(np.float64)
    order = np.argsort(-cnt, kind="stable")
    s_o, c_o = sizes[order], cnt[order]
    cum = np.cumsum(s_o)
    k = int(np.searchsorted(cum, cap_bytes, side="right"))
    byte_hits = float(np.sum(c_o[:k] * s_o[:k]))
    if k < len(s_o):
        rem = cap_bytes - (float(cum[k - 1]) if k else 0.0)
        byte_hits += float(c_o[k]) * max(rem, 0.0)
    return byte_hits
