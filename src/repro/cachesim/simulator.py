"""Trace-driven cache simulation harness.

Drives any policy implementing ``request(i) -> hit`` over a numpy trace and
records cumulative + windowed hit ratios, occupancy snapshots and wall-clock
throughput — the measurement loop behind every paper figure.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.cachesim.results import HitStatsMixin


@dataclass
class SimResult(HitStatsMixin):
    """Host-simulator result — shares the scalar-ratio implementations with
    the device-engine results (:mod:`repro.cachesim.results`)."""

    name: str
    T: int
    hits: int
    cum_hits: np.ndarray  # cumulative hits at every request (int64)
    windowed: np.ndarray  # hit ratio per non-overlapping window
    window: int
    occupancy: List[float] = field(default_factory=list)
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)


def simulate(
    policy,
    trace: np.ndarray,
    window: int = 100_000,
    occupancy_every: Optional[int] = None,
    record_cum: bool = True,
) -> SimResult:
    T = len(trace)
    # the hot loop avoids all per-request numpy traffic: the trace becomes a
    # plain python list once (no per-step scalar boxing), per-request hit
    # flags land in a bytearray (C-speed stores), and cumulative sums are one
    # vectorized pass at the end
    ids = trace.tolist() if isinstance(trace, np.ndarray) else list(trace)
    hitbuf = bytearray(T)
    occ: List[float] = []
    req = policy.request
    t0 = time.perf_counter()
    if occupancy_every:
        pos = 0
        while pos < T:
            end = min(pos + occupancy_every, T)
            for t in range(pos, end):
                hitbuf[t] = req(ids[t])
            if end - pos == occupancy_every:
                occ.append(float(policy.occupancy()))
            pos = end
    else:
        t = 0
        for j in ids:
            hitbuf[t] = req(j)
            t += 1
    # flush a trailing partial batch so final state is consistent
    if hasattr(policy, "batch_end"):
        policy.batch_end()
    wall = time.perf_counter() - t0

    flags = np.frombuffer(hitbuf, dtype=np.uint8)  # zero-copy view, read-only use
    hits = int(flags.sum())
    cum = (
        np.cumsum(flags, dtype=np.int64)
        if record_cum
        else np.empty(0, dtype=np.int64)
    )

    n_win = max(T // window, 1)
    w = min(window, T)
    if T:
        boundary = np.cumsum(
            flags[: n_win * w].reshape(n_win, w).sum(axis=1, dtype=np.int64)
        )
        prev = np.concatenate([[0], boundary[:-1]])
        windowed = (boundary - prev) / w
    else:
        windowed = np.array([0.0])
    return SimResult(
        name=getattr(policy, "name", type(policy).__name__),
        T=T,
        hits=hits,
        cum_hits=cum,
        windowed=windowed,
        window=w,
        occupancy=occ,
        wall_seconds=wall,
    )


def compare(
    policies,
    trace: np.ndarray,
    window: int = 100_000,
    catalog_size: Optional[int] = None,
    capacity: Optional[int] = None,
    policy_kw: Optional[Dict[str, Dict]] = None,
    **kw,
) -> Dict[str, SimResult]:
    """Simulate several policies over one trace.

    ``policies`` is either a mapping ``{name: policy-object}`` or an iterable
    of kind strings resolved through the one shared registry
    (:data:`repro.core.policies.POLICY_REGISTRY`) — pass ``catalog_size`` and
    ``capacity`` in that case, plus optional per-kind constructor kwargs via
    ``policy_kw={"ogb": {"horizon": T}, ...}``.  Keeping construction inside
    the registry means this comparison set cannot drift from
    ``make_policy`` / ``benchmarks.common.make_policies``.
    """
    if not isinstance(policies, dict):
        from repro.core.policies import make_policy

        if catalog_size is None or capacity is None:
            raise ValueError(
                "kind-string comparison needs catalog_size and capacity"
            )
        policy_kw = policy_kw or {}
        built = {}
        for kind in policies:
            p = make_policy(
                kind, catalog_size, capacity, **policy_kw.get(kind, {})
            )
            built[getattr(p, "name", kind)] = p
        policies = built
    return {name: simulate(p, trace, window=window, **kw) for name, p in policies.items()}
