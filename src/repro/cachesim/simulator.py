"""Trace-driven cache simulation harness.

Drives any policy implementing ``request(i) -> hit`` over a numpy trace and
records cumulative + windowed hit ratios, occupancy snapshots and wall-clock
throughput — the measurement loop behind every paper figure.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class SimResult:
    name: str
    T: int
    hits: int
    cum_hits: np.ndarray  # cumulative hits at every request (int64)
    windowed: np.ndarray  # hit ratio per non-overlapping window
    window: int
    occupancy: List[float] = field(default_factory=list)
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.T, 1)

    @property
    def us_per_request(self) -> float:
        return 1e6 * self.wall_seconds / max(self.T, 1)


def simulate(
    policy,
    trace: np.ndarray,
    window: int = 100_000,
    occupancy_every: Optional[int] = None,
    record_cum: bool = True,
) -> SimResult:
    T = len(trace)
    # the hot loop avoids all per-request numpy traffic: the trace becomes a
    # plain python list once (no per-step scalar boxing), per-request hit
    # flags land in a bytearray (C-speed stores), and cumulative sums are one
    # vectorized pass at the end
    ids = trace.tolist() if isinstance(trace, np.ndarray) else list(trace)
    hitbuf = bytearray(T)
    occ: List[float] = []
    req = policy.request
    t0 = time.perf_counter()
    if occupancy_every:
        pos = 0
        while pos < T:
            end = min(pos + occupancy_every, T)
            for t in range(pos, end):
                hitbuf[t] = req(ids[t])
            if end - pos == occupancy_every:
                occ.append(float(policy.occupancy()))
            pos = end
    else:
        t = 0
        for j in ids:
            hitbuf[t] = req(j)
            t += 1
    # flush a trailing partial batch so final state is consistent
    if hasattr(policy, "batch_end"):
        policy.batch_end()
    wall = time.perf_counter() - t0

    flags = np.frombuffer(hitbuf, dtype=np.uint8)  # zero-copy view, read-only use
    hits = int(flags.sum())
    cum = (
        np.cumsum(flags, dtype=np.int64)
        if record_cum
        else np.empty(0, dtype=np.int64)
    )

    n_win = max(T // window, 1)
    w = min(window, T)
    if T:
        boundary = np.cumsum(
            flags[: n_win * w].reshape(n_win, w).sum(axis=1, dtype=np.int64)
        )
        prev = np.concatenate([[0], boundary[:-1]])
        windowed = (boundary - prev) / w
    else:
        windowed = np.array([0.0])
    return SimResult(
        name=getattr(policy, "name", type(policy).__name__),
        T=T,
        hits=hits,
        cum_hits=cum,
        windowed=windowed,
        window=w,
        occupancy=occ,
        wall_seconds=wall,
    )


def compare(
    policies: Dict[str, object], trace: np.ndarray, window: int = 100_000, **kw
) -> Dict[str, SimResult]:
    return {name: simulate(p, trace, window=window, **kw) for name, p in policies.items()}
