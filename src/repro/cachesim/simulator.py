"""Trace-driven cache simulation harness.

Drives any policy implementing ``request(i) -> hit`` over a numpy trace and
records cumulative + windowed hit ratios, occupancy snapshots and wall-clock
throughput — the measurement loop behind every paper figure.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class SimResult:
    name: str
    T: int
    hits: int
    cum_hits: np.ndarray  # cumulative hits at every request (int64)
    windowed: np.ndarray  # hit ratio per non-overlapping window
    window: int
    occupancy: List[float] = field(default_factory=list)
    wall_seconds: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.T, 1)

    @property
    def us_per_request(self) -> float:
        return 1e6 * self.wall_seconds / max(self.T, 1)


def simulate(
    policy,
    trace: np.ndarray,
    window: int = 100_000,
    occupancy_every: Optional[int] = None,
    record_cum: bool = True,
) -> SimResult:
    T = len(trace)
    cum = np.empty(T, dtype=np.int64) if record_cum else np.empty(0, dtype=np.int64)
    occ: List[float] = []
    hits = 0
    t0 = time.perf_counter()
    req = policy.request
    for t in range(T):
        hits += req(int(trace[t]))
        if record_cum:
            cum[t] = hits
        if occupancy_every and (t + 1) % occupancy_every == 0:
            occ.append(float(policy.occupancy()))
    # flush a trailing partial batch so final state is consistent
    if hasattr(policy, "batch_end"):
        policy.batch_end()
    wall = time.perf_counter() - t0

    n_win = max(T // window, 1)
    w = min(window, T)
    if record_cum:
        boundary = cum[w - 1 :: w][:n_win]
        prev = np.concatenate([[0], boundary[:-1]])
        windowed = (boundary - prev) / w
    else:
        windowed = np.array([hits / max(T, 1)])
    return SimResult(
        name=getattr(policy, "name", type(policy).__name__),
        T=T,
        hits=hits,
        cum_hits=cum,
        windowed=windowed,
        window=w,
        occupancy=occ,
        wall_seconds=wall,
    )


def compare(
    policies: Dict[str, object], trace: np.ndarray, window: int = 100_000, **kw
) -> Dict[str, SimResult]:
    return {name: simulate(p, trace, window=window, **kw) for name, p in policies.items()}
