"""Pure-jnp oracle for the fused OGB capped-simplex update.

semantics(f, counts, eta, C):
    y   = f + eta * counts
    tau = root of  sum(clip(y - tau, 0, 1)) = C      (tau >= 0 in OGB: mass
          was added, never removed, so the projection only subtracts)
    out = clip(y - tau, 0, 1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_ogb_update_ref(
    f: jax.Array, counts: jax.Array, eta: float, capacity: float, iters: int = 64
) -> jax.Array:
    y = f + jnp.asarray(eta, f.dtype) * counts
    lo = jnp.zeros((), jnp.float32)
    hi = (1.0 + eta * jnp.sum(counts)).astype(jnp.float32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        mass = jnp.sum(jnp.clip(y.astype(jnp.float32) - mid, 0.0, 1.0))
        pred = mass >= capacity
        return jnp.where(pred, mid, lo), jnp.where(pred, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = (0.5 * (lo + hi)).astype(f.dtype)
    return jnp.clip(y - tau, 0.0, 1.0)
