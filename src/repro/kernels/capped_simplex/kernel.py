"""Pallas TPU kernels for the fused OGB capped-simplex projection.

TPU adaptation of the paper's projection (DESIGN.md §3): instead of K
bisection sweeps over HBM (the naive form — each sweep reads the whole
catalog), one *grid-mass* kernel evaluates the constraint function

    g(tau_k) = sum(clip(f + eta*counts - tau_k, 0, 1)),   k = 0..K-1

for K candidate thresholds in a single pass with the block resident in VMEM,
raising arithmetic intensity from ~1 to ~K FLOP/byte (the op is otherwise
purely memory-bound).  A few passes of K-way bracketing + an exact piecewise-
linear interpolation inside the final bracket replace ~50 bisection sweeps
with 2-3 sweeps.

Kernels:
  * ``mass_kernel``  — per-block partial masses + interior counts for K taus,
    accumulated across the grid into a single (K,) output block (TPU
    revisiting-output pattern).
  * ``apply_kernel`` — elementwise f' = clip(f + eta*counts - tau, 0, 1).

Blocks are (block_rows, 128) f32: 128-lane aligned for the VPU; the default
(256, 128) keeps f+counts+K-chunk intermediates well under VMEM (~1 MiB).

Warm-start invariant (used by ``ops.fused_ogb_update(tau0=...)`` and the
scan-replay engine): for a *feasible* pre-step state f (sum f = C,
0 <= f <= 1) and y = f + eta*counts with counts >= 0, the projection
threshold satisfies

    0 <= tau <= eta * sum(counts)

because g(0) = sum(clip(y, 0, 1)) >= sum(f) = C (each coordinate can only
grow) and g(eta*sum(counts)) <= sum(f) = C (no coordinate grew by more than
the total step).  K-way bracketing over that width-(eta*B) interval needs
``passes=2`` instead of 3+ over width (1 + eta*B).  Note the *per-step*
threshold is NOT monotone across chained projections of the re-projected f
(only the cumulative threshold rho_t = sum_{s<=t} tau_s of the lazy,
accumulated-y formulation is), so the previous step's tau is a valid
initial *guess* but never a valid lower bracket — the scan replay seeds its
bracketed-Newton solver with it inside the provable [0, eta*B] bracket
(``repro.jaxcache.fractional.capped_simplex_project_warm``).  Cold bisection
to the same accuracy costs ~50 catalog sweeps; the warm forms need single
digits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 256
DEFAULT_K = 64
_K_CHUNK = 8


def mass_kernel(f_ref, c_ref, taus_ref, mass_ref, cnt_ref, *, eta: float, k: int):
    """Accumulate sum(clip(y - tau_j, 0, 1)) and interior counts over blocks."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        mass_ref[...] = jnp.zeros_like(mass_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    y = f_ref[...].astype(jnp.float32) + jnp.float32(eta) * c_ref[...].astype(
        jnp.float32
    )
    taus = taus_ref[...]  # (k,)

    # chunk over candidates to bound VMEM: (chunk, rows, lanes)
    mass_acc = jnp.zeros((k,), jnp.float32)
    cnt_acc = jnp.zeros((k,), jnp.float32)
    n_chunks = k // _K_CHUNK

    def chunk_body(c, carry):
        mass_acc, cnt_acc = carry
        t = jax.lax.dynamic_slice(taus, (c * _K_CHUNK,), (_K_CHUNK,))
        z = y[None, :, :] - t[:, None, None]  # (chunk, rows, lanes)
        clipped = jnp.clip(z, 0.0, 1.0)
        m = jnp.sum(clipped, axis=(1, 2))  # (chunk,)
        interior = jnp.logical_and(z > 0.0, z < 1.0)
        n = jnp.sum(interior.astype(jnp.float32), axis=(1, 2))
        mass_acc = jax.lax.dynamic_update_slice(mass_acc, m, (c * _K_CHUNK,))
        cnt_acc = jax.lax.dynamic_update_slice(cnt_acc, n, (c * _K_CHUNK,))
        return mass_acc, cnt_acc

    mass_acc, cnt_acc = jax.lax.fori_loop(
        0, n_chunks, chunk_body, (mass_acc, cnt_acc)
    )
    mass_ref[...] += mass_acc
    cnt_ref[...] += cnt_acc


def apply_kernel(f_ref, c_ref, tau_ref, out_ref, *, eta: float):
    y = f_ref[...].astype(jnp.float32) + jnp.float32(eta) * c_ref[...].astype(
        jnp.float32
    )
    out_ref[...] = jnp.clip(y - tau_ref[0], 0.0, 1.0).astype(out_ref.dtype)


def _grid_masses(
    f2: jax.Array,
    c2: jax.Array,
    taus: jax.Array,
    eta: float,
    block_rows: int,
    interpret: bool,
):
    rows = f2.shape[0]
    k = taus.shape[0]
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(mass_kernel, eta=eta, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(f2, c2, taus)


def _grid_apply(
    f2: jax.Array,
    c2: jax.Array,
    tau: jax.Array,
    eta: float,
    block_rows: int,
    interpret: bool,
):
    rows = f2.shape[0]
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(apply_kernel, eta=eta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(f2.shape, f2.dtype),
        interpret=interpret,
    )(f2, c2, tau.reshape(1))
