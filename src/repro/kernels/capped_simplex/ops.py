"""jit'd public wrapper for the fused capped-simplex OGB update."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_ROWS, DEFAULT_K, LANES, _grid_apply, _grid_masses


@functools.partial(
    jax.jit,
    static_argnames=("eta", "capacity", "passes", "k", "block_rows", "interpret"),
)
def fused_ogb_update(
    f: jax.Array,
    counts: jax.Array,
    eta: float,
    capacity: float,
    passes: int = 3,
    k: int = DEFAULT_K,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """f' = Proj_F(f + eta * counts) via K-way bracketing Pallas kernels.

    ``passes`` sweeps of the K-candidate mass kernel narrow tau to a bracket
    of width (hi-lo)/(K-1)^passes, then a piecewise-linear interpolation
    (exact when the final bracket contains no clip breakpoint) produces tau.

    Memory traffic: (passes+1) catalog sweeps instead of ~50 for plain
    bisection — the headline Pallas win for this memory-bound op.
    """
    n = f.shape[0]
    dtype = f.dtype
    block = block_rows * LANES
    pad = (-n) % block
    f2 = jnp.pad(f, (0, pad)).reshape(-1, LANES)
    c2 = jnp.pad(counts, (0, pad)).reshape(-1, LANES)

    lo = jnp.zeros((), jnp.float32)
    hi = (1.0 + eta * jnp.sum(counts)).astype(jnp.float32)
    cap = jnp.float32(capacity)

    g_lo = None
    cnt_lo = None
    for _ in range(passes):
        # K candidates spanning [lo, hi] inclusive
        frac = jnp.arange(k, dtype=jnp.float32) / (k - 1)
        taus = lo + (hi - lo) * frac
        mass, cnt = _grid_masses(f2, c2, taus, eta, block_rows, interpret)
        # last index with mass >= C  (mass is non-increasing in tau)
        ge = mass >= cap
        idx = jnp.maximum(jnp.sum(ge.astype(jnp.int32)) - 1, 0)
        lo = taus[idx]
        hi = taus[jnp.minimum(idx + 1, k - 1)]
        g_lo = mass[idx]
        cnt_lo = cnt[idx]

    # piecewise-linear interpolation inside the final bracket:
    # g(tau) = g(lo) - cnt_lo * (tau - lo)  while no breakpoint is crossed
    tau_interp = lo + (g_lo - cap) / jnp.maximum(cnt_lo, 1.0)
    tau = jnp.where(
        cnt_lo > 0, jnp.clip(tau_interp, lo, hi), 0.5 * (lo + hi)
    ).astype(jnp.float32)

    out2 = _grid_apply(f2, c2, tau, eta, block_rows, interpret)
    return out2.reshape(-1)[:n].astype(dtype)
