"""jit'd public wrapper for the fused capped-simplex OGB update."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_ROWS, DEFAULT_K, LANES, _grid_apply, _grid_masses


@functools.partial(
    jax.jit,
    static_argnames=(
        "eta",
        "capacity",
        "passes",
        "k",
        "block_rows",
        "interpret",
        "return_tau",
    ),
)
def fused_ogb_update(
    f: jax.Array,
    counts: jax.Array,
    eta: float,
    capacity: float,
    passes: int = 3,
    k: int = DEFAULT_K,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
    tau0: Optional[jax.Array] = None,
    hi: Optional[jax.Array] = None,
    return_tau: bool = False,
):
    """f' = Proj_F(f + eta * counts) via K-way bracketing Pallas kernels.

    ``passes`` sweeps of the K-candidate mass kernel narrow tau to a bracket
    of width (hi-lo)/(K-1)^passes, then a piecewise-linear interpolation
    (exact when the final bracket contains no clip breakpoint) produces tau.

    Warm start (``tau0``/``hi``): ``tau0`` must be a valid *lower bound* on
    the threshold and ``hi`` an upper bound.  For a feasible ``f`` (sum f =
    C, 0 <= f <= 1) the per-step threshold provably lies in
    [0, eta * sum(counts)] — pass ``tau0=0.0`` to get that bracket (``hi``
    is then derived automatically), shrinking the initial bracket from
    O(1 + eta*B) to O(eta*B) so ``passes=2`` usually suffices.  Do NOT pass
    the previous step's tau when chaining projections of the re-projected
    ``f``: the per-step threshold is not monotone (only the cumulative
    threshold of the *accumulated*, never-re-projected y is), and an invalid
    lower bound silently yields an infeasible result.  A nonzero ``tau0`` is
    only correct when the caller maintains that accumulated-y formulation.

    ``interpret=None`` auto-selects: compiled Pallas on TPU, interpreter
    elsewhere.  ``return_tau=True`` additionally returns the threshold so
    callers can chain warm starts.

    Memory traffic: (passes+1) catalog sweeps instead of ~50 for plain
    bisection — the headline Pallas win for this memory-bound op.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = f.shape[0]
    dtype = f.dtype
    block = block_rows * LANES
    pad = (-n) % block
    f2 = jnp.pad(f, (0, pad)).reshape(-1, LANES)
    c2 = jnp.pad(counts, (0, pad)).reshape(-1, LANES)

    if tau0 is None:
        lo = jnp.zeros((), jnp.float32)
        if hi is None:
            hi = (1.0 + eta * jnp.sum(counts)).astype(jnp.float32)
    else:
        lo = jnp.asarray(tau0, jnp.float32)
        if hi is None:
            from repro.jaxcache.fractional import warm_bracket_hi

            hi = lo + warm_bracket_hi(eta * jnp.sum(counts))
    hi = jnp.asarray(hi, jnp.float32)
    cap = jnp.float32(capacity)

    g_lo = None
    cnt_lo = None
    for _ in range(passes):
        # K candidates spanning [lo, hi] inclusive
        frac = jnp.arange(k, dtype=jnp.float32) / (k - 1)
        taus = lo + (hi - lo) * frac
        mass, cnt = _grid_masses(f2, c2, taus, eta, block_rows, interpret)
        # last index with mass >= C  (mass is non-increasing in tau)
        ge = mass >= cap
        idx = jnp.maximum(jnp.sum(ge.astype(jnp.int32)) - 1, 0)
        lo = taus[idx]
        hi = taus[jnp.minimum(idx + 1, k - 1)]
        g_lo = mass[idx]
        cnt_lo = cnt[idx]

    # piecewise-linear interpolation inside the final bracket:
    # g(tau) = g(lo) - cnt_lo * (tau - lo)  while no breakpoint is crossed
    tau_interp = lo + (g_lo - cap) / jnp.maximum(cnt_lo, 1.0)
    tau = jnp.where(
        cnt_lo > 0, jnp.clip(tau_interp, lo, hi), 0.5 * (lo + hi)
    ).astype(jnp.float32)

    out2 = _grid_apply(f2, c2, tau, eta, block_rows, interpret)
    out = out2.reshape(-1)[:n].astype(dtype)
    if return_tau:
        return out, tau
    return out


# -- weighted (knapsack) capped simplex -------------------------------------
#
# Sized objects (core/ogb_sized.py, paper §8): the feasible set becomes
# F_s = {f in [0,1]^N : sum_i s_i f_i = C} and the Euclidean projection is
# f_i = clip(y_i - s_i * tau, 0, 1) with tau the root of the weighted mass
# g(tau) = sum_i s_i clip(y_i - s_i tau, 0, 1) = C.  g is non-increasing and
# piecewise linear with slope -sum_{interior} s_i^2, so the same
# bisection/safeguarded-Newton machinery applies.  These are pure-jnp element
# -wise sweeps (same memory-bound shape as the unit kernel); the Pallas
# fusion stays on the unit path, and the O(log N) device form lives in the
# per-size-class bucket trees (cachesim.tree_engines.SizedOGBTreeCarry).


def weighted_simplex_project(
    y: jax.Array,
    sizes: jax.Array,
    capacity: float,
    iters: int = 50,
    lo: Optional[jax.Array] = None,
    hi: Optional[jax.Array] = None,
):
    """Bisection projection onto F_s. Returns (f, tau).

    Mirrors ``jaxcache.fractional.capped_simplex_project`` operation-for-
    operation so that ``sizes == 1`` reduces *bit-exactly* to the unit path
    (cold bracket [min(y)-1, max(y)], midpoint bisection on ``mass >= C``)
    — locked down in tests/core/test_ogb_sized.py.  Sizes must be > 0
    (validated host-side by the callers; see
    ``core.ogb_sized.weighted_capped_simplex_tau``).
    """
    s = jnp.asarray(sizes, y.dtype)
    if lo is None:
        lo = jnp.min((y - 1.0) / s)
    if hi is None:
        hi = jnp.max(y / s)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        mass = jnp.sum(s * jnp.clip(y - s * mid, 0.0, 1.0))
        too_much = mass >= capacity
        return jnp.where(too_much, mid, lo), jnp.where(too_much, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    return jnp.clip(y - s * tau, 0.0, 1.0), tau


def weighted_simplex_project_warm(
    y: jax.Array,
    sizes: jax.Array,
    capacity: float,
    lo: jax.Array,
    hi: jax.Array,
    tau0: jax.Array,
    sweeps: int = 8,
):
    """Warm-bracketed safeguarded Newton on the weighted mass. Returns (f, tau).

    Each sweep evaluates (g(t), slope) in one catalog pass — the slope of the
    piecewise-linear g is -sum_{i interior} s_i^2 — shrinks the bracket by
    the sign of ``g(t) - C``, and proposes the Newton point safeguarded by
    the bisection midpoint.  Requires a valid bracket g(lo) >= C >= g(hi);
    the accumulated-y (never re-projected) formulation of the tree carry
    makes tau monotone so the previous threshold is a valid ``lo``.
    """
    cap = jnp.float32(capacity)
    s = jnp.asarray(sizes, y.dtype)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    t = jnp.clip(jnp.asarray(tau0, jnp.float32), lo, hi)

    def body(_, carry):
        lo, hi, t = carry
        clipped = jnp.clip(y - s * t, 0.0, 1.0)
        interior = jnp.logical_and(clipped > 0.0, clipped < 1.0)
        mass = jnp.sum(s * clipped)
        slope = jnp.sum(jnp.where(interior, s * s, 0.0))
        too_much = mass >= cap
        lo = jnp.where(too_much, t, lo)
        hi = jnp.where(too_much, hi, t)
        t_newton = t + (mass - cap) / jnp.maximum(slope, 1e-12)
        t_mid = 0.5 * (lo + hi)
        ok = jnp.logical_and(
            slope > 0.0, jnp.logical_and(t_newton >= lo, t_newton <= hi)
        )
        return lo, hi, jnp.where(ok, t_newton, t_mid)

    _lo, _hi, tau = jax.lax.fori_loop(0, sweeps, body, (lo, hi, t))
    return jnp.clip(y - s * tau, 0.0, 1.0), tau
