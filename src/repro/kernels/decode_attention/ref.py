"""Pure-jnp oracle for GQA flash-decode attention (single new token)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32 valid KV lengths
) -> jax.Array:
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, Hkv, group, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vf)
    return out.reshape(B, H, D).astype(q.dtype)
