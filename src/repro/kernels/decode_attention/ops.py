"""jit'd wrapper for GQA flash-decode attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_S_BLOCK, _grid_decode


@functools.partial(jax.jit, static_argnames=("s_block", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32
    s_block: int = DEFAULT_S_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Single-token GQA attention over a (possibly padded) KV cache."""
    B, H, D = q.shape
    S = k.shape[1]
    if q.shape[0] != k.shape[0]:
        raise ValueError("batch mismatch")
    if H % k.shape[2]:
        raise ValueError("H must be a multiple of Hkv")
    s_blk = min(s_block, S)
    pad = (-S) % s_blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return _grid_decode(q, k, v, lengths, s_blk, interpret)
