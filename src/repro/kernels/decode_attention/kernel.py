"""Pallas TPU GQA flash-decode attention kernel.

The serving hot spot that the OGB KV-page policy feeds (DESIGN.md §4): one
query token per sequence attends over a long KV cache.  The op is strictly
memory-bound (arithmetic intensity ~= 2 q-heads-per-kv FLOP per KV byte), so
the kernel's job is to stream K/V blocks HBM->VMEM exactly once with online
softmax in fp32 accumulators.

Grid: (batch, kv_head, s_blocks) with the s dimension innermost (TPU executes
the grid sequentially, so VMEM scratch carries the running max / denominator /
accumulator across s-blocks — the standard flash pattern).  The q-group
(H/Hkv queries sharing one kv head) rides along, giving the MXU a
(group x D) @ (D x s_blk) matmul per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_S_BLOCK = 512
NEG_INF = -1e30


def decode_kernel(
    q_ref,  # (1, group, D)
    k_ref,  # (1, s_blk, 1, D)
    v_ref,  # (1, s_blk, 1, D)
    len_ref,  # (1, 1) int32
    out_ref,  # (1, group, D)
    m_scr,  # (group, 1) f32 running max
    l_scr,  # (group, 1) f32 running denominator
    acc_scr,  # (group, D) f32 running numerator
    *,
    s_block: int,
    n_s_blocks: int,
):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (group, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (s_blk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (s_blk, D)
    length = len_ref[0, 0]

    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (group, s_blk)

    pos = s * s_block + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < length, scores, NEG_INF)

    m_prev = m_scr[...]  # (group, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)  # (group, 1)
    p = jnp.exp(scores - m_new)  # (group, s_blk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(s == n_s_blocks - 1)
    def _finalize():
        out_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            out_ref.dtype
        )


def _grid_decode(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,)
    s_block: int,
    interpret: bool,
):
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    n_s = S // s_block
    q4 = q.reshape(B, Hkv, group, D)
    len2 = lengths.reshape(B, 1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(decode_kernel, s_block=s_block, n_s_blocks=n_s),
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k, v, len2)
    return out.reshape(B, H, D)
