"""jit'd wrapper for the Pallas histogram."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_ROWS, DEFAULT_ID_CHUNK, LANES, _grid_histogram


@functools.partial(
    jax.jit,
    static_argnames=("catalog_size", "block_rows", "id_chunk", "interpret"),
)
def scatter_counts(
    ids: jax.Array,
    catalog_size: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    id_chunk: int = DEFAULT_ID_CHUNK,
    interpret: bool = True,
) -> jax.Array:
    """Dense float32 histogram of ``ids`` over ``[0, catalog_size)``.

    Negative ids are padding and ignored (they never match a catalog slot).
    """
    b = ids.shape[0]
    pad_b = (-b) % id_chunk
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, pad_b), constant_values=-1)
    block = block_rows * LANES
    n_blocks = -(-catalog_size // block)
    out2 = _grid_histogram(ids_p, n_blocks, block_rows, id_chunk, interpret)
    return out2.reshape(-1)[:catalog_size]
