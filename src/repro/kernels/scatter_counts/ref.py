"""Pure-jnp oracle for the request-histogram kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_counts_ref(ids: jax.Array, catalog_size: int) -> jax.Array:
    """counts[i] = #{t : ids[t] == i}; ids < 0 are padding and ignored."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    return jnp.zeros(catalog_size, jnp.float32).at[safe].add(
        valid.astype(jnp.float32)
    )
