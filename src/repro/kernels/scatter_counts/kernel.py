"""Pallas TPU histogram kernel (request-id -> dense counts).

TPU has no fast generic scatter; the idiomatic replacement at serving scale is
a compare-and-reduce over catalog blocks (equivalently a ones @ one-hot MXU
matmul): for each catalog block resident in VMEM, compare the id vector
against the block's position iota and reduce over the batch dimension.

Work is O(B * N / lanes) — the right trade at serving scale (B <= 4k ids,
page catalogs <= ~1M per shard), where it fuses with the projection update and
avoids XLA's sort-based scatter path.  For huge catalogs the jnp scatter
(repro.jaxcache.fractional.request_counts) is used instead; the crossover is
recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 8  # 8*128 = 1024 catalog slots per block
DEFAULT_ID_CHUNK = 256


def histogram_kernel(ids_ref, out_ref, *, block_rows: int, id_chunk: int):
    i = pl.program_id(0)
    offset = i * block_rows * LANES
    pos = offset + jax.lax.broadcasted_iota(
        jnp.int32, (block_rows, LANES), 0
    ) * LANES + jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 1)

    ids = ids_ref[...]  # (B_pad,)
    n_chunks = ids.shape[0] // id_chunk

    def body(c, acc):
        chunk = jax.lax.dynamic_slice(ids, (c * id_chunk,), (id_chunk,))
        eq = chunk[:, None, None] == pos[None, :, :]  # (chunk, rows, lanes)
        return acc + jnp.sum(eq.astype(jnp.float32), axis=0)

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((block_rows, LANES), jnp.float32)
    )
    out_ref[...] = acc


def _grid_histogram(
    ids: jax.Array,
    n_blocks: int,
    block_rows: int,
    id_chunk: int,
    interpret: bool,
):
    return pl.pallas_call(
        functools.partial(
            histogram_kernel, block_rows=block_rows, id_chunk=id_chunk
        ),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((ids.shape[0],), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_rows, LANES), jnp.float32),
        interpret=interpret,
    )(ids)
