"""Pallas TPU causal GQA flash attention for prefill.

The prefill hot spot: S x S attention without materializing the score matrix.
Grid (B, H, n_q, n_k), kv innermost; VMEM scratch carries the online-softmax
state (m, l, acc) across kv blocks.  GQA needs no head replication at all:
the K/V BlockSpec index_map divides the q-head index by the group size, so
each q-head's grid step streams exactly its shared KV head.

Causality is exploited two ways:
  * fully-masked kv blocks (ki > qi) skip compute via pl.when,
  * the diagonal block applies the triangular mask; blocks below it skip
    masking entirely.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def prefill_kernel(
    q_ref,  # (1, bq, 1, D)
    k_ref,  # (1, bk, 1, D)
    v_ref,  # (1, bk, 1, D)
    out_ref,  # (1, bq, 1, D)
    m_scr,  # (bq, 1)
    l_scr,  # (bq, 1)
    acc_scr,  # (bq, D)
    *,
    block_q: int,
    block_k: int,
    n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    # position arithmetic (block_q and block_k may differ)
    q_start = qi * block_q
    q_last = q_start + block_q - 1
    k_start = ki * block_k
    k_last = k_start + block_k - 1

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(k_start <= q_last)  # skip fully-masked (future) kv blocks
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        def _update(s_blk, v_blk):
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=1, keepdims=True))
            p = jnp.exp(s_blk - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
            acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
                p, v_blk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            m_scr[...] = m_new

        @pl.when(k_last > q_start)  # block straddles the diagonal: mask
        def _mask_diag():
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            _update(jnp.where(k_pos <= q_pos, s, NEG_INF), v)

        @pl.when(k_last <= q_start)  # fully visible block
        def _no_mask():
            _update(s, v)

    @pl.when(ki == n_k - 1)
    def _finalize():
        out_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(out_ref.dtype)


def _grid_prefill(q, k, v, block_q, block_k, interpret):
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    n_q = S // block_q
    n_k = S // block_k

    return pl.pallas_call(
        functools.partial(
            prefill_kernel, block_q=block_q, block_k=block_k, n_k=n_k
        ),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            # GQA: q-head h streams KV head h // g — no replication needed
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
