"""jit'd wrapper for the causal GQA prefill flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, _grid_prefill


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_prefill(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Causal GQA attention over a full prompt (no S x S buffer)."""
    B, S, H, D = q.shape
    if H % k.shape[2]:
        raise ValueError("H must be a multiple of Hkv")
    bq = min(block_q, S)
    bk = min(block_k, S)
    pad = (-S) % max(bq, bk)
    if pad:
        # pad queries/keys; padded queries attend to nothing extra because
        # padded keys sit at positions > every real query under the causal
        # mask... except for padded q rows themselves, which are sliced off.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _grid_prefill(q, k, v, bq, bk, interpret)
    return out[:, :S]
