"""Pure-jnp oracle for causal GQA prefill attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
) -> jax.Array:
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)  # kv head h//g convention
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)
