"""Float64 numpy oracles for the prefix-tree kernel family.

Everything here is deliberately naive — O(N) scans and explicit level
lists — so the packed jnp/Pallas implementations in :mod:`.ops` and
:mod:`.kernel` have an unambiguous reference for the differential tests.
"""

from __future__ import annotations

import numpy as np


def tree_sizes_ref(n: int, radix: int) -> list:
    """Level sizes, leaves first, until a level fits in one radix group."""
    sizes = [int(n)]
    while sizes[-1] > radix:
        sizes.append((sizes[-1] + radix - 1) // radix)
    return sizes


def build_ref(values, radix: int) -> list:
    """List of per-level numpy arrays; level l node i sums its subtree."""
    values = np.asarray(values)
    levels = [values.copy()]
    for size in tree_sizes_ref(len(values), radix)[1:]:
        prev = levels[-1]
        padded = np.zeros(size * radix, prev.dtype)
        padded[: len(prev)] = prev
        levels.append(padded.reshape(size, radix).sum(axis=1))
    return levels


def update_ref(levels: list, idx: int, delta, radix: int) -> None:
    """Point update: add ``delta`` along the ancestor path, in place."""
    node = int(idx)
    for lvl in levels:
        lvl[node] += delta
        node //= radix


def prefix_ref(levels: list, idx: int):
    """Inclusive prefix sum of leaves [0, idx]; idx < 0 gives 0."""
    if idx < 0:
        return levels[0].dtype.type(0)
    return levels[0][: int(idx) + 1].sum()


def select_ref(levels: list, target: float) -> int:
    """Smallest leaf i with inclusive prefix > target (weighted selection)."""
    csum = np.cumsum(levels[0])
    return int(np.searchsorted(csum, target, side="right"))


def madow_sample_ref(f, u: float, capacity: int):
    """Madow systematic sampling in float64: positions u, u+1, ... u+C-1
    over cumsum(f).  Distinct whenever all f <= 1."""
    f = np.asarray(f, np.float64)
    csum = np.cumsum(f)
    targets = u + np.arange(capacity, dtype=np.float64)
    return np.searchsorted(csum, targets, side="right").astype(np.int64)


def minpair_argmin_ref(hi, lo) -> int:
    """Index of the lexicographic minimum of (hi, lo) int32 pairs; first
    index wins ties (the eviction tie-break contract)."""
    hi = np.asarray(hi)
    lo = np.asarray(lo)
    m = hi.min()
    cand = np.where(hi == m)[0]
    return int(cand[np.argmin(lo[cand])])


def sortable_f32_ref(x):
    """Order-preserving float32 -> int32 map (total order, -0 == +0)."""
    x = np.asarray(x, np.float32) + np.float32(0.0)
    b = x.view(np.int32)
    return np.where(b < 0, b ^ np.int32(0x7FFFFFFF), b)


def stack_distance_hits_ref(trace, capacity: int):
    """Exact LRU hit sequence via reuse (stack) distances: a request hits
    iff the number of distinct items since its previous occurrence is at
    most capacity - 1.  O(T * window) — oracle only."""
    trace = np.asarray(trace)
    last = {}
    hits = np.zeros(len(trace), bool)
    for i, j in enumerate(trace):
        j = int(j)
        if j in last:
            d = len(set(trace[last[j] + 1 : i].tolist()))
            hits[i] = d <= capacity - 1
        last[j] = i
    return hits
