"""Device-resident implicit prefix trees (Fenwick/segment family).

The paper's O(log N) per-request machinery, on device: packed radix trees
over leaf vectors supporting point update, prefix/range query, weighted
selection (Madow systematic sampling by tree descent) and lexicographic
argmin — the data structures behind the tree-backed cache engines in
:mod:`repro.cachesim.engines` and the lazy bucketized OGB in
:mod:`repro.cachesim.api`.
"""

from .kernel import block_segment_sums, bucket_masses  # noqa: F401
from .ops import (  # noqa: F401
    madow_sample_tree,
    minpair_argmin,
    minpair_build,
    minpair_root,
    minpair_update,
    sortable_f32,
    tree_build,
    tree_offsets,
    tree_prefix,
    tree_range,
    tree_select,
    tree_sizes,
    tree_storage,
    tree_total,
    tree_update,
)
