"""Packed radix prefix trees — the jnp data plane behind the O(log N) claim.

A tree over ``n`` leaves with branching factor ``radix`` (a power of two)
is stored as ONE flat array: level 0 is the leaves, level l+1 holds the
per-group sums of level l, until a level fits in a single radix group.
Every op is batched and ``lax.scan``-safe, sized for carry residency:

* :func:`tree_update`   — batched point updates, O(Q log n) scatter-adds
* :func:`tree_prefix`   — batched inclusive prefix sums, O(Q R log n)
  gathers (gathers are an order of magnitude cheaper than scatters on every
  backend we run, so queries buy their speed with sibling reads)
* :func:`tree_select`   — batched weighted selection by root-to-leaf
  descent, the O(C log N) Madow/systematic sampler of the paper
* :func:`minpair_*`     — lexicographic (hi, lo) int32 min-trees for
  eviction keys (LFU frequency/tick, FTPL perturbed score/id)

``tree_build`` optionally routes its reduction passes through the Pallas
block kernel in :mod:`.kernel` (TPU; interpret-mode elsewhere) — the jnp
reshape fallback is bit-identical.

No int64 anywhere: the x64 flag stays off, so float order is embedded into
int32 via :func:`sortable_f32` and composite keys are (hi, lo) pairs.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def tree_sizes(n: int, radix: int) -> Tuple[int, ...]:
    sizes = [int(n)]
    while sizes[-1] > radix:
        sizes.append(-(-sizes[-1] // radix))
    return tuple(sizes)


def tree_offsets(n: int, radix: int) -> Tuple[int, ...]:
    offs, off = [], 0
    for s in tree_sizes(n, radix):
        offs.append(off)
        off += s
    return tuple(offs)


def tree_storage(n: int, radix: int) -> int:
    return sum(tree_sizes(n, radix))


def leaves_for_storage(total: int, radix: int) -> int:
    """Invert :func:`tree_storage` (leaf counts are powers of two here),
    so scan bodies can recover static level geometry from a carry shape."""
    n = 1
    while n < total:
        if tree_storage(n, radix) == total:
            return n
        n *= 2
    raise ValueError(f"no power-of-two leaf count stores {total} nodes")


def _shift(radix: int) -> int:
    s = radix.bit_length() - 1
    if 1 << s != radix:
        raise ValueError(f"radix must be a power of two, got {radix}")
    return s


def tree_build(values: jax.Array, radix: int, *, use_kernel: bool = False,
               interpret: Optional[bool] = None) -> jax.Array:
    """Flat packed tree from a leaf vector (any summable dtype)."""
    sizes = tree_sizes(values.shape[0], radix)
    parts, cur = [values], values
    for size in sizes[1:]:
        if use_kernel:
            from .kernel import block_segment_sums

            cur = block_segment_sums(cur, size, radix, interpret=interpret)
        else:
            pad = size * radix - cur.shape[0]
            cur = jnp.pad(cur, (0, pad)).reshape(size, radix).sum(
                axis=1, dtype=values.dtype
            )
        parts.append(cur)
    return jnp.concatenate(parts)


def tree_update(tree: jax.Array, n: int, radix: int, idx: jax.Array,
                delta: jax.Array) -> jax.Array:
    """Batched point update: add ``delta[q]`` along the ancestor path of
    leaf ``idx[q]``; ``idx < 0`` entries are skipped (masked to no-ops)."""
    offs = tree_offsets(n, radix)
    sh = _shift(radix)
    ok = idx >= 0
    node = jnp.where(ok, idx, 0)
    nodes, deltas = [], []
    zero = jnp.zeros((), delta.dtype)
    for off in offs:
        nodes.append(off + node)
        deltas.append(jnp.where(ok, delta, zero))
        node = node >> sh
    return tree.at[jnp.concatenate(nodes)].add(jnp.concatenate(deltas))


def tree_total(tree: jax.Array, n: int, radix: int) -> jax.Array:
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    return jnp.sum(jax.lax.dynamic_slice(tree, (offs[-1],), (sizes[-1],)))


def tree_prefix(tree: jax.Array, n: int, radix: int,
                idx: jax.Array) -> jax.Array:
    """Batched inclusive prefix sums over leaves [0, idx]; idx < 0 -> 0.

    Per level: gather the query ancestor's whole sibling group and mask the
    left part — R cheap gathers instead of a data-dependent walk.
    """
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    sh = _shift(radix)
    mask_lo = radix - 1
    lane = jnp.arange(radix, dtype=jnp.int32)
    ok = idx >= 0
    node = jnp.where(ok, idx, 0)
    acc = None
    for l, off in enumerate(offs):
        grp = (node >> sh) << sh
        gidx = off + jnp.minimum(grp[..., None] + lane, sizes[l] - 1)
        vals = tree[gidx]
        lim = node & mask_lo
        within = (
            lane <= lim[..., None] if l == 0 else lane < lim[..., None]
        )
        part = jnp.sum(
            jnp.where(within & ok[..., None], vals, 0), axis=-1
        )
        acc = part if acc is None else acc + part
        node = node >> sh
    return acc


def tree_range(tree: jax.Array, n: int, radix: int, lo: jax.Array,
               hi: jax.Array) -> jax.Array:
    """Batched sums over leaf ranges [lo, hi] (empty when hi < lo)."""
    return tree_prefix(tree, n, radix, hi) - tree_prefix(tree, n, radix,
                                                         lo - 1)


def tree_select(tree: jax.Array, n: int, radix: int,
                targets: jax.Array) -> jax.Array:
    """Batched weighted selection: smallest leaf with inclusive prefix
    strictly above ``targets`` (float trees; the Madow descent)."""
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    sh = _shift(radix)
    lane = jnp.arange(radix, dtype=jnp.int32)
    node = jnp.zeros(targets.shape, jnp.int32)
    rem = targets
    for l in range(len(offs) - 1, -1, -1):
        base = node << sh if l < len(offs) - 1 else node
        gidx = offs[l] + jnp.minimum(base[..., None] + lane, sizes[l] - 1)
        valid = base[..., None] + lane < sizes[l]
        vals = jnp.where(valid, tree[gidx], 0)
        csum = jnp.cumsum(vals, axis=-1)
        # first child whose cumulative mass exceeds the remaining target
        take = jnp.sum((csum <= rem[..., None]).astype(jnp.int32), axis=-1)
        take = jnp.minimum(take, radix - 1)
        node = base + take
        rem = rem - jnp.where(
            take > 0,
            jnp.take_along_axis(csum, (take - 1)[..., None], axis=-1)[..., 0],
            jnp.zeros((), csum.dtype),
        )
    return jnp.minimum(node, n - 1)


def madow_sample_tree(f: jax.Array, u: jax.Array, capacity: int,
                      radix: int = 64, *, use_kernel: bool = False,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Madow/systematic sample of ``capacity`` distinct items by tree
    descent: O(N/R) build passes + O(C log N) selection, replacing the
    O(N) cumsum + C-way searchsorted.  Returns ascending leaf indices
    (targets are ascending); distinct whenever all f <= 1."""
    tree = tree_build(f, radix, use_kernel=use_kernel, interpret=interpret)
    targets = u + jnp.arange(capacity, dtype=f.dtype)
    return tree_select(tree, f.shape[0], radix, targets)


def sortable_f32(x: jax.Array) -> jax.Array:
    """Order-preserving float32 -> int32 (IEEE-754 total order; +0.0 added
    so -0.0 and +0.0 map identically)."""
    b = jax.lax.bitcast_convert_type(x + jnp.float32(0.0), jnp.int32)
    return jnp.where(b < 0, b ^ jnp.int32(0x7FFFFFFF), b)


# ---------------------------------------------------------------------------
# lexicographic (hi, lo) min-trees — eviction keys
# ---------------------------------------------------------------------------
I32_MAX = jnp.iinfo(jnp.int32).max


def _lex_group_min(hi2: jax.Array, lo2: jax.Array):
    """Per-row lexicographic min over the last axis -> (hi, lo, argmin)."""
    mh = jnp.min(hi2, axis=-1)
    tied = hi2 == mh[..., None]
    lo_m = jnp.where(tied, lo2, I32_MAX)
    ml = jnp.min(lo_m, axis=-1)
    arg = jnp.argmin(
        jnp.where(lo_m == ml[..., None], 0, 1).astype(jnp.int32), axis=-1
    )
    return mh, ml, arg


def minpair_build(hi: jax.Array, lo: jax.Array, radix: int):
    """Flat (tree_hi, tree_lo) min-trees over (hi, lo) int32 key pairs.
    Padding nodes hold (I32_MAX, I32_MAX)."""
    sizes = tree_sizes(hi.shape[0], radix)
    parts_h, parts_l = [hi], [lo]
    ch, cl = hi, lo
    for size in sizes[1:]:
        pad = size * radix - ch.shape[0]
        ch = jnp.pad(ch, (0, pad), constant_values=I32_MAX).reshape(size, radix)
        cl = jnp.pad(cl, (0, pad), constant_values=I32_MAX).reshape(size, radix)
        mh, ml, _ = _lex_group_min(ch, cl)
        ch, cl = mh, ml
        parts_h.append(ch)
        parts_l.append(cl)
    return jnp.concatenate(parts_h), jnp.concatenate(parts_l)


def minpair_root(tree_hi: jax.Array, tree_lo: jax.Array, n: int, radix: int):
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    top_h = jax.lax.dynamic_slice(tree_hi, (offs[-1],), (sizes[-1],))
    top_l = jax.lax.dynamic_slice(tree_lo, (offs[-1],), (sizes[-1],))
    mh, ml, _ = _lex_group_min(top_h, top_l)
    return mh, ml


def minpair_argmin(tree_hi: jax.Array, tree_lo: jax.Array, n: int,
                   radix: int) -> jax.Array:
    """Leaf index of the lexicographic minimum (first index wins ties —
    group argmins prefer the lowest child at every level)."""
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    sh = _shift(radix)
    node = jnp.zeros((), jnp.int32)
    for l in range(len(offs) - 1, -1, -1):
        base = node << sh if l < len(offs) - 1 else node
        lane = jnp.arange(radix, dtype=jnp.int32)
        idx = offs[l] + jnp.minimum(base + lane, sizes[l] - 1)
        valid = base + lane < sizes[l]
        h = jnp.where(valid, tree_hi[idx], I32_MAX)
        lo_ = jnp.where(valid, tree_lo[idx], I32_MAX)
        _, _, arg = _lex_group_min(h, lo_)
        node = base + arg.astype(jnp.int32)
    return node


def minpair_update_plan(tree_hi: jax.Array, tree_lo: jax.Array, n: int,
                        radix: int, idx: jax.Array, hi: jax.Array,
                        lo: jax.Array):
    """Plan a point update: the (nodes, hi_vals, lo_vals) scatter that sets
    leaf ``idx`` to (hi, lo) and refreshes its ancestor mins, computed by
    in-register substitution against the *current* trees.

    Returning the plan instead of applying it is what lets the per-request
    engines run delayed-write: apply the previous request's plan first, then
    read — no read-after-write anti-dependency, no O(n) array copies."""
    offs = tree_offsets(n, radix)
    sizes = tree_sizes(n, radix)
    sh = _shift(radix)
    nodes = [idx]
    vals_h, vals_l = [hi], [lo]
    node, nh, nl = idx, hi, lo
    for l in range(1, len(offs)):
        grp = node >> sh
        base = grp << sh
        lane = jnp.arange(radix, dtype=jnp.int32)
        gidx = offs[l - 1] + jnp.minimum(base + lane, sizes[l - 1] - 1)
        valid = base + lane < sizes[l - 1]
        h = jnp.where(valid, tree_hi[gidx], I32_MAX)
        lo_ = jnp.where(valid, tree_lo[gidx], I32_MAX)
        pos = node - base
        h = h.at[pos].set(nh)
        lo_ = lo_.at[pos].set(nl)
        nh, nl, _ = _lex_group_min(h, lo_)
        node = grp
        nodes.append(node)
        vals_h.append(nh)
        vals_l.append(nl)
    sidx = jnp.stack([offs[l] + nodes[l] for l in range(len(offs))])
    return sidx, jnp.stack(vals_h), jnp.stack(vals_l)


def minpair_update(tree_hi: jax.Array, tree_lo: jax.Array, n: int,
                   radix: int, idx: jax.Array, hi: jax.Array,
                   lo: jax.Array):
    """Single point update: set leaf ``idx`` to (hi, lo) and recompute its
    ancestor groups (the eager form of :func:`minpair_update_plan`)."""
    sidx, vh, vl = minpair_update_plan(tree_hi, tree_lo, n, radix, idx, hi,
                                       lo)
    return tree_hi.at[sidx].set(vh), tree_lo.at[sidx].set(vl)
