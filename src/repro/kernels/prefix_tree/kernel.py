"""Pallas TPU kernels for the prefix-tree family.

Two batch-level passes dominate the tree engines' device profile once the
per-request scatter/gather paths are in place, and both are plain block
reductions — exactly the shape Pallas is good at:

  * ``segsum_kernel`` — the tree *build* reduction: one level of the packed
    radix tree from its child level, each output node summing a contiguous
    ``radix`` group.  Used by ``ops.tree_build(..., use_kernel=True)`` for
    full rebuilds (compaction / re-anchoring); the jnp reshape-sum fallback
    is bit-identical.

  * ``bucket_mass_kernel`` — the lazy-OGB threshold solve: for K candidate
    thresholds, ``mass(t) = sum_b cnt_b * clip(mean_b - t, 0, 1)`` over the
    (V,) bucket-count / bucket-sum arrays, accumulated across grid blocks
    into one (K,) output (TPU revisiting-output pattern, mirroring
    ``capped_simplex.kernel.mass_kernel``).  K-way bracketing over buckets
    replaces K full-catalog sweeps of the dense projection.

Blocks keep the 128-lane layout of the capped_simplex kernels; inputs are
padded host-side.  CPU hot paths use the jnp forms in :mod:`.ops` — these
kernels are the TPU artifacts, validated in interpret mode by the tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 256
_K_CHUNK = 8


def _auto_interpret(interpret: Optional[bool]) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def segsum_kernel(x_ref, out_ref):
    """Sum each row of a (block_rows, radix) child block into one node."""
    out_ref[...] = jnp.sum(x_ref[...], axis=1)


def block_segment_sums(values: jax.Array, out_size: int, radix: int, *,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: Optional[bool] = None) -> jax.Array:
    """One tree-build reduction level: (out_size,) per-group sums of a
    child level, groups of ``radix`` consecutive children."""
    interpret = _auto_interpret(interpret)
    pad_rows = -out_size % block_rows
    x2 = jnp.pad(values, (0, out_size * radix - values.shape[0]))
    x2 = jnp.pad(x2.reshape(out_size, radix), ((0, pad_rows), (0, 0)))
    rows = x2.shape[0]
    out = pl.pallas_call(
        segsum_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, radix), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), values.dtype),
        interpret=interpret,
    )(x2)
    return out[:out_size]


def bucket_mass_kernel(cnt_ref, sum_ref, taus_ref, mass_ref, *, k: int):
    """Accumulate sum_b cnt_b * clip(mean_b - tau_j, 0, 1) over blocks."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        mass_ref[...] = jnp.zeros_like(mass_ref)

    cnt = cnt_ref[...].astype(jnp.float32)
    tot = sum_ref[...].astype(jnp.float32)
    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1.0), 0.0)
    taus = taus_ref[...]  # (k,)

    mass_acc = jnp.zeros((k,), jnp.float32)
    n_chunks = k // _K_CHUNK

    def chunk_body(c, acc):
        t = jax.lax.dynamic_slice(taus, (c * _K_CHUNK,), (_K_CHUNK,))
        z = jnp.clip(mean[None, :, :] - t[:, None, None], 0.0, 1.0)
        m = jnp.sum(cnt[None, :, :] * z, axis=(1, 2))  # (chunk,)
        return jax.lax.dynamic_update_slice(acc, m, (c * _K_CHUNK,))

    mass_acc = jax.lax.fori_loop(0, n_chunks, chunk_body, mass_acc)
    mass_ref[...] += mass_acc


def bucket_masses(cnt: jax.Array, total: jax.Array, taus: jax.Array, *,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: Optional[bool] = None) -> jax.Array:
    """mass(tau_j) = sum_b cnt_b * clip(mean_b - tau_j, 0, 1) for K
    candidate thresholds over (V,) bucket count/sum arrays, one pass."""
    interpret = _auto_interpret(interpret)
    k = taus.shape[0]
    if k % _K_CHUNK:
        raise ValueError(f"K must be a multiple of {_K_CHUNK}, got {k}")
    v = cnt.shape[0]
    cols = block_rows * LANES
    pad = -v % cols
    c2 = jnp.pad(cnt, (0, pad)).reshape(-1, LANES).astype(jnp.float32)
    s2 = jnp.pad(total, (0, pad)).reshape(-1, LANES).astype(jnp.float32)
    rows = c2.shape[0]
    (mass,) = pl.pallas_call(
        functools.partial(bucket_mass_kernel, k=k),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((k,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32)],
        interpret=interpret,
    )(c2, s2, taus.astype(jnp.float32))
    return mass
