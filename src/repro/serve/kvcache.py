"""Prefix-page KV cache with pluggable residency policy.

The serving engine splits every prompt into pages of ``page_size`` tokens;
a page is identified by the hash of the *entire prefix* up to its end (so a
page hit implies the whole prefix matches — the vLLM prefix-caching
invariant).  The page pool holds ``pool_pages`` pages of KV in fast memory;
the residency policy decides admission/eviction.

Policies: the paper's OGB (regret-optimal, O(log N) per touch — the point of
this framework), plus LRU / LFU / FTPL for comparison.  The policy sees one
"request" per page *touch*, batched per engine step: exactly the paper's
batched integral-caching setting.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Sequence



def page_keys(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Prefix hashes at page granularity (full pages only)."""
    out = []
    h = hashlib.blake2b(digest_size=16)
    n_full = len(tokens) // page_size
    for p in range(n_full):
        chunk = bytes(
            int(t) % 256 for t in tokens[p * page_size : (p + 1) * page_size]
        ) + str(
            list(tokens[p * page_size : (p + 1) * page_size])
        ).encode()
        h.update(chunk)
        out.append(h.digest())
    return out


@dataclass
class PagePoolStats:
    touches: int = 0
    hits: int = 0
    tokens_total: int = 0
    tokens_reused: int = 0
    admissions: int = 0
    evictions: int = 0

    @property
    def page_hit_ratio(self) -> float:
        return self.hits / max(self.touches, 1)

    @property
    def token_reuse_ratio(self) -> float:
        return self.tokens_reused / max(self.tokens_total, 1)


class PagedKVPool:
    """Page pool + id mapping; residency decided by the wrapped policy."""

    def __init__(
        self,
        policy,  # OGB / LRU / ... over integer ids
        page_size: int = 64,
        catalog_size: int = 1 << 20,
    ):
        self.policy = policy
        self.page_size = page_size
        self.catalog_size = catalog_size
        self._ids: Dict[bytes, int] = {}
        self._next_id = 0
        self.stats = PagePoolStats()

    def _page_id(self, key: bytes) -> int:
        pid = self._ids.get(key)
        if pid is None:
            pid = self._next_id % self.catalog_size
            self._next_id += 1
            self._ids[key] = pid
        return pid

    def match_prefix(self, tokens: Sequence[int]) -> int:
        """Longest resident prefix (in tokens) without touching the policy."""
        n = 0
        for key in page_keys(tokens, self.page_size):
            pid = self._ids.get(key)
            if pid is None or not self.policy.contains(pid):
                break
            n += self.page_size
        return n

    def serve(self, tokens: Sequence[int]) -> int:
        """Process one prompt's pages; returns reused token count."""
        keys = page_keys(tokens, self.page_size)
        reused = 0
        still_prefix = True
        for key in keys:
            pid = self._page_id(key)
            hit = self.policy.request(pid)
            self.stats.touches += 1
            self.stats.hits += int(hit)
            if still_prefix and hit:
                reused += self.page_size
            else:
                still_prefix = False
        self.stats.tokens_total += len(tokens)
        self.stats.tokens_reused += reused
        return reused

    def batch_end(self) -> None:
        self.policy.batch_end()

    def occupancy(self) -> float:
        return float(self.policy.occupancy())
