"""OGB expert-residency manager for MoE offloading (beyond-paper, DESIGN.md §4).

Catalog = (layer, expert) pairs; the router's per-batch expert counts are the
gradient of the linear reward  sum_t w_t . x  (an expert "hit" = the tokens it
serves are processed from HBM rather than refetched from host).  The policy is
the registered ``ogb_grad`` :class:`~repro.cachesim.api.PolicyDef` — the
dense-gradient flavor of the same fractional OGB update the replay engine
scans — consumed one serving step at a time through the API's streaming-carry
contract: ``carry, out = step(carry, expert_counts)``.  Residency is the
coordinated Poisson sample with permanent random numbers (carried in the
policy state), so consecutive steps swap only O(changed mass) experts —
exactly the paper's positive-coordination property, applied to expert weights
instead of CDN objects.

Regret guarantee inherited from Theorem 3.1: total expert-fetch traffic is
asymptotically no worse than the best *static* expert placement in hindsight,
for any routing pattern — the interesting case being routing drift during
serving, where LFU-style placement (= FTPL) goes stale.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.api import policy_def
from repro.jaxcache.fractional import poisson_sample


@dataclass
class ExpertCacheConfig:
    n_layers: int
    n_experts: int
    resident_fraction: float = 0.25  # fraction of experts held in HBM
    eta: Optional[float] = None
    horizon_steps: int = 10_000
    bytes_per_expert: int = 0  # telemetry


class OGBExpertCache:
    """Streaming ``ogb_grad`` policy + Poisson residency over (L*E,) experts."""

    def __init__(self, cfg: ExpertCacheConfig, seed: int = 0):
        self.cfg = cfg
        n = cfg.n_layers * cfg.n_experts
        self.N = n
        self.C = max(1, int(round(n * cfg.resident_fraction)))
        if cfg.eta is None:
            # Theorem 3.1 with B = 1 policy step per serving step
            self.eta = float(
                np.sqrt(self.C * (1 - self.C / n) / cfg.horizon_steps)
            )
        else:
            self.eta = cfg.eta
        pd = policy_def("ogb_grad")
        self.carry = pd.init(n, self.C, seed=seed, eta=self.eta)
        self._step = jax.jit(pd.step, donate_argnums=(0,))
        self._resident = np.asarray(
            poisson_sample(self.carry.f, self.carry.p, self.C)
        )
        self.steps = 0
        self.swapped_in = 0
        self.swapped_out = 0
        self.hits_weighted = 0.0
        self.total_weighted = 0.0

    @property
    def resident(self) -> np.ndarray:
        """Current Poisson residency mask — always the one residency rule
        (:func:`~repro.jaxcache.fractional.poisson_sample` over the carried
        state and permanent random numbers), recomputed lazily from the
        carry when invalidated."""
        if self._resident is None:
            self._resident = np.asarray(
                poisson_sample(self.carry.f, self.carry.p, self.C)
            )
        return self._resident

    def step(self, expert_counts: np.ndarray) -> Dict[str, float]:
        """expert_counts: (L, E) routed-token counts from the router.

        ``swapped_in``/``swapped_out`` are the *true* residency churn —
        the diff between consecutive Poisson residency masks — not the hit
        count (``out.hits`` counts requested-and-resident experts).  The
        mask diff is what the paper's O(changed-mass) positive-coordination
        claim is about; ``bytes_per_expert`` scales it into the fetch
        traffic the swaps cost (``swap_bytes``/``resident_bytes``)."""
        counts = jnp.asarray(expert_counts, jnp.float32).reshape(-1)
        prev = self.resident  # materialize before the carry is donated
        self.carry, out = self._step(self.carry, counts)
        new = np.asarray(
            poisson_sample(self.carry.f, self.carry.p, self.C)
        )
        self._resident = new
        s_in = int(np.sum(new & ~prev))
        s_out = int(np.sum(prev & ~new))
        self.steps += 1
        self.swapped_in += s_in
        self.swapped_out += s_out
        self.hits_weighted += float(out.reward)
        self.total_weighted += 1.0
        bpe = int(self.cfg.bytes_per_expert)
        return {
            "resident_hit_ratio": float(out.reward),
            "hits": int(out.hits),
            "swapped_in": s_in,
            "swapped_out": s_out,
            "occupancy": int(out.occupancy),
            "swap_bytes": (s_in + s_out) * bpe,
            "resident_bytes": int(np.sum(new)) * bpe,
        }

    def resident_mask(self) -> np.ndarray:
        return np.asarray(self.resident).reshape(
            self.cfg.n_layers, self.cfg.n_experts
        )

    @property
    def mean_hit_ratio(self) -> float:
        return self.hits_weighted / max(self.total_weighted, 1.0)
