"""OGB expert-residency manager for MoE offloading (beyond-paper, DESIGN.md §4).

Catalog = (layer, expert) pairs; the router's per-batch expert counts are the
gradient of the linear reward  sum_t w_t . x  (an expert "hit" = the tokens it
serves are processed from HBM rather than refetched from host).  The
fractional state is maintained with the *batched fractional OGB* data-plane
update (one capped-simplex projection per serving step, vectorized in JAX),
and residency is the coordinated Poisson sample with permanent random numbers
— so consecutive steps swap only O(changed mass) experts: exactly the paper's
positive-coordination property, applied to expert weights instead of CDN
objects.

Regret guarantee inherited from Theorem 3.1: total expert-fetch traffic is
asymptotically no worse than the best *static* expert placement in hindsight,
for any routing pattern — the interesting case being routing drift during
serving, where LFU-style placement (= FTPL) goes stale.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.jaxcache.fractional import (
    capped_simplex_project,
    permanent_random_numbers,
    poisson_sample,
)


@dataclass
class ExpertCacheConfig:
    n_layers: int
    n_experts: int
    resident_fraction: float = 0.25  # fraction of experts held in HBM
    eta: Optional[float] = None
    horizon_steps: int = 10_000
    bytes_per_expert: int = 0  # telemetry


class OGBExpertCache:
    """Vectorized fractional OGB + Poisson sampling over (L*E,) expert slots."""

    def __init__(self, cfg: ExpertCacheConfig, seed: int = 0):
        self.cfg = cfg
        n = cfg.n_layers * cfg.n_experts
        self.N = n
        self.C = max(1, int(round(n * cfg.resident_fraction)))
        if cfg.eta is None:
            # Theorem 3.1 with B = 1 policy step per serving step
            self.eta = float(
                np.sqrt(self.C * (1 - self.C / n) / cfg.horizon_steps)
            )
        else:
            self.eta = cfg.eta
        self.f = jnp.full((n,), self.C / n, jnp.float32)
        self.p = permanent_random_numbers(jax.random.key(seed), n)
        self.resident = poisson_sample(self.f, self.p, self.C)
        self._update = jax.jit(self._update_impl)
        self.steps = 0
        self.swapped_in = 0
        self.hits_weighted = 0.0
        self.total_weighted = 0.0

    def _update_impl(self, f, counts, resident, p):
        total = jnp.sum(counts)
        norm = counts / jnp.maximum(total, 1.0)  # per-step gradient, unit mass
        reward = jnp.sum(norm * resident.astype(jnp.float32))
        y = f + self.eta * norm
        f_new, _ = capped_simplex_project(y, float(self.C))
        resident_new = f_new >= p
        swapped = jnp.sum(
            jnp.logical_and(resident_new, jnp.logical_not(resident))
        )
        return f_new, resident_new, reward, swapped

    def step(self, expert_counts: np.ndarray) -> Dict[str, float]:
        """expert_counts: (L, E) routed-token counts from the router."""
        counts = jnp.asarray(expert_counts, jnp.float32).reshape(-1)
        self.f, self.resident, reward, swapped = self._update(
            self.f, counts, self.resident, self.p
        )
        self.steps += 1
        self.swapped_in += int(swapped)
        self.hits_weighted += float(reward)
        self.total_weighted += 1.0
        return {
            "resident_hit_ratio": float(reward),
            "swapped_in": int(swapped),
            "occupancy": int(jnp.sum(self.resident)),
        }

    def resident_mask(self) -> np.ndarray:
        return np.asarray(self.resident).reshape(
            self.cfg.n_layers, self.cfg.n_experts
        )

    @property
    def mean_hit_ratio(self) -> float:
        return self.hits_weighted / max(self.total_weighted, 1.0)
