"""Batched serving engine: prefill + decode with an OGB-managed prefix cache.

One engine step serves a batch of requests:
  1. prefix-match each prompt against the page pool (tokens already cached
     skip recomputation — the measurable win of the cache policy),
  2. prefill the uncached suffixes (real jitted model call),
  3. decode greedily for `max_new_tokens`,
  4. feed the page touches to the residency policy; `batch_end()` triggers
     the policy's batched sample update (paper Algorithm 3 cadence).

This is deliberately the paper's *batched* regime: the cache content is
frozen within a step and resampled between steps.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, init_cache, prefill

from .kvcache import PagedKVPool


@dataclass
class EngineStats:
    requests: int = 0
    prefill_tokens: int = 0
    prefill_tokens_skipped: int = 0
    decode_tokens: int = 0
    wall_prefill: float = 0.0
    wall_decode: float = 0.0

    @property
    def prefix_reuse(self) -> float:
        return self.prefill_tokens_skipped / max(self.prefill_tokens, 1)


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        pool: Optional[PagedKVPool] = None,
        max_len: int = 256,
    ):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.max_len = max_len
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t)
        )

    def generate(
        self, prompts: np.ndarray, max_new_tokens: int = 16
    ) -> np.ndarray:
        """prompts: (B, S) int32. Greedy decode. Returns (B, max_new_tokens)."""
        B, S = prompts.shape
        self.stats.requests += B
        self.stats.prefill_tokens += B * S

        # 1) prefix-cache consultation (page pool is frozen during the step)
        if self.pool is not None:
            for b in range(B):
                reused = self.pool.match_prefix(list(prompts[b]))
                self.stats.prefill_tokens_skipped += int(reused)

        # 2) prefill (the model recomputes the non-reused part; the engine
        #    currently recomputes full prompts — KV splicing is the
        #    deployment optimization, reuse telemetry is what we measure)
        t0 = time.perf_counter()
        logits, cache = prefill(
            self.cfg, self.params, {"tokens": jnp.asarray(prompts)}, self.max_len
        )
        self.stats.wall_prefill += time.perf_counter() - t0

        # 3) greedy decode
        out = np.empty((B, max_new_tokens), np.int32)
        t0 = time.perf_counter()
        tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(
                jnp.int32
            )
        self.stats.decode_tokens += B * max_new_tokens
        self.stats.wall_decode += time.perf_counter() - t0

        # 4) page-touch accounting + batched policy update
        if self.pool is not None:
            for b in range(B):
                self.pool.serve(list(prompts[b]))
            self.pool.batch_end()
        return out
