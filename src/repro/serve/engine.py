"""Serving engines: batched prefill/decode and the continuous open-loop.

:class:`ServeEngine` — one engine step serves a batch of requests:
  1. prefix-match each prompt against the page pool (tokens already cached
     skip recomputation — the measurable win of the cache policy),
  2. prefill the uncached suffixes (real jitted model call),
  3. decode greedily for `max_new_tokens`,
  4. feed the page touches to the residency policy; `batch_end()` triggers
     the policy's batched sample update (paper Algorithm 3 cadence).

This is deliberately the paper's *batched* regime: the cache content is
frozen within a step and resampled between steps.

:class:`ContinuousServingLoop` — the *continuous* regime the online-serving
papers (Paschos et al.; Si Salem et al.) evaluate: requests arrive on
their own clock (**open-loop** — the arrival process does not wait for
the server, so a slow decision builds a backlog that inflates the next
request's latency, exactly like production traffic), the loop batches
whatever has arrived, makes one cache decision per batch, and records
**per-request decision latency** from arrival to decision-complete.  The
:class:`ServingSLO` it returns is the latency artifact: p50/p99 decision
latency plus sustained requests/sec — not an amortized us/request over a
dead trace.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, prefill

from .kvcache import PagedKVPool


@dataclass
class ServingSLO:
    """The latency-SLO artifact of one continuous-serving run.

    ``latencies_ms`` holds one entry per request: the time from its
    (open-loop) arrival to the completion of the decision that covered it
    — queueing delay included, which is what makes the p99 meaningful.
    ``req_per_sec`` is sustained throughput over the makespan (first
    arrival to last decision), not the offered rate."""

    requests: int
    steps: int  # decision batches dispatched
    seconds: float  # makespan: first arrival -> last decision complete
    req_per_sec: float
    p50_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    backlog_max: int  # deepest arrival backlog observed
    latencies_ms: np.ndarray = field(repr=False, default=None)

    @classmethod
    def from_latencies(
        cls, lat_s: np.ndarray, seconds: float, steps: int, backlog_max: int
    ) -> "ServingSLO":
        lat_ms = np.asarray(lat_s, np.float64) * 1e3
        return cls(
            requests=len(lat_ms),
            steps=steps,
            seconds=float(seconds),
            req_per_sec=len(lat_ms) / max(seconds, 1e-12),
            p50_ms=float(np.percentile(lat_ms, 50)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            mean_ms=float(np.mean(lat_ms)),
            max_ms=float(np.max(lat_ms)),
            backlog_max=int(backlog_max),
            latencies_ms=lat_ms,
        )


class ContinuousServingLoop:
    """Open-loop continuous serving: arrivals on a clock, decisions batched.

    ``decide(batch)`` is the per-step cache decision — e.g.
    ``OGBExpertCache.step`` over a routed-count vector, or one resumable
    ``api.run(carry=...)`` window — called with a list of up to
    ``batch_max`` arrived payloads.  The loop is deliberately host-driven
    and single-threaded: the serving question is how long a *decision*
    takes under sustained arrivals, not how fast a dead trace replays.

    ``clock``/``sleep`` are injectable for deterministic tests.
    """

    def __init__(self, decide, *, batch_max: int = 1, clock=None, sleep=None):
        if batch_max < 1:
            raise ValueError(f"batch_max must be >= 1, got {batch_max}")
        self.decide = decide
        self.batch_max = int(batch_max)
        self.clock = clock or time.perf_counter
        self.sleep = sleep or time.sleep

    def run(self, payloads: Sequence, rate: float) -> ServingSLO:
        """Serve ``payloads`` arriving open-loop at ``rate`` requests/sec.

        Request ``i`` arrives at ``i / rate`` seconds after the start,
        whether or not the server has kept up; its latency is measured to
        the completion of the decision batch that included it."""
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        n = len(payloads)
        arrivals = np.arange(n, dtype=np.float64) / float(rate)
        lat = np.empty(n, np.float64)
        t0 = self.clock()
        served = 0
        steps = 0
        backlog_max = 0
        while served < n:
            now = self.clock() - t0
            if arrivals[served] > now:  # open-loop: idle until next arrival
                self.sleep(min(arrivals[served] - now, 0.01))
                continue
            # everything that has arrived is backlog; take one batch of it
            arrived = int(np.searchsorted(arrivals, now, side="right"))
            backlog_max = max(backlog_max, arrived - served)
            take = min(arrived - served, self.batch_max)
            batch = payloads[served : served + take]
            self.decide(list(batch))
            done = self.clock() - t0
            lat[served : served + take] = done - arrivals[
                served : served + take
            ]
            served += take
            steps += 1
        makespan = self.clock() - t0
        return ServingSLO.from_latencies(lat, makespan, steps, backlog_max)


@dataclass
class EngineStats:
    requests: int = 0
    prefill_tokens: int = 0
    prefill_tokens_skipped: int = 0
    decode_tokens: int = 0
    wall_prefill: float = 0.0
    wall_decode: float = 0.0

    @property
    def prefix_reuse(self) -> float:
        return self.prefill_tokens_skipped / max(self.prefill_tokens, 1)


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        pool: Optional[PagedKVPool] = None,
        max_len: int = 256,
    ):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.max_len = max_len
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t)
        )

    def generate(
        self, prompts: np.ndarray, max_new_tokens: int = 16
    ) -> np.ndarray:
        """prompts: (B, S) int32. Greedy decode. Returns (B, max_new_tokens)."""
        B, S = prompts.shape
        self.stats.requests += B
        self.stats.prefill_tokens += B * S

        # 1) prefix-cache consultation (page pool is frozen during the step)
        if self.pool is not None:
            for b in range(B):
                reused = self.pool.match_prefix(list(prompts[b]))
                self.stats.prefill_tokens_skipped += int(reused)

        # 2) prefill (the model recomputes the non-reused part; the engine
        #    currently recomputes full prompts — KV splicing is the
        #    deployment optimization, reuse telemetry is what we measure)
        t0 = time.perf_counter()
        logits, cache = prefill(
            self.cfg, self.params, {"tokens": jnp.asarray(prompts)}, self.max_len
        )
        self.stats.wall_prefill += time.perf_counter() - t0

        # 3) greedy decode
        out = np.empty((B, max_new_tokens), np.int32)
        t0 = time.perf_counter()
        tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(
                jnp.int32
            )
        self.stats.decode_tokens += B * max_new_tokens
        self.stats.wall_decode += time.perf_counter() - t0

        # 4) page-touch accounting + batched policy update
        if self.pool is not None:
            for b in range(B):
                self.pool.serve(list(prompts[b]))
            self.pool.batch_end()
        return out
