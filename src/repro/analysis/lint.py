"""File-walking driver for the reprolint AST rules.

Usage from code::

    from repro.analysis.lint import lint_paths
    findings = lint_paths(["src/repro"])   # List[Finding], sorted

The CLI entry point is ``python -m repro.analysis`` (see ``__main__``),
which runs this pass plus the dynamic PolicyDef contract checker.
"""

from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence

from repro.analysis.rules import Finding, LintConfig, lint_source

__all__ = ["lint_paths", "lint_file", "iter_python_files"]

#: directories never linted (vendored fixtures carry seeded violations)
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "node_modules", "data"}


def iter_python_files(paths: Iterable[str]) -> List[str]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(root, f))
    return sorted(set(out))


def lint_file(
    path: str,
    cfg: Optional[LintConfig] = None,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    return lint_source(source, path, cfg=cfg, rules=rules)


def lint_paths(
    paths: Iterable[str],
    cfg: Optional[LintConfig] = None,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_python_files(paths):
        findings.extend(lint_file(path, cfg=cfg, rules=rules))
    return findings
