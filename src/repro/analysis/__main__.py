"""CLI gate: ``python -m repro.analysis [paths ...]``.

Runs the AST lint pass over the given paths (default: the installed
``repro`` source tree) and the PolicyDef contract checker over the live
registry.  Exit code 0 means every rule is silent and every registered
kind honors its contracts; anything else is a finding list on stdout.
CI runs this on every push (the ``lint`` job); policy authors run it
locally before registering a new kind.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _default_paths() -> list:
    """The repo's src/repro tree when run from a checkout, else the
    installed package directory."""
    here = os.path.dirname(os.path.abspath(__file__))  # .../repro/analysis
    return [os.path.dirname(here)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="JAX contract checker + AST lint for the OGB cache "
        "reproduction",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories to lint (default: the repro package)",
    )
    ap.add_argument(
        "--rules",
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--no-lint", action="store_true", help="skip the AST lint pass"
    )
    ap.add_argument(
        "--no-contracts",
        action="store_true",
        help="skip the PolicyDef contract checker",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule table"
    )
    args = ap.parse_args(argv)

    from repro.analysis.rules import RULES

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.rule_id}  {rule.slug}\n    {rule.doc}")
        return 0

    failed = False
    t0 = time.perf_counter()

    if not args.no_lint:
        from repro.analysis.lint import iter_python_files, lint_paths

        paths = args.paths or _default_paths()
        rules = args.rules.split(",") if args.rules else None
        findings = lint_paths(paths, rules=rules)
        n_files = len(iter_python_files(paths))
        for f in findings:
            print(f)
        if findings:
            failed = True
        print(
            f"reprolint: {len(findings)} finding(s) over {n_files} file(s)"
        )

    if not args.no_contracts:
        from repro.analysis.contracts import check_all

        reports = check_all()
        bad = [r for r in reports if not r.ok]
        for r in bad:
            print(r)
        n_checks = sum(len(r.checks) for r in reports)
        print(
            f"contracts: {len(reports) - len(bad)}/{len(reports)} "
            f"PolicyDef kinds ok ({n_checks} checks)"
        )
        if bad:
            failed = True

    print(f"total: {time.perf_counter() - t0:.1f}s")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
