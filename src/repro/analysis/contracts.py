"""Dynamic PolicyDef contract checker — abstract eval, no device steps.

The whole execution layer (``api.run``'s donated-carry AOT scan, the
vmapped ``api.sweep`` grid, ``run_stream``'s resumable segments, the
serving loop) assumes every registered :class:`~repro.cachesim.api.
PolicyDef` honors contracts the type system cannot express:

* ``init``/``step`` **signatures** follow the protocol (``init(
  catalog_size, capacity, *, seed, eta, horizon, n_slots, sizes, costs)``,
  ``step(carry, request_ids)``);
* the **carry pytree is a fixed point of step**: same treedef, same leaf
  shapes and dtypes out as in — otherwise ``lax.scan`` rejects it, the
  executable cache misses every segment, and resume breaks;
* ``step`` emits a **complete StepOut** (scalar f32 reward/aux/occupancy,
  scalar i32 hits, byte_hits None or scalar f32);
* **donation is actually honored**: every carry leaf aliases an output
  buffer in the lowered module (a dtype/shape drift silently disables
  donation and doubles peak memory at fleet scale);
* the **sizes=/costs= rejection paths fire**: a policy with no size or
  cost model must reject them loudly — and one that accepts sizes must
  emit ``byte_hits`` (silently dropping sizes corrupts byte accounting).

Everything runs through ``jax.eval_shape`` and ``jit(...).lower()`` on
``ShapeDtypeStruct`` avals: carries are initialized concretely (tiny host
arrays) but **no policy step is ever executed on device**, which is what
keeps the CI gate fast.
"""

from __future__ import annotations

import inspect
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "ContractReport",
    "check_policy_def",
    "check_all",
    "EXTRA_FLAVORS",
]

#: small-but-not-degenerate default geometry (catalog, capacity, window)
DEFAULT_N = 96
DEFAULT_C = 8
DEFAULT_W = 16

#: non-default static flavors also under contract (options as a callable of
#: the probe capacity, since madow flavors bind it statically)
EXTRA_FLAVORS: Sequence[Tuple[str, Any]] = (
    ("ogb", lambda cap: {"sample": "madow", "madow_capacity": cap}),
    ("ogb", lambda cap: {"sample": "madow_tree", "madow_capacity": cap}),
    ("ogb", lambda cap: {"sample": "none"}),
    ("ogb_sized", lambda cap: {"flavor": "scan"}),
    ("lru", lambda cap: {"impl": "dense"}),
    ("lfu", lambda cap: {"impl": "dense"}),
    ("ftpl", lambda cap: {"impl": "dense"}),
)

_REQUIRED_INIT_KWARGS = ("seed", "eta", "horizon", "n_slots")
_SIZED_KWARGS = ("sizes", "costs")


@dataclass
class ContractReport:
    """Outcome of one PolicyDef's contract check."""

    kind: str
    options: Dict[str, Any] = field(default_factory=dict)
    checks: List[str] = field(default_factory=list)  # passed check names
    errors: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.errors

    def __str__(self) -> str:
        tag = "ok" if self.ok else "FAIL"
        opts = f" {self.options}" if self.options else ""
        head = f"[{tag}] {self.kind}{opts}: {len(self.checks)} checks"
        if self.errors:
            head += "\n" + "\n".join(f"    - {e}" for e in self.errors)
        return head


def _avals(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), tree
    )


def _leaf_sig(tree):
    return [
        (tuple(np.shape(x)), str(x.dtype)) for x in jax.tree.leaves(tree)
    ]


def _check_signatures(pd, rep: ContractReport) -> None:
    sig = inspect.signature(pd.init)
    params = list(sig.parameters.values())
    pos = [
        p
        for p in params
        if p.kind
        in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
    ]
    names = [p.name for p in pos]
    if names[:2] != ["catalog_size", "capacity"]:
        rep.errors.append(
            f"init must take (catalog_size, capacity) positionally, got "
            f"{names[:2]}"
        )
    kw = {
        p.name
        for p in params
        if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
    }
    has_var_kw = any(p.kind == p.VAR_KEYWORD for p in params)
    missing = [k for k in _REQUIRED_INIT_KWARGS if k not in kw]
    if missing and not has_var_kw:
        rep.errors.append(f"init missing keyword params {missing}")
    missing_sized = [k for k in _SIZED_KWARGS if k not in kw]
    if missing_sized and not has_var_kw:
        rep.errors.append(
            f"init must accept (and accept-or-reject loudly) {missing_sized}"
        )
    step_sig = inspect.signature(pd.step)
    n_step = len(
        [
            p
            for p in step_sig.parameters.values()
            if p.kind
            in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
            and p.default is p.empty
        ]
    )
    if n_step != 2:
        rep.errors.append(
            f"step must take exactly (carry, request_ids), got {n_step} "
            "required positional params"
        )
    rep.checks.append("signatures")


def _build_carry(pd, n, c, w, rep):
    """Initialize a carry, probing whether the kind requires sizes."""
    eta = 0.05 if pd.fractional else None
    base = dict(seed=0, eta=eta, horizon=8 * w, n_slots=None)
    sizes = np.full(n, 2.0, np.float64)
    try:
        return pd.init(n, c, **base), False, eta
    except (ValueError, TypeError):
        pass
    try:
        return pd.init(n, c, sizes=sizes, **base), True, eta
    except (ValueError, TypeError) as e:
        rep.errors.append(
            f"init failed both unsized and sized probes: {e}"
        )
        return None, False, eta


#: kinds with a real miss-cost model; every other kind must reject costs=
#: loudly (a silently-dropped cost array corrupts cost-weighted results)
COST_MODEL_KINDS = frozenset({"gds", "ogb_sized"})


def _probe_rejections(pd, n, c, w, eta, requires_sizes, rep):
    """sizes=/costs= must be consumed meaningfully or rejected loudly."""
    sizes = np.full(n, 2.0, np.float64)
    costs = np.full(n, 3.0, np.float64)
    base = dict(seed=0, eta=eta, horizon=8 * w, n_slots=None)
    if requires_sizes:
        # the sized-only kinds: missing sizes must raise
        try:
            pd.init(n, c, **base)
            rep.errors.append(
                "init accepted a call without sizes although the kind "
                "requires them"
            )
        except ValueError:
            rep.checks.append("missing-sizes-rejected")
    else:
        # sizes: either rejected with ValueError, or the sized step must
        # emit byte_hits — accepting-and-ignoring is the silent hazard
        try:
            sized_carry = pd.init(n, c, sizes=sizes, **base)
        except ValueError:
            rep.checks.append("sizes-rejected")
            sized_carry = None
        if sized_carry is not None:
            ids = _ids_aval(pd, n, w)
            try:
                _, out = jax.eval_shape(pd.step, _avals(sized_carry), ids)
                if out.byte_hits is None:
                    rep.errors.append(
                        "init accepted sizes= but step emits no byte_hits "
                        "— sizes are silently dropped"
                    )
                else:
                    rep.checks.append("sizes-accepted-with-byte-hits")
            except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
                rep.errors.append(f"sized step failed abstract eval: {e}")
    # costs without a cost model must be rejected
    kw = dict(base)
    if requires_sizes:
        kw["sizes"] = sizes
    try:
        pd.init(n, c, costs=costs, **kw)
        accepted = True
    except ValueError:
        accepted = False
    if pd.kind in COST_MODEL_KINDS:
        if accepted:
            rep.checks.append("costs-accepted")
        else:
            rep.errors.append(
                f"{pd.kind} declares a cost model but rejected costs="
            )
    elif accepted:
        rep.errors.append(
            f"{pd.kind} has no cost model but accepted costs= — must "
            "raise ValueError"
        )
    else:
        rep.checks.append("costs-rejected")


def _ids_aval(pd, n, w):
    if pd.trace_driven:
        return jax.ShapeDtypeStruct((w,), jnp.int32)
    # gradient-vector flavors consume dense per-item weights
    return jax.ShapeDtypeStruct((n,), jnp.float32)


def _check_step_out(out, rep) -> None:
    from repro.cachesim.api import StepOut

    if not isinstance(out, StepOut):
        rep.errors.append(
            f"step output is {type(out).__name__}, not StepOut"
        )
        return
    expect = {
        "reward": ((), "float32"),
        "hits": ((), "int32"),
        "aux": ((), "float32"),
        "occupancy": ((), "float32"),
    }
    for name, (shape, dtype) in expect.items():
        leaf = getattr(out, name)
        if leaf is None:
            rep.errors.append(f"StepOut.{name} missing (None)")
            continue
        got = (tuple(leaf.shape), str(leaf.dtype))
        if got != (shape, dtype):
            rep.errors.append(
                f"StepOut.{name} must be {shape}/{dtype}, got {got}"
            )
    if out.byte_hits is not None:
        got = (tuple(out.byte_hits.shape), str(out.byte_hits.dtype))
        if got != ((), "float32"):
            rep.errors.append(
                f"StepOut.byte_hits must be ()/float32 (or None), got {got}"
            )
    rep.checks.append("step-out-complete")


def _check_carry_stability(pd, carry, ids, rep):
    """treedef/shape/dtype fixed point across one (and two) abstract steps."""
    avals = _avals(carry)
    try:
        carry2, out = jax.eval_shape(pd.step, avals, ids)
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(f"step failed abstract eval: {e}")
        return None
    if jax.tree.structure(carry2) != jax.tree.structure(carry):
        rep.errors.append(
            "carry treedef changed across step: "
            f"{jax.tree.structure(carry)} -> {jax.tree.structure(carry2)}"
        )
        return out
    before, after = _leaf_sig(carry), _leaf_sig(carry2)
    if before != after:
        drift = [
            f"leaf {i}: {b} -> {a}"
            for i, (b, a) in enumerate(zip(before, after))
            if b != a
        ]
        rep.errors.append(
            "carry leaf shapes/dtypes changed across step ("
            + "; ".join(drift)
            + ") — breaks lax.scan, donation, and the executable cache"
        )
        return out
    rep.checks.append("carry-stable")
    # second application from the abstract output: catches counters that
    # promote dtype on the second step (t + 1 weak-typing drift)
    try:
        carry3, _ = jax.eval_shape(pd.step, carry2, ids)
        if _leaf_sig(carry3) != after:
            rep.errors.append(
                "carry drifts on the second step (weak-type promotion?)"
            )
        else:
            rep.checks.append("carry-stable-2nd-step")
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(f"second abstract step failed: {e}")
    return out


def _check_vmappable(pd, carry, ids, rep, lanes: int = 3) -> None:
    """The sweep contract: a stacked carry must vmap through step."""
    stacked = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            (lanes,) + tuple(np.shape(x)), x.dtype
        ),
        carry,
    )
    try:
        carry2, out = jax.eval_shape(
            jax.vmap(pd.step, in_axes=(0, None)), stacked, ids
        )
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(f"step does not vmap over stacked carries: {e}")
        return
    if jax.tree.structure(carry2) != jax.tree.structure(carry):
        rep.errors.append("vmapped step changed the carry treedef")
        return
    rep.checks.append("vmappable")


def _check_fleet_stacked(
    pd, carry, requires_sizes, eta, n, c, w, rep, lanes: int = 3
) -> None:
    """The fleet contract (:mod:`repro.cachesim.fleet`).

    Two requirements beyond ``_check_vmappable``'s sweep contract:

    1. *Stackable*: carries built with different per-tenant parameters
       (capacity, seed) under a shared ``n_slots`` pad must agree on
       treedef and every leaf's shape/dtype — otherwise
       ``jax.tree.map(jnp.stack, *carries)`` cannot build the tenant axis.
    2. *Fleet-vmappable*: the stacked carry must vmap through ``step``
       with **per-tenant** ids (``in_axes=(0, 0)`` — every tenant replays
       its own stream, unlike the sweep's shared trace), with stable
       treedef/shapes across the vmapped step.
    """
    base = dict(seed=1, eta=eta, horizon=8 * w, n_slots=c)
    if requires_sizes:
        base["sizes"] = np.full(n, 2.0, np.float64)
    try:
        variant = pd.init(n, max(c // 2, 1), **base)
    except ValueError:
        # static-capacity flavors (madow) cannot vary capacity; a seed
        # variant at the same capacity still probes the stacking contract
        try:
            variant = pd.init(n, c, **base)
        except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
            rep.errors.append(f"fleet variant init failed: {e}")
            return
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(f"fleet variant init failed: {e}")
        return
    if jax.tree.structure(variant) != jax.tree.structure(carry):
        rep.errors.append(
            "fleet-stacking violation: a capacity/seed variant changed "
            "the carry treedef — tenants cannot stack"
        )
        return
    sig_a, sig_b = _leaf_sig(carry), _leaf_sig(variant)
    if sig_a != sig_b:
        drift = [
            f"leaf {i}: {a} vs {b}"
            for i, (a, b) in enumerate(zip(sig_a, sig_b))
            if a != b
        ]
        rep.errors.append(
            "fleet-stacking violation: carry leaf shapes/dtypes depend on "
            "per-tenant capacity/seed beyond the shared n_slots pad ("
            + "; ".join(drift)
            + ")"
        )
        return
    rep.checks.append("fleet-stackable")
    stacked = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            (lanes,) + tuple(np.shape(x)), x.dtype
        ),
        carry,
    )
    ids1 = _ids_aval(pd, n, w)
    ids = jax.ShapeDtypeStruct((lanes,) + tuple(ids1.shape), ids1.dtype)
    try:
        carry2, _out = jax.eval_shape(
            jax.vmap(pd.step, in_axes=(0, 0)), stacked, ids
        )
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(
            f"step does not vmap with per-tenant ids (in_axes=(0, 0)): {e}"
        )
        return
    if jax.tree.structure(carry2) != jax.tree.structure(carry):
        rep.errors.append("fleet-vmapped step changed the carry treedef")
        return
    if _leaf_sig(carry2) != _leaf_sig(stacked):
        rep.errors.append(
            "fleet-vmapped step changed stacked carry leaf shapes/dtypes "
            "under the tenant axis"
        )
        return
    rep.checks.append("fleet-vmappable")


def _unread_carry_leaves(pd, avals, ids):
    """Leaf indices the step never READS (it writes them fresh) — jit
    prunes those inputs at lowering, so they cannot alias an output."""
    from jax._src.interpreters import partial_eval as pe

    closed = jax.make_jaxpr(pd.step)(avals, ids)
    _, used = pe.dce_jaxpr(
        closed.jaxpr, [True] * len(closed.jaxpr.outvars)
    )
    n_carry = len(jax.tree.leaves(avals))
    return [i for i, u in enumerate(used[:n_carry]) if not u]


def _check_donation(pd, carry, ids, rep) -> None:
    """Every carry leaf the step reads must alias an output in the lowered
    module.

    Verified at the *lowering* level (``tf.aliasing_output`` attributes in
    the StableHLO), which is backend-independent — CPU drops donation at
    compile time, but the aliasing contract is decided here.  jax itself
    warns per unusable donated buffer; any such warning is a failure.

    Write-only *scalar* slots (a threshold diagnostic recomputed every
    step) are DCE-pruned from the lowered signature and tolerated; a
    pruned *array* leaf is dead state riding the carry and fails."""
    avals = _avals(carry)
    leaves = jax.tree.leaves(carry)
    n_leaves = len(leaves)
    try:
        unread = _unread_carry_leaves(pd, avals, ids)
    except Exception:  # reprolint: allow(broad-except) DCE is best-effort
        unread = []
    dead_arrays = [i for i in unread if np.size(leaves[i]) > 1]
    if dead_arrays:
        rep.errors.append(
            f"carry leaves {dead_arrays} are written but never read — "
            "dead array state rides (and recompiles) every step"
        )
        return
    n_expected = n_leaves - len(unread)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        try:
            lowered = jax.jit(pd.step, donate_argnums=(0,)).lower(
                avals, ids
            )
        except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
            rep.errors.append(f"donated lowering failed: {e}")
            return
        text = lowered.as_text()
    unusable = [
        str(w.message)
        for w in caught
        if "donated" in str(w.message).lower()
    ]
    if unusable:
        rep.errors.append(
            f"donation not honored for some carry leaves: {unusable[0]}"
        )
        return
    n_alias = text.count("tf.aliasing_output")
    if n_alias < n_expected:
        rep.errors.append(
            f"only {n_alias}/{n_expected} read carry leaves alias an "
            "output buffer in the lowered module — donation partially "
            "dropped"
        )
        return
    if unread:
        rep.checks.append(
            f"donation-honored ({len(unread)} write-only scalar slot(s) "
            "pruned)"
        )
    else:
        rep.checks.append("donation-honored")


def check_policy_def(
    kind: str,
    options: Optional[Dict[str, Any]] = None,
    *,
    catalog_size: int = DEFAULT_N,
    capacity: int = DEFAULT_C,
    window: int = DEFAULT_W,
) -> ContractReport:
    """Run every contract check against one registered kind."""
    from repro.cachesim import api

    options = dict(options or {})
    rep = ContractReport(kind=kind, options=options)
    try:
        pd = api.policy_def(kind, **options)
    except Exception as e:  # reprolint: allow(broad-except) recorded as contract error
        rep.errors.append(f"policy_def({kind!r}, {options}) failed: {e}")
        return rep
    if pd.kind != kind:
        rep.errors.append(
            f"PolicyDef.kind is {pd.kind!r}, registered as {kind!r}"
        )
    _check_signatures(pd, rep)
    built = _build_carry(pd, catalog_size, capacity, window, rep)
    carry, requires_sizes, eta = built
    if carry is None:
        return rep
    rep.checks.append("init")
    ids = _ids_aval(pd, catalog_size, window)
    out = _check_carry_stability(pd, carry, ids, rep)
    if out is not None:
        _check_step_out(out, rep)
    _check_vmappable(pd, carry, ids, rep)
    _check_fleet_stacked(
        pd, carry, requires_sizes, eta, catalog_size, capacity, window, rep
    )
    _check_donation(pd, carry, ids, rep)
    try:
        _probe_rejections(
            pd, catalog_size, capacity, window, eta, requires_sizes, rep
        )
    except Exception as e:  # reprolint: allow(broad-except) probe crash = contract failure
        rep.errors.append(f"sizes/costs rejection probe crashed: {e}")
    return rep


def check_all(
    kinds: Optional[Sequence[str]] = None,
    *,
    include_flavors: bool = True,
    catalog_size: int = DEFAULT_N,
    capacity: int = DEFAULT_C,
    window: int = DEFAULT_W,
) -> List[ContractReport]:
    """Check every registered kind (default options), plus the non-default
    static flavors in :data:`EXTRA_FLAVORS`."""
    from repro.cachesim import api

    reports = []
    for kind in kinds if kinds is not None else api.policy_def_kinds():
        reports.append(
            check_policy_def(
                kind,
                catalog_size=catalog_size,
                capacity=capacity,
                window=window,
            )
        )
    if include_flavors and kinds is None:
        for kind, opt_fn in EXTRA_FLAVORS:
            reports.append(
                check_policy_def(
                    kind,
                    opt_fn(capacity),
                    catalog_size=catalog_size,
                    capacity=capacity,
                    window=window,
                )
            )
    return reports
