"""reprolint: static + dynamic enforcement of the engine's contracts.

Three checkers, one gate:

* :mod:`repro.analysis.rules` / :mod:`~repro.analysis.lint` — an AST lint
  pass with repo-specific JAX rules (host sync in traced functions,
  numpy-on-tracer, Python branches on traced values, mutable defaults,
  hot-path classes without ``__slots__``, over-broad excepts, unlocked
  thread-shared writes, float64 hazards in kernel entry points);
* :mod:`repro.analysis.contracts` — a dynamic PolicyDef contract checker
  that walks the live registry and verifies carry stability, StepOut
  completeness, donation aliasing, and sizes/costs rejection via abstract
  eval (no device steps);
* :mod:`repro.analysis.recompile` — a compile tracker that locks the
  documented compile counts (one per stream shape, zero on resume).

Run the CI gate locally::

    python -m repro.analysis            # lint src/ + contract-check registry
    python -m repro.analysis --list-rules
"""

from repro.analysis.contracts import (
    ContractReport,
    check_all,
    check_policy_def,
)
from repro.analysis.lint import lint_file, lint_paths
from repro.analysis.recompile import CompileLog, track_compiles
from repro.analysis.rules import RULES, Finding, LintConfig, lint_source

__all__ = [
    "CompileLog",
    "ContractReport",
    "Finding",
    "LintConfig",
    "RULES",
    "check_all",
    "check_policy_def",
    "lint_file",
    "lint_paths",
    "lint_source",
    "track_compiles",
]
