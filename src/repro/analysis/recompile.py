"""Recompilation tracker: assert the compile counts the docs promise.

``api.run``'s executable cache and ``run_stream``'s fixed-shape segments
exist so that a multi-gigabyte stream costs *two* compilations (steady
segment + tail) and a resumed run costs *zero*.  Nothing enforced that —
a carry-dtype drift or a shape-keying bug silently turns every segment
into a recompile and the perf claims into fiction.

:func:`track_compiles` watches two signals at once:

* **trace compiles** — jax's own ``log_compiles`` stream ("Compiling
  <name> with global shapes...", emitted once per new (function, avals)
  trace, AOT or not), captured with a logging handler;
* **executable compiles** — misses of ``api._EXEC_CACHE``, reported by
  the hook :func:`repro.cachesim.api.add_compile_listener`; this is the
  precise "one compile per stream shape" counter.

Usage::

    with track_compiles() as log:
        run_stream(pd, chunks, ...)
    log.assert_executables(2)          # steady segment + tail
    assert log.trace_count("run_fn") <= 2

No device computation is performed by the tracker itself; it only
observes.
"""

from __future__ import annotations

import contextlib
import logging
import re
from dataclasses import dataclass, field
from typing import List, Optional

import jax

__all__ = ["CompileEvent", "CompileLog", "track_compiles"]

_COMPILING_RE = re.compile(r"^Compiling ([^\s]+) ")

#: loggers that carry the log_compiles "Compiling <name> ..." records
#: (pxla on current jax; dispatch kept as a fallback for older layouts)
_JAX_LOGGERS = (
    "jax._src.interpreters.pxla",
    "jax._src.dispatch",
)


@dataclass(frozen=True)
class CompileEvent:
    """One observed compilation."""

    source: str  # "trace" (jax log) | "executable" (api cache miss)
    name: str  # traced function name, or the api cache-key summary


@dataclass
class CompileLog:
    events: List[CompileEvent] = field(default_factory=list)

    # -- queries -----------------------------------------------------------
    @property
    def traces(self) -> List[CompileEvent]:
        return [e for e in self.events if e.source == "trace"]

    @property
    def executables(self) -> List[CompileEvent]:
        return [e for e in self.events if e.source == "executable"]

    def trace_count(self, name: Optional[str] = None) -> int:
        """Trace compiles, optionally restricted to one function name
        (tiny op compiles like ``convert_element_type`` otherwise count)."""
        return sum(1 for e in self.traces if name is None or e.name == name)

    @property
    def executable_count(self) -> int:
        return len(self.executables)

    # -- assertions --------------------------------------------------------
    def assert_executables(self, expected: int) -> None:
        got = self.executable_count
        if got != expected:
            raise AssertionError(
                f"expected exactly {expected} executable compiles, "
                f"observed {got}: {[e.name for e in self.executables]}"
            )

    def assert_no_recompilation(self) -> None:
        self.assert_executables(0)


class _Handler(logging.Handler):
    def __init__(self, log: CompileLog):
        super().__init__(level=logging.DEBUG)
        self._log = log

    def emit(self, record: logging.LogRecord) -> None:
        try:
            m = _COMPILING_RE.match(record.getMessage())
        except Exception:  # reprolint: allow(broad-except) a log record must never break the run
            return
        if m:
            self._log.events.append(CompileEvent("trace", m.group(1)))


@contextlib.contextmanager
def track_compiles():
    """Context manager yielding a live :class:`CompileLog`.

    Temporarily enables ``jax.log_compiles`` and attaches a counting
    handler; also subscribes to the api executable-cache-miss hook.  Both
    are detached on exit — nesting is safe (each tracker sees the events
    fired within its own extent)."""
    from repro.cachesim import api

    log = CompileLog()
    handler = _Handler(log)

    def _on_executable(info: dict) -> None:
        log.events.append(
            CompileEvent("executable", info.get("name", "<unknown>"))
        )

    loggers = [logging.getLogger(name) for name in _JAX_LOGGERS]
    prior_levels = [lg.level for lg in loggers]
    api.add_compile_listener(_on_executable)
    for lg in loggers:
        lg.addHandler(handler)
        if lg.level > logging.DEBUG or lg.level == logging.NOTSET:
            lg.setLevel(logging.DEBUG)
    try:
        with jax.log_compiles():
            yield log
    finally:
        for lg, lvl in zip(loggers, prior_levels):
            lg.removeHandler(handler)
            lg.setLevel(lvl)
        api.remove_compile_listener(_on_executable)
