"""reprolint rules: the unwritten JAX contracts, written down as AST checks.

Every performance claim in this repro rides on invariants no general-purpose
linter knows about: step bodies passed to ``lax.scan``/``jit`` must be pure
and device-only (no host sync, no numpy-on-tracer, no Python branching on
traced values), carries must keep a stable pytree/dtype layout so donation
and the compile cache hold, the host-side per-request policies are hot
enough that attribute-dict overhead shows up in benchmarks, and the
prefetch pipeline shares mutable state across threads.  Each rule here
enforces one of those contracts; :mod:`repro.analysis.contracts` enforces
the dynamic half (carry stability, donation, StepOut completeness) against
the live registry.

Suppressions are explicit and line-scoped::

    except Exception:  # reprolint: allow(broad-except) recorded, not fatal

and thread-ownership of ingest-side counters is declared file-wide::

    # reprolint: thread-owned(t_ingested, ingest_seconds, t_dropped)

An ``allow(...)`` with a rule id (``RL006``) or slug (``broad-except``)
silences exactly that rule on that line — never a file, never a rule
globally.  The rule table (ids, slugs, rationale) is mirrored in the
README's "policy author contract" section.
"""

from __future__ import annotations

import ast
import fnmatch
import re
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

__all__ = [
    "Finding",
    "LintConfig",
    "RULES",
    "collect_suppressions",
    "lint_source",
]


@dataclass(frozen=True)
class Finding:
    """One rule violation, pinned to a source position."""

    rule: str  # "RL001"
    slug: str  # "host-sync"
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: {self.rule} "
            f"[{self.slug}] {self.message}"
        )


@dataclass(frozen=True)
class LintConfig:
    """Which files each scoped rule applies to (posix-glob on the path)."""

    #: RL005: modules whose classes sit on the per-request host hot path
    hot_path_globs: Sequence[str] = (
        "*/core/policies.py",
        "*/core/treap.py",
        "*/core/ftpl.py",
        "*/core/omd.py",
        "*/core/ogb.py",
        "*/core/ogb_classic.py",
        "*/core/ogb_sized.py",
    )
    #: RL008: kernel entry points that must stay float32-clean (ref.py files
    #: are float64 oracles by design and are excluded)
    kernel_globs: Sequence[str] = (
        "*/kernels/*/ops.py",
        "*/kernels/*/kernel.py",
    )
    #: functions with these exact names (or these suffixes) are treated as
    #: traced even when the scan/jit call site is in another module — the
    #: PolicyDef protocol hands `step` functions to lax.scan by reference
    traced_name_hints: Sequence[str] = ("step",)
    traced_suffix_hints: Sequence[str] = ("_step", "_kernel")


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------
_ALLOW_RE = re.compile(r"#\s*reprolint:\s*allow\(([^)]*)\)")
_THREAD_OWNED_RE = re.compile(r"#\s*reprolint:\s*thread-owned\(([^)]*)\)")


def collect_suppressions(source: str):
    """Line-scoped ``allow(rule,...)`` plus file-wide thread-owned attrs."""
    allows: Dict[int, Set[str]] = {}
    thread_owned: Set[str] = set()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if m:
            allows.setdefault(lineno, set()).update(
                tok.strip() for tok in m.group(1).split(",") if tok.strip()
            )
        m = _THREAD_OWNED_RE.search(text)
        if m:
            thread_owned.update(
                tok.strip() for tok in m.group(1).split(",") if tok.strip()
            )
    return allows, thread_owned


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for an attribute chain, 'print' for a bare name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


#: calls whose function-valued arguments are traced by JAX
_TRACE_ENTRY_CALLS = {
    "jax.jit",
    "jit",
    "jax.pmap",
    "pmap",
    "jax.vmap",
    "vmap",
    "jax.lax.scan",
    "lax.scan",
    "jax.lax.while_loop",
    "lax.while_loop",
    "jax.lax.fori_loop",
    "lax.fori_loop",
    "jax.lax.cond",
    "lax.cond",
    "jax.lax.switch",
    "lax.switch",
    "jax.lax.map",
    "lax.map",
    "jax.lax.associative_scan",
    "lax.associative_scan",
    "jax.checkpoint",
    "jax.remat",
    "shard_map",
    "jax.experimental.shard_map.shard_map",
    "pl.pallas_call",
    "pallas_call",
    "jax.eval_shape",
}

_JIT_DECORATORS = {"jax.jit", "jit", "jax.pmap", "pmap", "jax.vmap", "vmap"}

#: attribute chains that yield static (python-level) values even on tracers
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "aval", "sharding"}

#: calls that launder a traced argument into a static python value
_STATIC_CALLS = {
    "isinstance",
    "issubclass",
    "len",
    "type",
    "hasattr",
    "getattr",
    "callable",
    "jax.tree.structure",
    "jax.tree_util.tree_structure",
}


def _decorator_is_jit(dec: ast.AST) -> bool:
    name = _dotted(dec)
    if name in _JIT_DECORATORS:
        return True
    if isinstance(dec, ast.Call):
        inner = _dotted(dec.func)
        if inner in _JIT_DECORATORS:
            return True
        if inner in ("functools.partial", "partial") and dec.args:
            return _dotted(dec.args[0]) in _JIT_DECORATORS
    return False


class _TracedCollector(ast.NodeVisitor):
    """Find every function that JAX will trace.

    Three signals: (a) lexically passed (by name or as a lambda) to a
    trace-entry call like ``lax.scan``/``jit``; (b) decorated with jit;
    (c) named per the PolicyDef convention (``step``/``*_step``/
    ``*_kernel``) — those are handed to ``lax.scan`` by reference through
    the registry, so no local call site exists.  Functions *defined inside*
    a traced function are traced too.
    """

    def __init__(self, cfg: LintConfig):
        self.cfg = cfg
        self.traced_names: Set[str] = set()
        self.traced_lambdas: Set[ast.Lambda] = set()

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name in _TRACE_ENTRY_CALLS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    self.traced_names.add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    self.traced_lambdas.add(arg)
        self.generic_visit(node)


#: builder prefixes: `_make_ogb_step` RETURNS the traced step, it is not
#: itself traced — its params (sample mode strings, sweep counts) are host
#: config and branching on them is the whole point of a factory
_FACTORY_PREFIXES = ("make", "_make", "build", "_build", "get_", "_get_")


def _is_method(fn: ast.AST) -> bool:
    args = fn.args.posonlyargs + fn.args.args
    return bool(args) and args[0].arg in ("self", "cls")


def _is_traced_def(fn: ast.AST, collector: _TracedCollector) -> bool:
    if isinstance(fn, ast.Lambda):
        return fn in collector.traced_lambdas
    assert isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
    cfg = collector.cfg
    if fn.name in collector.traced_names:
        return True
    if any(_decorator_is_jit(d) for d in fn.decorator_list):
        return True
    # name hints cover registry-referenced steps with no local call site;
    # they must NOT cover step *factories* or host-side `step` methods
    # (serve/ wrappers, core/ reference policies)
    if fn.name.startswith(_FACTORY_PREFIXES) or _is_method(fn):
        return False
    if fn.name in cfg.traced_name_hints:
        return True
    if any(fn.name.endswith(sfx) for sfx in cfg.traced_suffix_hints):
        return True
    return False


def _static_params(fn: ast.AST) -> Set[str]:
    """Params declared static via jit's static_argnames/static_argnums —
    concrete python values under the trace, exempt from taint."""
    if isinstance(fn, ast.Lambda):
        return set()
    positional = [p.arg for p in fn.args.posonlyargs + fn.args.args]
    out: Set[str] = set()

    def _harvest(call: ast.Call) -> None:
        for kw in call.keywords:
            if kw.arg not in ("static_argnames", "static_argnums"):
                continue
            vals = (
                kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value]
            )
            for v in vals:
                if not isinstance(v, ast.Constant):
                    continue
                if isinstance(v.value, str):
                    out.add(v.value)
                elif isinstance(v.value, int) and v.value < len(positional):
                    out.add(positional[v.value])

    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call) and _decorator_is_jit(dec):
            _harvest(dec)
    return out


def _iter_functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            yield node


def _fn_params(fn: ast.AST) -> Set[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def _body(fn: ast.AST):
    return fn.body if isinstance(fn.body, list) else [fn.body]


# ---------------------------------------------------------------------------
# taint tracking (single forward pass — lint precision, not an analyzer)
# ---------------------------------------------------------------------------
class _Taint:
    """Which local names (may) hold traced values inside a traced function.

    Seeds from the parameters, flows through assignments, and is laundered
    by static accessors (``x.shape``, ``isinstance``, ``len``).  One
    forward pass, no fixpoint — false negatives on write-before-read loops
    are acceptable for a linter; false positives are what we avoid.
    """

    def __init__(self, fn: ast.AST):
        self.tainted: Set[str] = (
            {p for p in _fn_params(fn) if p not in ("self", "cls")}
            - _static_params(fn)
        )

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value) or self.expr(node.slice)
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name in _STATIC_CALLS:
                return False
            if name and (name.startswith("jnp.") or name.startswith("jax.")):
                return True
            args = list(node.args) + [kw.value for kw in node.keywords]
            if isinstance(node.func, ast.Attribute) and self.expr(
                node.func.value
            ):
                return True
            return any(self.expr(a) for a in args)
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # identity tests (`x is None`) and comparisons against string
            # constants (`cfg.family == "ssm"`, `"moe" in params`) are
            # necessarily host-level config dispatch, never tracer math
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            operands = [node.left] + list(node.comparators)
            if any(
                isinstance(o, ast.Constant) and isinstance(o.value, str)
                for o in operands
            ):
                return False
            return self.expr(node.left) or any(
                self.expr(c) for c in node.comparators
            )
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False

    def assign(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            value_tainted = self.expr(stmt.value)
            for tgt in stmt.targets:
                for name in _target_names(tgt):
                    if value_tainted:
                        self.tainted.add(name)
                    else:
                        self.tainted.discard(name)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if isinstance(stmt.target, ast.Name):
                if self.expr(stmt.value):
                    self.tainted.add(stmt.target.id)
                else:
                    self.tainted.discard(stmt.target.id)
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name) and self.expr(stmt.value):
                self.tainted.add(stmt.target.id)


def _target_names(tgt: ast.AST) -> Iterable[str]:
    if isinstance(tgt, ast.Name):
        yield tgt.id
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for e in tgt.elts:
            yield from _target_names(e)
    elif isinstance(tgt, ast.Starred):
        yield from _target_names(tgt.value)


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------
_RULE_FUNCS: List[Callable] = []


@dataclass(frozen=True)
class Rule:
    rule_id: str
    slug: str
    doc: str
    func: Callable


RULES: Dict[str, Rule] = {}


def _rule(rule_id: str, slug: str, doc: str):
    def deco(func):
        RULES[rule_id] = Rule(rule_id, slug, doc, func)
        return func

    return deco


def _findings_ctx(path, cfg, tree, source):
    collector = _TracedCollector(cfg)
    collector.visit(tree)
    return collector


_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_SYNC_CALLS = {
    "jax.block_until_ready",
    "jax.device_get",
    "jax.debug.breakpoint",
}
_SCALARIZERS = {"float", "int", "bool", "complex"}


def _traced_functions(tree, collector):
    for fn in _iter_functions(tree):
        if _is_traced_def(fn, collector):
            yield fn


@_rule(
    "RL001",
    "host-sync",
    "host-synchronizing call (`.item()`, `print`, `block_until_ready`, "
    "`float(tracer)`) inside a function JAX traces — stalls the async "
    "dispatch pipeline and breaks inside `lax.scan`",
)
def _check_host_sync(path, cfg, tree, source, emit, ctx):
    for fn in _traced_functions(tree, ctx):
        taint = _Taint(fn)
        for stmt in _walk_stmts(_body(fn)):
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func)
                if name == "print":
                    emit(node, "print() inside a traced function (use "
                               "jax.debug.print for traced values)")
                elif name in _HOST_SYNC_CALLS:
                    emit(node, f"{name}() inside a traced function")
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _HOST_SYNC_METHODS
                    and not node.args
                ):
                    emit(node, f".{node.func.attr}() inside a traced "
                               "function forces a device sync")
                elif name in _SCALARIZERS and any(
                    taint.expr(a) for a in node.args
                ):
                    emit(node, f"{name}() on a traced value forces "
                               "concretization inside a traced function")
            taint.assign(stmt)


@_rule(
    "RL002",
    "numpy-on-tracer",
    "numpy call on a traced value inside a traced function — silently "
    "concretizes (or fails to trace); use jnp",
)
def _check_numpy_on_tracer(path, cfg, tree, source, emit, ctx):
    for fn in _traced_functions(tree, ctx):
        taint = _Taint(fn)
        for stmt in _walk_stmts(_body(fn)):
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func)
                if not name or not (
                    name.startswith("np.") or name.startswith("numpy.")
                ):
                    continue
                args = list(node.args) + [kw.value for kw in node.keywords]
                if any(taint.expr(a) for a in args):
                    emit(node, f"{name}() applied to a traced value "
                               "(numpy cannot consume tracers; use jnp)")
            taint.assign(stmt)


@_rule(
    "RL003",
    "traced-branch",
    "Python `if`/`while`/`assert` on a traced value inside a traced "
    "function — raises TracerBoolConversionError under jit; use "
    "lax.cond / jnp.where",
)
def _check_traced_branch(path, cfg, tree, source, emit, ctx):
    for fn in _traced_functions(tree, ctx):
        taint = _Taint(fn)
        for stmt in _walk_stmts(_body(fn)):
            if isinstance(stmt, (ast.If, ast.While)) and taint.expr(
                stmt.test
            ):
                kw = "if" if isinstance(stmt, ast.If) else "while"
                emit(stmt, f"Python `{kw}` on a traced value (use "
                           "lax.cond / lax.while_loop / jnp.where)")
            elif isinstance(stmt, ast.Assert) and taint.expr(stmt.test):
                emit(stmt, "assert on a traced value (use "
                           "checkify or equinox error_if)")
            taint.assign(stmt)


@_rule(
    "RL004",
    "mutable-default",
    "mutable default argument — shared across calls, a classic aliasing "
    "bug (and a pytree-identity hazard for carries)",
)
def _check_mutable_default(path, cfg, tree, source, emit, ctx):
    mutable_ctors = {"list", "dict", "set", "bytearray", "defaultdict",
                     "OrderedDict", "collections.defaultdict",
                     "collections.OrderedDict", "np.array", "np.zeros",
                     "np.ones", "jnp.zeros", "jnp.ones", "jnp.array"}
    for fn in _iter_functions(tree):
        if isinstance(fn, ast.Lambda):
            continue
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None
        ]
        for d in defaults:
            if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
                emit(d, f"mutable default in {fn.name}() — use None and "
                        "construct inside the body")
            elif isinstance(d, ast.Call) and _dotted(d.func) in mutable_ctors:
                emit(d, f"mutable default {_dotted(d.func)}() in "
                        f"{fn.name}() — use None and construct inside")


_SLOTS_EXEMPT_BASES = {"NamedTuple", "Exception", "BaseException", "object",
                       "threading.local", "Enum", "IntEnum", "Protocol",
                       "ABC", "abc.ABC", "tuple", "type"}


@_rule(
    "RL005",
    "no-slots-hot-class",
    "hot-path class without `__slots__` — per-request host policies pay "
    "the instance-dict tax millions of times per trace",
)
def _check_no_slots(path, cfg, tree, source, emit, ctx):
    if not any(fnmatch.fnmatch(path, g) for g in cfg.hot_path_globs):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        base_names = {_dotted(b) for b in node.bases} - {None}
        if base_names & _SLOTS_EXEMPT_BASES:
            continue
        if any(
            n.endswith(("Error", "Exception", "Warning"))
            for n in base_names
        ):
            continue
        deco = {_dotted(d) or _dotted(getattr(d, "func", d)) or ""
                for d in node.decorator_list}
        if any("dataclass" in d for d in deco):
            # dataclass(slots=True) carries its own layout; plain
            # dataclasses in hot modules should also migrate, but the
            # decorated form is at least explicit about field sets
            if any(
                isinstance(d, ast.Call)
                and any(
                    kw.arg == "slots"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value
                    for kw in d.keywords
                )
                for d in node.decorator_list
            ):
                continue
            emit(node, f"dataclass {node.name} in a hot-path module "
                       "without slots=True")
            continue
        assigned = {
            t.id
            for stmt in node.body
            if isinstance(stmt, ast.Assign)
            for t in stmt.targets
            if isinstance(t, ast.Name)
        } | {
            stmt.target.id
            for stmt in node.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)
        }
        if "__slots__" not in assigned:
            emit(node, f"class {node.name} in a hot-path module without "
                       "__slots__")


def _raises_at_scope(stmts) -> bool:
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(stmt, ast.Raise):
            return True
        for field_name in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field_name, None)
            if inner and _raises_at_scope(inner):
                return True
        for h in getattr(stmt, "handlers", []) or []:
            if _raises_at_scope(h.body):
                return True
    return False


@_rule(
    "RL006",
    "broad-except",
    "bare/over-broad except — swallows TracerErrors, KeyboardInterrupt "
    "(bare), and real bugs; catch the specific failure or annotate why "
    "broad is right",
)
def _check_broad_except(path, cfg, tree, source, emit, ctx):
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            emit(node, "bare `except:` (also catches KeyboardInterrupt/"
                       "SystemExit)")
            continue
        name = _dotted(node.type)
        if name in ("Exception", "BaseException"):
            # wrap-and-reraise handlers keep the failure visible; a
            # handler that re-raises is explicitly not swallowing.  Only
            # raises at handler scope count — a `raise` inside a class or
            # function *defined* in the handler runs later, if ever
            if _raises_at_scope(node.body):
                continue
            emit(node, f"`except {name}` without re-raise — narrow it or "
                       "annotate `# reprolint: allow(broad-except) <why>`")


@_rule(
    "RL007",
    "thread-shared-write",
    "attribute write to shared state from code reachable by a "
    "threading.Thread target, without declared ownership — the prefetch "
    "pipeline's bit-exactness rests on single-writer fields",
)
def _check_thread_shared_write(path, cfg, tree, source, emit, ctx):
    # entry points: threading.Thread(target=f) / Thread(target=f)
    entries: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in (
            "threading.Thread",
            "Thread",
        ):
            for kw in node.keywords:
                if kw.arg == "target" and isinstance(kw.value, ast.Name):
                    entries.add(kw.value.id)
    if not entries:
        return
    fns = {
        fn.name: fn
        for fn in _iter_functions(tree)
        if not isinstance(fn, ast.Lambda)
    }
    # BFS over the same-module call graph from the thread targets
    reachable: Set[str] = set()
    frontier = [n for n in entries if n in fns]
    while frontier:
        name = frontier.pop()
        if name in reachable:
            continue
        reachable.add(name)
        for node in ast.walk(fns[name]):
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee in fns and callee not in reachable:
                    frontier.append(callee)
    _, thread_owned = ctx_thread_owned(ctx)
    for name in reachable:
        fn = fns[name]
        for node in ast.walk(fn):
            target = None
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            else:
                continue
            for target in targets:
                if not (
                    isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id != "self"
                ):
                    continue
                if target.attr in thread_owned:
                    continue
                emit(
                    node,
                    f"`{target.value.id}.{target.attr}` written from "
                    f"thread-reachable `{name}()` — declare it with "
                    "`# reprolint: thread-owned(...)` (single writer) or "
                    "guard it with a lock",
                )


def ctx_thread_owned(ctx):
    """The collector carries the file's thread-owned declarations."""
    return None, getattr(ctx, "thread_owned", set())


@_rule(
    "RL008",
    "f64-promotion",
    "float64 in a kernel entry point — silently downcast (x64 disabled) "
    "or a 2x memory/bandwidth hit (x64 enabled); kernels are float32, "
    "ref.py oracles are the float64 surface",
)
def _check_f64_promotion(path, cfg, tree, source, emit, ctx):
    if path.endswith("ref.py"):
        return
    if not any(fnmatch.fnmatch(path, g) for g in cfg.kernel_globs):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            base = _dotted(node.value)
            if base in ("np", "numpy", "jnp", "jax.numpy"):
                emit(node, f"{base}.float64 in a kernel entry point")
        elif isinstance(node, ast.Constant) and node.value == "float64":
            emit(node, "'float64' dtype string in a kernel entry point")
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "dtype" and (
                    (isinstance(kw.value, ast.Name)
                     and kw.value.id == "float")
                ):
                    emit(kw.value, "dtype=float promotes to float64 in a "
                                   "kernel entry point")
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype"
                and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id == "float"
            ):
                emit(node, ".astype(float) promotes to float64 in a "
                           "kernel entry point")


def _walk_stmts(stmts):
    """Statements in source order, descending into compound bodies (but not
    into nested function definitions — they get their own taint pass)."""
    for stmt in stmts:
        yield stmt
        for field_name in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field_name, None)
            if inner and not isinstance(
                stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                yield from _walk_stmts(inner)
        for handler in getattr(stmt, "handlers", []) or []:
            yield from _walk_stmts(handler.body)


# ---------------------------------------------------------------------------
# driver for one source blob
# ---------------------------------------------------------------------------
def lint_source(
    source: str,
    path: str,
    cfg: Optional[LintConfig] = None,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Run the rule set over one file's source; returns surviving findings.

    ``rules`` restricts to specific rule ids; suppression comments are
    honored (an ``allow(...)`` must name the rule id or slug)."""
    cfg = cfg or LintConfig()
    path = path.replace("\\", "/")
    tree = ast.parse(source, filename=path)
    allows, thread_owned = collect_suppressions(source)
    ctx = _findings_ctx(path, cfg, tree, source)
    ctx.thread_owned = thread_owned
    findings: List[Finding] = []
    selected = (
        [RULES[r] for r in rules] if rules is not None else RULES.values()
    )
    for rule in selected:

        def emit(node, message, _rule=rule):
            findings.append(
                Finding(
                    rule=_rule.rule_id,
                    slug=_rule.slug,
                    path=path,
                    line=getattr(node, "lineno", 0),
                    col=getattr(node, "col_offset", 0),
                    message=message,
                )
            )

        rule.func(path, cfg, tree, source, emit, ctx)
    out = []
    for f in findings:
        allowed = allows.get(f.line, set())
        if f.rule in allowed or f.slug in allowed:
            continue
        out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out
