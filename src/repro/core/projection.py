"""Euclidean projection onto the capped simplex F = {f in [0,1]^N : sum f = C}.

This is the *eager* oracle used (a) as the ground truth for property-testing the
paper's lazy O(log N) projection, (b) inside the classic OGB_cl policy, and
(c) as the reference for the JAX / Pallas implementations.

The projection of y solves (paper Eq. 3):

    min_f 1/2 ||f - y||^2   s.t.  0 <= f_i <= 1,  sum_i f_i = C

KKT: the unique solution is  f_i = clip(y_i - tau, 0, 1)  where tau solves
``g(tau) = sum_i clip(y_i - tau, 0, 1) = C``.  ``g`` is non-increasing and
piecewise linear with breakpoints at {y_i} and {y_i - 1}; we locate the segment
containing C exactly in O(N log N) and interpolate — no iterative tolerance.
"""

from __future__ import annotations

import numpy as np


def capped_simplex_tau(y: np.ndarray, C: float) -> float:
    """Exact threshold tau with sum(clip(y - tau, 0, 1)) == C.

    Requires 0 < C <= N.  Exact up to float64 rounding (sort + prefix sums).
    """
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    if not (0 < C <= n):
        raise ValueError(f"need 0 < C <= N, got C={C}, N={n}")

    # breakpoints where a coordinate enters/leaves the interior regime
    bp = np.concatenate([y, y - 1.0])
    bp.sort(kind="stable")

    ys = np.sort(y, kind="stable")
    prefix = np.concatenate([[0.0], np.cumsum(ys)])  # prefix[k] = sum of k smallest

    def g(tau: float) -> float:
        # #{y_i >= tau + 1} (saturated at 1) + sum over interior of (y_i - tau)
        hi = np.searchsorted(ys, tau + 1.0, side="left")  # first idx with y >= tau+1
        lo = np.searchsorted(ys, tau, side="right")  # first idx with y > tau
        n_sat = n - hi
        interior_sum = prefix[hi] - prefix[lo]
        n_int = hi - lo
        return n_sat + interior_sum - n_int * tau

    # g is non-increasing in tau. Find the breakpoint segment where g crosses C.
    # Evaluate g at all breakpoints via vectorized searchsorted.
    taus = bp
    hi = np.searchsorted(ys, taus + 1.0, side="left")
    lo = np.searchsorted(ys, taus, side="right")
    g_vals = (n - hi) + (prefix[hi] - prefix[lo]) - (hi - lo) * taus

    # locate the last breakpoint with g(tau) >= C (g_vals non-increasing)
    idx = int(np.searchsorted(-g_vals, -float(C), side="right")) - 1
    if idx < 0:
        # C >= g(smallest breakpoint) = n: every coordinate saturates
        return float(bp[0])

    tau_a = float(taus[idx])
    g_a = float(g_vals[idx])
    if g_a == C:
        return tau_a
    # slope on the *open segment to the right* of tau_a is -#interior there:
    # interior = {i : tau_a < y_i <= tau_a + 1} (membership constant on the
    # segment because breakpoints are exactly the transition points)
    lo_a = int(np.searchsorted(ys, tau_a, side="right"))
    hi_a = int(np.searchsorted(ys, tau_a + 1.0, side="right"))
    n_int = hi_a - lo_a
    if n_int > 0:
        tau = tau_a + (g_a - C) / n_int
        if abs(g(tau) - C) < 1e-9 * max(1.0, C):
            return tau
    # fp-robust fallback: bisect within [tau_a, next breakpoint]
    lo_t = tau_a
    hi_t = float(taus[idx + 1]) if idx + 1 < len(taus) else tau_a + 1.0
    for _ in range(100):
        mid = 0.5 * (lo_t + hi_t)
        if g(mid) >= C:
            lo_t = mid
        else:
            hi_t = mid
    return 0.5 * (lo_t + hi_t)


def project_capped_simplex(y: np.ndarray, C: float) -> np.ndarray:
    """Exact Euclidean projection of y onto {f in [0,1]^N : sum f = C}."""
    tau = capped_simplex_tau(y, C)
    return np.clip(np.asarray(y, dtype=np.float64) - tau, 0.0, 1.0)


def capped_simplex_tau_bisect(
    y: np.ndarray, C: float, iters: int = 100
) -> float:
    """Bisection solver for tau — the form that vectorizes on TPU.

    Mirrors the JAX/Pallas implementations (repro.jaxcache / repro.kernels):
    tau in [min(y) - 1, max(y)] and ``g`` is monotone, so ``iters`` bisection
    steps give ~2^-iters * range accuracy.
    """
    y = np.asarray(y, dtype=np.float64)
    lo = float(np.min(y)) - 1.0
    hi = float(np.max(y))
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if np.clip(y - mid, 0.0, 1.0).sum() >= C:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
