"""Classic caching policies the paper compares against.

LRU, LFU, FIFO: O(1) per request.  ARC (Megiddo & Modha 2003): O(1).
GDS (Cao & Irani 1997): O(log C).  All expose the simulator interface
``request(i) -> hit``, ``contains(i)``, ``occupancy()``, ``batch_end()``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict

from .treap import make_store


class _Base:
    __slots__ = ("N", "C", "hits", "requests")

    def __init__(self, catalog_size: int, capacity: int, **_):
        self.N = int(catalog_size)
        self.C = int(capacity)
        self.hits = 0
        self.requests = 0

    def batch_end(self) -> None:
        pass

    def _account(self, hit: bool) -> bool:
        self.requests += 1
        self.hits += int(hit)
        return hit


class LRU(_Base):
    name = "LRU"
    __slots__ = ("_od",)

    def __init__(self, catalog_size: int, capacity: int, **kw):
        super().__init__(catalog_size, capacity)
        self._od: "OrderedDict[int, None]" = OrderedDict()

    def contains(self, i: int) -> bool:
        return i in self._od

    def occupancy(self) -> int:
        return len(self._od)

    def request(self, i: int) -> bool:
        hit = i in self._od
        if hit:
            self._od.move_to_end(i)
        else:
            if len(self._od) >= self.C:
                self._od.popitem(last=False)
            self._od[i] = None
        return self._account(hit)


class FIFO(_Base):
    name = "FIFO"
    __slots__ = ("_od",)

    def __init__(self, catalog_size: int, capacity: int, **kw):
        super().__init__(catalog_size, capacity)
        self._od: "OrderedDict[int, None]" = OrderedDict()

    def contains(self, i: int) -> bool:
        return i in self._od

    def occupancy(self) -> int:
        return len(self._od)

    def request(self, i: int) -> bool:
        hit = i in self._od
        if not hit:
            if len(self._od) >= self.C:
                self._od.popitem(last=False)
            self._od[i] = None
        return self._account(hit)


class LFU(_Base):
    """In-cache LFU with LRU tie-break (perfect-LFU counters kept for all items)."""

    name = "LFU"
    __slots__ = ("_freq", "_cached", "_order", "_tick")

    def __init__(self, catalog_size: int, capacity: int, **kw):
        super().__init__(catalog_size, capacity)
        self._freq: Dict[int, int] = {}
        self._cached: Dict[int, tuple] = {}  # item -> (freq, tick) key in order
        self._order = make_store("sorted")
        self._tick = 0

    def contains(self, i: int) -> bool:
        return i in self._cached

    def occupancy(self) -> int:
        return len(self._cached)

    def request(self, i: int) -> bool:
        self._tick += 1
        f = self._freq.get(i, 0) + 1
        self._freq[i] = f
        hit = i in self._cached
        if hit:
            old = self._cached[i]
            self._order.remove(old, i)
            key = (f, self._tick)
            self._order.insert(key, i)
            self._cached[i] = key
        else:
            if len(self._cached) >= self.C:
                # evict min (freq, tick): least frequent, oldest among ties
                mk, mi = self._order.min()
                # admit only if the newcomer's frequency beats the victim's
                if f >= mk[0]:
                    self._order.pop_min()
                    del self._cached[mi]
                    key = (f, self._tick)
                    self._order.insert(key, i)
                    self._cached[i] = key
            else:
                key = (f, self._tick)
                self._order.insert(key, i)
                self._cached[i] = key
        return self._account(hit)


class GDS(_Base):
    """Greedy-Dual-Size (unit size, unit cost ⇒ GDS reduces to LRU-with-aging;
    the H = L + cost_i/size_i machinery takes per-item ``sizes``/``costs``
    arrays for the heterogeneous setting — this is the host oracle the
    device tree engine (``repro.cachesim.tree_engines.TreeGDSCarry``) is
    differential-tested against, so the tie-break on equal H is the
    sorted-store's smallest item id, matching the device min-pair tree)."""

    name = "GDS"
    __slots__ = ("_L", "_cost", "_prio", "_h", "_order")

    def __init__(
        self,
        catalog_size: int,
        capacity: int,
        cost: float = 1.0,
        sizes=None,
        costs=None,
        **kw,
    ):
        super().__init__(catalog_size, capacity)
        self._L = 0.0
        import numpy as _np

        n = int(catalog_size)
        s = (
            _np.ones(n)
            if sizes is None
            else _np.asarray(sizes, _np.float64)
        )
        w = (
            _np.full(n, float(cost))
            if costs is None
            else _np.asarray(costs, _np.float64)
        )
        if s.shape != (n,) or w.shape != (n,):
            raise ValueError(f"sizes/costs must be ({n},) arrays")
        if not (_np.all(_np.isfinite(s)) and float(s.min()) > 0.0):
            raise ValueError("GDS sizes must be finite and > 0")
        if not (_np.all(_np.isfinite(w)) and float(w.min()) > 0.0):
            raise ValueError("GDS costs must be finite and > 0")
        self._cost = cost
        self._prio = w / s
        self._h: Dict[int, float] = {}
        self._order = make_store("sorted")

    def contains(self, i: int) -> bool:
        return i in self._h

    def occupancy(self) -> int:
        return len(self._h)

    def request(self, i: int) -> bool:
        hit = i in self._h
        if hit:
            self._order.remove(self._h[i], i)
        else:
            if len(self._h) >= self.C:
                hmin, imin = self._order.pop_min()
                self._L = hmin
                del self._h[imin]
        h = self._L + float(self._prio[i])
        self._h[i] = h
        self._order.insert(h, i)
        return self._account(hit)


class ARC(_Base):
    """Adaptive Replacement Cache (Megiddo & Modha, FAST'03) — exact."""

    name = "ARC"
    __slots__ = ("p", "t1", "t2", "b1", "b2")

    def __init__(self, catalog_size: int, capacity: int, **kw):
        super().__init__(catalog_size, capacity)
        self.p = 0.0
        self.t1: "OrderedDict[int, None]" = OrderedDict()  # recent, seen once
        self.t2: "OrderedDict[int, None]" = OrderedDict()  # frequent
        self.b1: "OrderedDict[int, None]" = OrderedDict()  # ghost of t1
        self.b2: "OrderedDict[int, None]" = OrderedDict()  # ghost of t2

    def contains(self, i: int) -> bool:
        return i in self.t1 or i in self.t2

    def occupancy(self) -> int:
        return len(self.t1) + len(self.t2)

    def _replace(self, in_b2: bool) -> None:
        if self.t1 and (
            len(self.t1) > self.p or (in_b2 and len(self.t1) == int(self.p))
        ):
            old, _ = self.t1.popitem(last=False)
            self.b1[old] = None
        elif self.t2:
            old, _ = self.t2.popitem(last=False)
            self.b2[old] = None
        elif self.t1:
            old, _ = self.t1.popitem(last=False)
            self.b1[old] = None

    def request(self, i: int) -> bool:
        C = self.C
        if i in self.t1 or i in self.t2:  # case I: hit
            if i in self.t1:
                del self.t1[i]
            else:
                del self.t2[i]
            self.t2[i] = None
            return self._account(True)
        if i in self.b1:  # case II: ghost hit in b1
            self.p = min(float(C), self.p + max(len(self.b2) / max(len(self.b1), 1), 1.0))
            self._replace(False)
            del self.b1[i]
            self.t2[i] = None
            return self._account(False)
        if i in self.b2:  # case III: ghost hit in b2
            self.p = max(0.0, self.p - max(len(self.b1) / max(len(self.b2), 1), 1.0))
            self._replace(True)
            del self.b2[i]
            self.t2[i] = None
            return self._account(False)
        # case IV: full miss
        if len(self.t1) + len(self.b1) == C:
            if len(self.t1) < C:
                self.b1.popitem(last=False)
                self._replace(False)
            else:
                self.t1.popitem(last=False)
        elif len(self.t1) + len(self.b1) < C:
            total = len(self.t1) + len(self.t2) + len(self.b1) + len(self.b2)
            if total >= C:
                if total == 2 * C:
                    self.b2.popitem(last=False)
                self._replace(False)
        self.t1[i] = None
        return self._account(False)


def _load_ogb(catalog_size, capacity, **kw):
    from .ogb import OGB

    return OGB(catalog_size, capacity, **kw)


def _load_ogb_cl(catalog_size, capacity, **kw):
    from .ogb_classic import OGBClassic

    return OGBClassic(catalog_size, capacity, **kw)


def _load_ftpl(catalog_size, capacity, **kw):
    from .ftpl import FTPL

    return FTPL(catalog_size, capacity, **kw)


def _load_omd_cl(catalog_size, capacity, **kw):
    from .omd import OMDClassic

    return OMDClassic(catalog_size, capacity, **kw)


#: THE policy registry — every constructor in the repo goes through this.
#: ``simulator.compare``, ``benchmarks.common.make_policies`` and the scenario
#: runner all resolve kind strings here, so the comparison sets cannot drift.
#: Values are callables ``(catalog_size, capacity, **kw) -> policy``; the
#: gradient/perturbed policies are lazy loaders to keep this module
#: numpy-light and cycle-free.
POLICY_REGISTRY = {
    "lru": LRU,
    "fifo": FIFO,
    "lfu": LFU,
    "gds": GDS,
    "arc": ARC,
    "ogb": _load_ogb,
    "ogb_cl": _load_ogb_cl,
    "ftpl": _load_ftpl,
    "omd_cl": _load_omd_cl,
}


#: device-engine PolicyDef factories, registered by repro.cachesim.api at
#: import time (values are ``factory(**static_options) -> PolicyDef``).
#: Kept next to POLICY_REGISTRY so the host-policy table and the scan-engine
#: table are one discoverable pair: a kind present in both runs device-
#: resident with the host policy as its differential-testing oracle.
ENGINE_DEFS: Dict[str, object] = {}


def register_engine_def(kind: str, factory) -> None:
    """Hook for :func:`repro.cachesim.api.register_policy_def`."""
    ENGINE_DEFS[kind.lower()] = factory


def engine_def_kinds() -> tuple:
    """Kind strings with a registered device-engine PolicyDef factory."""
    return tuple(ENGINE_DEFS)


def policy_kinds() -> tuple:
    """All registered kind strings (host-side per-request policies)."""
    return tuple(POLICY_REGISTRY)


def make_policy(kind: str, catalog_size: int, capacity: int, **kw):
    kind = kind.lower()
    if kind not in POLICY_REGISTRY:
        raise ValueError(
            f"unknown policy {kind!r}; registered: {sorted(POLICY_REGISTRY)}"
        )
    return POLICY_REGISTRY[kind](catalog_size, capacity, **kw)
