"""Order-statistic balanced BST (treap) — the O(log N) ordered multiset of the paper.

The paper's Algorithms 2 and 3 each rely on an ordered data structure over float
keys ("z", the positive unadjusted coefficients, and "d", the cached-item
differences).  The operations needed are:

  * insert(key, item)            O(log N)
  * remove(key, item)            O(log N)
  * min() / pop_min()            O(log N)
  * __len__                      O(1)

We provide two interchangeable implementations:

  * :class:`Treap` — a from-scratch randomized treap.  This is the artifact that
    substantiates the paper's O(log N) claim without leaning on library code.
  * :class:`SortedKeyStore` — backed by ``sortedcontainers.SortedList`` (a
    fan-out list with O(log N) amortized ops and far better constants).  Used as
    the default engine for large-trace benchmarks.

Both store (key: float, item: hashable) pairs, ordered by (key, tiebreak), and
both are exercised by the same test suite.
"""

from __future__ import annotations

import random
from typing import Any, Iterator, Optional, Tuple

try:  # pragma: no cover - import guard
    from sortedcontainers import SortedList

    _HAVE_SORTEDCONTAINERS = True
except ImportError:  # pragma: no cover
    _HAVE_SORTEDCONTAINERS = False

    import bisect

    class SortedList:  # type: ignore[no-redef]
        """Minimal bisect-backed fallback with SortedList's used surface.

        O(n) insertion/removal (list shifting) — correct but slow; production
        runs should prefer the treap engine (``make_store`` already falls back
        to it) or install sortedcontainers.
        """

        __slots__ = ("_l",)

        def __init__(self):
            self._l = []

        def __len__(self):
            return len(self._l)

        def __getitem__(self, i):
            return self._l[i]

        def __iter__(self):
            return iter(self._l)

        def add(self, v):
            bisect.insort(self._l, v)

        def remove(self, v):
            i = bisect.bisect_left(self._l, v)
            if i == len(self._l) or self._l[i] != v:
                raise ValueError(f"{v!r} not in list")
            del self._l[i]

        def pop(self, i=-1):
            return self._l.pop(i)

        def bisect_left(self, v):
            return bisect.bisect_left(self._l, v)


class _Node:
    __slots__ = ("key", "item", "prio", "left", "right", "size")

    def __init__(self, key: float, item: Any, prio: float):
        self.key = key
        self.item = item
        self.prio = prio
        self.left: Optional[_Node] = None
        self.right: Optional[_Node] = None
        self.size = 1


def _size(n: Optional[_Node]) -> int:
    return n.size if n is not None else 0


def _pull(n: _Node) -> None:
    n.size = 1 + _size(n.left) + _size(n.right)


class Treap:
    """Randomized treap keyed by ``(key, id(item-slot))`` with subtree sizes.

    Duplicate keys are allowed; ties are broken arbitrarily but deterministically
    per (key, item) pair so ``remove`` can find the exact entry.
    """

    __slots__ = ("_root", "_rng")

    def __init__(self, seed: int = 0):
        self._root: Optional[_Node] = None
        self._rng = random.Random(seed)

    def __len__(self) -> int:
        return _size(self._root)

    # -- internal rotations ------------------------------------------------
    @staticmethod
    def _cmp(key_a: float, item_a: Any, key_b: float, item_b: Any) -> int:
        if key_a < key_b:
            return -1
        if key_a > key_b:
            return 1
        ha, hb = hash(item_a), hash(item_b)
        if ha < hb:
            return -1
        if ha > hb:
            return 1
        return 0

    def _merge(self, a: Optional[_Node], b: Optional[_Node]) -> Optional[_Node]:
        # every key in a <= every key in b
        if a is None:
            return b
        if b is None:
            return a
        if a.prio < b.prio:
            a.right = self._merge(a.right, b)
            _pull(a)
            return a
        b.left = self._merge(a, b.left)
        _pull(b)
        return b

    def _split(self, n: Optional[_Node], key: float, item: Any):
        """Split into (< (key,item), >= (key,item))."""
        if n is None:
            return None, None
        if self._cmp(n.key, n.item, key, item) < 0:
            l, r = self._split(n.right, key, item)
            n.right = l
            _pull(n)
            return n, r
        l, r = self._split(n.left, key, item)
        n.left = r
        _pull(n)
        return l, n

    # -- public API --------------------------------------------------------
    def insert(self, key: float, item: Any) -> None:
        node = _Node(key, item, self._rng.random())
        l, r = self._split(self._root, key, item)
        self._root = self._merge(self._merge(l, node), r)

    def remove(self, key: float, item: Any) -> bool:
        """Remove one entry equal to (key, item). Returns True if found."""

        def _rm(n: Optional[_Node]) -> Tuple[Optional[_Node], bool]:
            if n is None:
                return None, False
            c = self._cmp(key, item, n.key, n.item)
            if c == 0 and n.item == item:
                return self._merge(n.left, n.right), True
            if c < 0:
                n.left, ok = _rm(n.left)
            else:
                n.right, ok = _rm(n.right)
            if not ok and c == 0:
                # hash tie with a different item: probe the other side too
                n.right, ok = _rm(n.right)
            _pull(n)
            return n, ok

        self._root, ok = _rm(self._root)
        return ok

    def min(self) -> Tuple[float, Any]:
        n = self._root
        if n is None:
            raise IndexError("min of empty treap")
        while n.left is not None:
            n = n.left
        return n.key, n.item

    def pop_min(self) -> Tuple[float, Any]:
        if self._root is None:
            raise IndexError("pop_min of empty treap")

        def _pop(n: _Node) -> Tuple[Optional[_Node], Tuple[float, Any]]:
            if n.left is None:
                return n.right, (n.key, n.item)
            n.left, kv = _pop(n.left)
            _pull(n)
            return n, kv

        self._root, kv = _pop(self._root)
        return kv

    def count_below(self, key: float) -> int:
        """Number of entries with entry.key < key (strict)."""
        n, acc = self._root, 0
        while n is not None:
            if n.key < key:
                acc += 1 + _size(n.left)
                n = n.right
            else:
                n = n.left
        return acc

    def __iter__(self) -> Iterator[Tuple[float, Any]]:
        def _walk(n):
            if n is None:
                return
            yield from _walk(n.left)
            yield (n.key, n.item)
            yield from _walk(n.right)

        yield from _walk(self._root)


class SortedKeyStore:
    """sortedcontainers-backed drop-in with the same API as :class:`Treap`."""

    __slots__ = ("_sl",)

    def __init__(self, seed: int = 0):  # seed ignored; signature parity
        self._sl = SortedList()

    def __len__(self) -> int:
        return len(self._sl)

    def insert(self, key: float, item: Any) -> None:
        self._sl.add((key, item))

    def remove(self, key: float, item: Any) -> bool:
        try:
            self._sl.remove((key, item))
            return True
        except ValueError:
            return False

    def min(self) -> Tuple[float, Any]:
        if not self._sl:
            raise IndexError("min of empty store")
        return self._sl[0]

    def pop_min(self) -> Tuple[float, Any]:
        if not self._sl:
            raise IndexError("pop_min of empty store")
        return self._sl.pop(0)

    def count_below(self, key: float) -> int:
        return self._sl.bisect_left((key, -1 << 62))

    def __iter__(self) -> Iterator[Tuple[float, Any]]:
        return iter(self._sl)


def make_store(kind: str = "sorted", seed: int = 0):
    """Factory: ``kind in {"treap", "sorted"}``."""
    if kind == "treap":
        return Treap(seed=seed)
    if kind == "sorted":
        if _HAVE_SORTEDCONTAINERS:
            return SortedKeyStore(seed=seed)
        return Treap(seed=seed)
    raise ValueError(f"unknown ordered-store kind: {kind!r}")
