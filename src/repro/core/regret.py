"""OPT-in-hindsight and regret accounting (paper Eq. 1).

OPT is the best *static* cache allocation knowing the whole trace: the C most
requested items; its reward is the total number of requests to them.  We also
provide the exact *prefix* OPT curve (best static set per prefix, maintained
incrementally in O(log N) per request via top-C sum maintenance) used for the
cumulative regret plots (paper Fig 2).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .treap import make_store


def best_static_hits(trace: np.ndarray, C: int) -> int:
    """Total hits of OPT (top-C items of the whole trace)."""
    counts = np.bincount(trace)
    if len(counts) <= C:
        return int(counts.sum())
    top = np.partition(counts, len(counts) - C)[len(counts) - C :]
    return int(top.sum())


def best_static_set(trace: np.ndarray, C: int) -> np.ndarray:
    counts = np.bincount(trace)
    if len(counts) <= C:
        return np.arange(len(counts))
    return np.argpartition(counts, len(counts) - C)[len(counts) - C :]


def opt_windowed_hit_ratio(
    trace: np.ndarray, C: int, window: int
) -> np.ndarray:
    """Windowed hit ratio of the whole-trace-OPT static set (paper Fig 7/8).

    Vectorized membership test (bool mask gather) so it holds up at paper
    scale — the per-request ``in set`` loop was O(T) Python.
    """
    trace = np.asarray(trace, dtype=np.int64)
    mask = np.zeros(int(trace.max()) + 1 if len(trace) else 1, dtype=bool)
    mask[best_static_set(trace, C)] = True
    hits = mask[trace]
    n_win = max(len(trace) // window, 1)
    w = min(window, len(trace))
    return hits[: n_win * w].reshape(n_win, w).mean(axis=1)


def prefix_opt_hits(trace: np.ndarray, C: int) -> np.ndarray:
    """h*(t) = max_static-set hits over the prefix r_0..r_{t-1}, for all t.

    h*(t) = sum of the top-C item counts of the prefix.  Maintained online:
    when count_j increments, the top-C sum changes by 1 if j is (now) in the
    top-C, else by (count_j+1 > min-of-top) swap.  O(log N) per request.
    """
    counts: Dict[int, int] = {}
    in_top: Dict[int, Tuple[int, int]] = {}  # item -> key in 'top' store
    top = make_store("sorted")
    top_sum = 0
    out = np.empty(len(trace) + 1, dtype=np.int64)
    out[0] = 0
    tick = 0
    for t, j in enumerate(trace):
        j = int(j)
        tick += 1
        c = counts.get(j, 0) + 1
        counts[j] = c
        if j in in_top:
            old = in_top[j]
            top.remove(old, j)
            key = (c, tick)
            top.insert(key, j)
            in_top[j] = key
            top_sum += 1
        elif len(in_top) < C:
            key = (c, tick)
            top.insert(key, j)
            in_top[j] = key
            top_sum += c
        else:
            mk, mi = top.min()
            if c > mk[0]:
                top.pop_min()
                del in_top[mi]
                top_sum -= mk[0]
                key = (c, tick)
                top.insert(key, j)
                in_top[j] = key
                top_sum += c
        out[t + 1] = top_sum
    return out


def regret_curve(policy_cumhits: np.ndarray, trace: np.ndarray, C: int) -> np.ndarray:
    """R(t) = prefix-OPT(t) - policy(t); sub-linear growth <=> no-regret."""
    opt = prefix_opt_hits(trace, C)
    assert len(policy_cumhits) == len(opt) - 1 or len(policy_cumhits) == len(opt)
    if len(policy_cumhits) == len(opt) - 1:
        return opt[1:] - policy_cumhits
    return opt - policy_cumhits
