# The paper's primary contribution: OGB, an integral online gradient-based
# caching policy with O(log N) amortized per-request complexity and
# sublinear-regret guarantees (Carra & Neglia, 2024).
from .ftpl import FTPL, theoretical_zeta
from .ogb import OGB, OGBStats, theoretical_eta, theoretical_regret_bound
from .ogb_classic import OGBClassic, madow_sample
from .ogb_sized import SizedOGB, project_weighted, weighted_capped_simplex_tau
from .policies import ARC, FIFO, GDS, LFU, LRU, make_policy
from .projection import (
    capped_simplex_tau,
    capped_simplex_tau_bisect,
    project_capped_simplex,
)
from .regret import (
    best_static_hits,
    best_static_set,
    opt_windowed_hit_ratio,
    prefix_opt_hits,
    regret_curve,
)
from .treap import SortedKeyStore, Treap, make_store

__all__ = [
    "OGB", "OGBStats", "OGBClassic", "FTPL", "SizedOGB",
    "project_weighted", "weighted_capped_simplex_tau",
    "LRU", "LFU", "FIFO", "ARC", "GDS", "make_policy",
    "make_store", "Treap", "SortedKeyStore",
    "capped_simplex_tau", "capped_simplex_tau_bisect", "project_capped_simplex",
    "madow_sample", "theoretical_eta", "theoretical_zeta",
    "theoretical_regret_bound", "best_static_hits", "best_static_set",
    "opt_windowed_hit_ratio", "prefix_opt_hits", "regret_curve",
]
