"""OGB_cl — the classic online gradient-based caching policy (paper Eq. 2).

The Paschos et al. / Si Salem et al. policy: every B requests,

    f_t = Proj_F( f_{t-B} + eta * sum_{tau} grad phi_tau(f_{t-B}) )

with an *eager* O(N log N) capped-simplex projection, plus (integral setting)
Madow systematic sampling to select exactly C items.  This is the baseline the
paper improves on: per-request amortized cost Theta(N log N / B), versus OGB's
O(log N).  For B = 1 the two policies produce identical fractional states
(paper footnote 3) — that equality is property-tested.
"""

from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from .ogb import theoretical_eta
from .projection import project_capped_simplex


class OGBClassic:
    """Eager-projection gradient policy, fractional or integral (Madow)."""

    name = "OGB_cl"
    __slots__ = ("N", "C", "B", "eta", "integral", "rng", "f", "_counts",
                 "_pending", "cached", "hits", "requests",
                 "fractional_reward", "replacements")

    def __init__(
        self,
        catalog_size: int,
        capacity: int,
        eta: Optional[float] = None,
        horizon: Optional[int] = None,
        batch_size: int = 1,
        integral: bool = True,
        seed: int = 0,
    ):
        self.N = int(catalog_size)
        self.C = int(capacity)
        self.B = int(batch_size)
        if eta is None:
            if horizon is None:
                raise ValueError("pass eta or horizon")
            eta = theoretical_eta(self.C, self.N, horizon, self.B)
        self.eta = float(eta)
        self.integral = integral
        self.rng = np.random.default_rng(seed)

        self.f = np.full(self.N, self.C / self.N, dtype=np.float64)
        self._counts = np.zeros(self.N, dtype=np.float64)
        self._pending = 0
        self.cached: Set[int] = set()
        self.hits = 0
        self.requests = 0
        self.fractional_reward = 0.0
        self.replacements = 0
        if integral:
            self._resample()

    # -- Madow systematic sampling: exactly C items with P(i in S) = f_i ----
    def _resample(self) -> None:
        cum = np.cumsum(self.f)
        u = self.rng.random()
        thresholds = u + np.arange(self.C)
        idx = np.searchsorted(cum, thresholds, side="left")
        idx = np.clip(idx, 0, self.N - 1)
        new_cache = set(int(i) for i in idx)
        self.replacements += len(new_cache - self.cached)
        self.cached = new_cache

    def contains(self, i: int) -> bool:
        return i in self.cached

    def value(self, i: int) -> float:
        return float(self.f[i])

    def request(self, i: int) -> bool:
        hit = self.contains(i) if self.integral else False
        self.requests += 1
        self.hits += int(hit)
        self.fractional_reward += float(self.f[i])
        self._counts[i] += 1.0
        self._pending += 1
        if self._pending >= self.B:
            self.batch_end()
        return hit

    def batch_end(self) -> None:
        if self._pending == 0:
            return
        y = self.f + self.eta * self._counts
        self.f = project_capped_simplex(y, self.C)
        self._counts[:] = 0.0
        self._pending = 0
        if self.integral:
            self._resample()

    def occupancy(self) -> int:
        return len(self.cached)


def madow_sample(f: np.ndarray, C: int, rng: np.random.Generator) -> List[int]:
    """Standalone Madow systematic sampler (P(i in S) = f_i, |S| = C)."""
    cum = np.cumsum(np.asarray(f, dtype=np.float64))
    u = rng.random()
    idx = np.searchsorted(cum, u + np.arange(C), side="left")
    return [int(i) for i in np.clip(idx, 0, len(f) - 1)]
