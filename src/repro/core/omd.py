"""OMD — no-regret caching via Online Mirror Descent (Si Salem et al. 2021).

Negative-entropy mirror map over the capped simplex F = {f in [0,1]^N :
sum f = C}.  Every B requests the log-weights take a gradient step and the
weights are Bregman(KL)-projected back onto F:

    w_t = w_{t-B} + eta * sum_tau grad phi_tau          (log-weight ascent)
    f_t = min(1, theta * exp(w_t)),  theta s.t. sum_i f_t,i = C   (KL proj.)

The KL projection onto the capped simplex has the water-filling form above
(Si Salem et al., Lemma 2): saturate the k largest weights at 1 and scale the
tail so the total mass is C.  :func:`project_capped_simplex_kl` solves for
theta *exactly* in float64 via one sort + prefix sums — it is the oracle the
device-resident scan engine (:mod:`repro.cachesim.engines`) is differentially
tested against.

This is the multiplicative-update counterpart of OGB_cl (Euclidean OGD):
OMD's regret constant scales with sqrt(C log(N/C)) instead of
sqrt(C (1 - C/N)), which is why the paper quotes it as the strongest
no-regret baseline in the small-C regime.
"""

from __future__ import annotations

import math
from typing import Optional, Set

import numpy as np


def theoretical_eta_omd(C: int, N: int, T: int, B: int = 1) -> float:
    """Learning rate balancing the neg-entropy Bregman diameter C log(N/C)
    against the summed local-norm gradient bound (M chunks of B unit
    rewards, sum_i c_i^2 f_i <= B^2):

        regret <= C log(N/C)/eta + eta M B^2 / 2
        eta*   =  sqrt(2 C log(N/C) / (T B))

    which recovers Si Salem et al.'s O(sqrt(T C log(N/C))) regret rate.
    """
    log_ratio = max(math.log(N / max(C, 1)), 1e-12)
    return math.sqrt(2.0 * C * log_ratio / (T * B))


def project_capped_simplex_kl(
    w: np.ndarray, C: float, return_lam: bool = False
):
    """Exact KL (I-projection) of weights exp(w) onto {f in [0,1]^N: sum f = C}.

    Returns f with f_i = min(1, exp(w_i - lam)) where lam solves
    sum_i min(1, exp(w_i - lam)) = C.  Water-filling: with weights sorted in
    descending order and k coordinates saturated at 1,

        exp(-lam) = (C - k) / sum_{i > k} exp(w_i)

    and k is the unique count with exp(w_(k) - lam) >= 1 > exp(w_(k+1) - lam).
    Computed in float64 with a max-shift so exp never overflows.
    """
    w = np.asarray(w, dtype=np.float64)
    n = w.shape[0]
    if not (0 < C <= n):
        raise ValueError(f"need 0 < C <= N, got C={C}, N={n}")
    shift = float(np.max(w))
    y = np.exp(w - shift)  # descending relevance, max == 1
    order = np.argsort(-y, kind="stable")
    ys = y[order]
    # tail[k] = sum_{i > k} ys_i  (k coords saturated)
    tail = np.concatenate([[y.sum()], y.sum() - np.cumsum(ys)])
    ks = np.arange(0, int(min(C, n)))  # k < C (need C - k > 0)
    with np.errstate(divide="ignore"):
        theta = (C - ks) / tail[ks]  # candidate exp(shift - lam)
    # validity: theta * ys[k] < 1 (first unsaturated stays interior)
    #           and (k == 0 or theta * ys[k-1] >= 1)
    ok_hi = theta * ys[ks] < 1.0
    ok_lo = np.concatenate([[True], theta[1:] * ys[ks[1:] - 1] >= 1.0])
    valid = np.nonzero(ok_hi & ok_lo)[0]
    if len(valid) == 0:
        # C == n or total mass pushes everything to saturation
        k = int(min(C, n)) - 1
        th = (C - k) / max(tail[k], 1e-300)
    else:
        k = int(valid[0])
        th = theta[k]
    f = np.minimum(1.0, th * y)
    if return_lam:
        return f, shift - math.log(th)
    return f


class OMDClassic:
    """Host-side (float64 numpy) OMD policy — the slow exact oracle.

    Mirrors :class:`repro.core.ogb_classic.OGBClassic`'s interface: per-request
    ``request(i) -> hit`` with a batched update every ``batch_size`` requests,
    Madow systematic sampling in the integral setting.
    """

    name = "OMD"
    __slots__ = ("N", "C", "B", "eta", "integral", "rng", "w", "f",
                 "_counts", "_pending", "cached", "hits", "requests",
                 "fractional_reward")

    def __init__(
        self,
        catalog_size: int,
        capacity: int,
        eta: Optional[float] = None,
        horizon: Optional[int] = None,
        batch_size: int = 1,
        integral: bool = True,
        seed: int = 0,
    ):
        self.N = int(catalog_size)
        self.C = int(capacity)
        self.B = int(batch_size)
        if eta is None:
            if horizon is None:
                raise ValueError("pass eta or horizon")
            eta = theoretical_eta_omd(self.C, self.N, horizon, self.B)
        self.eta = float(eta)
        self.integral = integral
        self.rng = np.random.default_rng(seed)

        # normalized log-weights: f = min(1, exp(w)) is feasible at all times
        self.w = np.full(self.N, math.log(self.C / self.N), dtype=np.float64)
        self.f = np.full(self.N, self.C / self.N, dtype=np.float64)
        self._counts = np.zeros(self.N, dtype=np.float64)
        self._pending = 0
        self.cached: Set[int] = set()
        self.hits = 0
        self.requests = 0
        self.fractional_reward = 0.0
        if integral:
            self._resample()

    def _resample(self) -> None:
        cum = np.cumsum(self.f)
        u = self.rng.random()
        idx = np.searchsorted(cum, u + np.arange(self.C), side="left")
        self.cached = set(int(i) for i in np.clip(idx, 0, self.N - 1))

    def contains(self, i: int) -> bool:
        return i in self.cached

    def value(self, i: int) -> float:
        return float(self.f[i])

    def request(self, i: int) -> bool:
        hit = self.contains(i) if self.integral else False
        self.requests += 1
        self.hits += int(hit)
        self.fractional_reward += float(self.f[i])
        self._counts[i] += 1.0
        self._pending += 1
        if self._pending >= self.B:
            self.batch_end()
        return hit

    def batch_end(self) -> None:
        if self._pending == 0:
            return
        self.w = self.w + self.eta * self._counts
        self.f, lam = project_capped_simplex_kl(self.w, self.C, return_lam=True)
        self.w -= lam  # renormalize so f = min(1, exp(w)) without a threshold
        self._counts[:] = 0.0
        self._pending = 0
        if self.integral:
            self._resample()

    def occupancy(self) -> int:
        return len(self.cached)
