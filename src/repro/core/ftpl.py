"""FTPL — Follow The Perturbed Leader with one-shot initial noise.

The only prior no-regret policy with O(log N) per-request complexity (paper
§2.2): LFU counters n_i plus a *single* initial Gaussian perturbation
zeta*gamma_i; the cache holds the top-C scores s_i = n_i + zeta*gamma_i.

Faithfulness note: the initial cache is the top-C of the *noise over the whole
catalog* — that "very large initial noise" is precisely the FTPL pathology the
paper demonstrates (Fig 4 right), so we materialize the N noise draws eagerly
(O(N) once at init, numpy) and then maintain the top-C incrementally in
O(log C) per request.  With unit increments the top-C set can only change by
the requested item swapping in (only its score moved), so greedy maintenance
is exact — unit-tested against a brute-force top-C oracle.

zeta tuning for sublinear regret (Bhattacharjee et al., quoted in paper §2.2):
    zeta = (4*pi*log N)^(-1/4) * sqrt(T / C)
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from .treap import make_store


def theoretical_zeta(C: int, N: int, T: int) -> float:
    return (4.0 * math.pi * math.log(max(N, 2))) ** -0.25 * math.sqrt(T / C)


def ftpl_noise(catalog_size: int, zeta: float, seed: int = 0) -> np.ndarray:
    """The one-shot Gaussian perturbation zeta * gamma, as float32.

    float32 on purpose: the device-resident scan engine
    (:mod:`repro.cachesim.engines`) computes scores ``count + noise`` in
    float32, and keeping the host policy on the identical grid makes the two
    implementations bit-exactly comparable (same IEEE single-precision adds).
    """
    rng = np.random.default_rng(seed)
    return (float(zeta) * rng.standard_normal(catalog_size)).astype(np.float32)


def ftpl_initial_top_c(noise: np.ndarray, capacity: int) -> np.ndarray:
    """Initial cache: top-C items of the noise alone (counts are all zero)."""
    n = noise.shape[0]
    return np.argpartition(noise, n - capacity)[n - capacity :].astype(np.int64)


class FTPL:
    name = "FTPL"
    __slots__ = ("N", "C", "zeta", "_noise", "_counts", "cached",
                 "_order", "hits", "requests")

    def __init__(
        self,
        catalog_size: int,
        capacity: int,
        zeta: Optional[float] = None,
        horizon: Optional[int] = None,
        seed: int = 0,
    ):
        self.N = int(catalog_size)
        self.C = int(capacity)
        if zeta is None:
            if horizon is None:
                raise ValueError("pass zeta or horizon")
            zeta = theoretical_zeta(self.C, self.N, horizon)
        self.zeta = float(zeta)
        # float32 noise + float32 score adds: bit-identical to the scan engine
        self._noise = ftpl_noise(self.N, self.zeta, seed=seed)
        self._counts: Dict[int, int] = {}
        self.cached: Dict[int, float] = {}
        self._order = make_store("sorted", seed=seed)  # (score, item), cached only
        for i in ftpl_initial_top_c(self._noise, self.C):
            s = self._noise[i]
            self.cached[int(i)] = s
            self._order.insert(s, int(i))
        self.hits = 0
        self.requests = 0

    def _score(self, i: int) -> np.float32:
        # python int + np.float32 stays float32 (value-based casting): the
        # exact same IEEE add the jnp.float32 engine performs
        return self._counts.get(i, 0) + self._noise[i]

    def contains(self, i: int) -> bool:
        return i in self.cached

    def request(self, i: int) -> bool:
        hit = i in self.cached
        self.requests += 1
        self.hits += int(hit)
        self._counts[i] = self._counts.get(i, 0) + 1
        s = self._score(i)
        if hit:
            old = self.cached[i]
            self._order.remove(old, i)
            self._order.insert(s, i)
            self.cached[i] = s
        else:
            min_score, min_item = self._order.min()
            if s > min_score:
                self._order.pop_min()
                del self.cached[min_item]
                self.cached[i] = s
                self._order.insert(s, i)
        return hit

    def batch_end(self) -> None:  # interface parity
        pass

    def occupancy(self) -> int:
        return len(self.cached)
