"""Size-aware OGB — the paper's §8 future work, implemented.

Items have sizes s_i (bytes); the knapsack-relaxed feasible set is
F_s = {f in [0,1]^N : sum_i s_i f_i = C}.  The Euclidean projection becomes

    f_i = clip(y_i - s_i * tau, 0, 1)          (KKT of the weighted program)

so the uniform-subtraction trick generalizes *per size class*: group items
into K size classes (realistic caches quantize object sizes anyway — slab
allocators); within class k every interior coordinate is lowered by
s_k * tau, so a per-class accumulator rho_k = s_k * rho_base and a per-class
ordered structure preserve the lazy O(log N) update — total O(K log N)
amortized per request, with K ~ 8-32 slab classes in practice.

The reward of a hit is proportional to the item's size (bytes served from
cache), matching the cost-aware setting w_{t,i} = s_i.

Correctness: property-tested against the eager weighted-projection oracle
(tests/core/test_ogb_sized.py).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .treap import make_store


def weighted_capped_simplex_tau(
    y: np.ndarray, sizes: np.ndarray, C: float, iters: int = 100
) -> float:
    """Solve sum_i s_i * clip(y_i - s_i*tau, 0, 1) = C by bisection.

    Monotone in tau (each term non-increasing), so bisection is exact to
    2^-iters of the bracket."""
    y = np.asarray(y, np.float64)
    s = np.asarray(sizes, np.float64)
    if s.shape != y.shape:
        raise ValueError(f"sizes shape {s.shape} != y shape {y.shape}")
    if s.size == 0:
        raise ValueError("empty y/sizes")
    if not np.all(np.isfinite(s)) or float(np.min(s)) <= 0.0:
        raise ValueError(
            "sizes must be finite and > 0 (zero/negative sizes make the "
            f"max(y/s) bracket inf/NaN); got min={np.min(s)!r}"
        )
    if not np.isfinite(C) or C <= 0.0:
        raise ValueError(f"capacity C must be finite and > 0; got {C!r}")
    if not np.all(np.isfinite(y)):
        raise ValueError("y must be finite")
    lo = 0.0
    hi = float(np.max(y / s)) + 1.0

    def g(tau):
        return float(np.sum(s * np.clip(y - s * tau, 0.0, 1.0)))

    if g(0.0) <= C:
        return 0.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if g(mid) >= C:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def project_weighted(y: np.ndarray, sizes: np.ndarray, C: float) -> np.ndarray:
    tau = weighted_capped_simplex_tau(y, sizes, C)
    return np.clip(y - np.asarray(sizes, np.float64) * tau, 0.0, 1.0)


def size_classes(
    sizes: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize per-item sizes into at most ``k`` slab classes.

    Returns ``(class_sizes (K,), item_class (N,) int32)``.  Exact (every
    class size is an observed size) when there are <= k distinct sizes —
    realistic caches slab-quantize anyway; otherwise geometric bins over
    [min, max] with each class sized at the geometric mean of its members.
    Validates sizes finite and > 0 (the weighted projection divides by
    them)."""
    s = np.asarray(sizes, np.float64)
    if s.ndim != 1 or s.size == 0:
        raise ValueError(f"sizes must be a non-empty 1-d array: {s.shape}")
    if not np.all(np.isfinite(s)) or float(np.min(s)) <= 0.0:
        raise ValueError(
            f"sizes must be finite and > 0; got min={np.min(s)!r}"
        )
    if k < 1:
        raise ValueError(f"need k >= 1 size classes, got {k}")
    uniq = np.unique(s)
    if len(uniq) <= k:
        cls = np.searchsorted(uniq, s)
        return uniq, cls.astype(np.int32)
    edges = np.geomspace(uniq[0], uniq[-1], k + 1)
    cls = np.clip(np.searchsorted(edges, s, side="right") - 1, 0, k - 1)
    out = np.sqrt(edges[:-1] * edges[1:])  # empty classes keep bin centers
    for j in np.unique(cls):
        out[j] = float(np.exp(np.mean(np.log(s[cls == j]))))
    return out, cls.astype(np.int32)


class SizedOGB:
    """Lazy size-aware OGB over K size classes.

    State per class k: ordered structure z_k of unadjusted values, and the
    invariant f_i = f̃_i - s_k * R for active i in class k, where R is the
    global accumulated multiplier (sum of per-request tau's).
    """

    name = "SizedOGB"
    __slots__ = ("s", "K", "item_class", "C", "eta", "R", "f_tilde",
                 "z", "mass")

    def __init__(
        self,
        sizes_by_class: Sequence[float],  # size of each class (K,)
        item_class: Dict[int, int],  # item -> class index
        capacity: float,  # total bytes
        eta: float,
        seed: int = 0,
    ):
        self.s = [float(x) for x in sizes_by_class]
        if not self.s:
            raise ValueError("need at least one size class")
        if any(not math.isfinite(x) or x <= 0.0 for x in self.s):
            raise ValueError(f"class sizes must be finite and > 0: {self.s}")
        if not math.isfinite(capacity) or capacity <= 0.0:
            raise ValueError(f"capacity must be finite and > 0: {capacity!r}")
        self.K = len(self.s)
        self.item_class = dict(item_class)
        self.C = float(capacity)
        self.eta = float(eta)
        self.R = 0.0  # accumulated multiplier: f_i = f̃_i - s_k * R
        self.f_tilde: Dict[int, float] = {}
        self.z = [make_store("sorted", seed=seed + k) for k in range(self.K)]
        self.mass = 0.0  # current sum_i s_i f_i (maintained incrementally)

    def value(self, i: int) -> float:
        v = self.f_tilde.get(i)
        if v is None:
            return 0.0
        k = self.item_class[i]
        return min(max(v - self.s[k] * self.R, 0.0), 1.0)

    def fractional_vector(self, n: int) -> np.ndarray:
        f = np.zeros(n)
        for i in self.f_tilde:
            f[i] = self.value(i)
        return f

    # -- the lazy weighted projection -----------------------------------
    def update(self, j: int, weight: Optional[float] = None) -> None:
        """One request for item j; ascent step eta * w_j (default w = s_j)."""
        kj = self.item_class[j]
        sj = self.s[kj]
        w = sj if weight is None else weight
        step = self.eta * w

        fj_old = self.value(j)
        if fj_old >= 1.0 - 1e-12:
            return
        # raise coordinate j (clip the step so f_j <= 1: the one-clip case)
        step = min(step, 1.0 - fj_old)
        if j in self.f_tilde:
            self.z[kj].remove(self.f_tilde[j], j)
            self.f_tilde[j] += step
        else:
            self.f_tilde[j] = sj * self.R + step
        self.z[kj].insert(self.f_tilde[j], j)
        self.mass += sj * step
        if self.mass <= self.C + 1e-12:
            return

        # remove the excess: find dR with sum_k s_k^2 * m_k * dR = excess,
        # popping coordinates that hit zero (amortized O(1) pops/request)
        excess = self.mass - self.C
        while excess > 1e-15:
            denom = sum(
                (self.s[k] ** 2) * len(self.z[k]) for k in range(self.K)
            )
            if denom <= 0:
                # every coordinate was popped: the true mass is exactly 0
                # (clear the float drift the incremental counter carries so
                # ``mass <= C + tol`` holds on this exit path too)
                self.mass = 0.0
                excess = 0.0
                break
            dR = excess / denom
            # find the earliest-clipping coordinate across classes
            popped_any = False
            for k in range(self.K):
                while len(self.z[k]) > 0:
                    key, i = self.z[k].min()
                    val = key - self.s[k] * self.R
                    if val <= self.s[k] * dR + 1e-18:
                        # coordinate i hits zero before absorbing s_k*dR
                        self.z[k].pop_min()
                        del self.f_tilde[i]
                        excess -= self.s[k] * val
                        self.mass -= self.s[k] * val
                        popped_any = True
                    else:
                        break
            if popped_any:
                continue  # recompute denom with the survivors
            # no coordinate clips: apply the uniform multiplier and finish
            self.R += dR
            self.mass -= denom * dR
            excess = 0.0

    # convenience: byte hit ratio bookkeeping ---------------------------
    def fractional_byte_reward(self, i: int) -> float:
        k = self.item_class[i]
        return self.s[k] * self.value(i)
