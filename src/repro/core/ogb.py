"""OGB — the paper's online gradient-based caching policy (Algorithms 1-3).

Faithful implementation of:

  * **UpdateProbabilities** (Algorithm 2): online gradient ascent step + lazy
    Euclidean projection onto F = {f in [0,1]^N : sum f = C}.  Instead of
    materializing f, we keep the *unadjusted* vector ``f̃`` (dict, active items
    only) and a global adjustment scalar ``rho`` with the invariant::

        f_i = f̃_i - rho     for i in the active set (f_i > 0)
        f_i = 0              otherwise

    plus an ordered structure ``z`` over the active ``f̃`` values so that the
    projection corner cases (coordinates hitting 0, the requested coordinate
    clipping at 1) cost O(log N) each and O(1) amortized per request.

  * **UpdateSample** (Algorithm 3): coordinated Poisson sampling with permanent
    random numbers p_i — item i is cached iff f_i >= p_i.  Because
    ``d_i = f̃_i - p_i`` is constant for cached-and-unrequested items, an
    ordered structure over d evicts exactly the items whose d_i fell below the
    advancing threshold rho.  E[x_t] = f_t (soft capacity constraint).

Complexity: O(log N) amortized per request for any batch size B >= 1.

Beyond-paper engineering (equivalence property-tested): ``lazy_init`` keeps the
untouched part of the catalog *implicit* (all untouched items share the same
unadjusted value f0 = C/N and a PRF-derived permanent random number), so memory
is O(C + #touched) instead of O(N) and startup is O(1).  The virgin group pops
out of the active set en masse when the shared value crosses zero.

The implementation is exact in float64: property tests check that the lazily
maintained f equals the eager projection oracle (:mod:`repro.core.projection`)
along arbitrary request sequences.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .treap import make_store


def theoretical_eta(C: int, N: int, T: int, B: int = 1) -> float:
    """Theorem 3.1 learning rate: eta = sqrt(C (1 - C/N) / (T B))."""
    return math.sqrt(C * (1.0 - C / N) / (T * B))


def theoretical_regret_bound(C: int, N: int, T: int, B: int = 1) -> float:
    """Theorem 3.1 regret bound: sqrt(C (1 - C/N) T B)."""
    return math.sqrt(C * (1.0 - C / N) * T * B)


@dataclass(slots=True)
class OGBStats:
    requests: int = 0
    hits: int = 0
    fractional_reward: float = 0.0
    zero_pops: int = 0  # coordinates driven to 0 by projections (paper Fig 9 right)
    pop_loop_rounds: int = 0
    one_clip_events: int = 0
    evictions: int = 0
    insertions: int = 0
    sample_updates: int = 0


class OGB:
    """The paper's O(log N) integral no-regret caching policy."""

    name = "OGB"

    __slots__ = (
        "N", "C", "B", "eta", "seed", "_rng", "redraw_period", "stats",
        "rho", "f_tilde", "z", "_f0", "lazy_init", "store_kind",
        "_touched", "_n_virgin",
        "p", "cached", "d", "_d_key", "_touched_sample", "rho_sample",
        "_batch",
    )

    def __init__(
        self,
        catalog_size: int,
        capacity: int,
        eta: Optional[float] = None,
        horizon: Optional[int] = None,
        batch_size: int = 1,
        store_kind: str = "sorted",
        lazy_init: bool = True,
        seed: int = 0,
        redraw_period: Optional[int] = None,
    ):
        if capacity <= 0 or capacity > catalog_size:
            raise ValueError("need 0 < C <= N")
        if redraw_period is not None and lazy_init:
            raise ValueError("redraw_period requires lazy_init=False")
        self.N = int(catalog_size)
        self.C = int(capacity)
        self.B = int(batch_size)
        if eta is None:
            if horizon is None:
                raise ValueError("pass eta or horizon (Theorem 3.1 tuning)")
            eta = theoretical_eta(self.C, self.N, horizon, self.B)
        self.eta = float(eta)
        self.seed = seed
        self._rng = random.Random(seed)
        self.redraw_period = redraw_period
        self.stats = OGBStats()

        # --- probability state (Algorithm 2) ---
        self.rho = 0.0
        self.f_tilde: Dict[int, float] = {}
        self.store_kind = store_kind
        self.z = make_store(store_kind, seed=seed + 1)
        self._f0 = self.C / self.N
        self.lazy_init = lazy_init
        self._touched: Set[int] = set()  # materialized-in-probability items
        self._n_virgin = self.N if lazy_init else 0

        # --- sample state (Algorithm 3) ---
        self.p: Dict[int, float] = {}
        self.cached: Set[int] = set()
        self.d = make_store(store_kind, seed=seed + 2)
        self._d_key: Dict[int, float] = {}
        self._touched_sample: Set[int] = set()  # items with explicit sample state
        self.rho_sample = 0.0  # rho snapshot at the last sample update
        self._batch: List[int] = []

        if not lazy_init:
            for i in range(self.N):
                self.f_tilde[i] = self._f0
                self.z.insert(self._f0, i)
            for i in range(self.N):  # initial Poisson sample over the catalog
                if self._perm_rand(i) <= self._f0:
                    self._admit(i, self.f_tilde[i])
                self._touched_sample.add(i)

    # ------------------------------------------------------------------
    # permanent random numbers (PRF-derived so lazy/eager modes agree)
    # ------------------------------------------------------------------
    def _perm_rand(self, i: int) -> float:
        pi = self.p.get(i)
        if pi is None:
            pi = random.Random((self.seed << 1) ^ (i * 0x9E3779B97F4A7C15)).random()
            self.p[i] = pi
        return pi

    # ------------------------------------------------------------------
    # fractional state accessors
    # ------------------------------------------------------------------
    def _is_virgin(self, i: int) -> bool:
        return self._n_virgin > 0 and i not in self._touched

    def _virgin_value(self) -> float:
        return self._f0 - self.rho

    def value(self, i: int) -> float:
        """Current fractional value f_i."""
        v = self.f_tilde.get(i)
        if v is not None:
            return min(v - self.rho, 1.0)
        if self._is_virgin(i):
            return self._virgin_value()
        return 0.0

    def fractional_vector(self) -> np.ndarray:
        """Materialize f (O(N)); for tests/small catalogs only."""
        f = np.zeros(self.N)
        if self._n_virgin > 0:
            vv = max(self._virgin_value(), 0.0)
            for i in range(self.N):
                if self._is_virgin(i):
                    f[i] = vv
        for i, v in self.f_tilde.items():
            f[i] = min(max(v - self.rho, 0.0), 1.0)
        return f

    # ------------------------------------------------------------------
    # Algorithm 2: UpdateProbabilities
    # ------------------------------------------------------------------
    def update_probabilities(self, j: int, weight: float = 1.0) -> None:
        """Process one request for item j (gradient step + lazy projection).

        ``weight`` implements the paper's general reward w_{t,j} (e.g. the
        retrieval cost of item j): the ascent step becomes eta * w_{t,j}.
        """
        f_tilde = self.f_tilde
        z = self.z
        rho = self.rho
        if self._n_virgin > 0:
            if self._f0 - rho <= 1e-15:
                self._n_virgin = 0  # the untouched group decayed to zero
            elif j not in self._touched:
                # materialize j out of the virgin group
                self._n_virgin -= 1
                f_tilde[j] = self._f0
                z.insert(self._f0, j)
        self._touched.add(j)

        ftj = f_tilde.get(j)
        fj_old = min(ftj - rho, 1.0) if ftj is not None else 0.0
        if fj_old >= 1.0 - 1e-12:
            return  # paper lines 1-2: saturated component, projection is identity

        step = self.eta * weight
        # gradient step on coordinate j
        if ftj is not None:
            z.remove(ftj, j)
            new_key = ftj + step
        else:
            new_key = rho + step  # f_j: 0 -> eta*w (unadjusted key)
        f_tilde[j] = new_key
        z.insert(new_key, j)

        # ---- zero-pop loop (paper lines 11-18) ----
        popped, tau, virgin_popped = self._zero_pop_loop(step)

        # ---- one-clip corner case (paper lines 19-24): can fire at most once ----
        if new_key - rho - tau > 1.0 + 1e-12:
            self.stats.one_clip_events += 1
            for key, i in popped:  # RestoreRemoved()
                z.insert(key, i)
            z.remove(new_key, j)
            popped, tau, virgin_popped = self._zero_pop_loop(1.0 - fj_old)
            rho += tau
            self.rho = rho
            f_tilde[j] = 1.0 + rho  # clipped at exactly 1
            z.insert(1.0 + rho, j)
        else:
            self.rho = rho + tau

        # commit: popped coordinates are now exactly 0
        for _key, i in popped:
            f_tilde.pop(i, None)
        self.stats.zero_pops += len(popped)
        if virgin_popped:
            self.stats.zero_pops += self._n_virgin
            self._n_virgin = 0

    def _zero_pop_loop(
        self, excess: float
    ) -> Tuple[List[Tuple[float, int]], float, bool]:
        """Uniform-redistribution fixed point with zero-clipping.

        Pops entries out of ``z`` (restorable via the returned list) but does
        NOT commit side effects: ``f_tilde`` deletion and virgin-group
        retirement happen in the caller so the one-clip path can roll back.

        Returns (popped entries, final per-coordinate subtraction tau,
        whether the implicit virgin group was popped).
        """
        popped: List[Tuple[float, int]] = []
        virgin_alive = self._n_virgin > 0
        n_virgin = self._n_virgin if virgin_alive else 0
        m = len(self.z) + n_virgin
        if m <= 0 or excess <= 0:
            return popped, 0.0, False
        tau = excess / m
        self.stats.pop_loop_rounds += 1
        while m > 1:
            zmin = self.z.min() if len(self.z) > 0 else None
            vvirgin = self._virgin_value() if n_virgin > 0 else math.inf
            use_virgin = n_virgin > 0 and (zmin is None or vvirgin <= zmin[0] - self.rho)
            min_val = vvirgin if use_virgin else (zmin[0] - self.rho)
            if min_val >= tau - 1e-18:
                break
            if use_virgin:
                if m - n_virgin <= 0:
                    break
                excess -= n_virgin * min_val
                m -= n_virgin
                n_virgin = 0
            else:
                key, i = self.z.pop_min()
                popped.append((key, i))
                excess -= key - self.rho
                m -= 1
            tau = excess / m
        virgin_popped = virgin_alive and n_virgin == 0
        return popped, tau, virgin_popped

    # ------------------------------------------------------------------
    # Algorithm 3: UpdateSample
    # ------------------------------------------------------------------
    def _admit(self, i: int, f_tilde_i: float) -> None:
        di = f_tilde_i - self._perm_rand(i)
        self.cached.add(i)
        self.d.insert(di, i)
        self._d_key[i] = di
        self.stats.insertions += 1

    def _update_sample_item(self, j: int) -> None:
        was_implicit = self._implicitly_cached(j)
        self._touched_sample.add(j)
        ftj = self.f_tilde.get(j)
        keep = ftj is not None and ftj - self.rho >= self._perm_rand(j)
        old = self._d_key.pop(j, None)  # cached <=> has a d entry
        if old is not None:
            self.d.remove(old, j)
            if keep:
                dj = ftj - self.p[j]
                self.d.insert(dj, j)
                self._d_key[j] = dj
            else:  # f_j dropped below p_j (or hit zero) during the batch
                self.cached.remove(j)
                self.stats.evictions += 1
        else:
            if keep:
                self._admit(j, ftj)
                if was_implicit:
                    self.stats.insertions -= 1  # it was already resident
            elif was_implicit:
                self.stats.evictions += 1

    def update_sample(self, requested: List[int]) -> None:
        """Resample the cache content (runs once every B requests)."""
        self.stats.sample_updates += 1
        for j in (requested if len(requested) <= 1 else set(requested)):
            self._update_sample_item(j)
        # evict every cached item whose difference fell below rho
        rho = self.rho
        d = self.d
        while len(d) > 0:
            dmin, i = d.min()
            if dmin >= rho:
                break
            d.pop_min()
            self._d_key.pop(i, None)
            self.cached.discard(i)
            self.stats.evictions += 1
        self.rho_sample = rho
        if (
            self.redraw_period is not None
            and self.stats.sample_updates % self.redraw_period == 0
        ):
            self._redraw_permanent_numbers()

    def _redraw_permanent_numbers(self) -> None:
        """Optional periodic redraw of p (paper §5.1). Requires eager init."""
        self.seed = self._rng.randrange(1 << 62)
        self.p.clear()
        self.d = make_store(self.store_kind, seed=self.seed + 2)
        self._d_key.clear()
        survivors: Set[int] = set()
        for i in list(self.cached):
            if i in self.f_tilde and self.f_tilde[i] - self.rho >= self._perm_rand(i):
                di = self.f_tilde[i] - self.p[i]
                self.d.insert(di, i)
                self._d_key[i] = di
                survivors.add(i)
        self.stats.evictions += len(self.cached) - len(survivors)
        self.cached = survivors

    # ------------------------------------------------------------------
    # cache-policy interface (used by the simulator / serving engine)
    # ------------------------------------------------------------------
    def _implicitly_cached(self, i: int) -> bool:
        """Virgin-at-last-sample items: cached iff p_i <= f0 - rho_sample."""
        if not self.lazy_init or i in self._touched_sample:
            return False
        thr = self._f0 - self.rho_sample
        return thr > 0 and self._perm_rand(i) <= thr

    def contains(self, i: int) -> bool:
        return i in self.cached or self._implicitly_cached(i)

    def request(self, i: int, weight: float = 1.0) -> bool:
        """Serve one request; returns integral hit/miss. Updates everything."""
        stats = self.stats
        hit = i in self.cached or self._implicitly_cached(i)
        stats.requests += 1
        if hit:
            stats.hits += 1
        v = self.value(i)
        if v > 0.0:
            stats.fractional_reward += weight * (v if v <= 1.0 else 1.0)
        self.update_probabilities(i, weight=weight)
        if self.B == 1:
            self.update_sample((i,))  # inlined single-item batch: no list churn
        else:
            self._batch.append(i)
            if len(self._batch) >= self.B:
                self.batch_end()
        return hit

    def batch_end(self) -> None:
        if self._batch:
            self.update_sample(self._batch)
            self._batch.clear()

    def occupancy(self, exact: bool = False) -> float:
        """Instantaneous cache occupancy.

        With ``lazy_init`` the implicit virgin population is counted by its
        Binomial mean unless ``exact=True`` (which is O(N - #touched))."""
        base = len(self.cached)
        if not self.lazy_init:
            return base
        thr = max(self._f0 - self.rho_sample, 0.0)
        if exact:
            extra = sum(
                1
                for i in range(self.N)
                if i not in self._touched_sample and self._perm_rand(i) <= thr
            )
            return base + extra
        n_virgin_sample = max(self.N - len(self._touched_sample), 0)
        return base + n_virgin_sample * thr

    # invariant checker used by tests -----------------------------------
    def check_invariants(self, atol: float = 1e-8) -> None:
        f = self.fractional_vector()
        assert abs(f.sum() - self.C) < atol * max(self.C, 1), (
            f"sum f = {f.sum()} != C = {self.C}"
        )
        assert (f >= -1e-12).all() and (f <= 1 + 1e-12).all()
        assert len(self.z) == len(self.f_tilde)
