"""Serving launcher: batched generation with the OGB page pool.

    python -m repro.launch.serve --arch <id> [--policy ogb|lru|lfu|ftpl]
           [--steps N] [--batch B] [--prompt-len L] [--pool-pages C]
"""

from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs.base import get_smoke
from repro.core.policies import make_policy
from repro.models.model import init_params
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import PagedKVPool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="ogb")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--pool-pages", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--hot-prompts", type=int, default=6)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.key(0))
    touches = args.steps * args.batch * (args.prompt_len // args.page_size)
    kw = {}
    if args.policy == "ogb":
        kw = {"horizon": touches, "batch_size": args.batch * (args.prompt_len // args.page_size)}
    elif args.policy == "ftpl":
        kw = {"horizon": touches}
    policy = make_policy(args.policy, 1 << 18, args.pool_pages, **kw)
    pool = PagedKVPool(policy, page_size=args.page_size)
    engine = ServeEngine(cfg, params, pool=pool, max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(0)
    hot = [rng.integers(1, cfg.vocab_size, args.prompt_len) for _ in range(args.hot_prompts)]
    for step in range(args.steps):
        prompts = []
        for b in range(args.batch):
            if b < args.batch // 2:
                prompts.append(hot[(step + b) % len(hot)])
            else:
                prompts.append(rng.integers(1, cfg.vocab_size, args.prompt_len))
        engine.generate(np.stack(prompts).astype(np.int32), args.new_tokens)
        if (step + 1) % 10 == 0:
            s, p = engine.stats, pool.stats
            print(
                f"[serve] step {step+1:>4} prefix-reuse {s.prefix_reuse:6.1%} "
                f"page-hits {p.page_hit_ratio:6.1%} occupancy {pool.occupancy():.0f}"
            )
    s = engine.stats
    print(
        f"[serve] done: {s.requests} requests, {s.decode_tokens} tokens decoded, "
        f"prefix reuse {s.prefix_reuse:.1%} with policy={args.policy}"
    )


if __name__ == "__main__":
    main()
