"""Trip-count-aware HLO analysis.

XLA's HloCostAnalysis (and a naive grep of the HLO text) counts the body of a
``while`` loop ONCE, but jax.lax.scan-based layer stacks execute the body L
times — so collective bytes parsed naively from the optimized module
under-count by the trip count (61x for kimi-k2!).  This module parses the
optimized HLO text into computations, recovers each while loop's trip count
from its condition (``compare(iter, constant)`` pattern), and multiplies the
collective bytes found in (transitively) called computations by the product
of enclosing trip counts.

This is the collective-bytes source for EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
    "f8e4m3": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_TARGETS = re.compile(
    r"(?:condition|body|to_apply|branch_computations|called_computations|calls)="
    r"[{]?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)[}]?"
)
_WHILE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONST_DEF = re.compile(r"%?([\w\.\-]+)\s*=\s*s\d+\[\]\s*constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if stripped == "}" or stripped.startswith("} "):
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


def scalar_int_constants(hlo: str) -> Dict[str, int]:
    """Global table: %name = s32[] constant(N)."""
    out: Dict[str, int] = {}
    for m in _CONST_DEF.finditer(hlo):
        out[m.group(1)] = int(m.group(2))
    return out


def while_trip_count(
    cond_lines: List[str], const_table: Dict[str, int]
) -> Optional[int]:
    """Extract N from the canonical `i < N` while condition.

    The bound is either an inline `constant(N)` in the condition computation
    or a named scalar constant referenced by the compare/fusion — resolve both
    and take the max (the induction start, usually 0, is also a constant)."""
    consts: List[int] = []
    for ln in cond_lines:
        consts += [int(v) for v in _CONST_INT.findall(ln)]
        if "compare" in ln or "fusion" in ln:
            for name in _OPERANDS.findall(ln):
                if name in const_table:
                    consts.append(const_table[name])
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else None


def analyze_collectives(hlo: str) -> Dict[str, float]:
    """Collective kind -> total bytes, trip-count corrected."""
    comps = split_computations(hlo)
    const_table = scalar_int_constants(hlo)

    # map: computation -> list of (callee, multiplier)
    calls: Dict[str, List[Tuple[str, int]]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = while_trip_count(comps.get(cond, []), const_table) or 1
                calls[name].append((body, max(trip, 1)))
                continue
            cm = _CALL_TARGETS.search(ln)
            if cm and "while(" not in ln:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        calls[name].append((callee, 1))

    # multiplier of each computation = sum over call paths from entry
    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))

    mult: Dict[str, float] = {name: 0.0 for name in comps}

    def visit(name: str, m: float, depth: int = 0):
        if depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, k in calls.get(name, []):
            visit(callee, m * k, depth + 1)

    visit(entry, 1.0)

    out: Dict[str, float] = {}
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            for kind in COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start)?\(", ln):
                    # output shape(s) are on the lhs of '='
                    lhs = ln.split("=", 1)[0]
                    b = _shape_bytes(lhs)
                    if b == 0:  # fall back to whole line minus operands
                        b = _shape_bytes(ln.split("(", 1)[0])
                    out[kind] = out.get(kind, 0.0) + b * m
                    break
    return out


def analyze_flops_undercount(hlo: str) -> Dict[str, float]:
    """Report the total while multiplier mass — a diagnostic for how much
    cost_analysis undercounts loop bodies in this module."""
    comps = split_computations(hlo)
    n_while = sum(
        1 for lines in comps.values() for ln in lines if "while(" in ln
    )
    return {"n_computations": len(comps), "n_while": n_while}
