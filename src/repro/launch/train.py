"""Production training launcher.

    python -m repro.launch.train --arch <id> [--smoke] [--steps N]
           [--mesh-data D --mesh-model M] [--ckpt-dir DIR] [--microbatches K]

On this CPU host it runs the smoke config end-to-end (real optimization); on
a TPU fleet the same driver runs the full config under the production mesh —
the sharding annotations, checkpointing, fault handling and data pipeline are
identical code paths (see repro.launch.dryrun for the compile-only proof at
512 chips).
"""

from __future__ import annotations

import argparse
import time


import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, get_smoke
from repro.dist.fault import StragglerMonitor
from repro.dist.sharding import default_rules, use_sharding
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import create_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    opt_cfg = OptimizerConfig(
        lr=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps
    )
    mesh = None
    if args.mesh_data and args.mesh_model:
        mesh = jax.make_mesh((args.mesh_data, args.mesh_model), ("data", "model"))

    state = create_train_state(cfg, opt_cfg, jax.random.key(0))
    data = SyntheticLM(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.global_batch,
        )
    )
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.microbatches))
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    monitor = StragglerMonitor()

    start = 0
    if ckpt and latest_step(args.ckpt_dir) is not None:
        state, start, extra = restore_checkpoint(args.ckpt_dir, state)
        data.load_state_dict(extra)
        print(f"[train] resumed at step {start}")

    ctx = use_sharding(mesh, default_rules()) if mesh else None
    if ctx:
        ctx.__enter__()
    try:
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            state, metrics = step_fn(state, batch)
            straggle = monitor.observe(step, time.perf_counter() - t0)
            if step % 10 == 0 or step == args.steps - 1:
                print(
                    f"[train] step {step:>5} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f}"
                    + (" STRAGGLER" if straggle else "")
                )
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, extra=data.state_dict())
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
        if ckpt:
            ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
