import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the jitted step (train_step for train
shapes, prefill forward for prefill shapes, serve_step for decode shapes),
lowers it against ShapeDtypeStruct inputs under the production mesh, compiles
it, and records:

  * memory_analysis()  — per-device bytes (proves the sharding fits / where
    it doesn't, see EXPERIMENTS.md §Dry-run)
  * cost_analysis()    — HLO FLOPs + bytes accessed for §Roofline
  * collective bytes   — parsed from the optimized HLO text: operand bytes of
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

Results go to benchmarks/results/dryrun/<cell>.json so the run is resumable
cell-by-cell (each cell can also run in a fresh subprocess via --subprocess,
isolating any single-cell failure).

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--subprocess]
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import numpy as np

RESULTS_DIR = os.path.join("benchmarks", "results", "dryrun")

# TPU v5e constants (per chip) for §Roofline
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\w+\[[^\]]*\]|\([^)]*\)))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, kind = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


def _normalize_cost(cost):
    """compiled.cost_analysis() is a dict on new jax, a per-device list on old."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def build_step(arch_name: str, shape_name: str, mesh, multi_pod: bool):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings, donate)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, get_arch
    from repro.dist.param_sharding import (
        batch_shardings,
        cache_shardings,
        param_shardings,
        state_shardings,
    )
    from repro.dist.sharding import default_rules, use_sharding
    from repro.models.model import (
        decode_step,
        init_params,
        input_specs,
        prefill,
    )
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import create_train_state, make_train_step

    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)

    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    p_sh = param_shardings(cfg, params_shape, mesh)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(
            total_steps=10_000,
            moment_dtype="bfloat16" if cfg.param_count() > 2e10 else "float32",
        )
        step = make_train_step(cfg, opt_cfg)
        state_shape = jax.eval_shape(
            lambda: create_train_state(cfg, opt_cfg, jax.random.key(0))
        )
        s_sh = state_shardings(cfg, state_shape, mesh)
        b_sh = batch_shardings(mesh, specs)
        fn = step
        args = (state_shape, specs)
        in_sh = (s_sh, b_sh)
    elif shape.kind == "prefill":
        def fn(params, batch):
            return prefill(cfg, params, batch, shape.seq_len)

        b_sh = batch_shardings(mesh, specs)
        args = (params_shape, specs)
        in_sh = (p_sh, b_sh)
    else:  # decode
        def fn(params, cache, tokens):
            return decode_step(cfg, params, cache, tokens)

        cache_shape = specs["cache"]
        c_sh = cache_shardings(cfg, cache_shape, mesh)
        t_sh = batch_shardings(mesh, specs["tokens"])
        args = (params_shape, cache_shape, specs["tokens"])
        in_sh = (p_sh, c_sh, t_sh)
    return fn, args, in_sh


def run_cell(
    arch_name: str, shape_name: str, mesh_kind: str, out_dir: str = RESULTS_DIR
) -> Dict[str, Any]:
    import jax

    from repro.dist.sharding import default_rules, use_sharding
    from repro.launch.mesh import make_production_mesh

    multi = mesh_kind == "multi"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi)
    result: Dict[str, Any] = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "mesh_kind": mesh_kind,
        "n_devices": int(np.prod(mesh.devices.shape)),
    }
    try:
        fn, args, in_sh = build_step(arch_name, shape_name, mesh, multi)
        rules = default_rules(multi_pod=multi)
        with use_sharding(mesh, rules):
            jitted = jax.jit(fn, in_shardings=in_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

        mem = compiled.memory_analysis()
        cost = _normalize_cost(compiled.cost_analysis())
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        from repro.launch.hlo_analysis import analyze_collectives

        coll_corr = analyze_collectives(hlo)

        def _get(obj, key):
            try:
                v = obj[key] if isinstance(obj, dict) else getattr(obj, key, None)
                return float(v) if v is not None else None
            except (TypeError, ValueError, KeyError, AttributeError):
                return None

        result.update(
            {
                "ok": True,
                "lower_s": round(t_lower - t0, 2),
                "compile_s": round(t_compile - t_lower, 2),
                "flops": _get(cost, "flops"),
                "bytes_accessed": _get(cost, "bytes accessed"),
                "transcendentals": _get(cost, "transcendentals"),
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
                "collective_bytes": coll,
                "collective_bytes_total": float(sum(coll.values())),
                # while-trip-count corrected (scan bodies execute L times but
                # appear once in the HLO text — see launch/hlo_analysis.py)
                "collective_bytes_corrected": coll_corr,
                "collective_bytes_corrected_total": float(sum(coll_corr.values())),
                "hlo_n_lines": hlo.count("\n"),
            }
        )
    except Exception as e:  # reprolint: allow(broad-except) recorded, not fatal to the sweep
        result.update(
            {
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
        )
    result["total_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch_name}__{shape_name}__{mesh_kind}.json")
    with open(fname, "w") as f:
        json.dump(result, f, indent=2)
    return result


def run_cache_cell(mesh_kind: str, out_dir: str = RESULTS_DIR) -> Dict[str, Any]:
    """The paper's technique at datacenter scale: batched fractional OGB over
    a 2^30-item catalog sharded across the production mesh (one psum per
    bisection iteration).  Lower + compile + roofline terms, like any cell."""
    import jax
    import jax.numpy as jnp

    from repro.core.ogb import theoretical_eta
    from repro.jaxcache.sharded import make_sharded_step
    from repro.launch.mesh import make_production_mesh

    multi = mesh_kind == "multi"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi)
    N, C, B = 1 << 30, 1 << 24, 1 << 20  # 1.07B items, 16M cache, 1M reqs/step
    eta = theoretical_eta(C, N, 10_000 * B, B)
    result: Dict[str, Any] = {
        "arch": "ogb-cache-dataplane",
        "shape": f"N{N}_B{B}",
        "mesh_kind": mesh_kind,
        "n_devices": int(mesh.size),
    }
    try:
        step, f_sh = make_sharded_step(
            mesh, N, C, B, eta, pod_axis="pod" if multi else None
        )
        f_spec = jax.ShapeDtypeStruct((N,), jnp.float32, sharding=f_sh)
        ids_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
        lowered = step.lower(f_spec, ids_spec)
        compiled = lowered.compile()
        cost = _normalize_cost(compiled.cost_analysis())
        from repro.launch.hlo_analysis import analyze_collectives

        coll = analyze_collectives(compiled.as_text())
        mem = compiled.memory_analysis()
        result.update(
            {
                "ok": True,
                "flops": float(cost.get("flops", 0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0)),
                "collective_bytes_corrected": coll,
                "collective_bytes_corrected_total": float(sum(coll.values())),
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "compile_s": round(time.time() - t0, 2),
            }
        )
    except Exception as e:  # reprolint: allow(broad-except) recorded, not fatal to the sweep
        result.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"cache-dataplane__{mesh_kind}.json"), "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--cache-cell", action="store_true",
                    help="dry-run the OGB cache data plane instead of an LM cell")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh interpreter")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.cache_cell:
        for m in meshes:
            res = run_cache_cell(m, args.out)
            print(f"[cache ] {m}: ok={res.get('ok')} "
                  f"coll={res.get('collective_bytes_corrected_total')} "
                  f"err={res.get('error', '')[:160]}", flush=True)
        return

    if args.all:
        from repro.configs.base import cells

        todo = [(a, s, m) for (a, s) in cells() for m in meshes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo = [(args.arch, args.shape, m) for m in meshes]

    for arch, shape, mesh_kind in todo:
        fname = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
        if not args.force and os.path.exists(fname):
            with open(fname) as f:
                prev = json.load(f)
            if prev.get("ok"):
                print(f"[cached] {arch} {shape} {mesh_kind}", flush=True)
                continue
        if args.subprocess:
            import subprocess

            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                "--out", args.out,
            ] + (["--force"] if args.force else [])
            print(f"[spawn ] {arch} {shape} {mesh_kind}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            status = "ok" if r.returncode == 0 else f"rc={r.returncode}"
            print(f"[done  ] {arch} {shape} {mesh_kind}: {status}", flush=True)
            if r.returncode != 0:
                print(r.stderr[-2000:], flush=True)
        else:
            print(f"[run   ] {arch} {shape} {mesh_kind}", flush=True)
            res = run_cell(arch, shape, mesh_kind, args.out)
            ok = res.get("ok")
            extra = "" if ok else f" ERROR {res.get('error', '')[:200]}"
            print(
                f"[done  ] {arch} {shape} {mesh_kind}: ok={ok} "
                f"compile={res.get('compile_s')}s flops={res.get('flops')}{extra}",
                flush=True,
            )


if __name__ == "__main__":
    main()
