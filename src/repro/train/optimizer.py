"""AdamW with dtype-configurable moments, global-norm clipping, cosine schedule.

Implemented from scratch (no optax dependency in this environment).  Moment
dtype matters at the 1T scale: bf16 moments halve optimizer HBM (the knob that
lets kimi-k2 train_4k fit 512 chips — EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_optimizer(cfg: OptimizerConfig, params: Any) -> AdamWState:
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(
    cfg: OptimizerConfig, params: Any, grads: Any, state: AdamWState
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        params_new,
        AdamWState(step=step, m=m_new, v=v_new),
        {"grad_norm": gnorm, "lr": lr},
    )
