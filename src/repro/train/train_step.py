"""The jitted training step: grad + microbatching + optimizer, sharding-aware.

Microbatching (gradient accumulation) runs as a lax.scan over microbatches so
arbitrary global batches fit; each microbatch's backward is rematerialized.
The step is a single pjit program: GSPMD handles DP gradient reductions, TP
collectives and (optional) FSDP gathers from the sharding annotations placed
in the model code.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import forward_train, init_params

from .optimizer import AdamWState, OptimizerConfig, apply_updates, init_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def create_train_state(cfg: ArchConfig, opt_cfg: OptimizerConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=init_optimizer(opt_cfg, params))


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OptimizerConfig,
    n_microbatches: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = forward_train(cfg, params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def mb_step(carry, mb):
                acc, loss_acc = carry
                (loss, _m), g = grad_fn(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, loss_sum), _ = jax.lax.scan(
                mb_step, (zeros, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = loss_sum / n_microbatches
            metrics = {}

        params, opt, opt_metrics = apply_updates(
            opt_cfg, state.params, grads, state.opt
        )
        out = {"loss": loss, **opt_metrics}
        out.update({k: v for k, v in metrics.items()})
        return TrainState(params=params, opt=opt), out

    return train_step
