"""Fault-tolerant checkpointing: atomic, async, resumable.

Layout:
    <dir>/step_<n>/arrays.npz     flattened pytree leaves
    <dir>/step_<n>/meta.json      treedef + extra state (data cursor, OGB cache)
    <dir>/LATEST                  pointer file (written last -> atomic commit)

Crash-safety: a checkpoint directory is written under a temp name and renamed
(rename is atomic on POSIX); LATEST is updated only after the rename, so a
crash mid-write can never corrupt the restore path.  An async writer thread
overlaps serialization with training (block only on the previous write).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(
    directory: str, step: int, tree: Any, extra: Optional[Dict] = None
) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    np.savez(
        os.path.join(tmp, "arrays.npz"),
        **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)},
    )
    meta = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(
        os.path.join(directory, "LATEST.tmp"), os.path.join(directory, "LATEST")
    )
    return final


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
        return int(name.split("_")[-1])
    except (FileNotFoundError, ValueError):
        return None


def restore_checkpoint(
    directory: str, tree_like: Any, step: Optional[int] = None
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of `tree_like`. Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(tree_like)
    n = meta["n_leaves"]
    if n != len(leaves_like):
        raise ValueError(f"checkpoint has {n} leaves, expected {len(leaves_like)}")
    leaves = []
    for i, like in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree.unflatten(treedef, leaves), step, meta.get("extra", {})


class AsyncCheckpointer:
    """Overlap checkpoint writes with training; keep_last pruning included."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()  # one write in flight at a time
        # snapshot to host memory synchronously (cheap), write async
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._prune()
            except BaseException as e:  # reprolint: allow(broad-except) surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _prune(self) -> None:
        steps = sorted(
            int(d.split("_")[-1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
