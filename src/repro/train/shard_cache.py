"""OGB-scored dataset-shard cache (DESIGN.md §4.3, light integration).

Training fleets stream dataset shards from object storage; local NVMe holds a
fraction.  Catalog = dataset shards; a "request" = a pipeline step touching a
shard; the residency policy decides which shards stay local.  Under shard
re-visitation patterns (multi-epoch training, curriculum mixes, resumable
jobs) the no-regret guarantee bounds total remote-fetch traffic against the
best static shard pinning in hindsight.

This wraps the exact O(log N) OGB policy (host-side control plane — the same
object the serving page pool uses), so the pipeline integration is: call
``touch(shard_id)`` per shard read; consult ``is_local``/``stats``.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.ogb import OGB


@dataclass
class ShardCacheStats:
    touches: int = 0
    local_hits: int = 0
    fetches: int = 0

    @property
    def local_ratio(self) -> float:
        return self.local_hits / max(self.touches, 1)


class OGBShardCache:
    def __init__(
        self,
        n_shards: int,
        local_capacity: int,
        horizon_touches: int = 100_000,
        batch_size: int = 16,
        seed: int = 0,
    ):
        self.policy = OGB(
            n_shards,
            local_capacity,
            horizon=horizon_touches,
            batch_size=batch_size,
            seed=seed,
        )
        self.stats = ShardCacheStats()

    def is_local(self, shard_id: int) -> bool:
        return self.policy.contains(shard_id)

    def touch(self, shard_id: int) -> bool:
        """Record a shard read; returns True if it was served locally."""
        hit = self.policy.request(shard_id)
        self.stats.touches += 1
        self.stats.local_hits += int(hit)
        self.stats.fetches += int(not hit)
        return hit
