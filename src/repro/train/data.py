"""Deterministic sharded synthetic data pipeline.

Produces reproducible token streams (per-step PRF seeded by (run_seed, step,
shard)) so training is bit-reproducible across restarts — the property the
checkpoint/resume test asserts.  The pipeline also exposes a *cursor* that is
checkpointed with the model.

The token distribution is a Zipf mixture with local n-gram structure so the
loss actually decreases (pure uniform tokens have no learnable signal).

Beyond-paper tie-in (DESIGN.md §4.3): an OGB fractional cache instance scores
dataset *shards* for local-disk residency; the pipeline consults it to decide
which shards to "prefetch" (simulated).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np



@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard_id: int = 0
    zipf_alpha: float = 1.1


class SyntheticLM:
    """Markov-ish synthetic language: next token depends on current token."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse transition structure: each token has a few likely successors
        self._succ = rng.integers(0, v, size=(v, 4))
        w = 1.0 / np.power(np.arange(1, v + 1), cfg.zipf_alpha)
        self._base_p = w / w.sum()

    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, d: Dict) -> None:
        self.step = int(d["step"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b_local = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + self.step) * 97 + cfg.shard_id
        )
        toks = np.empty((b_local, cfg.seq_len + 1), np.int32)
        cur = rng.choice(cfg.vocab_size, size=b_local, p=self._base_p)
        toks[:, 0] = cur
        for t in range(1, cfg.seq_len + 1):
            use_markov = rng.random(b_local) < 0.75
            succ_pick = self._succ[cur, rng.integers(0, 4, size=b_local)]
            fresh = rng.choice(cfg.vocab_size, size=b_local, p=self._base_p)
            cur = np.where(use_markov, succ_pick, fresh).astype(np.int32)
            toks[:, t] = cur
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
