"""Batched fractional OGB_cl in pure JAX — the TPU data-plane form.

Per batch of B requests over a catalog of N items (paper Eq. 2 / §5.3):

    counts = histogram(request_ids)           # the summed gradient
    y      = f + eta * counts                 # ascent step
    tau    = root of sum(clip(y - tau, 0, 1)) = C     (capped-simplex proj.)
    f'     = clip(y - tau, 0, 1)

Everything is element-wise over the catalog except the scalar root-find, which
is K bisection iterations each needing one global sum — this is the structure
the Pallas kernel (repro.kernels.capped_simplex) fuses and the shard_map
version (repro.jaxcache.sharded) distributes with one psum per iteration.

`jnp.float32` is sufficient: tau only needs ~1e-7 relative accuracy for the
sampling decisions downstream (validated against the float64 numpy oracle).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_BISECT_ITERS = 50
DEFAULT_WARM_SWEEPS = 5


def request_counts(ids: jax.Array, catalog_size: int) -> jax.Array:
    """Histogram of request ids — the batch gradient (one-hot sum)."""
    return jnp.zeros(catalog_size, jnp.float32).at[ids].add(1.0)


def warm_bracket_hi(step_mass) -> jax.Array:
    """Upper bracket for the warm projection of y = f + (gradient step).

    ``step_mass`` is the total gradient mass added this step (eta * B for a
    B-request batch, or eta * sum(counts) in general).  For a *feasible*
    pre-step f the threshold satisfies 0 <= tau <= step_mass; the small
    relative + absolute slack absorbs float32 rounding of the mass sums.
    This is the single definition of that invariant — every warm path
    (scan replay, per-batch, sharded, Pallas) must use it.
    """
    return jnp.float32(step_mass) * (1.0 + 1e-5) + 1e-7


def capped_simplex_project(
    y: jax.Array,
    capacity: float,
    iters: int = DEFAULT_BISECT_ITERS,
    lo: Optional[jax.Array] = None,
    hi: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Bisection projection onto {f in [0,1]^N : sum f = C}. Returns (f, tau).

    ``lo``/``hi`` override the cold bracket [min(y)-1, max(y)].  When the step
    comes from an OGB update (y = f + eta*counts with f already feasible) the
    threshold provably lies in [0, eta*sum(counts)], a far tighter bracket.
    """
    if lo is None:
        lo = jnp.min(y) - 1.0
    if hi is None:
        hi = jnp.max(y)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        mass = jnp.sum(jnp.clip(y - mid, 0.0, 1.0))
        too_much = mass >= capacity
        return jnp.where(too_much, mid, lo), jnp.where(too_much, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    return jnp.clip(y - tau, 0.0, 1.0), tau


def capped_simplex_project_warm(
    y: jax.Array,
    capacity: float,
    lo: jax.Array,
    hi: jax.Array,
    tau0: jax.Array,
    sweeps: int = DEFAULT_WARM_SWEEPS,
) -> Tuple[jax.Array, jax.Array]:
    """Warm-started projection: bracketed Newton on the piecewise-linear g.

    g(tau) = sum(clip(y - tau, 0, 1)) is non-increasing and piecewise linear
    with slope -#{i : 0 < y_i - tau < 1}.  Each sweep evaluates (mass,
    interior count) in one catalog pass, shrinks the bracket, and proposes the
    Newton point ``tau + (g - C) / count`` (exact whenever the remaining
    bracket contains no clip breakpoint), safeguarded by the bisection
    midpoint.  ``sweeps`` single-digit passes match ~50 cold bisection sweeps.

    Requires a valid bracket g(lo) >= C >= g(hi); for an OGB step
    (y = f + eta*counts, f feasible) lo=0, hi=eta*sum(counts) always works,
    and ``tau0`` = previous step's tau is an excellent seed because the
    cumulative threshold rho_t = sum_s tau_s drifts slowly (it is monotone
    non-decreasing, with per-step increment tau_t in that same bracket).
    """
    cap = jnp.float32(capacity)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    t = jnp.clip(jnp.asarray(tau0, jnp.float32), lo, hi)

    # y is fixed across sweeps: pad once to a block multiple so each sweep is
    # a single blocked traversal.  -inf pads contribute 0 mass / 0 count for
    # any threshold.
    block = 64
    yr = y.ravel()
    pad = (-yr.shape[0]) % block
    if pad:
        yr = jnp.pad(yr, (0, pad), constant_values=-jnp.inf)
    yb = yr.reshape(-1, block)

    def body(_, carry):
        lo, hi, t = carry
        # one catalog traversal: a variadic per-block reduce yields mass and
        # interior count together (two separate jnp.sums cost ~5x more on
        # CPU), and the pairwise jnp.sum over block partials keeps the
        # accumulation error at pairwise-summation level
        clipped = jnp.clip(yb - t, 0.0, 1.0)
        interior = jnp.logical_and(clipped > 0.0, clipped < 1.0).astype(
            jnp.float32
        )
        pm, pc = jax.lax.reduce(
            (clipped, interior),
            (jnp.float32(0.0), jnp.float32(0.0)),
            lambda a, b: (a[0] + b[0], a[1] + b[1]),
            (1,),
        )
        mass = jnp.sum(pm)
        cnt = jnp.sum(pc)
        too_much = mass >= cap
        lo = jnp.where(too_much, t, lo)
        hi = jnp.where(too_much, hi, t)
        t_newton = t + (mass - cap) / jnp.maximum(cnt, 1.0)
        t_mid = 0.5 * (lo + hi)
        ok = jnp.logical_and(cnt > 0.0, jnp.logical_and(t_newton >= lo, t_newton <= hi))
        return lo, hi, jnp.where(ok, t_newton, t_mid)

    _lo, _hi, tau = jax.lax.fori_loop(0, sweeps, body, (lo, hi, t))
    return jnp.clip(y - tau, 0.0, 1.0), tau


class FractionalState(NamedTuple):
    """Catalog-wide fractional cache state (the data-plane state)."""

    f: jax.Array  # (N,) float32, in the capped simplex
    step: jax.Array  # () int32

    @staticmethod
    def create(catalog_size: int, capacity: int) -> "FractionalState":
        f0 = jnp.full(catalog_size, capacity / catalog_size, jnp.float32)
        return FractionalState(f=f0, step=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("capacity", "iters"))
def ogb_batch_update(
    state: FractionalState,
    request_ids: jax.Array,  # (B,) int32
    eta: jax.Array,
    capacity: int,
    iters: int = DEFAULT_BISECT_ITERS,
) -> Tuple[FractionalState, jax.Array]:
    """One batched OGB_cl step. Returns (new_state, fractional_reward).

    Reward is sum_t f[r_t] evaluated at the *pre-update* state (OCO order).
    """
    reward = jnp.sum(state.f[request_ids])
    counts = request_counts(request_ids, state.f.shape[0])
    y = state.f + eta * counts
    f_new, _tau = capped_simplex_project(y, float(capacity), iters)
    return FractionalState(f=f_new, step=state.step + 1), reward


@functools.partial(jax.jit, static_argnames=("capacity", "sweeps"))
def ogb_batch_update_warm(
    state: FractionalState,
    request_ids: jax.Array,  # (B,) int32
    eta: jax.Array,
    capacity: int,
    tau_prev: jax.Array,
    sweeps: int = DEFAULT_WARM_SWEEPS,
) -> Tuple[FractionalState, jax.Array, jax.Array]:
    """`ogb_batch_update` with the warm-started projection.

    Returns (new_state, fractional_reward, tau) so the caller can thread tau
    into the next step.  Because ``state.f`` is feasible, the new threshold
    lies in [0, eta * B] — the provable warm bracket (see
    :func:`capped_simplex_project_warm`).
    """
    reward = jnp.sum(state.f[request_ids])
    counts = request_counts(request_ids, state.f.shape[0])
    y = state.f + eta * counts
    hi = warm_bracket_hi(eta * jnp.float32(request_ids.shape[0]))
    f_new, tau = capped_simplex_project_warm(
        y, float(capacity), jnp.float32(0.0), hi, tau_prev, sweeps
    )
    return FractionalState(f=f_new, step=state.step + 1), reward, tau


@functools.partial(jax.jit, static_argnames=("capacity",))
def poisson_sample(
    f: jax.Array, p: jax.Array, capacity: int
) -> jax.Array:
    """Coordinated Poisson sample: x_i = (f_i >= p_i); E[sum x] = C."""
    del capacity  # soft constraint: capacity is implied by sum(f)
    return (f >= p).astype(jnp.bool_)


def permanent_random_numbers(key: jax.Array, catalog_size: int) -> jax.Array:
    """The p_i of §5.1 (drawn once; may be re-drawn periodically)."""
    return jax.random.uniform(key, (catalog_size,), jnp.float32)


@functools.partial(jax.jit, static_argnames=("capacity",))
def madow_sample_jax(f: jax.Array, u: jax.Array, capacity: int) -> jax.Array:
    """Madow systematic sampling in JAX: exactly C items, P(i) = f_i.

    Returns a bool mask. Used by the hard-capacity serving configurations.
    """
    cum = jnp.cumsum(f)
    # item i selected iff some threshold u+k falls in (cum[i-1], cum[i]]
    lower = jnp.concatenate([jnp.zeros(1, f.dtype), cum[:-1]])
    # number of thresholds <= x is floor(x - u) + 1 for x >= u
    n_below = lambda x: jnp.floor(x - u + 1.0)
    sel = n_below(cum) - n_below(lower)
    return sel >= 1.0


def fractional_hit_ratio(
    state: FractionalState, request_ids: jax.Array
) -> jax.Array:
    return jnp.mean(state.f[request_ids])
