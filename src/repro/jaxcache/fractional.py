"""Batched fractional OGB_cl in pure JAX — the TPU data-plane form.

Per batch of B requests over a catalog of N items (paper Eq. 2 / §5.3):

    counts = histogram(request_ids)           # the summed gradient
    y      = f + eta * counts                 # ascent step
    tau    = root of sum(clip(y - tau, 0, 1)) = C     (capped-simplex proj.)
    f'     = clip(y - tau, 0, 1)

Everything is element-wise over the catalog except the scalar root-find, which
is K bisection iterations each needing one global sum — this is the structure
the Pallas kernel (repro.kernels.capped_simplex) fuses and the shard_map
version (repro.jaxcache.sharded) distributes with one psum per iteration.

`jnp.float32` is sufficient: tau only needs ~1e-7 relative accuracy for the
sampling decisions downstream (validated against the float64 numpy oracle).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_BISECT_ITERS = 50


def request_counts(ids: jax.Array, catalog_size: int) -> jax.Array:
    """Histogram of request ids — the batch gradient (one-hot sum)."""
    return jnp.zeros(catalog_size, jnp.float32).at[ids].add(1.0)


def capped_simplex_project(
    y: jax.Array, capacity: float, iters: int = DEFAULT_BISECT_ITERS
) -> Tuple[jax.Array, jax.Array]:
    """Bisection projection onto {f in [0,1]^N : sum f = C}. Returns (f, tau)."""
    lo = jnp.min(y) - 1.0
    hi = jnp.max(y)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        mass = jnp.sum(jnp.clip(y - mid, 0.0, 1.0))
        too_much = mass >= capacity
        return jnp.where(too_much, mid, lo), jnp.where(too_much, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    return jnp.clip(y - tau, 0.0, 1.0), tau


class FractionalState(NamedTuple):
    """Catalog-wide fractional cache state (the data-plane state)."""

    f: jax.Array  # (N,) float32, in the capped simplex
    step: jax.Array  # () int32

    @staticmethod
    def create(catalog_size: int, capacity: int) -> "FractionalState":
        f0 = jnp.full(catalog_size, capacity / catalog_size, jnp.float32)
        return FractionalState(f=f0, step=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("capacity", "iters"))
def ogb_batch_update(
    state: FractionalState,
    request_ids: jax.Array,  # (B,) int32
    eta: jax.Array,
    capacity: int,
    iters: int = DEFAULT_BISECT_ITERS,
) -> Tuple[FractionalState, jax.Array]:
    """One batched OGB_cl step. Returns (new_state, fractional_reward).

    Reward is sum_t f[r_t] evaluated at the *pre-update* state (OCO order).
    """
    reward = jnp.sum(state.f[request_ids])
    counts = request_counts(request_ids, state.f.shape[0])
    y = state.f + eta * counts
    f_new, _tau = capped_simplex_project(y, float(capacity), iters)
    return FractionalState(f=f_new, step=state.step + 1), reward


@functools.partial(jax.jit, static_argnames=("capacity",))
def poisson_sample(
    f: jax.Array, p: jax.Array, capacity: int
) -> jax.Array:
    """Coordinated Poisson sample: x_i = (f_i >= p_i); E[sum x] = C."""
    del capacity  # soft constraint: capacity is implied by sum(f)
    return (f >= p).astype(jnp.bool_)


def permanent_random_numbers(key: jax.Array, catalog_size: int) -> jax.Array:
    """The p_i of §5.1 (drawn once; may be re-drawn periodically)."""
    return jax.random.uniform(key, (catalog_size,), jnp.float32)


@functools.partial(jax.jit, static_argnames=("capacity",))
def madow_sample_jax(f: jax.Array, u: jax.Array, capacity: int) -> jax.Array:
    """Madow systematic sampling in JAX: exactly C items, P(i) = f_i.

    Returns a bool mask. Used by the hard-capacity serving configurations.
    """
    cum = jnp.cumsum(f)
    # item i selected iff some threshold u+k falls in (cum[i-1], cum[i]]
    lower = jnp.concatenate([jnp.zeros(1, f.dtype), cum[:-1]])
    # number of thresholds <= x is floor(x - u) + 1 for x >= u
    n_below = lambda x: jnp.floor(x - u + 1.0)
    sel = n_below(cum) - n_below(lower)
    return sel >= 1.0


def fractional_hit_ratio(
    state: FractionalState, request_ids: jax.Array
) -> jax.Array:
    return jnp.mean(state.f[request_ids])
