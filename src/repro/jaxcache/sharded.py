"""Catalog-sharded OGB across a TPU mesh (shard_map + one psum per iteration).

The fractional cache state ``f`` (catalog of N items) is sharded across every
mesh axis; request batches are replicated (single logical cache) or sharded
over ``pod`` with a cross-pod count reduction.  Each bisection iteration of
the capped-simplex projection needs exactly one scalar ``psum`` — everything
else is local to the shard, so the step is bandwidth-bound on the catalog
sweep and scales to catalogs of 10^9+ items across pods.

Also provides the *cache-fleet* form: E independent edge caches sharded over
the ``data`` axis, each with the catalog sharded over ``model`` — the
deployment shape for a CDN fleet.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .fractional import DEFAULT_BISECT_ITERS, DEFAULT_WARM_SWEEPS, warm_bracket_hi

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary only exists on newer jax; older versions need check_rep=False instead
_pvary = getattr(jax.lax, "pvary", None)
_HAVE_PVARY = _pvary is not None


def _mark_varying(x, axes):
    return _pvary(x, axes) if _HAVE_PVARY else x


def _shard_map_relaxed(fn, *, mesh, in_specs, out_specs):
    """shard_map without replication checking on jax versions lacking pvary."""
    if _HAVE_PVARY:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def _local_histogram(
    ids: jax.Array, shard_size: int, offset: jax.Array
) -> jax.Array:
    """Histogram of the ids that fall inside [offset, offset+shard_size)."""
    local = ids - offset
    inb = (local >= 0) & (local < shard_size)
    idx = jnp.where(inb, local, 0)
    return jnp.zeros(shard_size, jnp.float32).at[idx].add(inb.astype(jnp.float32))


def make_sharded_step(
    mesh: Mesh,
    catalog_size: int,
    capacity: int,
    batch: int,
    eta: float,
    iters: int = DEFAULT_BISECT_ITERS,
    pod_axis: Optional[str] = None,
    warm_start: bool = False,
    sweeps: int = DEFAULT_WARM_SWEEPS,
):
    """Build the jitted sharded OGB step for `mesh`.

    Returns (step_fn, f_sharding) where step_fn(f, ids) -> (f', reward).
    ``f`` is (N,) sharded over every mesh axis; ``ids`` is (B,) replicated
    (or (B,) globally with pod-sharding when ``pod_axis`` is given).

    With ``warm_start=True`` the step becomes
    ``step_fn(f, ids, tau_prev) -> (f', reward, tau)``: the projection uses
    the provable warm bracket [0, eta*B] seeded at ``tau_prev`` and a
    bracketed-Newton iteration (one psum of the stacked (mass, interior-count)
    pair per sweep), so ``sweeps`` single-digit catalog sweeps replace
    ``iters`` ~50 bisection sweeps — one psum saved per sweep avoided.
    """
    axes = tuple(mesh.axis_names)
    n_dev = mesh.size
    if catalog_size % n_dev:
        raise ValueError(f"catalog {catalog_size} must divide devices {n_dev}")
    shard_size = catalog_size // n_dev
    f_spec = P(axes)  # (N,) sharded over the flattened device grid
    ids_spec = P(pod_axis) if pod_axis else P()
    eta_f = jnp.float32(eta)
    cap = float(capacity)

    def _local_prologue(f_local: jax.Array, ids: jax.Array):
        if pod_axis is not None:
            # each pod ingests its own request slice; the catalog range owned
            # by a device is globally unique, so every device must see every
            # id — one cheap DCN all-gather of the (B/pods,) int32 ids.
            ids = jax.lax.all_gather(ids, pod_axis, tiled=True)

        # flattened linear device index = position of this shard in f
        dev_linear = jnp.zeros((), jnp.int32)
        stride = 1
        for ax in reversed(axes):
            dev_linear = dev_linear + jax.lax.axis_index(ax) * stride
            stride *= mesh.shape[ax]
        offset = dev_linear * shard_size

        counts = _local_histogram(ids, shard_size, offset)

        # reward = sum_t f[r_t] at the pre-update state (only in-range ids)
        local = ids - offset
        inb = (local >= 0) & (local < shard_size)
        reward = jnp.sum(
            jnp.where(inb, f_local[jnp.where(inb, local, 0)], 0.0)
        )
        reward = jax.lax.psum(reward, axes)
        return f_local + eta_f * counts, reward

    def local_step(f_local: jax.Array, ids: jax.Array):
        y, reward = _local_prologue(f_local, ids)

        lo = jnp.float32(0.0)
        hi = jnp.float32(1.0) + eta_f * jnp.float32(batch)

        def body(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            mass = jax.lax.psum(jnp.sum(jnp.clip(y - mid, 0.0, 1.0)), axes)
            too_much = mass >= cap
            return jnp.where(too_much, mid, lo), jnp.where(too_much, hi, mid)

        lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
        tau = 0.5 * (lo + hi)
        return jnp.clip(y - tau, 0.0, 1.0), reward

    def local_step_warm(f_local: jax.Array, ids: jax.Array, tau_prev: jax.Array):
        y, reward = _local_prologue(f_local, ids)

        # provable per-step bracket for a feasible f: tau in [0, eta*B];
        # the carries stay replicated (mass/cnt are psum'd over every axis)
        lo = jnp.float32(0.0)
        hi = warm_bracket_hi(eta_f * jnp.float32(batch))
        t = jnp.clip(tau_prev, lo, hi)

        def body(_, carry):
            lo, hi, t = carry
            z = y - t
            part = jnp.stack(
                [
                    jnp.sum(jnp.clip(z, 0.0, 1.0)),
                    jnp.sum(
                        jnp.logical_and(z > 0.0, z < 1.0).astype(jnp.float32)
                    ),
                ]
            )
            mass, cnt = jax.lax.psum(part, axes)  # one psum per sweep
            too_much = mass >= cap
            lo = jnp.where(too_much, t, lo)
            hi = jnp.where(too_much, hi, t)
            t_newton = t + (mass - cap) / jnp.maximum(cnt, 1.0)
            t_mid = 0.5 * (lo + hi)
            ok = jnp.logical_and(
                cnt > 0.0, jnp.logical_and(t_newton >= lo, t_newton <= hi)
            )
            return lo, hi, jnp.where(ok, t_newton, t_mid)

        _lo, _hi, tau = jax.lax.fori_loop(0, sweeps, body, (lo, hi, t))
        return jnp.clip(y - tau, 0.0, 1.0), reward, tau

    if warm_start:
        shard_fn = _shard_map_relaxed(
            local_step_warm,
            mesh=mesh,
            in_specs=(f_spec, ids_spec, P()),
            out_specs=(f_spec, P(), P()),
        )
    else:
        shard_fn = _shard_map_relaxed(
            local_step,
            mesh=mesh,
            in_specs=(f_spec, ids_spec),
            out_specs=(f_spec, P()),
        )
    step = jax.jit(shard_fn)
    f_sharding = NamedSharding(mesh, f_spec)
    return step, f_sharding


def _per_cache_param(value, n_caches: int, name: str) -> jax.Array:
    """Normalize a scalar or (E,) per-cache parameter to an (E,) f32 array."""
    arr = jnp.asarray(value, jnp.float32)
    if arr.ndim == 0:
        return jnp.full((n_caches,), arr)
    if arr.shape != (n_caches,):
        raise ValueError(
            f"{name} must be a scalar or an ({n_caches},) array, got shape "
            f"{arr.shape}"
        )
    return arr


def make_fleet_step(
    mesh: Mesh,
    n_caches: int,
    catalog_size: int,
    capacity,
    batch: int,
    eta,
    iters: int = DEFAULT_BISECT_ITERS,
    cache_axis: str = "data",
    catalog_axis: str = "model",
    warm_start: bool = False,
    sweeps: int = DEFAULT_WARM_SWEEPS,
):
    """E independent edge caches: f (E, N), ids (E, B). Per-cache projection.

    Caches shard over ``cache_axis``; the catalog dimension shards over
    ``catalog_axis``; the projection psum reduces over the catalog axis only,
    so caches never synchronize with each other (embarrassingly parallel
    across the fleet, as a real CDN deployment would be).

    ``eta`` and ``capacity`` may each be a scalar (one value for the whole
    fleet) or an ``(E,)`` array (heterogeneous edge nodes).  Scalars are
    broadcast to ``(E,)`` internally, which is bitwise identical to the old
    scalar-only path.

    With ``warm_start=True`` the step becomes
    ``step(f, ids, tau_prev) -> (f', reward, tau)`` with ``tau_prev``/``tau``
    of shape ``(E,)``: each cache's projection runs the bracketed-Newton
    iteration inside the provable warm bracket [0, eta_e*B], with a single
    psum of the stacked per-cache (mass, interior-count) pair per sweep —
    ``sweeps`` single-digit catalog sweeps instead of ``iters`` ~50 cold
    bisection sweeps, and half the psums per sweep.  The fourth return value
    is the (E,) tau sharding.
    """
    if n_caches % mesh.shape[cache_axis]:
        raise ValueError("n_caches must divide the cache axis")
    if catalog_size % mesh.shape[catalog_axis]:
        raise ValueError("catalog must divide the catalog axis")
    shard_n = catalog_size // mesh.shape[catalog_axis]
    eta_all = _per_cache_param(eta, n_caches, "eta")
    cap_all = _per_cache_param(capacity, n_caches, "capacity")

    def _prologue(f_local: jax.Array, ids_local: jax.Array, eta_c: jax.Array):
        # f_local: (E_loc, N_loc); ids_local: (E_loc, B); eta_c: (E_loc,)
        offset = jax.lax.axis_index(catalog_axis) * shard_n

        def counts_and_reward(f_c, ids_c):
            local = ids_c - offset
            inb = (local >= 0) & (local < shard_n)
            idx = jnp.where(inb, local, 0)
            counts = jnp.zeros(shard_n, jnp.float32).at[idx].add(
                inb.astype(jnp.float32)
            )
            reward_part = jnp.sum(jnp.where(inb, f_c[idx], 0.0))
            return counts, reward_part

        counts, reward_part = jax.vmap(counts_and_reward)(f_local, ids_local)
        reward = jax.lax.psum(reward_part, catalog_axis)  # (E_loc,)
        y = f_local + eta_c[:, None] * counts  # (E_loc, N_loc)
        return y, reward

    def local_step(
        f_local: jax.Array,
        ids_local: jax.Array,
        eta_c: jax.Array,
        cap_c: jax.Array,
    ):
        y, reward = _prologue(f_local, ids_local, eta_c)
        lo = jnp.zeros_like(eta_c)
        hi = 1.0 + eta_c * jnp.float32(ids_local.shape[1])
        # mark the carries as varying over the cache axis (their updates
        # depend on f, which is sharded over it)
        lo = _mark_varying(lo, (cache_axis,))
        hi = _mark_varying(hi, (cache_axis,))

        def body(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            mass = jax.lax.psum(
                jnp.sum(jnp.clip(y - mid[:, None], 0.0, 1.0), axis=1),
                catalog_axis,
            )
            pred = mass >= cap_c
            return jnp.where(pred, mid, lo), jnp.where(pred, hi, mid)

        lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
        tau = 0.5 * (lo + hi)
        return jnp.clip(y - tau[:, None], 0.0, 1.0), reward

    def local_step_warm(
        f_local: jax.Array,
        ids_local: jax.Array,
        tau_prev: jax.Array,
        eta_c: jax.Array,
        cap_c: jax.Array,
    ):
        y, reward = _prologue(f_local, ids_local, eta_c)
        # provable per-cache bracket for a feasible f: tau_e in [0, eta_e*B]
        lo = _mark_varying(jnp.zeros_like(eta_c), (cache_axis,))
        hi = warm_bracket_hi(eta_c * jnp.float32(ids_local.shape[1]))
        t = jnp.clip(tau_prev, lo, hi)

        def body(_, carry):
            lo, hi, t = carry
            z = y - t[:, None]
            part = jnp.stack(
                [
                    jnp.sum(jnp.clip(z, 0.0, 1.0), axis=1),
                    jnp.sum(
                        jnp.logical_and(z > 0.0, z < 1.0).astype(jnp.float32),
                        axis=1,
                    ),
                ]
            )  # (2, E_loc)
            mass, cnt = jax.lax.psum(part, catalog_axis)  # one psum per sweep
            too_much = mass >= cap_c
            lo = jnp.where(too_much, t, lo)
            hi = jnp.where(too_much, hi, t)
            t_newton = t + (mass - cap_c) / jnp.maximum(cnt, 1.0)
            t_mid = 0.5 * (lo + hi)
            ok = jnp.logical_and(
                cnt > 0.0, jnp.logical_and(t_newton >= lo, t_newton <= hi)
            )
            return lo, hi, jnp.where(ok, t_newton, t_mid)

        _lo, _hi, tau = jax.lax.fori_loop(0, sweeps, body, (lo, hi, t))
        return jnp.clip(y - tau[:, None], 0.0, 1.0), reward, tau

    f_spec = P(cache_axis, catalog_axis)
    ids_spec = P(cache_axis, None)
    par_spec = P(cache_axis)  # per-cache params slice with their cache

    if warm_start:
        shard_fn = _shard_map_relaxed(
            local_step_warm,
            mesh=mesh,
            in_specs=(f_spec, ids_spec, par_spec, par_spec, par_spec),
            out_specs=(f_spec, par_spec, par_spec),
        )

        def step_warm(f, ids, tau_prev):
            return shard_fn(f, ids, tau_prev, eta_all, cap_all)

        return (
            jax.jit(step_warm),
            NamedSharding(mesh, f_spec),
            NamedSharding(mesh, ids_spec),
            NamedSharding(mesh, par_spec),
        )

    shard_fn = _shard_map_relaxed(
        local_step,
        mesh=mesh,
        in_specs=(f_spec, ids_spec, par_spec, par_spec),
        out_specs=(f_spec, par_spec),
    )

    def step_cold(f, ids):
        return shard_fn(f, ids, eta_all, cap_all)

    step = jax.jit(step_cold)
    return step, NamedSharding(mesh, f_spec), NamedSharding(mesh, ids_spec)
