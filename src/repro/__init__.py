"""Public surface of the OGB caching reproduction.

The supported entry points live here so examples and docs can say::

    from repro import policy_def, run, sweep

    result = run(policy_def("ogb"), trace, catalog_size, capacity, window=1000)

Everything is re-exported lazily (resolving an attribute imports the owning
module on first use), so ``import repro`` stays cheap and the config/model
subpackages never pull JAX-heavy cachesim code they don't need.
"""

from __future__ import annotations

import importlib

__version__ = "0.1.0"

#: attribute name -> owning module (resolved lazily via module __getattr__)
_LAZY = {
    # the policy protocol + the one execution layer
    "PolicyDef": "repro.cachesim.api",
    "StepOut": "repro.cachesim.api",
    "policy_def": "repro.cachesim.api",
    "policy_def_kinds": "repro.cachesim.api",
    "register_policy_def": "repro.cachesim.api",
    "run": "repro.cachesim.api",
    "sweep": "repro.cachesim.api",
    # result views
    "RunResult": "repro.cachesim.results",
    "StreamResult": "repro.cachesim.results",
    "SweepResult": "repro.cachesim.results",
    "FleetResult": "repro.cachesim.results",
    "EdgeFleetResult": "repro.cachesim.results",
    # multi-tenant fleet replay (vmapped per-tenant caches)
    "run_fleet": "repro.cachesim.fleet",
    "run_fleet_stream": "repro.cachesim.fleet",
    "run_edge_fleet": "repro.cachesim.fleet",
    "run_edge_fleet_scenario": "repro.cachesim.fleet",
    # tracelab: trace-file ingestion + out-of-core streaming replay
    "CatalogRemap": "repro.cachesim.tracelab",
    "TraceProfile": "repro.cachesim.tracelab",
    "fit_profile": "repro.cachesim.tracelab",
    "load_trace": "repro.cachesim.tracelab",
    "open_trace": "repro.cachesim.tracelab",
    "run_stream": "repro.cachesim.tracelab",
    "StreamFault": "repro.cachesim.tracelab",
    "synthesize": "repro.cachesim.tracelab",
    "synthesize_chunks": "repro.cachesim.tracelab",
    "synthesize_sizes": "repro.cachesim.tracelab",
    "tenant_streams": "repro.cachesim.tracelab",
    "write_trace": "repro.cachesim.tracelab",
    # host-side policies (the slow exact oracles) + per-request simulator
    "make_policy": "repro.core.policies",
    "policy_kinds": "repro.core.policies",
    "simulate": "repro.cachesim.simulator",
    "compare": "repro.cachesim.simulator",
    # named experiment scenarios and trace families
    "SCENARIOS": "repro.cachesim.scenarios",
    "EDGE_FLEET_SCENARIOS": "repro.cachesim.scenarios",
    "get_edge_fleet_scenario": "repro.cachesim.scenarios",
    "get_scenario": "repro.cachesim.scenarios",
    "run_scenario": "repro.cachesim.scenarios",
    "make_trace": "repro.cachesim.traces",
}

__all__ = ["__version__", *sorted(_LAZY)]


def __getattr__(name: str):
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value  # cache: next access skips this hook
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
