"""Tracelab walkthrough: ingest a raw trace file, replay it out-of-core.

Covers the full path from a log file on disk to paper-style numbers:

1. write a CDN-style log with sparse raw ids (stand-in for a real trace),
2. stream it back in chunks (never materializing the file),
3. densify the ids on the fly with ``CatalogRemap`` (first-seen order),
4. replay OGB and LRU through ``run_stream`` — fixed memory, with the
   windowed time-varying-OPT ("dynamic regret") comparator,
5. fit a ``TraceProfile`` on the ingested trace and synthesize a 10x
   longer stats-matched stream, replayed the same way.

    PYTHONPATH=src python examples/ingest_replay.py
"""

import os
import tempfile

import numpy as np

from repro import make_trace, policy_def
from repro.cachesim.tracelab import (
    CatalogRemap,
    fit_profile,
    load_trace,
    open_trace,
    run_stream,
    synthesize_chunks,
    write_trace,
)


def main():
    T, N_RAW = 200_000, 20_000

    with tempfile.TemporaryDirectory() as workdir:
        # --- 1. a "real" log: bursty (twitter-like) traffic under sparse
        # 64-bit raw ids, written as whitespace `timestamp id size` lines
        dense_source = make_trace(
            "bursty", N_RAW, T, seed=0,
            burst_fraction=0.5, burst_len_mean=8.0, burst_span=60,
        )
        raw_ids = dense_source * 977_771 + 13  # sparse, gappy id space
        path = write_trace(os.path.join(workdir, "requests.log"), raw_ids)
        print(f"wrote {path} ({os.path.getsize(path) / 1e6:.1f} MB, "
              f"T={T}, {len(np.unique(raw_ids))} distinct raw ids)")

        # --- 2+3. stream it back, densifying ids chunk by chunk
        n_seen = len(np.unique(raw_ids))  # in practice: from a catalog pass
        capacity = n_seen // 20

        # --- 4. out-of-core replay: OGB (fractional) and LRU (automaton)
        print(f"\nreplaying N={n_seen} C={capacity} out-of-core:")
        for kind, window in (("ogb", 1_000), ("lru", 10_000)):
            chunks = CatalogRemap().remap(
                open_trace(path, chunk_size=20_000)
            )
            res = run_stream(
                policy_def(kind), chunks, n_seen, capacity,
                window=window, horizon=T, opt_window=T // 10,
            )
            ratios = " ".join(f"{r:.3f}" for r in res.dyn_opt_ratio())
            print(f"  {res.name:>4}: hit={res.hit_ratio:.4f}  "
                  f"dyn-OPT={res.dynamic_opt_total / res.T:.4f}  "
                  f"dyn-regret={res.dynamic_regret:9.1f}  "
                  f"{res.us_per_request:.2f}us/req  "
                  f"[{res.n_segments} segments]")
            if kind == "ogb":
                print(f"        windowed OPT ratio: {ratios}")

        # --- 5. fit the ingested trace, synthesize 10x more of it
        trace = CatalogRemap().apply(load_trace(path))
        profile = fit_profile(trace)
        print(f"\nfitted profile: oneshot={profile.oneshot_frac:.3f} "
              f"burst={profile.burst_frac:.3f} "
              f"drift_phase={profile.drift_phase}")
        t_long = 10 * T
        res = run_stream(
            policy_def("ogb"),
            synthesize_chunks(profile, t_long, catalog=n_seen, seed=1),
            n_seen, capacity, window=1_000, horizon=t_long,
        )
        print(f"synthesized 10x stream (T={t_long}): "
              f"OGB hit={res.hit_ratio:.4f}  {res.us_per_request:.2f}us/req "
              f"(trace never materialized)")


if __name__ == "__main__":
    main()
