"""End-to-end serving driver (the paper's kind: caching at the serving layer).

Serves a small LM with batched requests through the ServeEngine; the OGB
policy manages the prefix-page pool.  The workload interleaves a hot set of
system prompts with one-shot scans — the regime where LRU page pools thrash
and OGB's regret guarantee pays off.  Compares OGB vs LRU page pools on
identical request streams.

    PYTHONPATH=src python examples/serve_cached.py
"""

import numpy as np

import jax

from repro.configs.base import get_smoke
from repro.core.ogb import OGB
from repro.core.policies import LRU
from repro.models.model import init_params
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import PagedKVPool


def request_stream(rng, vocab, n_steps=150, batch=4, prompt_len=48):
    """Hot system-prompts + cold scans, batched.

    Every step serves 2 hot prompts and 2 one-shot scans; the scan pages
    (~12/step) exceed the pool over a few steps, so a recency policy keeps
    evicting the hot set — the paper's adversarial motif at the page level.
    """
    hot = [rng.integers(1, vocab, prompt_len) for _ in range(6)]
    for step in range(n_steps):
        batch_prompts = []
        for b in range(batch):
            if b < 2:
                batch_prompts.append(hot[(2 * step + b) % len(hot)])
            else:  # one-shot scan prompt
                batch_prompts.append(rng.integers(1, vocab, prompt_len))
        yield np.stack(batch_prompts).astype(np.int32)


def run(pool_policy_name: str, seed: int = 0):
    cfg = get_smoke("mistral-nemo-12b")
    params = init_params(cfg, jax.random.key(seed))
    C_pages = 24
    n_steps = 150
    # ~24 page touches per engine step (4 prompts x 6 pages)
    horizon_touches = n_steps * 24
    if pool_policy_name == "ogb":
        policy = OGB(catalog_size=1 << 16, capacity=C_pages,
                     horizon=horizon_touches, batch_size=24, seed=seed)
    else:
        policy = LRU(1 << 16, C_pages)
    pool = PagedKVPool(policy, page_size=8)
    engine = ServeEngine(cfg, params, pool=pool, max_len=64)

    rng = np.random.default_rng(seed)
    for prompts in request_stream(rng, cfg.vocab_size, n_steps=n_steps):
        engine.generate(prompts, max_new_tokens=4)
    return engine, pool


def main():
    print("serving a smoke-scale mistral-nemo with OGB vs LRU page pools\n")
    for name in ["ogb", "lru"]:
        engine, pool = run(name)
        s, p = engine.stats, pool.stats
        print(
            f"  {name.upper():>4} pool: prefix reuse {s.prefix_reuse:6.1%}   "
            f"page hits {p.page_hit_ratio:6.1%}   "
            f"decode tok {s.decode_tokens}   "
            f"prefill tok {s.prefill_tokens} (skipped {s.prefill_tokens_skipped})"
        )
    print("\nOGB keeps the hot system prompts resident through the scans;")
    print("its regret bound guarantees this for ANY request pattern.")


if __name__ == "__main__":
    main()
