"""Train a small LM for a few hundred steps on CPU (end-to-end driver).

Exercises the full training substrate: synthetic data pipeline, microbatched
train step, cosine schedule, async checkpointing with resume.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import create_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-14b")  # smoke variant is used
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    opt_cfg = OptimizerConfig(
        lr=3e-3, warmup_steps=20, total_steps=args.steps, clip_norm=1.0
    )
    state = create_train_state(cfg, opt_cfg, jax.random.key(0))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16, seed=0)
    )
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=2))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep_last=2)

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, start, extra = restore_checkpoint(args.ckpt_dir, state)
        data.load_state_dict(extra)
        print(f"resumed from step {start}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {step:>4}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  ({dt:.1f}s)"
            )
        if (step + 1) % 50 == 0:
            ckpt.save(step + 1, state, extra=data.state_dict())
    ckpt.wait()
    print("done; checkpoint in", args.ckpt_dir)


if __name__ == "__main__":
    main()
