"""Quickstart: the paper's policy in 40 lines.

Runs OGB against LRU/LFU/FTPL and the optimal static allocation on an
adversarial trace (paper Fig. 2) and on a stationary cdn-like trace; prints
hit ratios and the regret trajectory.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import adversarial, zipf
from repro.core import (
    FTPL,
    LFU,
    LRU,
    OGB,
    best_static_hits,
    regret_curve,
    theoretical_regret_bound,
)


def main():
    N, C, T = 2000, 500, 100_000

    for name, trace in {
        "adversarial (paper Fig.2)": adversarial(N, T, seed=0),
        "cdn-like zipf": zipf(N, T, alpha=0.9, seed=0),
    }.items():
        print(f"\n=== {name}:  N={N} C={C} T={T}")
        opt = best_static_hits(trace, C)
        print(f"  OPT (best static in hindsight): {opt / T:.4f}")
        for policy in [
            OGB(N, C, horizon=T),  # eta per Theorem 3.1
            FTPL(N, C, horizon=T),
            LRU(N, C),
            LFU(N, C),
        ]:
            res = simulate(policy, trace, window=T)
            reg = regret_curve(res.cum_hits, trace, C)
            print(
                f"  {policy.name:>5}: hit={res.hit_ratio:.4f}  "
                f"final regret={reg[-1]:>8d}  "
                f"(Thm 3.1 bound {theoretical_regret_bound(C, N, T):,.0f})  "
                f"{res.us_per_request:.1f}us/req"
            )


if __name__ == "__main__":
    main()
