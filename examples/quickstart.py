"""Quickstart: the paper's policy through the public API, in 40 lines.

Runs OGB against OMD/LRU/LFU/FTPL and the optimal static allocation on an
adversarial trace (paper Fig. 2) and on a stationary cdn-like trace — every
policy is an optax-style ``(init, step)`` PolicyDef replayed by the one
``repro.run`` engine (a single compiled ``lax.scan``).  Also demonstrates
the streaming-carry contract: resuming a replay chunk by chunk reproduces
the one-shot run bit for bit.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import make_trace, policy_def, run


def main():
    N, C, T, B = 2000, 500, 100_000, 500

    for name, trace in {
        "adversarial (paper Fig.2)": make_trace("adversarial", N, T, seed=0),
        "cdn-like zipf": make_trace("zipf", N, T, seed=0, alpha=0.9),
    }.items():
        print(f"\n=== {name}:  N={N} C={C} T={T}")
        for kind in ("ogb", "omd", "ftpl", "lru", "lfu"):
            pd = policy_def(kind)
            res = run(pd, trace, N, C, window=B, horizon=T)
            print(
                f"  {res.name:>5}: hit={res.hit_ratio:.4f}  "
                f"OPT={res.opt_hits / res.T:.4f}  "
                f"regret={res.integral_regret:>9.1f}  "
                f"{res.us_per_request:.2f}us/req"
            )

    # streaming: two chunked runs with a handed-off carry == one full run
    trace = make_trace("zipf", N, T, seed=1, alpha=0.9)
    full = run(policy_def("ogb"), trace, N, C, window=B, eta=0.01)
    first = run(policy_def("ogb"), trace[: T // 2], N, C, window=B, eta=0.01,
                track_opt=False)
    second = run(policy_def("ogb"), trace[T // 2 :], capacity=C, window=B,
                 carry=first.carry, track_opt=False)
    resumed = np.concatenate([first.hits, second.hits])
    assert np.array_equal(resumed, full.hits)
    print(f"\nstreamed replay == one-shot replay "
          f"({int(resumed.sum())} hits either way)")


if __name__ == "__main__":
    main()
