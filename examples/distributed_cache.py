"""Catalog-sharded OGB across (fake) devices — the datacenter-scale data plane.

Runs the batched fractional OGB with the catalog sharded over an 8-device
host mesh (the same shard_map program that the 512-chip dry-run lowers),
checks it against the single-device reference, and runs the CDN edge-fleet
variant (independent per-edge caches, catalog sharded across the model axis).

    PYTHONPATH=src python examples/distributed_cache.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.traces import shifting_zipf
from repro.core.ogb import theoretical_eta
from repro.jaxcache.fractional import FractionalState, ogb_batch_update
from repro.jaxcache.sharded import make_fleet_step, make_sharded_step


def main():
    N, C, B = 1 << 16, 4096, 2048
    T_batches = 40
    eta = theoretical_eta(C, N, T_batches * B, B)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"catalog N={N:,} C={C} sharded over {mesh.devices.size} devices")

    step, f_shard = make_sharded_step(mesh, N, C, B, eta)
    trace = shifting_zipf(N, T_batches * B, alpha=0.9, phase=B * 10, seed=0)

    f = jax.device_put(jnp.full((N,), C / N, jnp.float32), f_shard)
    state = FractionalState.create(N, C)  # single-device reference
    reward_sh = reward_ref = 0.0
    for i in range(T_batches):
        ids = jnp.asarray(trace[i * B : (i + 1) * B], jnp.int32)
        f, r = step(f, ids)
        reward_sh += float(r)
        state, rr = ogb_batch_update(state, ids, jnp.float32(eta), C)
        reward_ref += float(rr)
    drift = float(jnp.max(jnp.abs(f - state.f)))
    print(f"  sharded fractional hit ratio: {reward_sh / (T_batches * B):.4f}")
    print(f"  reference (1 device):         {reward_ref / (T_batches * B):.4f}")
    print(f"  max |f_sharded - f_ref|:      {drift:.2e}")
    assert drift < 1e-4

    # CDN edge fleet: 4 independent caches, catalog over the model axis
    E = 4
    fleet_step, f_sh, ids_sh = make_fleet_step(mesh, E, N, C, B, eta,
                                               cache_axis="data")
    ff = jax.device_put(jnp.full((E, N), C / N, jnp.float32), f_sh)
    rng = np.random.default_rng(1)
    total = 0.0
    for _ in range(10):
        ids = jnp.asarray(rng.integers(0, N, size=(E, B)), jnp.int32)
        ff, rewards = fleet_step(ff, jax.device_put(ids, ids_sh))
        total += float(jnp.sum(rewards))
    print(f"  fleet of {E} edge caches: mean fractional hit "
          f"{total / (10 * E * B):.4f} (uniform traffic -> ~C/N = {C/N:.4f})")


if __name__ == "__main__":
    main()
