"""ContinuousServingLoop under an injected clock + expert-cache swap books.

The open-loop serving loop is exercised with a fake clock/sleep pair so
latency arithmetic is deterministic: an underloaded server's per-request
latency is exactly the service time, an overloaded one builds backlog
linearly, and batching drains the backlog in ``batch_max`` gulps.  The
expert-cache half pins the swap accounting fix: ``swapped_in`` /
``swapped_out`` are the diff between consecutive Poisson residency masks
(not the hit count), ``hits`` is the requested-and-resident count, and
``bytes_per_expert`` scales churn into swap/resident byte telemetry.
"""

import numpy as np
import pytest

from repro.jaxcache.fractional import poisson_sample
from repro.serve.engine import ContinuousServingLoop, ServingSLO
from repro.serve.expert_cache import ExpertCacheConfig, OGBExpertCache


class FakeTime:
    """Deterministic clock: sleeps and explicit service-time advances."""

    def __init__(self):
        self.t = 0.0

    def clock(self):
        return self.t

    def sleep(self, dt):
        assert dt > 0
        self.t += dt

    def busy(self, dt):
        self.t += dt


def _loop(fake, decide, **kw):
    return ContinuousServingLoop(
        decide, clock=fake.clock, sleep=fake.sleep, **kw
    )


def test_underloaded_latency_is_the_service_time():
    """Service faster than the arrival gap: every request waits zero and
    pays exactly the decision time — p50 == p99 == service time."""
    fake = FakeTime()
    service = 0.002

    def decide(batch):
        assert len(batch) == 1
        fake.busy(service)

    slo = _loop(fake, decide).run(list(range(100)), rate=100.0)  # gap 10ms
    assert isinstance(slo, ServingSLO)
    assert slo.requests == 100 and slo.steps == 100
    np.testing.assert_allclose(slo.latencies_ms, 1e3 * service, rtol=1e-9)
    assert slo.p50_ms == pytest.approx(1e3 * service)
    assert slo.p99_ms == pytest.approx(1e3 * service)
    assert slo.backlog_max == 1
    # makespan: last arrival at 99/rate plus its own service
    assert slo.seconds == pytest.approx(99 / 100.0 + service)
    assert slo.req_per_sec == pytest.approx(100 / slo.seconds)


def test_overloaded_latency_grows_linearly():
    """Open-loop means a slow server cannot shed load: with service time
    2x the arrival gap, request i queues behind i unfinished peers and
    latency climbs linearly — the backlog is visible in the SLO."""
    fake = FakeTime()
    gap, service = 0.001, 0.002

    def decide(batch):
        fake.busy(service)

    n = 50
    slo = _loop(fake, decide).run(list(range(n)), rate=1.0 / gap)
    # request i is decided at (i+1)*service, arrived at i*gap
    expect = np.array([(i + 1) * service - i * gap for i in range(n)])
    np.testing.assert_allclose(slo.latencies_ms, 1e3 * expect, rtol=1e-9)
    assert slo.max_ms == pytest.approx(1e3 * expect[-1])
    assert slo.backlog_max > 1
    assert np.all(np.diff(slo.latencies_ms) > 0)


def test_batching_drains_backlog():
    """batch_max > 1 lets one decision cover the whole backlog, so an
    overloaded-per-decision server still keeps up per request."""
    fake = FakeTime()
    sizes = []

    def decide(batch):
        sizes.append(len(batch))
        fake.busy(0.004)

    slo = _loop(fake, decide, batch_max=8).run(list(range(64)), rate=1000.0)
    assert sum(sizes) == 64
    assert slo.steps == len(sizes) < 64  # batching actually happened
    assert max(sizes) > 1
    # with 4ms service and 1ms arrivals, steady-state batches reach 4+
    assert max(sizes) >= 4
    # bounded latency: the batch ahead plus own batch, not a linear climb
    assert slo.max_ms < 1e3 * (2 * 0.004 + 0.001)


def test_loop_rejects_bad_parameters():
    with pytest.raises(ValueError, match="batch_max"):
        ContinuousServingLoop(lambda b: None, batch_max=0)
    with pytest.raises(ValueError, match="rate"):
        ContinuousServingLoop(lambda b: None).run([1, 2], rate=0.0)


def _shift_counts(hot, shape=(2, 32)):
    counts = np.zeros(shape, np.float32)
    counts[:, hot] = 100.0
    return counts


def test_swap_accounting_is_the_residency_mask_diff():
    """Regression: per-step swapped_in/out must equal the element-wise
    diff of consecutive Poisson residency masks, and the byte telemetry
    must scale that churn by bytes_per_expert."""
    bpe = 7_340_032
    cfg = ExpertCacheConfig(
        n_layers=2, n_experts=32, resident_fraction=0.25,
        horizon_steps=100, bytes_per_expert=bpe,
    )
    ec = OGBExpertCache(cfg, seed=0)
    rng = np.random.default_rng(0)
    tot_in = tot_out = 0
    for step in range(60):
        hot = np.arange(8) if step < 30 else np.arange(16, 24)
        counts = _shift_counts(hot) + rng.random((2, 32), np.float32)
        prev = ec.resident.copy()
        stats = ec.step(counts)
        new = ec.resident
        assert stats["swapped_in"] == int(np.sum(new & ~prev))
        assert stats["swapped_out"] == int(np.sum(prev & ~new))
        # hits = requested-and-resident against the pre-step mask
        assert stats["hits"] == int(np.sum((counts.reshape(-1) > 0) & prev))
        assert stats["swap_bytes"] == (
            stats["swapped_in"] + stats["swapped_out"]
        ) * bpe
        assert stats["resident_bytes"] == int(np.sum(new)) * bpe
        assert 0.0 <= stats["resident_hit_ratio"] <= 1.0
        tot_in += stats["swapped_in"]
        tot_out += stats["swapped_out"]
    assert ec.swapped_in == tot_in and ec.swapped_out == tot_out
    # the mid-run routing shift must actually move experts
    assert tot_in > 0 and tot_out > 0
    # soft capacity: in/out churn stays balanced (occupancy is stable)
    assert abs(tot_in - tot_out) < ec.C


def test_resident_recompute_routes_through_poisson_sample():
    """The lazy ``resident`` property is the one residency rule: identical
    to calling poisson_sample on the carried state directly."""
    cfg = ExpertCacheConfig(
        n_layers=2, n_experts=16, resident_fraction=0.5, horizon_steps=50
    )
    ec = OGBExpertCache(cfg, seed=3)
    ec.step(np.ones((2, 16), np.float32))
    direct = np.asarray(poisson_sample(ec.carry.f, ec.carry.p, ec.C))
    np.testing.assert_array_equal(ec.resident, direct)
    ec._resident = None  # invalidate: the property must rebuild the mask
    np.testing.assert_array_equal(ec.resident, direct)
    np.testing.assert_array_equal(
        ec.resident_mask(), direct.reshape(2, 16)
    )


def test_stationary_routing_has_near_zero_swap_bytes():
    """Positive coordination, in bytes: stationary routing keeps the
    coordinated samples aligned, so swap traffic stays a sliver of the
    resident footprint."""
    bpe = 1 << 20
    cfg = ExpertCacheConfig(
        n_layers=2, n_experts=64, resident_fraction=0.25,
        horizon_steps=300, bytes_per_expert=bpe,
    )
    ec = OGBExpertCache(cfg, seed=1)
    counts = np.zeros((2, 64), np.float32)
    counts[:, :16] = 10.0
    rng = np.random.default_rng(3)
    swap_bytes = 0
    for _ in range(100):
        stats = ec.step(counts + rng.random((2, 64), np.float32))
        swap_bytes += stats["swap_bytes"]
    assert swap_bytes < 0.6 * 100 * ec.C * bpe
