"""Serving: engine end-to-end, OGB prefix cache vs LRU, expert residency."""

import numpy as np

import jax

from repro.configs.base import get_smoke
from repro.core.ogb import OGB
from repro.core.policies import LRU
from repro.models.model import init_params
from repro.serve.engine import ServeEngine
from repro.serve.expert_cache import ExpertCacheConfig, OGBExpertCache
from repro.serve.kvcache import PagedKVPool, page_keys


def test_page_keys_prefix_property():
    a = page_keys([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = page_keys([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert a[0] == b[0]  # shared first page
    assert a[1] != b[1]  # divergent second page
    c = page_keys([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a[0] != c[0]  # page hash covers the whole prefix


def test_pool_serves_and_updates():
    policy = OGB(catalog_size=1 << 16, capacity=8, eta=0.3, batch_size=4)
    pool = PagedKVPool(policy, page_size=4)
    prompt = list(range(16))
    pool.serve(prompt)
    pool.batch_end()
    for _ in range(6):
        pool.serve(prompt)
        pool.batch_end()
    assert pool.stats.token_reuse_ratio > 0.3  # repeated prefix gets cached
    assert pool.match_prefix(prompt) > 0


def test_ogb_pool_beats_lru_on_scan_mix():
    """The paper's motif at the serving layer: a scan-heavy page workload
    evicts LRU's useful pages; OGB's regret guarantee keeps the hot set."""
    rng = np.random.default_rng(0)
    hot_prompts = [list(rng.integers(0, 50, 32)) for _ in range(8)]
    C = 48  # pages
    T_steps = 160

    def run(policy):
        pool = PagedKVPool(policy, page_size=4)
        for step in range(T_steps):
            pool.serve(hot_prompts[step % len(hot_prompts)])
            scan = list(1000 + 64 * step + np.arange(64))  # one-shot scan pages
            pool.serve(scan)
            pool.batch_end()
        return pool.stats

    n_pages_horizon = T_steps * (8 + 16)
    ogb_stats = run(
        OGB(catalog_size=1 << 18, capacity=C, horizon=n_pages_horizon, batch_size=24)
    )
    lru_stats = run(LRU(1 << 18, C))
    assert ogb_stats.page_hit_ratio > lru_stats.page_hit_ratio + 0.05, (
        ogb_stats.page_hit_ratio,
        lru_stats.page_hit_ratio,
    )


def test_engine_generates_and_reuses():
    cfg = get_smoke("mistral-nemo-12b")
    params = init_params(cfg, jax.random.key(0))
    policy = OGB(catalog_size=1 << 16, capacity=16, eta=0.3, batch_size=8)
    pool = PagedKVPool(policy, page_size=4)
    engine = ServeEngine(cfg, params, pool=pool, max_len=48)
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, (2, 16)).astype(np.int32)
    out1 = engine.generate(prompt, max_new_tokens=4)
    assert out1.shape == (2, 4)
    for _ in range(4):
        engine.generate(prompt, max_new_tokens=4)
    assert engine.stats.prefix_reuse > 0.2  # identical prompts -> page reuse
    # greedy decode is deterministic given params+prompt
    out2 = engine.generate(prompt, max_new_tokens=4)
    np.testing.assert_array_equal(out1, out2)


def test_expert_cache_tracks_routing_shift():
    """Routing distribution shifts mid-serve; OGB placement follows it."""
    cfg = ExpertCacheConfig(n_layers=4, n_experts=32, resident_fraction=0.25,
                            horizon_steps=400)
    cache = OGBExpertCache(cfg, seed=0)
    rng = np.random.default_rng(2)

    def route(phase):
        counts = np.zeros((4, 32))
        hot = np.arange(8) if phase == 0 else np.arange(16, 24)
        for l in range(4):
            counts[l, hot] = rng.integers(50, 100, size=8)
            counts[l, rng.integers(0, 32, 4)] += rng.integers(0, 10, 4)
        return counts

    early = []
    for _ in range(200):
        early.append(cache.step(route(0))["resident_hit_ratio"])
    late = []
    for _ in range(200):
        late.append(cache.step(route(1))["resident_hit_ratio"])
    # adapts to the shift: late-phase hit ratio recovers well above C/N
    assert np.mean(late[-50:]) > 0.5
    assert np.mean(early[-50:]) > 0.5
    occ = cache.step(route(1))["occupancy"]
    assert abs(occ - cache.C) < 0.35 * cache.C  # soft capacity holds


def test_expert_cache_positive_coordination():
    cfg = ExpertCacheConfig(n_layers=2, n_experts=64, resident_fraction=0.25,
                            horizon_steps=300)
    cache = OGBExpertCache(cfg, seed=1)
    rng = np.random.default_rng(3)
    counts = np.zeros((2, 64))
    counts[:, :16] = 10
    total_swaps = 0
    for _ in range(100):
        total_swaps += cache.step(counts + rng.random((2, 64)))["swapped_in"]
    # stationary routing => near-zero churn after warmup (coordinated samples)
    assert total_swaps < 0.3 * 100 * cache.C, total_swaps
