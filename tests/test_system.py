"""End-to-end behaviour tests for the paper's system.

The two headline behaviours, exercised through the full stack:
  1. the O(log N) no-regret policy beats recency/frequency policies under
     pattern shifts and tracks OPT (the paper's core claim), and
  2. the policy works as the serving-layer page-cache of a real (smoke-scale)
     LM engine end-to-end with training/checkpointing alongside.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import adversarial, shifting_zipf
from repro.configs.base import get_smoke, list_archs
from repro.core import LRU, OGB, best_static_hits
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import PagedKVPool


def test_end_to_end_no_regret_vs_classics():
    """Adversarial + shifting traffic: OGB stays near OPT, LRU doesn't."""
    N, C, T = 400, 100, 40_000
    trace = np.concatenate(
        [adversarial(N, T // 2, seed=0), shifting_zipf(N, T // 2, phase=5000, seed=1)]
    )
    ogb = OGB(N, C, horizon=T, seed=0)
    r_ogb = simulate(ogb, trace, window=T, record_cum=False)
    r_lru = simulate(LRU(N, C), trace, window=T, record_cum=False)
    opt = best_static_hits(trace, C) / T
    assert r_ogb.hit_ratio > r_lru.hit_ratio
    assert r_ogb.hit_ratio > 0.6 * opt


def test_end_to_end_serving_with_training_and_cache():
    """Train a smoke LM a few steps, serve it behind an OGB page pool."""
    from repro.train.data import DataConfig, SyntheticLM
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import create_train_state, make_train_step

    cfg = get_smoke("glm4-9b")
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    state = create_train_state(cfg, opt_cfg, jax.random.key(0))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    step = jax.jit(make_train_step(cfg, opt_cfg))
    first = last = None
    for _ in range(20):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
        first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first  # it learned something

    policy = OGB(catalog_size=1 << 14, capacity=16, eta=0.25, batch_size=16, seed=0)
    pool = PagedKVPool(policy, page_size=4)
    engine = ServeEngine(cfg, state.params, pool=pool, max_len=24)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, (2, 16)).astype(np.int32)
    out = None
    for _ in range(6):
        out = engine.generate(prompt, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert engine.stats.prefix_reuse > 0.0  # repeated prompts got cached
    # greedy decoding from fixed params is deterministic
    np.testing.assert_array_equal(out, engine.generate(prompt, max_new_tokens=4))


def test_all_archs_registered():
    assert len(list_archs()) == 10
