"""Test-suite bootstrap: vendor a hypothesis fallback when it isn't installed.

Several core test modules hard-import ``hypothesis``; without this shim the
whole tier-1 run fails at collection on machines that don't have it.  The
stub (:mod:`_hypothesis_stub`) draws deterministic random examples with the
same ``given``/``settings``/``strategies`` API — the real package is used
whenever importable.
"""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

# The model/serving/training stack imports repro.dist (sharding-rule
# helpers), which is absent from the seed snapshot.  Gate those test modules
# instead of letting their import errors interrupt collection of the whole
# suite — the caching stack (core, cachesim, jaxcache, kernels) does not
# depend on repro.dist.
try:
    import repro.dist  # noqa: F401
except ImportError:
    collect_ignore_glob = ["models/*", "serve/*", "launch/*"]
    collect_ignore = [
        "test_system.py",
        "train/test_train.py",
        "train/test_checkpoint.py",
    ]
