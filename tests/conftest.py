"""Test-suite bootstrap: vendor a hypothesis fallback when it isn't installed.

Several core test modules hard-import ``hypothesis``; without this shim the
whole tier-1 run fails at collection on machines that don't have it.  The
stub (:mod:`_hypothesis_stub`) draws deterministic random examples with the
same ``given``/``settings``/``strategies`` API — the real package is used
whenever importable.
"""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

# repro.dist is a hard dependency of the model/serving/training stack and is
# part of the library proper — no collection gating.  Genuinely optional deps
# are handled per-module (the hypothesis shim above; pytest.importorskip at
# the test site for anything else).


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden",
        action="store_true",
        default=False,
        help="rewrite tests/cachesim/golden/*.json from the current engines "
        "instead of asserting against them (commit the diff deliberately)",
    )
