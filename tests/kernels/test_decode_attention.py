"""Pallas GQA flash-decode kernel vs jnp oracle — shape/dtype sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _mk(B, H, Hkv, D, S, seed, dtype):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(B, H, D)).astype(dtype)
    k = rng.normal(size=(B, S, Hkv, D)).astype(dtype)
    v = rng.normal(size=(B, S, Hkv, D)).astype(dtype)
    lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
    return map(jnp.asarray, (q, k, v, lengths))


@pytest.mark.parametrize(
    "B,H,Hkv,D,S",
    [
        (2, 8, 8, 64, 256),  # MHA
        (2, 8, 2, 64, 256),  # GQA 4:1
        (1, 16, 1, 128, 512),  # MQA
        (3, 4, 4, 128, 130),  # ragged S (padding path)
    ],
)
@pytest.mark.parametrize("dtype", [np.float32])
def test_matches_ref(B, H, Hkv, D, S, dtype):
    q, k, v, lengths = _mk(B, H, Hkv, D, S, 0, dtype)
    got = decode_attention(q, k, v, lengths, s_block=128, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_bf16_inputs():
    q, k, v, lengths = _mk(2, 8, 4, 64, 256, 3, np.float32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = decode_attention(q, k, v, lengths, s_block=128, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


@pytest.mark.parametrize("s_block", [64, 128, 512])
def test_s_block_sweep(s_block):
    q, k, v, lengths = _mk(2, 8, 4, 64, 512, 5, np.float32)
    got = decode_attention(q, k, v, lengths, s_block=s_block, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_short_lengths_mask():
    """All-masked blocks must not contribute (running max stays -inf safe)."""
    B, H, Hkv, D, S = 2, 4, 2, 64, 512
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([1, 3], jnp.int32)  # only the first block has data
    got = decode_attention(q, k, v, lengths, s_block=128, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)
    assert np.isfinite(np.asarray(got)).all()
