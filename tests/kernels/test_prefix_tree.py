"""Prefix-tree kernel family vs float64 numpy oracles and core.treap.

Covers the four tentpole capabilities: prefix-sum point-update/range-query,
Madow sampling by tree descent, min-pair (eviction key) trees, and the
Pallas block reductions (interpret mode on CPU).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core.treap import Treap
from repro.kernels.prefix_tree import (
    block_segment_sums,
    bucket_masses,
    madow_sample_tree,
    minpair_argmin,
    minpair_build,
    minpair_root,
    minpair_update,
    sortable_f32,
    tree_build,
    tree_prefix,
    tree_range,
    tree_select,
    tree_storage,
    tree_total,
    tree_update,
)
from repro.kernels.prefix_tree import ref


# ---------------------------------------------------------------------------
# prefix-sum trees vs float64 oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 5, 64, 65, 1000, 4097])
@pytest.mark.parametrize("radix", [16, 64])
def test_build_prefix_total_vs_ref(n, radix):
    rng = np.random.default_rng(n * 131 + radix)
    vals = rng.random(n).astype(np.float32)
    tree = tree_build(jnp.asarray(vals), radix)
    assert tree.shape[0] == tree_storage(n, radix)
    levels = ref.build_ref(vals.astype(np.float64), radix)
    idx = jnp.asarray(np.arange(-1, n), jnp.int32)
    got = np.asarray(tree_prefix(tree, n, radix, idx))
    expect = np.array([ref.prefix_ref(levels, i) for i in range(-1, n)])
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(
        float(tree_total(tree, n, radix)), vals.astype(np.float64).sum(),
        rtol=1e-5,
    )


@pytest.mark.parametrize("radix", [16, 64])
def test_update_then_query_vs_ref(radix):
    n, rounds, batch = 777, 8, 32
    rng = np.random.default_rng(7)
    vals = rng.random(n).astype(np.float32)
    tree = tree_build(jnp.asarray(vals), radix)
    levels = ref.build_ref(vals.astype(np.float64), radix)
    for _ in range(rounds):
        idx = rng.integers(-1, n, size=batch)  # -1 = masked no-op
        delta = rng.standard_normal(batch).astype(np.float32)
        tree = tree_update(
            tree, n, radix, jnp.asarray(idx, jnp.int32), jnp.asarray(delta)
        )
        for i, d in zip(idx, delta):
            if i >= 0:
                ref.update_ref(levels, i, float(d), radix)
        q = rng.integers(0, n, size=16)
        got = np.asarray(tree_prefix(tree, n, radix, jnp.asarray(q, jnp.int32)))
        expect = np.array([ref.prefix_ref(levels, i) for i in q])
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-3)


def test_range_query_matches_slices():
    n, radix = 513, 16
    rng = np.random.default_rng(3)
    vals = rng.random(n).astype(np.float32)
    tree = tree_build(jnp.asarray(vals), radix)
    lo = jnp.asarray([0, 10, 100, 500, 200], jnp.int32)
    hi = jnp.asarray([0, 99, 99, 512, 199], jnp.int32)  # one empty range
    got = np.asarray(tree_range(tree, n, radix, lo, hi))
    expect = np.array(
        [vals[l : h + 1].astype(np.float64).sum() for l, h in zip(lo, hi)]
    )
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-4)


def test_prefix_matches_treap_order_statistics():
    """Integer count tree == Treap.count_below on the same multiset."""
    n, radix = 300, 16
    rng = np.random.default_rng(11)
    keys = rng.integers(0, n, size=500)
    counts = np.bincount(keys, minlength=n).astype(np.float32)
    tree = tree_build(jnp.asarray(counts), radix)
    treap = Treap(seed=5)
    for i, k in enumerate(keys):
        treap.insert(float(k), i)
    q = np.arange(n)
    got = np.asarray(
        tree_prefix(tree, n, radix, jnp.asarray(q, jnp.int32))
    ).astype(np.int64)
    # inclusive prefix over leaves [0, k] == #entries with key < k + 1
    expect = np.array([treap.count_below(float(k) + 0.5) for k in q])
    np.testing.assert_array_equal(got, expect)


# ---------------------------------------------------------------------------
# weighted selection / Madow sampling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,radix", [(100, 16), (1000, 64), (4097, 64)])
def test_select_vs_ref(n, radix):
    rng = np.random.default_rng(n)
    vals = (rng.random(n) < 0.3).astype(np.float32) * rng.random(n).astype(
        np.float32
    )
    tree = tree_build(jnp.asarray(vals), radix)
    levels = ref.build_ref(vals.astype(np.float64), radix)
    total = vals.astype(np.float64).sum()
    targets = np.linspace(0.0, total * 0.999, 50)
    got = np.asarray(tree_select(tree, n, radix, jnp.asarray(targets, jnp.float32)))
    expect = np.array([ref.select_ref(levels, t) for t in targets])
    # f32 cumsum boundaries may land one leaf off exactly at a target tie;
    # everywhere else the descent must agree with the float64 searchsorted
    assert np.all(np.abs(got - expect) <= 1)
    assert np.mean(got != expect) < 0.1


@pytest.mark.parametrize("n,cap", [(50, 7), (2000, 100), (4096, 512)])
def test_madow_tree_distinct_and_matches_ref(n, cap):
    rng = np.random.default_rng(cap)
    f = rng.random(n).astype(np.float32)
    f = np.clip(f * (cap / f.sum()), 0.0, 1.0)
    # make the mass >= cap so all C positions land inside the cumsum
    f = np.minimum(f * (cap / max(f.sum(), 1e-9)), 1.0)
    u = float(rng.random()) * 0.9
    got = np.asarray(madow_sample_tree(jnp.asarray(f), jnp.float32(u), cap))
    assert got.shape == (cap,)
    assert len(set(got.tolist())) == cap  # distinct (systematic sampling)
    assert np.all(np.diff(got) > 0)  # ascending targets -> ascending leaves
    expect = ref.madow_sample_ref(f, u, cap)
    assert np.mean(got != expect) < 0.05  # f32 boundary slips only
    assert np.all(np.abs(got - expect) <= 1)


# ---------------------------------------------------------------------------
# min-pair trees (LFU/FTPL eviction keys)
# ---------------------------------------------------------------------------
def test_minpair_build_root_argmin_vs_ref():
    rng = np.random.default_rng(0)
    for n in (5, 64, 321):
        hi = rng.integers(-3, 3, size=n).astype(np.int32)  # many ties
        lo = rng.integers(0, n, size=n).astype(np.int32)
        th, tl = minpair_build(jnp.asarray(hi), jnp.asarray(lo), 64)
        rh, rl = minpair_root(th, tl, n, 64)
        k = ref.minpair_argmin_ref(hi, lo)
        assert (int(rh), int(rl)) == (int(hi[k]), int(lo[k]))
        assert int(minpair_argmin(th, tl, n, 64)) == k


def test_minpair_update_stream_vs_ref():
    n, radix = 200, 64
    rng = np.random.default_rng(9)
    hi = rng.integers(0, 50, size=n).astype(np.int32)
    lo = np.arange(n, dtype=np.int32)
    th, tl = minpair_build(jnp.asarray(hi), jnp.asarray(lo), radix)
    for step in range(60):
        i = int(rng.integers(0, n))
        nh = np.int32(rng.integers(0, 50))
        hi[i] = nh
        th, tl = minpair_update(
            th, tl, n, radix, jnp.int32(i), jnp.asarray(nh), jnp.int32(lo[i])
        )
        k = ref.minpair_argmin_ref(hi, lo)
        assert int(minpair_argmin(th, tl, n, radix)) == k, step


def test_sortable_f32_preserves_order():
    rng = np.random.default_rng(2)
    x = np.concatenate(
        [rng.standard_normal(500).astype(np.float32), [0.0, -0.0, 1e-30]]
    )
    got = np.asarray(sortable_f32(jnp.asarray(x)))
    expect = ref.sortable_f32_ref(x)
    np.testing.assert_array_equal(got, expect)
    order = np.argsort(x, kind="stable")
    assert np.all(np.diff(got[order]) >= 0)


# ---------------------------------------------------------------------------
# property tests (real hypothesis or the in-repo stub)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=300),
    radix=st.sampled_from([16, 64]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_interleaved_update_query(n, radix, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 5, size=n).astype(np.float32)
    tree = tree_build(jnp.asarray(vals), radix)
    dense = vals.astype(np.float64).copy()
    for _ in range(4):
        idx = rng.integers(0, n, size=8)
        delta = rng.integers(-2, 3, size=8).astype(np.float32)
        tree = tree_update(
            tree, n, radix, jnp.asarray(idx, jnp.int32), jnp.asarray(delta)
        )
        np.add.at(dense, idx, delta.astype(np.float64))
        q = int(rng.integers(0, n))
        got = float(tree_prefix(tree, n, radix, jnp.asarray([q], jnp.int32))[0])
        assert got == pytest.approx(dense[: q + 1].sum(), abs=1e-3)
    assert float(tree_total(tree, n, radix)) == pytest.approx(
        dense.sum(), abs=1e-3
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=200),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_minpair_matches_oracle(n, seed):
    rng = np.random.default_rng(seed)
    hi = rng.integers(-10, 10, size=n).astype(np.int32)
    lo = rng.integers(0, n, size=n).astype(np.int32)
    th, tl = minpair_build(jnp.asarray(hi), jnp.asarray(lo), 16)
    assert int(minpair_argmin(th, tl, n, 16)) == ref.minpair_argmin_ref(hi, lo)


# ---------------------------------------------------------------------------
# Pallas kernels, interpret mode (CPU-safe)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,radix", [(4096, 16), (65536, 64), (9999, 64)])
def test_block_segment_sums_matches_jnp_build(n, radix):
    rng = np.random.default_rng(n)
    vals = rng.random(n).astype(np.float32)
    out_size = (n + radix - 1) // radix
    got = block_segment_sums(jnp.asarray(vals), out_size, radix, interpret=True)
    padded = np.zeros(out_size * radix, np.float32)
    padded[:n] = vals
    expect = padded.reshape(out_size, radix).sum(axis=1)
    np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-6)


def test_tree_build_kernel_path_matches_plain():
    vals = jnp.asarray(np.random.default_rng(4).random(20000), jnp.float32)
    plain = tree_build(vals, 64)
    kern = tree_build(vals, 64, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(kern), rtol=1e-6)


def test_bucket_masses_matches_numpy():
    v, k = 1024, 16
    rng = np.random.default_rng(6)
    cnt = rng.integers(0, 20, size=v).astype(np.float32)
    s = cnt * rng.random(v).astype(np.float32) * 3.0
    taus = np.linspace(0.0, 3.0, k).astype(np.float32)
    mean = np.divide(s, cnt, out=np.zeros_like(s), where=cnt > 0)
    expect = np.array(
        [np.sum(cnt * np.clip(mean - t, 0.0, 1.0)) for t in taus]
    )
    got = np.asarray(
        bucket_masses(jnp.asarray(cnt), jnp.asarray(s), jnp.asarray(taus),
                      interpret=True)
    )
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-2)
