"""Pallas causal GQA prefill kernel vs oracle — shape/dtype/block sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.flash_prefill.ops import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref


def _mk(B, S, H, Hkv, D, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,S,H,Hkv,D",
    [
        (1, 256, 4, 4, 64),   # MHA
        (2, 256, 8, 2, 64),   # GQA 4:1 (index-map division path)
        (1, 512, 4, 1, 128),  # MQA
    ],
)
def test_matches_ref(B, S, H, Hkv, D):
    q, k, v = _mk(B, S, H, Hkv, D, 0)
    got = flash_prefill(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 256), (256, 128)])
def test_block_sweep(bq, bk):
    q, k, v = _mk(1, 512, 4, 2, 64, 1)
    got = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_ragged_seq_padding():
    q, k, v = _mk(1, 200, 4, 4, 64, 2)  # not a block multiple
    got = flash_prefill(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_bf16():
    q, k, v = _mk(1, 256, 4, 2, 64, 3)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = flash_prefill(qb, kb, vb, interpret=True)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref), atol=4e-2, rtol=4e-2
    )
