"""Pallas histogram kernel vs oracle — shape sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.scatter_counts.ops import scatter_counts
from repro.kernels.scatter_counts.ref import scatter_counts_ref


@pytest.mark.parametrize("n", [1024, 4096, 10_000])
@pytest.mark.parametrize("b", [17, 256, 1024])
@pytest.mark.parametrize("seed", [0, 1])
def test_matches_ref(n, b, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)
    got = scatter_counts(ids, n, interpret=True)
    ref = scatter_counts_ref(ids, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert float(got.sum()) == b


def test_padding_ignored():
    ids = jnp.asarray([3, 3, -1, 5, -1], jnp.int32)
    got = scatter_counts(ids, 1024, interpret=True)
    assert float(got[3]) == 2 and float(got[5]) == 1
    assert float(got.sum()) == 3


@pytest.mark.parametrize("block_rows,id_chunk", [(8, 128), (16, 512), (32, 64)])
def test_block_sweep(block_rows, id_chunk):
    rng = np.random.default_rng(7)
    n, b = 8192, 700
    ids = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)
    got = scatter_counts(
        ids, n, block_rows=block_rows, id_chunk=id_chunk, interpret=True
    )
    ref = scatter_counts_ref(ids, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
