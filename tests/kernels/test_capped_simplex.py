"""Pallas capped-simplex kernel vs pure-jnp/numpy oracles — shape/dtype sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.projection import capped_simplex_tau, project_capped_simplex
from repro.kernels.capped_simplex.ops import fused_ogb_update
from repro.kernels.capped_simplex.ref import fused_ogb_update_ref


def _mk(n, B, seed, dtype):
    rng = np.random.default_rng(seed)
    f = rng.random(n)
    C = max(1, n // 10)
    f = np.clip(f * (C / f.sum()), 0, 1)
    # renormalize onto the simplex via the exact oracle
    f = project_capped_simplex(f, C)
    ids = rng.integers(0, n, size=B)
    counts = np.bincount(ids, minlength=n).astype(np.float64)
    return f.astype(dtype), counts.astype(dtype), C


@pytest.mark.parametrize("n", [1000, 32768, 100_000])
@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("seed", [0, 1])
def test_matches_exact_oracle(n, dtype, seed):
    f, counts, C = _mk(n, 512, seed, dtype)
    eta = 0.01
    got = fused_ogb_update(
        jnp.asarray(f), jnp.asarray(counts), eta, float(C), interpret=True
    )
    expect = project_capped_simplex(f.astype(np.float64) + eta * counts, C)
    np.testing.assert_allclose(np.asarray(got), expect, atol=2e-4)
    assert abs(float(jnp.sum(got)) - C) < 0.05


@pytest.mark.parametrize("n,block_rows", [(4096, 8), (65536, 256), (9999, 32)])
def test_block_shape_sweep(n, block_rows):
    f, counts, C = _mk(n, 256, 3, np.float32)
    eta = 0.05
    got = fused_ogb_update(
        jnp.asarray(f),
        jnp.asarray(counts),
        eta,
        float(C),
        block_rows=block_rows,
        interpret=True,
    )
    ref = fused_ogb_update_ref(jnp.asarray(f), jnp.asarray(counts), eta, float(C))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)


@pytest.mark.parametrize("passes,k", [(2, 64), (3, 64), (3, 32), (4, 16)])
def test_pass_count_accuracy(passes, k):
    f, counts, C = _mk(20000, 1024, 5, np.float32)
    eta = 0.02
    got = fused_ogb_update(
        jnp.asarray(f),
        jnp.asarray(counts),
        eta,
        float(C),
        passes=passes,
        k=k,
        interpret=True,
    )
    expect = project_capped_simplex(f.astype(np.float64) + eta * counts, C)
    np.testing.assert_allclose(np.asarray(got), expect, atol=5e-4)


def test_warm_bracket_matches_cold_and_returns_tau():
    """tau0 warm bracket: 2 passes match the cold 3-pass result and the
    float64 oracle's threshold (the f from _mk is feasible, so tau lies in
    [0, eta*sum(counts)])."""
    f, counts, C = _mk(20000, 512, 7, np.float32)
    eta = 0.02
    cold = fused_ogb_update(
        jnp.asarray(f), jnp.asarray(counts), eta, float(C), interpret=True
    )
    warm, tau = fused_ogb_update(
        jnp.asarray(f),
        jnp.asarray(counts),
        eta,
        float(C),
        passes=2,
        tau0=jnp.float32(0.0),
        return_tau=True,
        interpret=True,
    )
    np.testing.assert_allclose(np.asarray(warm), np.asarray(cold), atol=2e-4)
    expect = project_capped_simplex(f.astype(np.float64) + eta * counts, C)
    np.testing.assert_allclose(np.asarray(warm), expect, atol=2e-4)
    tau_ref = capped_simplex_tau(f.astype(np.float64) + eta * counts, C)
    assert abs(float(tau) - tau_ref) < 1e-4


def test_large_eta_saturation():
    """Drive coordinates to the [0,1] bounds."""
    n, C = 5000, 500
    f = np.full(n, C / n, np.float32)
    counts = np.zeros(n, np.float32)
    counts[:3] = 200.0  # huge mass on three items
    eta = 0.05
    got = fused_ogb_update(jnp.asarray(f), jnp.asarray(counts), eta, float(C))
    expect = project_capped_simplex(f.astype(np.float64) + eta * counts, C)
    np.testing.assert_allclose(np.asarray(got), expect, atol=5e-4)
    assert float(got[0]) > 0.999  # saturated at 1
