"""§Perf H3: int8 KV cache — accuracy and layout checks."""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke
from repro.models.model import decode_step, init_cache, init_params, prefill


def _int8_cfg():
    return dataclasses.replace(get_smoke("mistral-nemo-12b"), kv_cache_dtype="int8")


def test_cache_layout_halves_kv_bytes():
    cfg = _int8_cfg()
    cache = init_cache(cfg, batch=2, max_len=32)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["kv"]
    f32_bytes = 2 * 32 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * 2 * 2  # bf16
    int8_bytes = (
        2 * 32 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * 2 * 1
        + 2 * 32 * cfg.n_kv_heads * cfg.n_layers * 2 * 4
    )
    assert int8_bytes < 0.66 * f32_bytes  # ~0.53x with head_dim=16 scales


def test_int8_decode_close_to_fp():
    cfg_q = _int8_cfg()
    cfg_f = get_smoke("mistral-nemo-12b")
    params = init_params(cfg_f, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg_f.vocab_size, (1, 8)).astype(np.int32)

    def run(cfg):
        cache = init_cache(cfg, 1, 16)
        step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
        for t in range(8):
            logits, cache = step(params, cache, jnp.asarray(toks[:, t]))
        return np.asarray(logits)

    lq, lf = run(cfg_q), run(cfg_f)
    # int8 KV quantization error should barely move the logits
    denom = np.maximum(np.abs(lf).max(), 1e-6)
    assert np.abs(lq - lf).max() / denom < 0.08, np.abs(lq - lf).max()


def test_int8_prefill_matches_decode():
    cfg = _int8_cfg()
    params = init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab_size, (1, 6)).astype(np.int32)
    logits_pre, _ = prefill(cfg, params, {"tokens": jnp.asarray(toks)}, 16)
    cache = init_cache(cfg, 1, 16)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    for t in range(6):
        logits_dec, cache = step(params, cache, jnp.asarray(toks[:, t]))
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_dec), atol=5e-3, rtol=5e-2
    )
