"""Parity: model forward with the Pallas flash_prefill backend == jnp flash."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke
from repro.models import attention
from repro.models.model import forward_train, init_params


@pytest.mark.parametrize("arch", ["glm4-9b", "gemma-7b"])
def test_pallas_prefill_parity(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
    }
    try:
        loss_jnp, _ = forward_train(cfg, params, batch)
        attention.set_pallas_prefill(True)
        loss_pls, _ = forward_train(cfg, params, batch)
    finally:
        attention.set_pallas_prefill(False)
    np.testing.assert_allclose(
        float(loss_jnp), float(loss_pls), rtol=1e-5, atol=1e-5
    )
