"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finite values; decode-vs-prefill consistency.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke, list_archs
from repro.models.model import (
    decode_step,
    forward_train,
    init_cache,
    init_params,
    padded_vocab,
    prefill,
)

ARCHS = list_archs()


def _smoke_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _smoke_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    # a gradient step must be finite too
    g = jax.grad(lambda p: forward_train(cfg, p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(1))
    B, max_len = 2, 32
    cache = init_cache(cfg, B, max_len)
    tok = jnp.asarray([1, 2], jnp.int32)
    logits, cache = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))(
        params, cache, tok
    )
    assert logits.shape == (B, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"]) == 1
    # a second step advances
    logits2, cache = decode_step(cfg, params, cache, tok)
    assert int(cache["pos"]) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_decode(arch):
    """prefill(tokens) then decode == decoding token-by-token from scratch."""
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(2))
    B, S, max_len = 1, 6, 16
    rng = np.random.default_rng(3)
    toks = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)), jnp.float32
        )
    # vlm: compare on a text-only prompt (decode_step has no image path)

    logits_pre, _cache = prefill(cfg, params, batch, max_len)

    # token-by-token decode from an empty cache
    cache = init_cache(cfg, B, max_len)
    if cfg.family == "encdec":
        from repro.models.attention import project_cross_kv
        from repro.models.model import _encoder_forward

        enc = _encoder_forward(cfg, params, batch["frames"])
        cks, cvs = [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
            ck, cv = project_cross_kv(p["cross"], enc, cfg)
            cks.append(ck)
            cvs.append(cv)
        cache["cross_k"] = jnp.stack(cks)
        cache["cross_v"] = jnp.stack(cvs)

    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    for t in range(S):
        logits_dec, cache = step(params, cache, jnp.asarray(toks[:, t]))

    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_dec), atol=2e-3, rtol=2e-3
    )


def test_param_counts_match_published():
    """Full configs should land near the published parameter counts."""
    from repro.configs.base import get_arch

    expect = {
        "gemma-7b": (7e9, 0.4),
        "qwen3-14b": (14e9, 0.3),
        "mistral-nemo-12b": (12e9, 0.3),
        "glm4-9b": (9e9, 0.4),
        "granite-moe-1b-a400m": (1.3e9, 0.5),
        "kimi-k2-1t-a32b": (1.0e12, 0.4),
        "rwkv6-1.6b": (1.6e9, 0.5),
        "jamba-1.5-large-398b": (398e9, 0.35),
        "whisper-large-v3": (1.55e9, 0.6),
        "phi-3-vision-4.2b": (4.2e9, 0.4),
    }
    for name, (target, tol) in expect.items():
        got = get_arch(name).param_count()
        assert abs(got - target) / target < tol, (name, got, target)


def test_active_params_moe():
    from repro.configs.base import get_arch

    kimi = get_arch("kimi-k2-1t-a32b")
    active = kimi.active_param_count()
    assert 20e9 < active < 60e9, active  # ~32B active
