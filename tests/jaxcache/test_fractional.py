"""JAX batched OGB_cl vs the float64 numpy oracle, and sharded == unsharded."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.projection import project_capped_simplex
from repro.jaxcache.fractional import (
    FractionalState,
    capped_simplex_project,
    madow_sample_jax,
    ogb_batch_update,
    permanent_random_numbers,
    poisson_sample,
    request_counts,
)


def test_counts():
    ids = jnp.array([1, 1, 3, 0], dtype=jnp.int32)
    c = request_counts(ids, 5)
    np.testing.assert_array_equal(np.asarray(c), [1, 2, 0, 1, 0])


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,C", [(64, 8), (301, 17), (1024, 256)])
def test_projection_matches_oracle(seed, n, C):
    rng = np.random.default_rng(seed)
    y = rng.normal(0.3, 0.5, size=n).astype(np.float32)
    f_jax, tau = capped_simplex_project(jnp.asarray(y), float(C))
    f_ref = project_capped_simplex(y.astype(np.float64), C)
    np.testing.assert_allclose(np.asarray(f_jax), f_ref, atol=2e-5)
    assert abs(float(jnp.sum(f_jax)) - C) < 1e-2


def test_batch_update_matches_numpy_classic():
    """ogb_batch_update == numpy OGB_cl batch step."""
    N, C, B, eta = 128, 16, 32, 0.05
    rng = np.random.default_rng(0)
    f = np.full(N, C / N)
    state = FractionalState.create(N, C)
    for _ in range(5):
        ids = rng.integers(0, N, size=B).astype(np.int32)
        # numpy reference
        counts = np.bincount(ids, minlength=N)
        f = project_capped_simplex(f + eta * counts, C)
        # jax
        state, reward = ogb_batch_update(state, jnp.asarray(ids), jnp.float32(eta), C)
        np.testing.assert_allclose(np.asarray(state.f), f, atol=5e-5)


def test_poisson_sample_expectation():
    N, C = 4096, 512
    f = jnp.full(N, C / N, jnp.float32)
    p = permanent_random_numbers(jax.random.key(0), N)
    x = poisson_sample(f, p, C)
    occ = int(jnp.sum(x))
    assert abs(occ - C) < 4 * np.sqrt(C)  # ~4 sigma


def test_madow_sample_exact_size():
    N, C = 512, 64
    rng = np.random.default_rng(1)
    f = rng.random(N).astype(np.float32)
    f = np.clip(f * (C / f.sum()), 0, 1)
    f = f * (C / f.sum())
    mask = madow_sample_jax(jnp.asarray(f), jnp.float32(0.37), C)
    assert int(jnp.sum(mask)) in (C, C - 1, C + 1)  # fp cumsum edge tolerance


def test_sharded_matches_unsharded():
    """8 fake XLA host devices: sharded step == single-device step."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.jaxcache.fractional import FractionalState, ogb_batch_update
from repro.jaxcache.sharded import make_sharded_step

N, C, B, eta = 256, 32, 64, 0.04
mesh = jax.make_mesh((2, 4), ("data", "model"))
step, f_shard = make_sharded_step(mesh, N, C, B, eta)
rng = np.random.default_rng(0)
f = jax.device_put(jnp.full((N,), C / N, jnp.float32), f_shard)
state = FractionalState.create(N, C)
for i in range(4):
    ids = jnp.asarray(rng.integers(0, N, size=B), jnp.int32)
    f, reward_sh = step(f, ids)
    state, reward_un = ogb_batch_update(state, ids, jnp.float32(eta), C)
    np.testing.assert_allclose(np.asarray(f), np.asarray(state.f), atol=5e-5)
    np.testing.assert_allclose(float(reward_sh), float(reward_un), atol=1e-3)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    assert "OK" in out.stdout, out.stderr[-3000:]


def test_fleet_step_independent_caches():
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.jaxcache.fractional import FractionalState, ogb_batch_update
from repro.jaxcache.sharded import make_fleet_step

E, N, C, B, eta = 4, 128, 16, 32, 0.05
mesh = jax.make_mesh((2, 4), ("data", "model"))
step, f_shard, ids_shard = make_fleet_step(mesh, E, N, C, B, eta)
rng = np.random.default_rng(1)
f = jax.device_put(jnp.full((E, N), C / N, jnp.float32), f_shard)
states = [FractionalState.create(N, C) for _ in range(E)]
for i in range(3):
    ids = jnp.asarray(rng.integers(0, N, size=(E, B)), jnp.int32)
    f, rewards = step(jax.device_put(f, f_shard), jax.device_put(ids, ids_shard))
    for e in range(E):
        states[e], r = ogb_batch_update(states[e], ids[e], jnp.float32(eta), C)
        np.testing.assert_allclose(np.asarray(f[e]), np.asarray(states[e].f), atol=5e-5)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    assert "OK" in out.stdout, out.stderr[-3000:]


def test_fleet_step_warm_and_heterogeneous():
    """Warm-bracket Newton fleet step: (a) scalar eta/capacity and their
    (E,) broadcasts are bit-exact, (b) heterogeneous per-cache eta and
    capacity match the per-cache ``ogb_batch_update`` oracle, (c) the warm
    path tracks the cold bisection within bracket tolerance."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.jaxcache.fractional import FractionalState, ogb_batch_update
from repro.jaxcache.sharded import make_fleet_step

E, N, B = 4, 128, 32
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(2)
ids_all = [jnp.asarray(rng.integers(0, N, size=(E, B)), jnp.int32)
           for _ in range(4)]

# (a) scalar params == (E,) broadcast, bit-exact
C, eta = 16, 0.05
step_s, f_sh, ids_sh = make_fleet_step(mesh, E, N, C, B, eta)
step_v, _, _ = make_fleet_step(
    mesh, E, N, jnp.full((E,), C, jnp.float32), B,
    jnp.full((E,), eta, jnp.float32))
f_s = jax.device_put(jnp.full((E, N), C / N, jnp.float32), f_sh)
f_v = f_s
for ids in ids_all:
    ids = jax.device_put(ids, ids_sh)
    f_s, r_s = step_s(f_s, ids)
    f_v, r_v = step_v(f_v, ids)
assert np.array_equal(np.asarray(f_s), np.asarray(f_v)), "broadcast drift"
assert np.array_equal(np.asarray(r_s), np.asarray(r_v))

# (b) heterogeneous (E,) eta/capacity vs the per-cache oracle
caps = jnp.asarray([8.0, 16.0, 24.0, 32.0], jnp.float32)
etas = jnp.asarray([0.02, 0.05, 0.08, 0.11], jnp.float32)
step_h, f_sh, ids_sh = make_fleet_step(mesh, E, N, caps, B, etas)
f = jnp.stack([jnp.full((N,), float(c) / N, jnp.float32) for c in caps])
f = jax.device_put(f, f_sh)
states = [FractionalState.create(N, int(c)) for c in caps]
for ids in ids_all:
    f, _ = step_h(f, jax.device_put(ids, ids_sh))
    for e in range(E):
        states[e], _ = ogb_batch_update(
            states[e], ids[e], etas[e], int(caps[e]))
        np.testing.assert_allclose(
            np.asarray(f[e]), np.asarray(states[e].f), atol=5e-5)

# (c) warm-start Newton vs cold bisection on the same stream
warm, f_sh, ids_sh, tau_sh = make_fleet_step(
    mesh, E, N, caps, B, etas, warm_start=True)
f_w = jax.device_put(
    jnp.stack([jnp.full((N,), float(c) / N, jnp.float32) for c in caps]),
    f_sh)
tau = jax.device_put(jnp.zeros((E,), jnp.float32), tau_sh)
f_c = f_w
for ids in ids_all:
    f_w, _, tau = warm(f_w, jax.device_put(ids, ids_sh), tau)
    f_c, _ = step_h(f_c, jax.device_put(ids, ids_sh))
drift = float(jnp.max(jnp.abs(f_w - f_c)))
assert drift < 1e-4, f"warm/cold drift {drift}"
assert bool(jnp.all(tau >= 0.0)), "negative dual variable"
# the warm path must actually hit the per-cache capacity constraints
mass = jnp.sum(f_w, axis=1)
np.testing.assert_allclose(np.asarray(mass), np.asarray(caps), rtol=1e-4)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    assert "OK" in out.stdout, out.stderr[-3000:]
