"""OGB dataset-shard cache: multi-epoch revisitation keeps hot shards local."""

import numpy as np

from repro.train.shard_cache import OGBShardCache


def test_multi_epoch_shard_locality():
    """A curriculum that revisits a 'core' mix every epoch: the core shards
    should converge to local residency despite interleaved cold scans."""
    n_shards, local = 1000, 100
    core = np.arange(60)  # revisited every epoch
    # Theorem 3.1 tuning wants the TRUE horizon: 20 epochs x 100 touches
    cache = OGBShardCache(n_shards, local, horizon_touches=2_000, seed=0)
    rng = np.random.default_rng(0)
    for epoch in range(20):
        for s in rng.permutation(core):
            cache.touch(int(s))
        # cold one-pass shards (fresh each epoch)
        for s in 100 + epoch * 40 + np.arange(40):
            cache.touch(int(s % n_shards))
    # late-phase locality on the core set
    late_hits = sum(cache.is_local(int(s)) for s in core)
    assert late_hits > 0.6 * len(core), late_hits
    assert cache.stats.local_ratio > 0.3


def test_fetch_accounting():
    cache = OGBShardCache(100, 10, horizon_touches=100)
    cache.touch(5)
    assert cache.stats.touches == 1
    assert cache.stats.fetches + cache.stats.local_hits == 1
