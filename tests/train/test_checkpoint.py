"""Checkpointing: atomic save/restore, async writer, resume-exactness."""

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.train.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "b": {"c": jnp.arange(5, dtype=jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree(0)
    save_checkpoint(str(tmp_path), 7, t, extra={"cursor": 3})
    got, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra == {"cursor": 3}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_pruning(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep_last=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(s))
    ck.wait()
    assert latest_step(str(tmp_path)) == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # pruned to keep_last


def test_restore_dtype_follows_template(tmp_path):
    t = {"w": jnp.ones((3,), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, t)
    template = {"w": jnp.zeros((3,), jnp.bfloat16)}
    got, _, _ = restore_checkpoint(str(tmp_path), template)
    assert got["w"].dtype == jnp.bfloat16


def test_crash_safety_partial_write(tmp_path):
    """A .tmp directory (simulated crash) must not break restore."""
    t = _tree(1)
    save_checkpoint(str(tmp_path), 5, t)
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash remnant
    got, step, _ = restore_checkpoint(str(tmp_path), t)
    assert step == 5


def test_resume_training_bit_exact(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    from repro.configs.base import get_smoke
    from repro.train.data import DataConfig, SyntheticLM
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import create_train_state, make_train_step

    cfg = get_smoke("glm4-9b")
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=50)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=4, seed=9)
    step = jax.jit(make_train_step(cfg, opt_cfg))

    # run A: 6 straight steps
    state = create_train_state(cfg, opt_cfg, jax.random.key(5))
    data = SyntheticLM(dcfg)
    for _ in range(6):
        state, _m = step(state, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    final_a = jax.tree.leaves(state.params)

    # run B: 3 steps -> checkpoint -> restore -> 3 steps
    state = create_train_state(cfg, opt_cfg, jax.random.key(5))
    data = SyntheticLM(dcfg)
    for _ in range(3):
        state, _m = step(state, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    save_checkpoint(str(tmp_path), 3, state, extra=data.state_dict())
    state2, s, extra = restore_checkpoint(str(tmp_path), state)
    data2 = SyntheticLM(dcfg)
    data2.load_state_dict(extra)
    for _ in range(3):
        state2, _m = step(
            state2, {k: jnp.asarray(v) for k, v in data2.next_batch().items()}
        )
    final_b = jax.tree.leaves(state2.params)
    for a, b in zip(final_a, final_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
