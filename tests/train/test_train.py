"""Training substrate: loss decreases, microbatching equivalence, optimizer."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptimizerConfig, init_optimizer, lr_at
from repro.train.train_step import create_train_state, make_train_step


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_at(cfg, jnp.asarray(100))) >= 0.1 * 1e-3 - 1e-9
    assert float(lr_at(cfg, jnp.asarray(55))) < 1e-3


def test_loss_decreases_small_lm():
    cfg = get_smoke("gemma-7b")
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100, clip_norm=1.0)
    state = create_train_state(cfg, opt_cfg, jax.random.key(0))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    )
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch step."""
    cfg = get_smoke("qwen3-14b")
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=1e9)
    state = create_train_state(cfg, opt_cfg, jax.random.key(1))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    )
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    s1, m1 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=4))(state, batch)
    # z-loss means microbatch-mean-of-means == full mean only when sizes are
    # equal, which they are here
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5
        )


def test_grad_clipping():
    opt_cfg = OptimizerConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    st = init_optimizer(opt_cfg, params)
    from repro.train.optimizer import apply_updates

    _p, _s, metrics = apply_updates(opt_cfg, params, grads, st)
    assert float(metrics["grad_norm"]) > 1.0  # raw norm reported


def test_data_determinism_and_cursor():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=7)
    a = SyntheticLM(cfg)
    b1 = a.next_batch()
    b2 = a.next_batch()
    # restore cursor -> identical replay
    b = SyntheticLM(cfg)
    b.load_state_dict({"step": 1})
    b2r = b.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_data_sharding_partitions():
    kw = dict(vocab_size=50, seq_len=4, global_batch=8, seed=3, n_shards=2)
    s0 = SyntheticLM(DataConfig(shard_id=0, **kw)).next_batch()
    s1 = SyntheticLM(DataConfig(shard_id=1, **kw)).next_batch()
    assert s0["tokens"].shape == (4, 4)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
