"""reprolint's AST rules against seeded-violation fixtures.

Each fixture under ``data/`` marks its violations inline with
``# VIOLATION RLxxx`` comments; the tests derive the expected (rule, line)
set from those markers, so fixture and expectation cannot drift apart.
Every marked line must be flagged, nothing unmarked may fire, and the
whole production tree must stay clean (the CI gate's exit-0 contract).
"""

import os
import re
import subprocess
import sys

import pytest

from repro.analysis import lint_paths, lint_source
from repro.analysis.rules import RULES

DATA = os.path.join(os.path.dirname(__file__), "data")
SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(__file__))),
    "src",
    "repro",
)

_MARK = re.compile(r"#\s*VIOLATION\s+(RL\d{3})")


def _expected(path):
    out = set()
    with open(path) as fh:
        for lineno, text in enumerate(fh, start=1):
            for m in _MARK.finditer(text):
                out.add((m.group(1), lineno))
    return out


def _fixture_paths():
    for root, dirs, files in os.walk(DATA):
        dirs[:] = [d for d in dirs if d != "__pycache__"]
        for fn in sorted(files):
            if fn.endswith(".py"):
                yield os.path.join(root, fn)


@pytest.mark.parametrize(
    "path", list(_fixture_paths()), ids=lambda p: os.path.relpath(p, DATA)
)
def test_fixture_violations_exactly_match_markers(path):
    expected = _expected(path)
    assert expected, f"fixture {path} declares no VIOLATION markers"
    findings = lint_source(open(path).read(), path)
    got = {(f.rule, f.line) for f in findings}
    missed = expected - got
    spurious = got - expected
    assert not missed, f"rules failed to fire: {sorted(missed)}"
    assert not spurious, (
        f"rules fired on unmarked lines: {sorted(spurious)}\n"
        + "\n".join(str(f) for f in findings)
    )


def test_every_rule_is_exercised_by_some_fixture():
    covered = set()
    for path in _fixture_paths():
        covered |= {r for r, _ in _expected(path)}
    assert covered == set(RULES), (
        f"rules without a seeded fixture: {sorted(set(RULES) - covered)}"
    )


def test_allow_suppression_is_line_scoped():
    src = (
        "def f(fn):\n"
        "    try:\n"
        "        return fn()\n"
        "    except Exception:  # reprolint: allow(broad-except) why\n"
        "        pass\n"
        "def g(fn):\n"
        "    try:\n"
        "        return fn()\n"
        "    except Exception:\n"
        "        pass\n"
    )
    findings = lint_source(src, "x.py")
    assert [f.line for f in findings if f.rule == "RL006"] == [9]


def test_allow_accepts_rule_id_and_slug():
    for tag in ("RL006", "broad-except"):
        src = (
            "def f(fn):\n"
            "    try:\n"
            "        return fn()\n"
            f"    except Exception:  # reprolint: allow({tag}) why\n"
            "        pass\n"
        )
        assert lint_source(src, "x.py") == []


def test_rule_filter_restricts_output():
    path = os.path.join(DATA, "bad_defaults_and_excepts.py")
    findings = lint_source(open(path).read(), path, rules=["RL004"])
    assert findings and all(f.rule == "RL004" for f in findings)


def test_clean_code_is_silent():
    src = (
        "import jax.numpy as jnp\n"
        "def step(carry, ids):\n"
        "    counts = jnp.zeros(8).at[ids].add(1.0)\n"
        "    f = carry + counts\n"
        "    return f, jnp.sum(f)\n"
    )
    assert lint_source(src, "x.py") == []


def test_production_tree_is_clean():
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_paths_skips_fixture_data_dirs():
    here = os.path.dirname(__file__)
    assert lint_paths([here]) == []


def test_cli_exit_codes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(__file__))), "src"
    )
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-contracts", SRC],
        env=env,
        capture_output=True,
        text=True,
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.analysis",
            "--no-contracts",
            os.path.join(DATA, "bad_host_sync.py"),
        ],
        env=env,
        capture_output=True,
        text=True,
    )
    assert bad.returncode == 1
    assert "RL001" in bad.stdout
