"""Fixture: RL008 float64 hazards — path mimics a kernel entry point
(matched by the `*/kernels/*/ops.py` glob)."""
import numpy as np

import jax.numpy as jnp


def promote(x):
    a = jnp.zeros(4, jnp.float64)  # VIOLATION RL008 (jnp.float64)
    b = np.float64(1.0)  # VIOLATION RL008 (np.float64)
    c = x.astype("float64")  # VIOLATION RL008 ('float64' string)
    d = jnp.asarray(x, dtype=float)  # VIOLATION RL008 (dtype=float)
    e = x.astype(float)  # VIOLATION RL008 (.astype(float))
    return a, b, c, d, e


def stay_f32(x):
    return jnp.asarray(x, jnp.float32)  # clean
