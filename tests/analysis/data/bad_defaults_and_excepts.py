"""Fixture: RL004 mutable defaults and RL006 broad excepts."""


def accumulate(x, seen=[]):  # VIOLATION RL004 (list default)
    seen.append(x)
    return seen


def lookup(key, table={}):  # VIOLATION RL004 (dict default)
    return table.get(key)


def clean(key, table=None):
    return (table or {}).get(key)


def swallow(fn):
    try:
        return fn()
    except:  # VIOLATION RL006 (bare except)
        return None


def swallow_broad(fn):
    try:
        return fn()
    except Exception:  # VIOLATION RL006 (no re-raise)
        return None


def wrap_and_reraise(fn):
    try:
        return fn()
    except Exception as e:  # clean: re-raises
        raise RuntimeError("wrapped") from e


def annotated(fn):
    try:
        return fn()
    except Exception:  # reprolint: allow(broad-except) fixture shows the escape hatch
        return None
