"""Fixture: RL007 thread-shared-write violations (direct and transitive)."""
import threading


class State:
    def __init__(self):
        self.count = 0
        self.done = False
        self.owned = 0


def _helper(st):
    st.count += 1  # VIOLATION RL007 (reached transitively from the target)


def _worker(st):
    _helper(st)
    st.done = True  # VIOLATION RL007 (written from the thread target)
    st.owned += 1  # clean: declared below
    # reprolint: thread-owned(owned)


def launch(st):
    t = threading.Thread(target=_worker, args=(st,))
    t.start()
    return t


def not_threaded(st):
    st.count = 0  # clean: not reachable from any Thread target
