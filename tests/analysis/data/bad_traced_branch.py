"""Fixture: RL003 traced-branch violations (and laundered non-violations)."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def threshold(x, limit):
    if x > limit:  # VIOLATION RL003 (if on tracer)
        return x
    while x < limit:  # VIOLATION RL003 (while on tracer)
        x = x + 1.0
    assert x >= 0  # VIOLATION RL003 (assert on tracer)
    return x


@functools.partial(jax.jit, static_argnames=("mode",))
def dispatch(x, mode):
    if mode == "fast":  # clean: static_argnames param
        return x * 2.0
    if x.shape[0] > 4:  # clean: .shape is static
        return x
    if mode is None:  # clean: identity test
        return -x
    return jnp.abs(x)


def make_update_step(kind):
    if kind == "bad":  # clean: factory prefix, host config dispatch
        scale = 2.0
    else:
        scale = 1.0

    def step(carry, ids):
        return carry * scale, jnp.sum(ids)

    return step
