"""Fixture: RL005 slots violations — path mimics a hot-path module
(matched by the `*/core/policies.py` glob)."""
from dataclasses import dataclass
from typing import NamedTuple


class HotPolicy:  # VIOLATION RL005 (no __slots__)
    def __init__(self):
        self.hits = 0


@dataclass
class HotRecord:  # VIOLATION RL005 (dataclass without slots=True)
    hits: int


class SlottedPolicy:  # clean
    __slots__ = ("hits",)

    def __init__(self):
        self.hits = 0


@dataclass(slots=True)
class SlottedRecord:  # clean
    hits: int


class CarryOut(NamedTuple):  # clean: NamedTuple is exempt
    reward: float


class PolicyError(Exception):  # clean: exception types are exempt
    pass
