"""Fixture: RL001/RL002 violations inside traced functions.

Syntactically valid, deliberately broken; reprolint's tests assert on the
exact (rule, line) pairs.  Never imported.
"""
import numpy as np

import jax
import jax.numpy as jnp


def step(carry, ids):  # traced via the PolicyDef name hint
    f = carry
    print("debug", ids)  # VIOLATION RL001 (print)
    x = f.item()  # VIOLATION RL001 (.item)
    jax.block_until_ready(f)  # VIOLATION RL001 (block_until_ready)
    y = float(f)  # VIOLATION RL001 (float on tracer)
    z = np.asarray(f)  # VIOLATION RL002 (numpy on tracer)
    return f + x + y + jnp.sum(z), jnp.sum(ids)


def update_step(carry, ids):  # traced via the _step suffix hint
    ok = int(ids.shape[0])  # clean: .shape is static
    n = len(carry)  # clean: len() launders
    return carry, ok + n
