"""PolicyDef contract checker: clean registry passes, seeded breakage fails.

The positive half is the CI gate itself (every registered kind and flavor
passes all checks without a device step).  The negative half registers
deliberately broken PolicyDefs — dtype-drifting carries, dropped StepOut
fields, silently-accepted sizes — and asserts the checker names the exact
contract each one breaks.
"""

from typing import NamedTuple

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import check_all, check_policy_def
from repro.analysis.contracts import COST_MODEL_KINDS, EXTRA_FLAVORS
from repro.cachesim import api
from repro.core.policies import ENGINE_DEFS


# ---------------------------------------------------------------------------
# positive: the live registry
# ---------------------------------------------------------------------------
def test_every_registered_kind_passes():
    reports = check_all(include_flavors=True)
    bad = [str(r) for r in reports if not r.ok]
    assert not bad, "\n".join(bad)
    kinds = {r.kind for r in reports}
    assert kinds == set(api.policy_def_kinds())


def test_flavor_matrix_covers_tree_and_sized_kinds():
    flavored = {k for k, _ in EXTRA_FLAVORS}
    assert {"ogb", "ogb_sized", "lru", "lfu", "ftpl"} <= flavored
    assert {"ogb_sized", "gds"} <= set(api.policy_def_kinds())
    assert COST_MODEL_KINDS <= set(api.policy_def_kinds())


def test_checks_stay_abstract():
    """The gate never executes a policy step on device: carry stability is
    asserted via ``jax.eval_shape`` over ``ShapeDtypeStruct`` avals and
    donation via ``jit(...).lower()``, so checking a kind with a huge
    catalog must stay instant (it would OOM/stall if steps ran)."""
    reports = check_all(
        kinds=["ogb", "lru", "gds"], catalog_size=2_000_003, capacity=4096
    )
    assert all(r.ok for r in reports), [str(r) for r in reports]


# ---------------------------------------------------------------------------
# negative: seeded contract breakage, checked via a temp registration
# ---------------------------------------------------------------------------
class _Carry(NamedTuple):
    f: jax.Array
    t: jax.Array


def _register(kind, pd):
    ENGINE_DEFS[kind] = lambda **kw: pd


@pytest.fixture
def scratch_registry():
    added = []

    def add(kind, pd):
        _register(kind, pd)
        added.append(kind)

    yield add
    for kind in added:
        ENGINE_DEFS.pop(kind, None)


def _base_init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
               n_slots=None, sizes=None, costs=None):
    if sizes is not None or costs is not None:
        raise ValueError("unit-size test policy")
    return _Carry(
        f=jnp.zeros(catalog_size, jnp.float32),
        t=jnp.zeros((), jnp.int32),
    )


def _out(reward, hits, occ):
    return api.StepOut(
        jnp.float32(reward), jnp.int32(hits), jnp.float32(0.0),
        jnp.float32(occ),
    )


def test_dtype_drift_is_caught(scratch_registry):
    def step(carry, ids):
        # t drifts int32 -> float32: scan rejects it, cache misses forever
        return _Carry(carry.f, carry.t + 1.0), _out(0.0, 0, 0.0)

    scratch_registry(
        "broken_dtype",
        api.PolicyDef(kind="broken_dtype", name="X", init=_base_init,
                      step=step),
    )
    rep = check_policy_def("broken_dtype")
    assert not rep.ok
    assert any("dtype" in e or "leaf" in e for e in rep.errors), rep.errors


def test_treedef_change_is_caught(scratch_registry):
    def step(carry, ids):
        return (carry.f, carry.t + 1), _out(0.0, 0, 0.0)  # tuple != _Carry

    scratch_registry(
        "broken_tree",
        api.PolicyDef(kind="broken_tree", name="X", init=_base_init,
                      step=step),
    )
    rep = check_policy_def("broken_tree")
    assert not rep.ok
    assert any("treedef" in e for e in rep.errors), rep.errors


def test_shape_drift_is_caught(scratch_registry):
    def step(carry, ids):
        return _Carry(jnp.pad(carry.f, (0, 1)), carry.t + 1), _out(
            0.0, 0, 0.0
        )

    scratch_registry(
        "broken_shape",
        api.PolicyDef(kind="broken_shape", name="X", init=_base_init,
                      step=step),
    )
    rep = check_policy_def("broken_shape")
    assert not rep.ok


def test_bad_stepout_dtype_is_caught(scratch_registry):
    def step(carry, ids):
        out = api.StepOut(
            jnp.float64(0.0) if jax.config.jax_enable_x64
            else jnp.int32(0),  # reward must be f32
            jnp.int32(0), jnp.float32(0.0), jnp.float32(0.0),
        )
        return _Carry(carry.f, carry.t + 1), out

    scratch_registry(
        "broken_out",
        api.PolicyDef(kind="broken_out", name="X", init=_base_init,
                      step=step),
    )
    rep = check_policy_def("broken_out")
    assert not rep.ok
    assert any("reward" in e for e in rep.errors), rep.errors


def test_silently_dropped_sizes_are_caught(scratch_registry):
    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if costs is not None:
            raise ValueError("no cost model")
        return _Carry(  # accepts sizes=... but never uses them
            f=jnp.zeros(catalog_size, jnp.float32),
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, ids):
        return _Carry(carry.f, carry.t + 1), _out(0.0, 0, 0.0)

    scratch_registry(
        "broken_sized",
        api.PolicyDef(kind="broken_sized", name="X", init=init, step=step),
    )
    rep = check_policy_def("broken_sized")
    assert not rep.ok
    assert any("byte_hits" in e or "silently" in e for e in rep.errors), (
        rep.errors
    )


def test_bad_init_signature_is_caught(scratch_registry):
    def init(n, c, seed=0):  # wrong positional names, missing kwargs
        return _Carry(jnp.zeros(n, jnp.float32), jnp.zeros((), jnp.int32))

    def step(carry, ids):
        return _Carry(carry.f, carry.t + 1), _out(0.0, 0, 0.0)

    scratch_registry(
        "broken_sig",
        api.PolicyDef(kind="broken_sig", name="X", init=init, step=step),
    )
    rep = check_policy_def("broken_sig")
    assert not rep.ok
    assert any("init" in e for e in rep.errors), rep.errors


def test_dead_array_state_is_caught(scratch_registry):
    class _Fat(NamedTuple):
        f: jax.Array
        ghost: jax.Array  # written fresh, never read — dead array state
        t: jax.Array

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError("unit-size test policy")
        return _Fat(
            f=jnp.zeros(catalog_size, jnp.float32),
            ghost=jnp.zeros(catalog_size, jnp.float32),
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, ids):
        return _Fat(
            carry.f,
            jnp.zeros_like(carry.f),  # rebuilt without reading the old one
            carry.t + 1,
        ), _out(0.0, 0, 0.0)

    scratch_registry(
        "broken_dead",
        api.PolicyDef(kind="broken_dead", name="X", init=init, step=step),
    )
    rep = check_policy_def("broken_dead")
    assert not rep.ok
    assert any("never read" in e for e in rep.errors), rep.errors


def test_costs_on_cost_blind_kind_must_reject():
    """The live unit-size kinds all reject costs= loudly."""
    with pytest.raises(ValueError):
        api.policy_def("ogb").init(
            16, 4, seed=0, eta=0.05, horizon=64, n_slots=None,
            costs=np.ones(16),
        )


# ---------------------------------------------------------------------------
# fleet-stacking contract (cachesim.fleet tenant axis)
# ---------------------------------------------------------------------------
def test_every_kind_is_fleet_stackable():
    """Every registered kind/flavor must pass the fleet checks: carries
    built with different per-tenant capacity/seed under a shared n_slots
    pad stack, and the stacked carry vmaps with per-tenant ids."""
    for rep in check_all(include_flavors=True):
        assert "fleet-stackable" in rep.checks, (rep.kind, rep.options)
        assert "fleet-vmappable" in rep.checks, (rep.kind, rep.options)


def test_capacity_shaped_carry_fails_fleet_stacking(scratch_registry):
    class _SlotCarry(NamedTuple):
        slots: jax.Array
        t: jax.Array

    def init(catalog_size, capacity, *, seed=0, eta=None, horizon=None,
             n_slots=None, sizes=None, costs=None):
        if sizes is not None or costs is not None:
            raise ValueError("unit-size test policy")
        # BUG: sizes a leaf by capacity and ignores the n_slots pad, so
        # heterogeneous-capacity tenants cannot stack
        return _SlotCarry(
            slots=jnp.full(capacity, -1, jnp.int32),
            t=jnp.zeros((), jnp.int32),
        )

    def step(carry, ids):
        occ = jnp.sum((carry.slots >= 0).astype(jnp.float32))
        return _SlotCarry(carry.slots, carry.t + 1), api.StepOut(
            jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0), occ
        )

    scratch_registry(
        "broken_fleet",
        api.PolicyDef(kind="broken_fleet", name="X", init=init, step=step),
    )
    rep = check_policy_def("broken_fleet")
    assert not rep.ok
    assert any("fleet-stacking" in e for e in rep.errors), rep.errors
