"""Recompilation regression: the documented compile counts, enforced.

The perf story of the execution layer is a compile-count story:
``run_stream`` re-batches any trace into fixed-shape segments so a whole
stream costs two executable compiles (steady + tail), resuming via
``api.run(carry=...)`` costs zero, and a full parameter sweep costs one.
``track_compiles`` observes the executable-cache misses (and jax's own
``log_compiles`` stream) without touching the computation; these tests
pin the counts so a carry-layout or cache-keying regression fails loudly
instead of silently recompiling every segment.

Geometry note: jit caches persist process-wide, so each test uses a
unique (catalog, window) pair — its shapes are traced nowhere else in
the suite.
"""


from repro.analysis import track_compiles
from repro.cachesim import api
from repro.cachesim.tracelab import run_stream
from repro.cachesim.traces import zipf


def _trace(n, t, seed):
    return zipf(n, t, alpha=0.8, seed=seed)


def test_run_stream_two_compiles_steady_plus_tail():
    n, c, w = 101, 7, 19
    seg = 3 * w  # 3 windows per steady segment
    trace = _trace(n, 4 * seg + 2 * w, seed=11)  # 4 segments + 2-window tail
    pd = api.policy_def("ogb")

    api.clear_executable_cache()
    with track_compiles() as log:
        sr = run_stream(
            pd, [trace], n, c, window=w, segment_len=seg, eta=0.05,
            horizon=trace.size, prefetch=2,
        )
    assert sr.T == trace.size  # 4*seg + 2*w is an exact multiple of w
    # 4 same-shape steady segments share one executable; the shorter tail
    # segment compiles once more
    log.assert_executables(2)
    assert all(e.name == "run_fn" for e in log.executables)
    # jax's log_compiles stream agrees (shapes unique to this test)
    assert log.trace_count("run_fn") == 2


def test_resume_from_carry_zero_recompiles():
    n, c, w = 103, 9, 23
    pd = api.policy_def("ogb")
    t1 = _trace(n, 8 * w, seed=3)
    t2 = _trace(n, 8 * w, seed=4)

    first = api.run(pd, t1, n, c, window=w, eta=0.05, keep_carry=True)
    with track_compiles() as log:
        second = api.run(pd, t2, window=w, carry=first.carry)
    assert second.T == t2.size
    log.assert_no_recompilation()
    assert log.trace_count("run_fn") == 0


def test_sweep_is_one_compile():
    n, w = 107, 29
    trace = _trace(n, 6 * w, seed=7)
    pd = api.policy_def("ogb")

    api.clear_executable_cache()
    with track_compiles() as log:
        sw = api.sweep(
            pd, trace, n, capacities=[5, 11], etas=[0.02, 0.05, 0.1],
            window=w, track_opt=False,
        )
    assert len(sw.combos) == 6
    log.assert_executables(1)
    assert log.executables[0].name == "one"


def test_same_shape_rerun_hits_the_cache():
    n, c, w = 109, 5, 31
    pd = api.policy_def("lru")
    trace = _trace(n, 4 * w, seed=9)

    api.clear_executable_cache()
    with track_compiles() as log:
        api.run(pd, trace, n, c, window=w)
        api.run(pd, trace, n, c, window=w)  # identical shapes: cache hit
    log.assert_executables(1)


def test_tracker_detaches_cleanly():
    n, c, w = 113, 6, 37
    pd = api.policy_def("fifo")
    trace = _trace(n, 2 * w, seed=13)
    with track_compiles() as outer:
        with track_compiles() as inner:
            api.run(pd, trace, n, c, window=w)
        n_inner = inner.executable_count
        api.run(pd, trace, n, c, window=w)  # cache hit, no new events
    assert inner.executable_count == n_inner  # inner sealed after exit
    assert outer.executable_count >= n_inner  # outer saw at least as much
