"""Dry-run machinery on a small fake mesh (8 host devices, subprocess)."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def _run_small_dryrun(arch, shape):
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import get_smoke, SHAPES, ShapeConfig
from repro.dist.param_sharding import param_shardings, batch_shardings, cache_shardings, state_shardings
from repro.dist.sharding import default_rules, use_sharding
from repro.models.model import forward_train, init_params, input_specs, decode_step
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import create_train_state, make_train_step
from repro.launch.hlo_analysis import analyze_collectives

cfg = get_smoke("{arch}")
shape = ShapeConfig("t", 32, 8, "{shape}")
mesh = jax.make_mesh((2, 4), ("data", "model"))
specs = input_specs(cfg, shape)
if shape.kind == "train":
    opt_cfg = OptimizerConfig(total_steps=10)
    step = make_train_step(cfg, opt_cfg)
    state_shape = jax.eval_shape(lambda: create_train_state(cfg, opt_cfg, jax.random.key(0)))
    s_sh = state_shardings(cfg, state_shape, mesh)
    b_sh = batch_shardings(mesh, specs)
    with use_sharding(mesh, default_rules()):
        compiled = jax.jit(step, in_shardings=(s_sh, b_sh)).lower(state_shape, specs).compile()
else:
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    p_sh = param_shardings(cfg, params_shape, mesh)
    c_sh = cache_shardings(cfg, specs["cache"], mesh)
    t_sh = batch_shardings(mesh, specs["tokens"])
    fn = lambda p, c, t: decode_step(cfg, p, c, t)
    with use_sharding(mesh, default_rules()):
        compiled = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh)).lower(
            params_shape, specs["cache"], specs["tokens"]).compile()
# per-device list on older jax (kept inline: importing repro.launch.dryrun
# for its _normalize_cost would overwrite this process's XLA_FLAGS)
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0] if cost else {{}}
coll = analyze_collectives(compiled.as_text())
print(json.dumps({{"flops": float(cost.get("flops", 0)), "collectives": coll}}))
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["glm4-9b", "granite-moe-1b-a400m", "rwkv6-1.6b"])
def test_train_cell_compiles_small_mesh(arch):
    r = _run_small_dryrun(arch, "train")
    assert r["flops"] > 0
    # data parallelism must produce gradient reductions
    assert any("all-reduce" in k or "reduce-scatter" in k for k in r["collectives"]), r


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "jamba-1.5-large-398b"])
def test_decode_cell_compiles_small_mesh(arch):
    r = _run_small_dryrun(arch, "decode")
    assert r is not None


def test_production_mesh_shapes():
    """make_production_mesh axis layout (no device init needed for spec)."""
    import inspect

    from repro.launch import mesh as mesh_mod

    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_dryrun_artifacts_complete():
    """Every runnable (arch x shape) cell has both mesh artifacts on disk."""
    from repro.configs.base import cells

    d = os.path.join(ROOT, "benchmarks", "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    missing, failed = [], []
    for arch, shape in cells():
        for mesh_kind in ("single", "multi"):
            path = os.path.join(d, f"{arch}__{shape}__{mesh_kind}.json")
            if not os.path.exists(path):
                missing.append((arch, shape, mesh_kind))
                continue
            with open(path) as f:
                if not json.load(f).get("ok"):
                    failed.append((arch, shape, mesh_kind))
    assert not missing, f"missing dry-run cells: {missing}"
    assert not failed, f"failed dry-run cells: {failed}"
