"""Minimal stand-in for ``hypothesis`` when it isn't installed.

Implements just the surface the OGB property tests use — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)`` and the
``integers`` / ``floats`` / ``booleans`` strategies — by drawing examples from
a deterministically seeded RNG.  No shrinking, no database: a failing example
is reported with its drawn values so it can be reproduced by hand.  The real
hypothesis is preferred whenever importable.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Dict

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

    @staticmethod
    def lists(
        elements: _Strategy, min_size: int = 0, max_size: int = 10
    ) -> _Strategy:
        def draw(rng: random.Random):
            size = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(size)]

        return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strats: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES)

        @functools.wraps(fn)
        def wrapper():
            # crc32, not hash(): str hashes are salted per process, and drawn
            # examples must be reproducible across runs
            rng = random.Random(0xC0FFEE ^ zlib.crc32(fn.__name__.encode()))
            for n in range(max_examples):
                drawn: Dict[str, Any] = {
                    name: s.draw(rng) for name, s in strats.items()
                }
                try:
                    fn(**drawn)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"{fn.__name__} failed on example {n}: {drawn!r}"
                    ) from e

        # pytest must see a zero-arg signature, not the wrapped one (it would
        # otherwise look for fixtures named after the strategy kwargs)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
