"""Unit tests for the repro.dist.sharding mesh/rules registry."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    current_mesh,
    current_rules,
    default_rules,
    logical_to_spec,
    named_sharding,
    shard,
    use_sharding,
)

SIZES = {"data": 2, "model": 4}
POD_SIZES = {"pod": 2, "data": 2, "model": 4}


def test_default_rules_single_vs_multi_pod():
    single = default_rules()
    multi = default_rules(multi_pod=True)
    assert single.rules["batch"] == "data"
    assert multi.rules["batch"] == ("pod", "data")
    for r in (single, multi):
        assert r.rules["heads"] == "model"
        assert r.rules["fsdp"] == "data"
        assert r.rules["kv_heads"] is None


def test_rule_override_precedence():
    base = default_rules()
    over = base.with_overrides(embed="data", heads=None)
    # overrides win over the base table...
    assert over.mesh_axes("embed") == ("data",)
    assert over.mesh_axes("heads") == ()
    # ...without mutating the base, and untouched names pass through
    assert base.mesh_axes("embed") == ()
    assert over.mesh_axes("ff") == ("model",)
    # unknown logical names resolve to replicated, not an error
    assert over.mesh_axes("no_such_axis") == ()
    assert over.mesh_axes(None) == ()


def test_logical_to_spec_basics():
    rules = default_rules()
    spec = logical_to_spec(("batch", None, "heads"), rules, SIZES, (4, 3, 8))
    assert spec == P("data", None, "model")


def test_logical_to_spec_drops_non_divisible():
    rules = default_rules()
    # 7 % 4 != 0: the heads constraint must be dropped, batch kept
    spec = logical_to_spec(("batch", "heads"), rules, SIZES, (4, 7))
    assert spec == P("data")
    # without a shape there is no divisibility information: keep both
    spec = logical_to_spec(("batch", "heads"), rules, SIZES, None)
    assert spec == P("data", "model")


def test_logical_to_spec_drops_missing_mesh_axis():
    rules = default_rules(multi_pod=True)
    # "pod" is absent from a single-pod mesh: batch falls back to "data" only
    spec = logical_to_spec(("batch", None), rules, SIZES, (4, 3))
    assert spec == P("data")
    spec = logical_to_spec(("batch", None), rules, POD_SIZES, (4, 3))
    assert spec == P(("pod", "data"))


def test_logical_to_spec_no_mesh_axis_reuse():
    rules = default_rules()
    # "ff" and "heads" both map to "model": the later dim must be dropped
    spec = logical_to_spec(("ff", "heads"), rules, SIZES, (8, 8))
    assert spec == P("model")


def test_logical_to_spec_multi_axis_divisibility():
    rules = default_rules(multi_pod=True)
    # batch -> ("pod", "data"), total 4: 6 is not divisible -> dropped
    spec = logical_to_spec(("batch",), rules, POD_SIZES, (6,))
    assert spec == P()


def test_use_sharding_nesting_and_restoration():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    outer_rules = default_rules()
    inner_rules = outer_rules.with_overrides(batch=None)
    assert current_mesh() is None and current_rules() is None
    with use_sharding(mesh, outer_rules):
        assert current_mesh() is mesh
        assert current_rules() is outer_rules
        with use_sharding(mesh, inner_rules):
            assert current_rules() is inner_rules
        # inner exit restores the outer frame, not the empty stack
        assert current_rules() is outer_rules
    assert current_mesh() is None and current_rules() is None


def test_use_sharding_restores_on_exception():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(RuntimeError):
        with use_sharding(mesh, default_rules()):
            raise RuntimeError("boom")
    assert current_mesh() is None and current_rules() is None


def test_shard_is_noop_off_context():
    x = jnp.ones((4, 8))
    y = shard(x, "batch", "heads")
    assert y is x


def test_shard_applies_constraint_in_context():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 8))
    with use_sharding(mesh, default_rules()):
        y = shard(x, "batch", "heads")
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        # and inside jit it must trace cleanly
        out = jax.jit(lambda a: shard(a * 2.0, "batch", "heads"))(x)
    np.testing.assert_array_equal(np.asarray(out), 2 * np.asarray(x))


def test_shard_rank_mismatch_raises():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_sharding(mesh, default_rules()):
        with pytest.raises(ValueError, match="rank"):
            shard(jnp.ones((4, 8)), "batch")
    # arity is validated off-context too, so CPU tests catch bad annotations
    with pytest.raises(ValueError, match="rank"):
        shard(jnp.ones((4, 8)), "batch")


def test_named_sharding_resolves_logical_names():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = named_sharding(mesh, ("batch", None), shape=(4, 3))
    assert s.mesh is mesh
    assert s.spec == P("data")
