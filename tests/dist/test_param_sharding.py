"""Unit tests for the name-based pytree sharding resolvers."""

from types import SimpleNamespace


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_smoke
from repro.dist.param_sharding import (
    FSDP_THRESHOLD,
    batch_shardings,
    cache_shardings,
    is_fsdp,
    param_shardings,
    state_shardings,
)
from repro.models.model import init_cache, init_params


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _cfg_with_params(n):
    return SimpleNamespace(param_count=lambda: n)


def test_fsdp_threshold_boundary():
    assert not is_fsdp(_cfg_with_params(FSDP_THRESHOLD - 1))
    # exactly at the threshold: pure TP/DP (strict inequality)
    assert not is_fsdp(_cfg_with_params(FSDP_THRESHOLD))
    assert is_fsdp(_cfg_with_params(FSDP_THRESHOLD + 1))


def test_fsdp_remaps_embed_dim_to_data():
    mesh = _mesh()
    wq = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    tree = {"attn": {"wq": wq}}
    below = param_shardings(_cfg_with_params(1), tree, mesh)
    above = param_shardings(_cfg_with_params(int(FSDP_THRESHOLD * 2)), tree, mesh)
    # pure TP: d_model dim replicated, heads dim over "model"
    assert below["attn"]["wq"].spec == P(None, "model")
    # FSDP: d_model dim additionally sharded over "data"
    assert above["attn"]["wq"].spec == P("data", "model")


def test_param_shardings_smoke_model_structure_and_rules():
    cfg = get_smoke("glm4-9b")
    mesh = _mesh()
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    sh = param_shardings(cfg, params_shape, mesh)
    assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(
        params_shape
    )
    assert all(isinstance(s, NamedSharding) for s in jax.tree_util.tree_leaves(sh))
    # embedding: vocab dim over "model"
    assert sh["embed"].spec == P("model")
    # stacked layer weights: leading L dim replicated, TP on trailing dims
    assert sh["blocks"]["attn"]["wq"].spec == P(None, None, "model")
    assert sh["blocks"]["attn"]["wo"].spec == P(None, "model")
    assert sh["blocks"]["mlp"]["w_down"].spec == P(None, "model")
    # GQA kv projections and norms stay replicated
    assert sh["blocks"]["attn"]["wk"].spec == P()
    assert sh["blocks"]["ln1"].spec == P()


def test_fsdp_moe_hidden_dim_follows_data():
    """Above the threshold, expert weights take the F~data (ZeRO-3) layout
    moe_forward's decode path relies on — not d_model~data."""
    mesh = _mesh()
    tree = {
        "moe": {
            "w_gate": jax.ShapeDtypeStruct((8, 64, 32), jnp.float32),
            "w_down": jax.ShapeDtypeStruct((8, 32, 64), jnp.float32),
        }
    }
    sh = param_shardings(_cfg_with_params(int(FSDP_THRESHOLD * 2)), tree, mesh)
    assert sh["moe"]["w_gate"].spec == P("model", None, "data")
    assert sh["moe"]["w_down"].spec == P("model", "data")


def test_moe_expert_weights_sharded_over_experts():
    cfg = get_smoke("granite-moe-1b-a400m")
    mesh = _mesh()
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    sh = param_shardings(cfg, params_shape, mesh)
    moe = sh["blocks"]["moe"]
    # (L, E, d, f): experts over "model" (EP), hidden replicated below FSDP
    assert moe["w_gate"].spec == P(None, "model")
    assert moe["w_down"].spec == P(None, "model")
    assert moe["router"].spec == P()


def test_state_shardings_moments_follow_params():
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import create_train_state

    cfg = get_smoke("glm4-9b")
    mesh = _mesh()
    state_shape = jax.eval_shape(
        lambda: create_train_state(cfg, OptimizerConfig(total_steps=10), jax.random.key(0))
    )
    sh = state_shardings(cfg, state_shape, mesh)
    assert sh.params["blocks"]["attn"]["wq"].spec == sh.opt.m["blocks"]["attn"]["wq"].spec
    assert sh.opt.m["blocks"]["attn"]["wq"].spec == P(None, None, "model")
    assert sh.opt.step.spec == P()  # scalar counter replicated


def test_batch_shardings_leading_dim_only():
    mesh = _mesh()
    specs = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
    }
    sh = batch_shardings(mesh, specs)
    assert sh["tokens"].spec == P("data")
    # a bare leaf (decode tokens) works too
    one = batch_shardings(mesh, jax.ShapeDtypeStruct((8,), jnp.int32))
    assert one.spec == P("data")


def test_cache_shardings_find_batch_dim_across_families():
    mesh = _mesh()
    # dense: kv leaves are (L, B, S, kvh, hd)
    cfg = get_smoke("mistral-nemo-12b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 16))
    sh = cache_shardings(cfg, cache, mesh)
    assert sh["kv"]["k"].spec == P(None, "data")
    assert sh["pos"].spec == P()
    # hybrid: conv/ssm carry two stacked leading dims before batch
    cfg_h = get_smoke("jamba-1.5-large-398b")
    cache_h = jax.eval_shape(lambda: init_cache(cfg_h, 4, 16))
    sh_h = cache_shardings(cfg_h, cache_h, mesh)
    assert sh_h["conv"].spec == P(None, None, "data", None, "model")
    assert sh_h["ssm"].spec == P(None, None, "data", "model")
    # ssm (rwkv): recurrent state is (L, B, ...)
    cfg_s = get_smoke("rwkv6-1.6b")
    cache_s = jax.eval_shape(lambda: init_cache(cfg_s, 4, 16))
    sh_s = cache_shardings(cfg_s, cache_s, mesh)
    assert sh_s["tm_s"].spec == P(None, "data")


def test_odd_batch_falls_back_to_replicated():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # rule resolution itself (not device layout) decides the fallback: on a
    # {"data": 2} mesh a batch of 3 cannot be split evenly
    from repro.dist.sharding import default_rules, logical_to_spec

    spec = logical_to_spec(("batch",), default_rules(), {"data": 2, "model": 4}, (3,))
    assert spec == P()
    # end-to-end on the real (1,1) mesh: still a valid NamedSharding
    one = batch_shardings(mesh, jax.ShapeDtypeStruct((3,), jnp.int32))
    assert isinstance(one, NamedSharding)
