"""Unit tests for StragglerMonitor / FaultConfig timeout accounting."""

import pytest

from repro.dist.fault import FaultConfig, StragglerMonitor


def _cfg(**kw):
    base = dict(straggler_factor=2.0, warmup_steps=2, ewma_alpha=0.5,
                max_consecutive_stragglers=3)
    base.update(kw)
    return FaultConfig(**base)


def test_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(straggler_factor=1.0)
    with pytest.raises(ValueError):
        FaultConfig(ewma_alpha=0.0)


def test_steady_steps_never_flagged():
    mon = StragglerMonitor(_cfg())
    assert not any(mon.observe(i, 1.0) for i in range(20))
    assert mon.n_stragglers == 0
    assert mon.excess_s == 0.0
    assert abs(mon.baseline_s - 1.0) < 1e-12


def test_warmup_steps_never_flagged():
    # the first (compile) step is routinely 100x the steady step
    mon = StragglerMonitor(_cfg(warmup_steps=2))
    assert not mon.observe(0, 100.0)
    assert not mon.observe(1, 1.0)
    assert mon.n_stragglers == 0
    # warmup must not seed the baseline either
    assert mon.baseline_s is None


def test_warmup_compile_time_does_not_mask_stragglers():
    """A 100x compile step must not inflate the threshold after warmup."""
    mon = StragglerMonitor(_cfg(warmup_steps=1, ewma_alpha=0.1))
    mon.observe(0, 100.0)  # compile
    for i in range(1, 6):
        assert not mon.observe(i, 1.0)
    assert mon.baseline_s == pytest.approx(1.0)
    # a genuinely sick step right after warmup is caught immediately
    assert mon.observe(6, 5.0)


def test_spike_flagged_with_excess_accounting():
    mon = StragglerMonitor(_cfg())
    for i in range(5):
        mon.observe(i, 1.0)
    baseline = mon.baseline_s
    assert mon.observe(5, 5.0)  # 5.0 > 2.0 * 1.0
    assert mon.n_stragglers == 1
    assert mon.last_flagged_step == 5
    # excess is time past the threshold, not past the baseline
    assert mon.excess_s == pytest.approx(5.0 - 2.0 * baseline)
    # the straggler must not contaminate the baseline
    assert mon.baseline_s == pytest.approx(baseline)
    # recovery resets the consecutive counter
    assert not mon.observe(6, 1.0)
    assert mon.consecutive_stragglers == 0


def test_should_reschedule_on_sustained_slowdown():
    mon = StragglerMonitor(_cfg(max_consecutive_stragglers=3))
    for i in range(5):
        mon.observe(i, 1.0)
    for i in range(5, 8):
        assert mon.observe(i, 10.0)
    assert mon.consecutive_stragglers == 3
    assert mon.should_reschedule()
    assert mon.straggler_ratio == pytest.approx(3 / 8)


def test_baseline_tracks_gradual_drift():
    # a 5% slowdown per-step is drift, not straggling: EWMA follows it
    mon = StragglerMonitor(_cfg(ewma_alpha=0.5))
    d = 1.0
    for i in range(30):
        assert not mon.observe(i, d)
        d *= 1.05
    assert mon.n_stragglers == 0
    assert mon.baseline_s > 1.5


def test_heartbeat_accounting():
    mon = StragglerMonitor(_cfg(heartbeat_timeout_s=1e9))
    assert mon.seconds_since_heartbeat() is None
    assert not mon.heartbeat_expired()  # never-beaten != expired
    mon.heartbeat()
    since = mon.seconds_since_heartbeat()
    assert since is not None and since >= 0.0
    assert not mon.heartbeat_expired()
    # observe() is also a liveness signal
    mon2 = StragglerMonitor(_cfg())
    mon2.observe(0, 1.0)
    assert mon2.seconds_since_heartbeat() is not None
