"""The ordered-store engine must survive a permanent-number redraw.

Regression test: ``_redraw_permanent_numbers`` used to rebuild ``d`` with a
hardcoded ``make_store("sorted")``, silently switching treap-backed runs onto
a different structure mid-run.
"""

import numpy as np

from repro.core.ogb import OGB
from repro.core.treap import Treap


def _drive(ogb, T=60, seed=0):
    rng = np.random.default_rng(seed)
    for j in rng.integers(0, ogb.N, size=T):
        ogb.request(int(j))


def test_redraw_preserves_treap_engine():
    ogb = OGB(
        50, 5, eta=0.1, store_kind="treap", lazy_init=False, redraw_period=3
    )
    assert isinstance(ogb.d, Treap)
    _drive(ogb)
    assert ogb.stats.sample_updates >= 3  # at least one redraw happened
    assert isinstance(ogb.d, Treap), "redraw switched the ordered-store engine"
    ogb.check_invariants()


def test_redraw_preserves_sorted_engine():
    ogb = OGB(
        50, 5, eta=0.1, store_kind="sorted", lazy_init=False, redraw_period=3
    )
    kind = type(ogb.d)
    _drive(ogb)
    assert type(ogb.d) is kind
    ogb.check_invariants()


def test_store_kind_attribute_persisted():
    assert OGB(20, 2, eta=0.1, store_kind="treap").store_kind == "treap"
    assert OGB(20, 2, eta=0.1).store_kind == "sorted"
