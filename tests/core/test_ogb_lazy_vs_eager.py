"""The paper's central correctness claim: the lazy O(log N) projection
(Algorithm 2: f̃ + rho + ordered z) maintains *exactly* the same fractional
state as eagerly projecting after every request.

We drive both representations with identical request sequences (hypothesis-
generated, plus targeted corner-case sequences) and require allclose at every
step.  Both the lazy_init (implicit virgin group) and eager-materialization
modes are covered, and both ordered-store engines (treap / sortedcontainers).
"""

import numpy as np

# real hypothesis when installed; otherwise tests/conftest.py has registered
# the vendored fallback (tests/_hypothesis_stub.py) under this name
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ogb import OGB
from repro.core.projection import project_capped_simplex


def eager_reference(N, C, eta, requests):
    """Materialized per-request gradient + eager projection."""
    f = np.full(N, C / N, dtype=np.float64)
    states = []
    for j in requests:
        y = f.copy()
        y[j] += eta
        f = project_capped_simplex(y, C)
        states.append(f.copy())
    return states


def run_lazy(N, C, eta, requests, lazy_init, store_kind="sorted"):
    ogb = OGB(
        N, C, eta=eta, batch_size=1, lazy_init=lazy_init, store_kind=store_kind
    )
    states = []
    for j in requests:
        ogb.update_probabilities(j)
        states.append(ogb.fractional_vector())
    return ogb, states


@given(
    n=st.integers(3, 30),
    c_frac=st.floats(0.1, 0.9),
    eta_exp=st.floats(-2.5, 0.5),
    seed=st.integers(0, 2**31 - 1),
    lazy=st.booleans(),
)
@settings(max_examples=120, deadline=None)
def test_lazy_equals_eager_random(n, c_frac, eta_exp, seed, lazy):
    C = max(1, min(n - 1, int(round(n * c_frac))))
    eta = 10.0**eta_exp
    rng = np.random.default_rng(seed)
    # zipf-ish skew so some items are requested repeatedly (exercises the
    # one-clip corner case) and others never (exercises zero-pops)
    w = 1.0 / np.arange(1, n + 1) ** 1.2
    reqs = rng.choice(n, size=60, p=w / w.sum())
    ref = eager_reference(n, C, eta, reqs)
    ogb, lazy_states = run_lazy(n, C, eta, reqs, lazy_init=lazy)
    for t, (a, b) in enumerate(zip(lazy_states, ref)):
        np.testing.assert_allclose(
            a, b, atol=1e-8, err_msg=f"diverged at request {t} (item {reqs[t]})"
        )
    ogb.check_invariants()


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_lazy_equals_eager_treap_engine(seed):
    n, C, eta = 12, 4, 0.3
    rng = np.random.default_rng(seed)
    reqs = rng.integers(0, n, size=80)
    ref = eager_reference(n, C, eta, reqs)
    _, states = run_lazy(n, C, eta, reqs, lazy_init=True, store_kind="treap")
    for a, b in zip(states, ref):
        np.testing.assert_allclose(a, b, atol=1e-8)


def test_one_clip_corner_case():
    """Hammer one item until it saturates at 1, then keep requesting it."""
    n, C = 6, 2
    eta = 0.4
    reqs = [0] * 8 + [1, 0, 2, 0, 0]
    ref = eager_reference(n, C, eta, reqs)
    ogb, states = run_lazy(n, C, eta, reqs, lazy_init=True)
    for t, (a, b) in enumerate(zip(states, ref)):
        np.testing.assert_allclose(a, b, atol=1e-8, err_msg=f"t={t}")
    assert ogb.stats.one_clip_events >= 1
    # item 0 must be saturated and the projection must be the identity now
    assert abs(ogb.value(0) - 1.0) < 1e-9


def test_zero_pop_cascade():
    """Tiny capacity + large eta drives many coordinates to zero."""
    n, C, eta = 20, 1, 0.9
    rng = np.random.default_rng(0)
    reqs = rng.integers(0, n, size=50)
    ref = eager_reference(n, C, eta, reqs)
    ogb, states = run_lazy(n, C, eta, reqs, lazy_init=True)
    for t, (a, b) in enumerate(zip(states, ref)):
        np.testing.assert_allclose(a, b, atol=1e-8, err_msg=f"t={t}")
    assert ogb.stats.zero_pops > 0


def test_virgin_group_mass_pop():
    """lazy_init: the untouched group must retire exactly when C/N - rho <= 0."""
    n, C, eta = 1000, 10, 0.5
    reqs = list(np.random.default_rng(1).integers(0, 30, size=200))
    ref = eager_reference(n, C, eta, reqs)
    _, states = run_lazy(n, C, eta, reqs, lazy_init=True)
    np.testing.assert_allclose(states[-1], ref[-1], atol=1e-8)


def test_requested_when_saturated_is_noop():
    n, C, eta = 5, 2, 0.5
    ogb = OGB(n, C, eta=eta, batch_size=1, lazy_init=False)
    for _ in range(10):
        ogb.update_probabilities(3)
    f_before = ogb.fractional_vector()
    ogb.update_probabilities(3)  # saturated: must be a no-op
    np.testing.assert_allclose(ogb.fractional_vector(), f_before, atol=0)


def test_average_zero_pops_bounded():
    """Paper §4.2: on average <= 1 + (N-C)/t coordinates hit zero per request;
    empirically (Fig 9 right) < 0.5 per request on real traces."""
    n, C = 500, 50
    T = 4000
    rng = np.random.default_rng(7)
    w = 1.0 / np.arange(1, n + 1) ** 0.8
    reqs = rng.choice(n, size=T, p=w / w.sum())
    ogb = OGB(n, C, horizon=T, batch_size=1, lazy_init=True)
    for j in reqs:
        ogb.update_probabilities(int(j))
    # zero_pops counts include the one-time virgin-group retirement (N-C-ish)
    assert ogb.stats.zero_pops / T < 1.0 + (n - C) / T + 0.5
