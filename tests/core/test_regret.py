"""Regret properties — the paper's headline guarantees, checked empirically.

* Theorem 3.1: OGB's regret <= sqrt(C (1 - C/N) T B) under the prescribed eta,
  for any trace.  We check it on adversarial + zipf + shifting traces (the
  theorem is a sup over traces, so every instance must satisfy the bound).
* Paper Fig 2 / [29]: LRU and LFU have *linear* regret on the adversarial
  round-robin trace (hit ratio -> 0), while OGB approaches OPT.
"""

import numpy as np
import pytest

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import adversarial, shifting_zipf, zipf
from repro.core.ogb import OGB, theoretical_regret_bound
from repro.core.policies import LFU, LRU
from repro.core.regret import (
    best_static_hits,
    best_static_set,
    prefix_opt_hits,
    regret_curve,
)


def test_prefix_opt_matches_bruteforce():
    rng = np.random.default_rng(0)
    trace = rng.integers(0, 12, size=300)
    C = 4
    curve = prefix_opt_hits(trace, C)
    # brute force at a few prefixes
    for t in [1, 7, 50, 150, 300]:
        counts = np.bincount(trace[:t], minlength=12)
        expect = np.sort(counts)[-C:].sum()
        assert curve[t] == expect, t


def test_opt_static_hits():
    trace = np.array([0, 0, 0, 1, 1, 2, 3, 0, 1])
    assert best_static_hits(trace, 2) == 7  # items 0 (4) + 1 (3)
    assert set(best_static_set(trace, 2)) == {0, 1}


@pytest.mark.parametrize(
    "trace_fn,kw",
    [
        (adversarial, {}),
        (zipf, {"alpha": 0.9}),
        (shifting_zipf, {"phase": 500}),
    ],
)
def test_ogb_regret_below_theorem_bound(trace_fn, kw):
    N, C, T = 200, 50, 4000
    trace = trace_fn(N, T, seed=1, **kw)
    ogb = OGB(N, C, horizon=T, batch_size=1, seed=0)
    simulate(ogb, trace, window=T)
    # fractional regret is what Theorem 3.1 bounds; hits fluctuate around it
    opt = best_static_hits(trace, C)
    frac_regret = opt - ogb.stats.fractional_reward
    bound = theoretical_regret_bound(C, N, T, 1)
    assert frac_regret <= bound * 1.05, (frac_regret, bound)


def test_adversarial_ogb_beats_lru_lfu():
    """Paper Fig 2: round-robin permutations starve LRU/LFU; OGB ~ OPT = C/N."""
    N, C, T = 300, 75, 30_000
    trace = adversarial(N, T, seed=2)
    r_lru = simulate(LRU(N, C), trace, window=T)
    r_lfu = simulate(LFU(N, C), trace, window=T)
    ogb = OGB(N, C, horizon=T, seed=0)
    r_ogb = simulate(ogb, trace, window=T)
    opt_ratio = C / N  # any C items give C/N on round-robin
    assert r_lru.hit_ratio < 0.05
    assert r_lfu.hit_ratio < 0.6 * opt_ratio
    assert r_ogb.hit_ratio > 0.7 * opt_ratio
    # and the fractional reward should be closer still
    assert ogb.stats.fractional_reward / T > 0.8 * opt_ratio


def test_lru_linear_regret_adversarial():
    """Regret curve of LRU grows ~linearly; OGB's flattens (sub-linear)."""
    N, C, T = 200, 50, 20_000
    trace = adversarial(N, T, seed=3)
    r_lru = simulate(LRU(N, C), trace, window=T)
    reg = regret_curve(r_lru.cum_hits, trace, C)
    # linear growth: regret at T ~ 2x regret at T/2 (within slack)
    assert reg[-1] > 1.6 * reg[len(reg) // 2]
    ogb = OGB(N, C, horizon=T, seed=0)
    r_ogb = simulate(ogb, trace, window=T)
    reg_ogb = regret_curve(r_ogb.cum_hits, trace, C)
    assert reg_ogb[-1] < 0.5 * reg[-1]
