"""Paper footnote 4: general rewards w_{t,i} (e.g. retrieval costs).

The lazy projection must still match the eager oracle when the gradient step
is eta * w_t, and a cost-aware OGB should learn to prefer expensive items.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ogb import OGB
from repro.core.projection import project_capped_simplex


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_weighted_lazy_equals_eager(seed):
    n, C, eta = 15, 5, 0.2
    rng = np.random.default_rng(seed)
    reqs = rng.integers(0, n, size=50)
    weights = rng.uniform(0.2, 3.0, size=50)

    f = np.full(n, C / n)
    ogb = OGB(n, C, eta=eta, batch_size=1, lazy_init=True)
    for j, w in zip(reqs, weights):
        y = f.copy()
        y[j] += eta * w
        f = project_capped_simplex(y, C)
        ogb.update_probabilities(int(j), weight=float(w))
        np.testing.assert_allclose(ogb.fractional_vector(), f, atol=1e-8)


def test_cost_aware_caching_prefers_expensive_items():
    """Two equally-popular groups, one 5x costlier: cache the costly one."""
    n, C = 100, 20
    T = 20_000
    rng = np.random.default_rng(0)
    ogb = OGB(n, C, horizon=T, batch_size=10, seed=0)
    cheap = np.arange(0, 30)
    costly = np.arange(30, 60)
    for _ in range(T // 2):
        if rng.random() < 0.5:
            ogb.request(int(rng.choice(cheap)), weight=1.0)
        else:
            ogb.request(int(rng.choice(costly)), weight=5.0)
    f = ogb.fractional_vector()
    assert f[costly].sum() > 2.0 * f[cheap].sum()
