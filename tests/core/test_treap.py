"""Treap / SortedKeyStore equivalence and correctness."""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.treap import SortedKeyStore, Treap, make_store


@pytest.mark.parametrize("kind", ["treap", "sorted"])
def test_basic_ops(kind):
    s = make_store(kind)
    s.insert(3.0, 1)
    s.insert(1.0, 2)
    s.insert(2.0, 3)
    assert len(s) == 3
    assert s.min() == (1.0, 2)
    assert s.count_below(2.5) == 2
    assert s.remove(2.0, 3)
    assert not s.remove(2.0, 3)  # already gone
    assert s.pop_min() == (1.0, 2)
    assert s.min() == (3.0, 1)


@given(ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 20)), max_size=300))
@settings(max_examples=100, deadline=None)
def test_treap_matches_sorted_reference(ops):
    """Random op sequences: treap == brute-force sorted list."""
    t = Treap(seed=42)
    ref = []  # list of (key, item)
    rng = random.Random(0)
    live = {}
    for op, item in ops:
        if op == 0:  # insert
            key = round(rng.uniform(0, 10), 6)
            if item in live:
                continue
            t.insert(key, item)
            ref.append((key, item))
            live[item] = key
        elif op == 1 and live:  # remove existing
            victim = sorted(live)[item % len(live)]
            key = live.pop(victim)
            assert t.remove(key, victim)
            ref.remove((key, victim))
        elif op == 2 and ref:  # pop_min
            got_key, got_item = t.pop_min()
            exp_key = min(k for k, _ in ref)
            assert got_key == exp_key
            ref.remove((got_key, got_item))
            live.pop(got_item, None)
        assert len(t) == len(ref)
        if ref:
            assert t.min()[0] == min(k for k, _ in ref)
    inorder = [k for k, _ in t]
    assert inorder == sorted(inorder)


def test_treap_large_balanced():
    """Depth sanity via timing proxy: 20k inserts + pops stay fast."""
    t = Treap(seed=1)
    rng = random.Random(2)
    keys = [(rng.random(), i) for i in range(20_000)]
    for k, i in keys:
        t.insert(k, i)
    assert len(t) == 20_000
    prev = -1.0
    for _ in range(20_000):
        k, _ = t.pop_min()
        assert k >= prev
        prev = k
    assert len(t) == 0


def test_count_below():
    s = SortedKeyStore()
    for i in range(100):
        s.insert(i * 0.01, i)
    assert s.count_below(0.5) == 50
    t = Treap()
    for i in range(100):
        t.insert(i * 0.01, i)
    assert t.count_below(0.5) == 50
