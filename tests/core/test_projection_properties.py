"""Property-based capped-simplex invariants (hypothesis; stub-compatible).

Complements tests/core/test_projection.py with the OGB-shaped instances the
replay engines actually produce: y = f + eta * counts with f feasible.  On
those instances the warm-started bracketed-Newton projection (lo=0,
hi=warm_bracket_hi, tau0 seeded from the previous step) must agree with the
cold bisection and with the float64 oracle — the warm-vs-cold contract every
device path (scan replay, sharded, Pallas) relies on.

These run under the real hypothesis package when installed and under the
vendored deterministic stub (tests/_hypothesis_stub.py) otherwise; the test
bodies use only the shared given/settings/strategies surface.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.projection import (
    capped_simplex_tau,
    capped_simplex_tau_bisect,
    project_capped_simplex,
)
from repro.jaxcache.fractional import (
    capped_simplex_project,
    capped_simplex_project_warm,
    warm_bracket_hi,
)


def _ogb_instance(n, c_frac, eta, seed):
    """A feasible f plus one batched gradient step — the warm-path setting."""
    rng = np.random.default_rng(seed)
    C = max(1, int(round(n * c_frac)))
    f = project_capped_simplex(rng.normal(0.5, 1.0, size=n), C)
    counts = rng.integers(0, 5, size=n).astype(np.float64)
    if counts.sum() == 0:
        counts[rng.integers(0, n)] = 1.0
    return f, counts, C


@given(
    n=st.integers(2, 80),
    c_frac=st.floats(0.05, 0.95),
    eta=st.floats(0.01, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=100, deadline=None)
def test_ogb_step_projection_feasible(n, c_frac, eta, seed):
    """Feasibility 0 <= x <= 1, sum x = C on post-gradient-step instances."""
    f, counts, C = _ogb_instance(n, c_frac, eta, seed)
    y = f + eta * counts
    x = project_capped_simplex(y, C)
    assert np.all(x >= -1e-9) and np.all(x <= 1 + 1e-9)
    assert abs(x.sum() - C) < 1e-6


@given(
    n=st.integers(2, 60),
    c_frac=st.floats(0.05, 0.95),
    eta=st.floats(0.01, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_ogb_step_projection_idempotent(n, c_frac, eta, seed):
    f, counts, C = _ogb_instance(n, c_frac, eta, seed)
    x = project_capped_simplex(f + eta * counts, C)
    np.testing.assert_allclose(project_capped_simplex(x, C), x, atol=1e-8)


@given(
    n=st.integers(2, 60),
    c_frac=st.floats(0.1, 0.9),
    eta=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_warm_tau_in_provable_bracket(n, c_frac, eta, seed):
    """For y = f + eta*counts with f feasible: 0 <= tau <= eta*sum(counts)."""
    f, counts, C = _ogb_instance(n, c_frac, eta, seed)
    tau = capped_simplex_tau(f + eta * counts, C)
    assert tau >= -1e-9
    assert tau <= eta * counts.sum() + 1e-6


@given(
    n=st.integers(4, 60),
    c_frac=st.floats(0.1, 0.9),
    eta=st.floats(0.01, 0.5),
    batch=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_warm_vs_cold_tau_agreement(n, c_frac, eta, batch, seed):
    """Warm bracketed-Newton == cold bisection == float64 oracle.

    Replicates the replay setting the warm path is specified for: two
    consecutive OGB steps, where step 2 is warm-projected with the provable
    per-step bracket [0, eta*B] and tau0 threaded from step 1's threshold.
    """
    rng = np.random.default_rng(seed)
    C = max(1, int(round(n * c_frac)))
    f = project_capped_simplex(rng.normal(0.5, 1.0, size=n), C)
    # step 1 (cold) provides the tau seed
    counts1 = np.bincount(rng.integers(0, n, size=batch), minlength=n).astype(float)
    x1, tau1 = capped_simplex_project(jnp.asarray(f + eta * counts1, jnp.float32), float(C))
    # step 2: warm vs cold on the same instance
    counts = np.bincount(rng.integers(0, n, size=batch), minlength=n).astype(float)
    y64 = np.asarray(x1, np.float64) + eta * counts
    y = jnp.asarray(y64, jnp.float32)
    step_mass = eta * counts.sum()

    x_cold, tau_cold = capped_simplex_project(y, float(C))
    # sweeps=25 runs the warm solver to float32 convergence on arbitrary
    # instances (the 5-sweep default is a speed contract for steady-state
    # replay, covered by tests/cachesim/test_replay.py)
    x_warm, tau_warm = capped_simplex_project_warm(
        y,
        float(C),
        lo=jnp.float32(0.0),
        hi=warm_bracket_hi(step_mass),
        tau0=tau1,
        sweeps=25,
    )
    # the projected POINT is unique even when tau is not (g can be flat at C
    # when no coordinate is interior), so agreement is asserted on x and on
    # the capacity mass that each tau reproduces
    tau_oracle = capped_simplex_tau(y64, C)
    x_oracle = np.clip(y64 - tau_oracle, 0.0, 1.0)
    np.testing.assert_allclose(np.asarray(x_warm), np.asarray(x_cold), atol=1e-4)
    np.testing.assert_allclose(np.asarray(x_warm), x_oracle, atol=1e-4)
    for tau in (float(tau_warm), float(tau_cold)):
        assert abs(np.clip(y64 - tau, 0.0, 1.0).sum() - C) < 1e-3
    # where tau IS unique (interior coordinates exist at the oracle tau),
    # warm and cold must land on the same threshold
    interior = np.sum((x_oracle > 1e-4) & (x_oracle < 1 - 1e-4))
    if interior > 0:
        assert abs(float(tau_warm) - float(tau_cold)) < 1e-4
        assert abs(float(tau_warm) - tau_oracle) < 1e-4
    bis = capped_simplex_tau_bisect(y64, C, iters=80)
    assert abs(np.clip(y64 - bis, 0.0, 1.0).sum() - C) < 1e-9


def test_stub_or_real_hypothesis_importable():
    """The suite must run with either the real package or the vendored stub."""
    import hypothesis

    assert hasattr(hypothesis, "given") and hasattr(hypothesis, "settings")
