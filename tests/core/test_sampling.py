"""Properties of the coordinated Poisson sampling (Algorithm 3) and Madow.

Key paper claims checked:
  * E[x_i] = f_i (soft capacity: E[occupancy] = C)
  * occupancy coefficient of variation <= 1/sqrt(C) (paper §5.1)
  * positive coordination: the cache state is exactly {i : f_i >= p_i} at
    every batch boundary (permanent-random-number rule), so consecutive
    samples overlap maximally given the marginals
  * Madow systematic sampling returns exactly C items with P(i) = f_i
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ogb import OGB
from repro.core.ogb_classic import madow_sample


def _drive(ogb, reqs):
    for j in reqs:
        ogb.request(int(j))


@given(seed=st.integers(0, 2**31 - 1), B=st.sampled_from([1, 3, 10]))
@settings(max_examples=30, deadline=None)
def test_cache_state_matches_poisson_rule(seed, B):
    """After every batch boundary: x_i == (f_i >= p_i) for all i (eager mode)."""
    N, C = 40, 8
    rng = np.random.default_rng(seed)
    ogb = OGB(N, C, eta=0.05, batch_size=B, lazy_init=False, seed=seed)
    reqs = rng.integers(0, N, size=12 * B)
    for t, j in enumerate(reqs):
        ogb.request(int(j))
        if (t + 1) % B == 0:
            f = ogb.fractional_vector()
            for i in range(N):
                p_i = ogb._perm_rand(i)
                expected = f[i] >= p_i
                got = ogb.contains(i)
                # boundary-equal cases can tip either way within fp noise
                if abs(f[i] - p_i) > 1e-9:
                    assert got == expected, (i, f[i], p_i)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_lazy_and_eager_sampling_agree(seed):
    """lazy_init must not change cache decisions (same PRF p_i)."""
    N, C, B = 60, 10, 4
    rng = np.random.default_rng(seed)
    reqs = rng.integers(0, N, size=80)
    a = OGB(N, C, eta=0.07, batch_size=B, lazy_init=True, seed=seed)
    b = OGB(N, C, eta=0.07, batch_size=B, lazy_init=False, seed=seed)
    hits_a, hits_b = [], []
    for j in reqs:
        hits_a.append(a.request(int(j)))
        hits_b.append(b.request(int(j)))
    assert hits_a == hits_b
    np.testing.assert_allclose(a.fractional_vector(), b.fractional_vector(), atol=1e-9)
    for i in range(N):
        assert a.contains(i) == b.contains(i)


def test_expected_occupancy_is_C():
    """E[occupancy] = C across seeds (soft constraint, paper §5.1)."""
    N, C = 200, 40
    occs = []
    for seed in range(30):
        ogb = OGB(N, C, eta=0.02, batch_size=1, lazy_init=False, seed=seed)
        reqs = np.random.default_rng(seed).integers(0, N, size=300)
        _drive(ogb, reqs)
        occs.append(ogb.occupancy())
    occs = np.asarray(occs, dtype=float)
    # CV <= 1/sqrt(C) ~= 0.158; the mean over 30 seeds should be within ~3 se
    se = occs.std() / np.sqrt(len(occs))
    assert abs(occs.mean() - C) < max(3 * se, 0.05 * C), (occs.mean(), se)
    assert occs.std() / C <= 1.5 / np.sqrt(C)


def test_positive_coordination_small_churn():
    """Consecutive samples overlap: per-batch evictions ~ O(B), not O(C)."""
    N, C, B = 1000, 100, 10
    ogb = OGB(N, C, horizon=5000, batch_size=B, lazy_init=False, seed=3)
    rng = np.random.default_rng(3)
    w = 1.0 / np.arange(1, N + 1) ** 0.9
    reqs = rng.choice(N, size=5000, p=w / w.sum())
    _drive(ogb, reqs)
    n_batches = ogb.stats.sample_updates
    assert n_batches > 0
    # paper: on average ~B elements evicted per sample update
    assert ogb.stats.evictions / n_batches < 3 * B


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_madow_exact_size(seed):
    rng = np.random.default_rng(seed)
    N, C = 50, 12
    f = rng.random(N)
    f = f / f.sum() * C
    f = np.clip(f, 0, 1)
    # renormalize into the capped simplex (approximately fine for the test)
    f = f * (C / f.sum())
    f = np.clip(f, 0, 1)
    sample = madow_sample(f, C, rng)
    assert len(sample) == C


def test_madow_marginals():
    rng = np.random.default_rng(0)
    N, C = 20, 5
    f = np.array([0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.3, 0.2, 0.1] + [0.02] * 10)
    f = f * (C / f.sum())
    counts = np.zeros(N)
    trials = 3000
    for _ in range(trials):
        for i in set(madow_sample(f, C, rng)):
            counts[i] += 1
    emp = counts / trials
    np.testing.assert_allclose(emp, f, atol=0.05)
