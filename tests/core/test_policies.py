"""Unit tests for the classic policies and FTPL."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ftpl import FTPL
from repro.core.policies import ARC, FIFO, GDS, LFU, LRU, make_policy


def test_lru_semantics():
    p = LRU(10, 2)
    assert not p.request(1)
    assert not p.request(2)
    assert p.request(1)  # hit, moves 1 to MRU
    assert not p.request(3)  # evicts 2
    assert not p.request(2)
    assert p.request(3)


def test_fifo_semantics():
    p = FIFO(10, 2)
    p.request(1)
    p.request(2)
    assert p.request(1)  # hit; FIFO does NOT refresh
    p.request(3)  # evicts 1 (oldest)
    assert not p.request(1)


def test_lfu_prefers_frequent():
    p = LFU(10, 2)
    for _ in range(5):
        p.request(1)
    for _ in range(3):
        p.request(2)
    p.request(3)  # freq 1 < min(5,3): not admitted
    assert p.contains(1) and p.contains(2)
    assert not p.contains(3)


def test_arc_adapts():
    p = ARC(100, 4)
    for i in [1, 2, 3, 4, 5, 1, 2, 3, 4, 5]:
        p.request(i)
    assert p.occupancy() <= 4
    # frequent items should survive a scan
    for i in range(6, 30):
        p.request(i)
    assert p.occupancy() <= 4


def test_gds_unit_cost_evicts_lowest_h():
    p = GDS(10, 2)
    p.request(1)
    p.request(2)
    assert p.request(1)
    p.request(3)
    assert p.occupancy() == 2


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_ftpl_matches_bruteforce_topC(seed):
    """FTPL's incremental top-C must equal argmax over all scores."""
    N, C = 30, 5
    ftpl = FTPL(N, C, zeta=2.0, seed=seed)
    rng = np.random.default_rng(seed)
    counts = np.zeros(N)
    for j in rng.integers(0, N, size=200):
        ftpl.request(int(j))
        counts[j] += 1
        scores = counts + ftpl._noise
        top = set(int(i) for i in np.argpartition(scores, N - C)[N - C :])
        assert set(ftpl.cached) == top or _tie_tolerant(scores, ftpl.cached, top)


def _tie_tolerant(scores, got, expected):
    """Sets may differ only on exactly-tied scores."""
    diff = set(got) ^ expected
    if not diff:
        return True
    vals = sorted(scores[i] for i in diff)
    return max(vals) - min(vals) < 1e-12


def test_make_policy_registry():
    for kind in ["lru", "lfu", "fifo", "arc", "gds"]:
        p = make_policy(kind, 100, 10)
        p.request(1)
        assert p.occupancy() >= 0
    p = make_policy("ogb", 100, 10, eta=0.01)
    p.request(1)
    p = make_policy("ogb_cl", 100, 10, eta=0.01)
    p.request(1)
    p = make_policy("ftpl", 100, 10, zeta=1.0)
    p.request(1)
    p = make_policy("omd_cl", 100, 10, eta=0.01)
    p.request(1)


def test_one_shared_registry():
    """make_policy, simulator.compare and benchmarks.common.make_policies all
    resolve through POLICY_REGISTRY — the kind-string sets cannot drift."""
    import numpy as np

    from repro.cachesim.simulator import compare
    from repro.core.policies import POLICY_REGISTRY, policy_kinds

    assert set(policy_kinds()) == set(POLICY_REGISTRY)
    # every registered kind is constructible through the registry
    kw = {
        "ogb": {"eta": 0.01},
        "ogb_cl": {"eta": 0.01},
        "omd_cl": {"eta": 0.01},
        "ftpl": {"zeta": 1.0},
    }
    for kind in policy_kinds():
        make_policy(kind, 64, 8, **kw.get(kind, {})).request(3)
    # compare() accepts kind strings and builds via the same registry
    trace = np.array([1, 2, 1, 3, 2, 1], dtype=np.int64)
    out = compare(
        ["lru", "ftpl"],
        trace,
        window=3,
        catalog_size=64,
        capacity=8,
        policy_kw={"ftpl": {"zeta": 1.0}},
    )
    assert set(out) == {"LRU", "FTPL"}
    host = LRU(64, 8)
    hits = sum(host.request(int(j)) for j in trace)
    assert out["LRU"].hits == hits
    import pytest

    with pytest.raises(ValueError):
        make_policy("nope", 10, 2)
    with pytest.raises(ValueError):
        compare(["lru"], trace)  # kind strings need catalog_size/capacity


def test_benchmarks_make_policies_uses_registry(monkeypatch):
    import sys

    sys.path.insert(0, ".")
    try:
        from benchmarks.common import make_policies
    except ImportError:
        import pytest

        pytest.skip("benchmarks package not importable from this rootdir")
    finally:
        sys.path.pop(0)
    seen = []
    import repro.core.policies as polmod

    real = polmod.make_policy

    def spy(kind, *a, **k):
        seen.append(kind)
        return real(kind, *a, **k)

    monkeypatch.setattr(polmod, "make_policy", spy)
    out = make_policies(100, 10, T=1000)
    assert set(out) == {"OGB", "FTPL", "LRU", "LFU", "ARC"}
    assert set(seen) == {"ogb", "ftpl", "lru", "lfu", "arc"}
