"""Properties of the eager capped-simplex projection oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.projection import (
    capped_simplex_tau,
    capped_simplex_tau_bisect,
    project_capped_simplex,
)


@given(
    n=st.integers(2, 60),
    c_frac=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=200, deadline=None)
def test_projection_feasibility(n, c_frac, seed):
    rng = np.random.default_rng(seed)
    C = max(1, int(round(n * c_frac)))
    y = rng.normal(0.5, 1.0, size=n)
    f = project_capped_simplex(y, C)
    assert np.all(f >= -1e-9)
    assert np.all(f <= 1 + 1e-9)
    assert abs(f.sum() - C) < 1e-6


@given(n=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_projection_idempotent_on_feasible(n, seed):
    rng = np.random.default_rng(seed)
    C = max(1, n // 3)
    # random feasible point: project a random vector first
    f = project_capped_simplex(rng.normal(0.5, 1.0, size=n), C)
    f2 = project_capped_simplex(f, C)
    np.testing.assert_allclose(f2, f, atol=1e-8)


@given(n=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_exact_matches_bisection(n, seed):
    rng = np.random.default_rng(seed)
    C = max(1, n // 4)
    y = rng.normal(0.3, 0.8, size=n)
    t1 = capped_simplex_tau(y, C)
    t2 = capped_simplex_tau_bisect(y, C, iters=80)
    f1 = np.clip(y - t1, 0, 1)
    f2 = np.clip(y - t2, 0, 1)
    np.testing.assert_allclose(f1, f2, atol=1e-7)


@given(n=st.integers(3, 40), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_projection_optimality_kkt(n, seed):
    """Check the KKT structure directly: f = clip(y - tau, 0, 1)."""
    rng = np.random.default_rng(seed)
    C = max(1, n // 3)
    y = rng.normal(0.5, 1.0, size=n)
    f = project_capped_simplex(y, C)
    tau = capped_simplex_tau(y, C)
    np.testing.assert_allclose(f, np.clip(y - tau, 0, 1), atol=1e-9)
    # projection is the closest feasible point: compare against random
    # feasible candidates
    for _ in range(5):
        g = project_capped_simplex(rng.normal(0.5, 1.0, size=n), C)
        assert np.sum((f - y) ** 2) <= np.sum((g - y) ** 2) + 1e-7


def test_projection_single_bump():
    """The OGB case: feasible f plus eta on one coordinate."""
    f = np.array([0.5, 0.3, 0.2, 0.0, 1.0])
    C = f.sum()
    y = f.copy()
    y[1] += 0.2
    proj = project_capped_simplex(y, C)
    assert abs(proj.sum() - C) < 1e-9
    assert proj[1] > f[1]  # requested coordinate grew
    assert proj[3] == 0.0  # zero coordinate stays zero


def test_rejects_bad_capacity():
    with pytest.raises(ValueError):
        capped_simplex_tau(np.ones(3), 0)
    with pytest.raises(ValueError):
        capped_simplex_tau(np.ones(3), 4)
