"""Size-aware OGB (paper §8 future work) vs the eager weighted oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ogb_sized import (
    SizedOGB,
    project_weighted,
    weighted_capped_simplex_tau,
)


def test_weighted_projection_feasibility():
    rng = np.random.default_rng(0)
    y = rng.random(50)
    s = rng.choice([1.0, 4.0, 16.0], size=50)
    C = 30.0
    f = project_weighted(y, s, C)
    assert np.all(f >= -1e-9) and np.all(f <= 1 + 1e-9)
    assert abs(np.sum(s * f) - min(C, np.sum(s * np.clip(y, 0, 1)))) < 1e-5


def test_reduces_to_unit_size_case():
    from repro.core.projection import project_capped_simplex

    rng = np.random.default_rng(1)
    y = rng.normal(0.4, 0.5, size=40)
    f_w = project_weighted(y, np.ones(40), 10.0)
    f_u = project_capped_simplex(y, 10.0)
    np.testing.assert_allclose(f_w, f_u, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_lazy_sized_matches_eager(seed):
    """Request sequence: lazy per-class structure == eager weighted oracle."""
    rng = np.random.default_rng(seed)
    n = 20
    classes = rng.integers(0, 3, size=n)
    sizes_by_class = [1.0, 2.0, 8.0]
    s = np.array([sizes_by_class[c] for c in classes])
    C = 12.0
    eta = 0.05

    ogb = SizedOGB(
        sizes_by_class, {i: int(classes[i]) for i in range(n)}, C, eta
    )
    f = np.zeros(n)  # eager reference starts empty (mass constraint is <= C
    # until full, then projection activates — mirror the lazy semantics)
    reqs = rng.integers(0, n, size=120)
    for j in reqs:
        j = int(j)
        y = f.copy()
        y[j] = min(y[j] + eta * s[j], 1.0)
        if np.sum(s * y) > C:
            f = project_weighted(y, s, C)
        else:
            f = y
        ogb.update(j)
        got = ogb.fractional_vector(n)
        np.testing.assert_allclose(got, f, atol=5e-6, err_msg=f"item {j}")


@pytest.mark.parametrize(
    "kw",
    [
        dict(s=[1.0, 0.0, 2.0]),  # zero size -> inf bracket
        dict(s=[1.0, -3.0, 2.0]),  # negative size
        dict(s=[1.0, float("nan"), 2.0]),  # NaN size -> NaN bracket
        dict(s=[1.0, float("inf"), 2.0]),  # inf size
        dict(C=0.0),  # zero capacity
        dict(C=-4.0),  # negative capacity
        dict(C=float("nan")),  # NaN capacity
        dict(y=[0.5, float("inf"), 0.5]),  # non-finite y
    ],
)
def test_weighted_tau_rejects_degenerate_inputs(kw):
    """A zero/negative/NaN size (or capacity) makes the bisection bracket
    inf/NaN and the loop would silently return garbage — reject loudly."""
    y = np.asarray(kw.get("y", [0.5, 0.8, 0.9]), np.float64)
    s = np.asarray(kw.get("s", [1.0, 2.0, 4.0]), np.float64)
    with pytest.raises(ValueError):
        weighted_capped_simplex_tau(y, s, float(kw.get("C", 2.0)))


def test_weighted_tau_rejects_shape_mismatch_and_empty():
    with pytest.raises(ValueError):
        weighted_capped_simplex_tau(np.ones(3), np.ones(4), 1.0)
    with pytest.raises(ValueError):
        weighted_capped_simplex_tau(np.ones(0), np.ones(0), 1.0)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_weighted_tau_bracket_property(seed):
    """For arbitrary valid inputs the bisection bracket always contains the
    root: the returned tau is feasible (projected mass == min(C, clipped
    mass)) and non-negative."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 40))
    y = rng.normal(0.0, 2.0, size=n)
    s = np.exp(rng.uniform(np.log(0.25), np.log(64.0), size=n))
    C = float(np.exp(rng.uniform(np.log(0.1), np.log(2 * s.sum()))))
    tau = weighted_capped_simplex_tau(y, s, C)
    assert tau >= 0.0 and np.isfinite(tau)
    f = np.clip(y - s * tau, 0.0, 1.0)
    target = min(C, float(np.sum(s * np.clip(y, 0.0, 1.0))))
    assert abs(float(np.sum(s * f)) - target) < 1e-6 * max(1.0, target)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_sized_mass_invariant(seed):
    """The incremental ``mass`` counter never leaks past capacity and always
    matches the recomputed sum — including the all-coordinates-popped exit
    where ``denom <= 0`` (the regression this guards: that path used to
    leave the float drift in ``mass``, so later updates compared against a
    phantom overfull cache)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 25))
    k = int(rng.integers(1, 4))
    sizes_by_class = sorted(
        float(x) for x in np.exp(rng.uniform(0.0, 4.0, size=k))
    )
    classes = {i: int(rng.integers(0, k)) for i in range(n)}
    # tiny capacity relative to step sizes maximizes pop pressure
    C = float(np.exp(rng.uniform(np.log(0.5), np.log(8.0))))
    eta = float(np.exp(rng.uniform(np.log(0.01), np.log(2.0))))
    ogb = SizedOGB(sizes_by_class, classes, C, eta)
    s = np.array([sizes_by_class[classes[i]] for i in range(n)])
    for j in rng.integers(0, n, size=80):
        ogb.update(int(j))
        assert ogb.mass <= C + 1e-9, (ogb.mass, C)
        f = ogb.fractional_vector(n)
        assert np.all(f >= -1e-12) and np.all(f <= 1 + 1e-12)
        assert abs(float(np.sum(s * f)) - ogb.mass) < 1e-6 * max(1.0, C)


def test_byte_hit_optimization():
    """Equal request rates, very different sizes: under byte-hit reward the
    policy fills capacity with the items that maximize bytes served."""
    rng = np.random.default_rng(2)
    n = 60
    classes = {i: (0 if i < 30 else 1) for i in range(n)}
    sizes = [1.0, 10.0]
    C = 100.0
    ogb = SizedOGB(sizes, classes, C, eta=0.02)
    for _ in range(20_000):
        ogb.update(int(rng.integers(0, n)))
    f = ogb.fractional_vector(n)
    bytes_small = float(np.sum(f[:30]) * 1.0)
    bytes_big = float(np.sum(f[30:]) * 10.0)
    assert bytes_big > 2.0 * bytes_small  # capacity flows to byte-heavy items
    # capacity constraint respected
    s = np.array([sizes[classes[i]] for i in range(n)])
    assert np.sum(s * f) <= C + 1e-6
