"""The unified policy protocol: parity, streaming, deprecation.

Three contracts lock the api redesign down:

* **Golden parity** — ``api.run`` must reproduce the committed per-scenario
  fixtures for every registered kind, using only the public protocol (no
  ``run_scenario``): exact for the discrete automata, within the usual
  float32 allowance for the fractional engines.
* **Streaming** — two chunked ``run`` calls with a handed-off carry replay
  the same dynamics as one full run, bit for bit, for every kind.
* **Deprecation** — each legacy entry point still works but warns, and
  returns the same numbers as the api path it forwards to.
"""

import json
import os
import warnings

import numpy as np
import pytest

from repro.cachesim import api
from repro.cachesim.scenarios import get_scenario
from repro.cachesim.traces import zipf

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_FILES = sorted(
    f[: -len(".json")] for f in os.listdir(GOLDEN_DIR) if f.endswith(".json")
)

#: every kind the single run/sweep engine must serve (acceptance criterion)
API_KINDS = ("ogb", "omd", "lru", "fifo", "lfu", "ftpl")

FLOAT_KINDS = ("ogb", "omd")
FLOAT_ATOL = 5e-3


def test_all_kinds_registered():
    for kind in API_KINDS + ("ogb_grad",):
        pd = api.policy_def(kind)
        assert pd.kind == kind
        # memoized: the step identity is stable, which keys the compile cache
        assert api.policy_def(kind) is pd


@pytest.mark.parametrize("scenario", GOLDEN_FILES)
@pytest.mark.parametrize("kind", API_KINDS)
def test_api_run_reproduces_golden(scenario, kind):
    """Per-kind parity with the committed fixtures through bare api.run."""
    with open(os.path.join(GOLDEN_DIR, f"{scenario}.json")) as f:
        golden = json.load(f)
    sc = get_scenario(scenario)
    if kind not in sc.policies:
        pytest.skip(f"{kind} not in the {scenario} policy set")
    n, t, c = sc.dims("mini")
    assert (n, t, c) == (golden["N"], golden["T"], golden["C"])
    trace = sc.make_trace("mini")
    pd = api.policy_def(kind)
    window = (
        min(sc.batch, max(t // 20, 1)) if pd.fractional else max(t // 20, 1)
    )
    res = api.run(
        pd, trace, n, c, window=window, seed=0, horizon=t,
        track_opt=pd.fractional,
    )
    want = golden["rows"][pd.name]
    if kind in FLOAT_KINDS:
        assert res.hit_ratio == pytest.approx(
            want["hit_ratio"], abs=FLOAT_ATOL
        )
        assert res.regret == pytest.approx(
            want["regret"], abs=max(FLOAT_ATOL * t, abs(want["regret"]) * 5e-3)
        )
    else:
        # discrete automata: the port must be bit-exact (fixtures store the
        # ratio rounded to 10 digits, so compare on the same grid)
        assert round(res.hit_ratio, 10) == want["hit_ratio"]


N, C, T = 311, 23, 6400  # T/2 divisible by the window: clean resume point


@pytest.mark.parametrize("kind", API_KINDS)
def test_streaming_carry_resumes_bit_exact(kind):
    """Two chunked runs with a handed-off carry == one full run."""
    trace = zipf(N, T, alpha=0.9, seed=3)
    pd = api.policy_def(kind)
    kw = dict(window=16, eta=0.03, seed=0, horizon=T, track_opt=False)
    full = api.run(pd, trace, N, C, **kw)
    first = api.run(pd, trace[: T // 2], N, C, **kw)
    second = api.run(pd, trace[T // 2 :], capacity=C, carry=first.carry,
                     window=16, track_opt=False)
    np.testing.assert_array_equal(
        np.concatenate([first.hits, second.hits]), full.hits
    )
    np.testing.assert_array_equal(
        np.concatenate([first.reward, second.reward]), full.reward
    )
    # the final carries agree leaf by leaf (resume ends in the same state)
    import jax

    for a, b in zip(jax.tree.leaves(second.carry), jax.tree.leaves(full.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sweep_matches_single_runs_across_kinds():
    """One vmapped grid == stacked single runs, for a fractional policy and
    an automaton (the two carry families)."""
    trace = zipf(N, T, alpha=0.9, seed=5)
    for kind, eta in (("omd", 0.05), ("fifo", None)):
        pd = api.policy_def(kind)
        sw = api.sweep(
            pd, trace, N, capacities=[7, 23], etas=(eta,), seeds=(0,),
            window=100, horizon=T,
        )
        for cap in (7, 23):
            single = api.run(
                pd, trace, N, cap, window=100, eta=eta, horizon=T,
                n_slots=23,
            )
            r = sw.row(capacity=cap)
            np.testing.assert_array_equal(sw.hits[r], single.hits)
            np.testing.assert_allclose(sw.reward[r], single.reward, atol=1e-3)
            assert sw.opt_hits[r] == single.opt_hits


def test_run_requires_shape_or_carry():
    pd = api.policy_def("lru")
    with pytest.raises(ValueError, match="catalog_size"):
        api.run(pd, zipf(N, 320, seed=1), window=16)
    with pytest.raises(ValueError, match="shorter than one window"):
        api.run(pd, zipf(N, 10, seed=1), N, C, window=16)


def test_resume_rejects_init_params():
    """A resumed run takes its parameters from the carry — passing eta
    alongside a carry would silently mislabel the result, so it raises."""
    trace = zipf(N, 320, alpha=0.9, seed=1)
    pd = api.policy_def("ogb")
    first = api.run(pd, trace, N, C, window=16, eta=0.03, track_opt=False)
    with pytest.raises(ValueError, match="carry's parameters"):
        api.run(pd, trace, capacity=C, window=16, carry=first.carry, eta=0.5)


def test_unknown_kind_lists_registry():
    with pytest.raises(KeyError, match="registered"):
        api.policy_def("nope")


# ---------------------------------------------------------------------------
# legacy wrappers: still correct, but warn
# ---------------------------------------------------------------------------
def _legacy_calls():
    from repro.cachesim.engines import run_engine, run_omd, sweep_engine
    from repro.cachesim.replay import replay_trace, sweep_replay

    trace = zipf(N, 640, alpha=0.9, seed=7)
    return [
        ("replay_trace", lambda: replay_trace(trace, N, C, batch=16)),
        ("run_omd", lambda: run_omd(trace, N, C, 16)),
        ("run_engine", lambda: run_engine("lru", trace, N, C, window=16)),
        (
            "sweep_replay",
            lambda: sweep_replay(trace, N, capacities=[C], batch=16),
        ),
        (
            "sweep_engine",
            lambda: sweep_engine("lru", trace, N, capacities=[C], window=16),
        ),
    ]


@pytest.mark.parametrize(
    "name,call", _legacy_calls(), ids=[n for n, _ in _legacy_calls()]
)
def test_legacy_wrappers_deprecated(name, call):
    with pytest.warns(DeprecationWarning, match=name):
        res = call()
    assert res.T == 640


def test_legacy_wrapper_matches_api():
    """The wrapper and the api path are the same computation."""
    trace = zipf(N, 640, alpha=0.9, seed=9)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.cachesim.replay import replay_trace

        legacy = replay_trace(trace, N, C, batch=16, eta=0.03, seed=0)
    direct = api.run(
        api.policy_def("ogb"), trace, N, C, window=16, eta=0.03, seed=0
    )
    np.testing.assert_array_equal(legacy.hits, direct.hits)
    np.testing.assert_array_equal(legacy.reward, direct.reward)
    assert legacy.opt_hits == direct.opt_hits


def test_public_surface():
    """Top-level lazy re-exports resolve to the real objects."""
    import repro

    assert repro.run is api.run
    assert repro.sweep is api.sweep
    assert repro.PolicyDef is api.PolicyDef
    assert repro.policy_def is api.policy_def
    assert "RunResult" in repro.__all__ and "__version__" in repro.__all__
    assert isinstance(repro.__version__, str)
    with pytest.raises(AttributeError):
        repro.not_a_thing
