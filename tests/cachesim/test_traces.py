"""Trace generator invariants + calibration statistics."""

import numpy as np
import pytest

from repro.cachesim.traces import (
    adversarial,
    bursty,
    make_trace,
    reuse_distances,
    scan_mix,
    shifting_zipf,
    trace_stats,
    zipf,
)


@pytest.mark.parametrize(
    "gen", [adversarial, zipf, shifting_zipf, bursty, scan_mix]
)
def test_ranges_and_determinism(gen):
    a = gen(500, 4000, seed=7)
    b = gen(500, 4000, seed=7)
    c = gen(500, 4000, seed=8)
    assert a.dtype == np.int64 and len(a) == 4000
    assert a.min() >= 0 and a.max() < 500
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_adversarial_round_robin_property():
    """Each full round touches every item exactly once."""
    N = 100
    tr = adversarial(N, 300, seed=0)
    for r in range(3):
        assert len(set(tr[r * N : (r + 1) * N])) == N


def test_zipf_skew():
    tr = zipf(1000, 50_000, alpha=1.1, seed=0)
    counts = np.bincount(tr, minlength=1000)
    top10 = np.sort(counts)[-10:].sum()
    assert top10 / len(tr) > 0.25  # heavy head


def test_shifting_zipf_changes_popularity():
    tr = shifting_zipf(1000, 20_000, phase=10_000, seed=1)
    c1 = np.bincount(tr[:10_000], minlength=1000)
    c2 = np.bincount(tr[10_000:], minlength=1000)
    top1 = set(np.argsort(c1)[-20:])
    top2 = set(np.argsort(c2)[-20:])
    assert len(top1 & top2) < 10  # hot sets mostly disjoint across phases


def test_bursty_short_lifetimes():
    tr = bursty(5000, 60_000, seed=2)
    st = trace_stats(tr)
    assert st.hit_share_lifetime_below(100) > 0.05


def test_scan_mix_has_sequential_runs():
    tr = scan_mix(10_000, 20_000, seed=3)
    diffs = np.diff(tr)
    assert np.mean(diffs == 1) > 0.2  # sequential scans present


def test_registry():
    tr = make_trace("cdn_like", 100, 1000, seed=0)
    assert len(tr) == 1000


def test_reuse_distance():
    rd = reuse_distances(np.array([1, 2, 1, 3, 2, 1]))
    np.testing.assert_array_equal(rd, [2, 3, 3])
