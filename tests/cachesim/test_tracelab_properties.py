"""Hypothesis property tests for the tracelab loaders and synthesizer.

Real-or-stub (PR-2 conftest pattern): runs in tier-1 either way.  The
invariants are exact, not statistical:

* **round-trip** — write ids in any on-disk format, load them back,
  get the identical stream;
* **chunking invariance** — any ``chunk_size`` split of a loader or of
  the synthesizer concatenates to the one-shot result;
* **catalog density** — remapped ids are exactly ``0..N-1``, assigned in
  first-seen order, independent of the chunking;
* **determinism** — loaders and synthesizer are pure functions of
  (bytes,) and (profile, catalog, seed) respectively.
"""

import os
import tempfile

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cachesim.tracelab import (
    CatalogRemap,
    fit_profile,
    load_trace,
    open_trace,
    synthesize,
    synthesize_chunks,
    write_trace,
)
from repro.cachesim.traces import make_trace

FORMATS = ("csv", "tsv", "cdn", "bin32", "bin64")
_EXT = {"csv": ".csv", "tsv": ".tsv", "cdn": ".log",
        "bin32": ".u32", "bin64": ".u64"}


def _random_ids(rng: np.random.Generator, n: int, sparse: bool) -> np.ndarray:
    hi = (1 << 62) if sparse else 10_000
    return rng.integers(0, hi, size=n, dtype=np.int64)


@given(
    fmt=st.sampled_from(FORMATS),
    n=st.integers(1, 400),
    sparse=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    chunk_size=st.integers(1, 500),
)
@settings(max_examples=25, deadline=None)
def test_write_load_round_trip(fmt, n, sparse, seed, chunk_size):
    rng = np.random.default_rng(seed)
    ids = _random_ids(rng, n, sparse and fmt != "bin32")
    if fmt == "bin32":
        ids %= 1 << 32
    with tempfile.TemporaryDirectory() as d:
        path = write_trace(os.path.join(d, "t" + _EXT[fmt]), ids, fmt)
        got = load_trace(path, fmt)
        np.testing.assert_array_equal(got, ids)
        chunks = list(open_trace(path, fmt, chunk_size=chunk_size))
        np.testing.assert_array_equal(np.concatenate(chunks), ids)
        assert all(len(c) == chunk_size for c in chunks[:-1])


@given(
    n=st.integers(1, 600),
    n_distinct=st.integers(1, 40),
    sparse=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    chunk_size=st.integers(1, 200),
)
@settings(max_examples=25, deadline=None)
def test_catalog_remap_density_first_seen(
    n, n_distinct, sparse, seed, chunk_size
):
    rng = np.random.default_rng(seed)
    pool = np.unique(_random_ids(rng, n_distinct, sparse))
    raw = pool[rng.integers(0, len(pool), size=n)]

    cr = CatalogRemap()
    parts = [
        cr.apply(raw[i : i + chunk_size]) for i in range(0, n, chunk_size)
    ]
    out = np.concatenate(parts)
    # dense: ids are exactly 0..N-1 over the distinct set
    uniq = np.unique(out)
    np.testing.assert_array_equal(uniq, np.arange(len(cr)))
    # first-seen monotone: the running max over first occurrences is the
    # sequence 0,1,2,... (each *new* id is the next integer)
    firsts = out[np.sort(np.unique(out, return_index=True)[1])]
    np.testing.assert_array_equal(firsts, np.arange(len(cr)))
    # chunking never changes the mapping
    np.testing.assert_array_equal(out, CatalogRemap().apply(raw))
    # and the mapping inverts through raw_ids
    np.testing.assert_array_equal(cr.raw_ids[out], raw)


@given(
    src=st.sampled_from(("zipf", "bursty", "shifting_zipf", "scan_mix")),
    n=st.integers(8, 500),
    t=st.integers(1, 4000),
    seed=st.integers(0, 2**31 - 1),
    chunk_size=st.integers(1, 5000),
)
@settings(max_examples=20, deadline=None)
def test_synthesizer_chunking_invariance_and_determinism(
    src, n, t, seed, chunk_size
):
    source = make_trace(src, n, max(t, 256), seed=seed % 1000)
    prof = fit_profile(source)
    one = synthesize(prof, t, catalog=n, seed=seed)
    np.testing.assert_array_equal(
        one, synthesize(prof, t, catalog=n, seed=seed)
    )
    chunks = list(
        synthesize_chunks(prof, t, catalog=n, seed=seed,
                          chunk_size=chunk_size)
    )
    got = (
        np.concatenate(chunks) if chunks else np.empty(0, np.int64)
    )
    np.testing.assert_array_equal(got, one)
    assert all(len(c) == chunk_size for c in chunks[:-1])
    # range/dtype/length invariants (the trace-generator contract)
    assert one.dtype == np.int64 and len(one) == t
    if t:
        assert one.min() >= 0 and one.max() < n


@given(
    n=st.integers(8, 300),
    t=st.integers(64, 3000),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_synthesizer_prefix_stability(n, t, seed):
    """A shorter synthesis is a prefix of a longer one (same seed): the
    stream is block-deterministic, so T only truncates."""
    source = make_trace("zipf", n, 2000, seed=seed % 997)
    prof = fit_profile(source)
    long = synthesize(prof, t, catalog=n, seed=seed)
    short = synthesize(prof, t // 2, catalog=n, seed=seed)
    np.testing.assert_array_equal(short, long[: t // 2])
