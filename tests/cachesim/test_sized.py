"""Sized-object pipeline: loaders -> catalog -> engines -> byte metrics.

The heterogeneous-size setting (paper §2.2/§8) threads per-object sizes
through every layer; these tests lock each joint:

* the CDN/text loaders actually surface the size column (the regression
  this PR fixes: it used to be parsed past and dropped), and
  ``write_trace(sizes=...)`` round-trips it;
* the device GDS tree engine is differential-exact against the host
  ``core.policies.GDS`` oracle under dyadic sizes/costs;
* ``ogb_sized`` (scan and tree) tracks the float64 weighted-projection
  oracle on byte hit ratio, and reduces **bit-exactly** to the unit OGB
  engines when every size is 1;
* byte accounting (``byte_hits``/``bytes_total``/``byte_hit_ratio``) is
  consistent across ``run``, ``sweep`` and ``run_stream``.
"""

import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.cachesim import api
from repro.cachesim.tracelab.catalog import CatalogRemap
from repro.cachesim.tracelab.loaders import load_trace, write_trace
from repro.core.ogb_sized import project_weighted
from repro.core.policies import GDS

SLABS = np.asarray([1.0, 4.0, 16.0, 64.0])
DATA = os.path.join(os.path.dirname(__file__), "data")


def _sized_instance(seed, n=120, t=4000, c=11):
    rng = np.random.default_rng(seed)
    trace = jnp.asarray(rng.integers(0, n, size=t), jnp.int32)
    sizes = SLABS[rng.integers(0, len(SLABS), size=n)]
    return trace, sizes, n, c


# -- satellite: the size column is no longer dropped ----------------------


@pytest.mark.parametrize(
    "fmt,fname,expect_first",
    [
        ("csv", "sample.csv", 229.0),
        ("tsv", "sample.tsv", 64.0),
        ("cdn", "sample_cdn.log", 889.0),
    ],
)
def test_loader_surfaces_size_column(fmt, fname, expect_first):
    """Every bundled text fixture carries real sizes; ``with_sizes=True``
    must return them (not a unit placeholder)."""
    path = os.path.join(DATA, fname)
    ids_plain = load_trace(path, fmt)
    ids, sizes = load_trace(path, fmt, with_sizes=True)
    np.testing.assert_array_equal(ids, ids_plain)
    assert sizes.shape == ids.shape and sizes.dtype == np.float64
    assert float(sizes[0]) == expect_first
    assert np.all(sizes > 0) and not np.all(sizes == 1.0)


@pytest.mark.parametrize("fmt,ext", [("csv", "csv"), ("tsv", "tsv"), ("cdn", "log")])
def test_write_trace_sizes_round_trip(fmt, ext, tmp_path):
    rng = np.random.default_rng(3)
    ids0 = rng.integers(0, 1000, size=64).astype(np.int64)
    sizes0 = np.concatenate(
        [SLABS[rng.integers(0, 4, size=32)], rng.uniform(0.5, 900.5, size=32)]
    )
    path = str(tmp_path / f"rt.{ext}")
    write_trace(path, ids0, fmt, sizes=sizes0)
    ids1, sizes1 = load_trace(path, fmt, with_sizes=True)
    np.testing.assert_array_equal(ids0, ids1)
    np.testing.assert_array_equal(sizes0, sizes1)  # bit-for-float
    # the same file still loads unsized (sizes simply ignored)
    np.testing.assert_array_equal(load_trace(path, fmt), ids0)


def test_binary_formats_reject_sizes(tmp_path):
    ids = np.arange(5, dtype=np.int64)
    with pytest.raises(ValueError, match="size"):
        write_trace(str(tmp_path / "t.bin"), ids, "bin64", sizes=np.ones(5))
    write_trace(str(tmp_path / "t.bin"), ids, "bin64")
    with pytest.raises(ValueError, match="size"):
        load_trace(str(tmp_path / "t.bin"), "bin64", with_sizes=True)


def test_write_trace_rejects_bad_sizes(tmp_path):
    ids = np.arange(4, dtype=np.int64)
    for bad in ([1.0, 0.0, 2.0, 3.0], [1.0, -1.0, 2.0, 3.0],
                [1.0, np.nan, 2.0, 3.0], [1.0, 2.0, 3.0]):
        with pytest.raises(ValueError):
            write_trace(str(tmp_path / "t.csv"), ids, "csv", sizes=bad)


def test_catalog_remap_item_sizes_first_seen_and_chunk_invariant():
    raw = np.asarray([70, 80, 70, 90, 80, 100], np.int64)
    szs = np.asarray([8.0, 2.0, 9.0, 4.0, 3.0, 1.0])
    cm1 = CatalogRemap()
    cm1.apply(raw, sizes=szs)
    # first-seen size wins: 70 -> 8 (not the later 9), 80 -> 2
    np.testing.assert_array_equal(cm1.item_sizes, [8.0, 2.0, 4.0, 1.0])
    # chunking cannot change the mapping or the recorded sizes
    cm2 = CatalogRemap()
    for sl in (slice(0, 1), slice(1, 4), slice(4, 6)):
        cm2.apply(raw[sl], sizes=szs[sl])
    np.testing.assert_array_equal(cm1.item_sizes, cm2.item_sizes)
    # ids never observed with a size read the unit default
    cm1.apply(np.asarray([110], np.int64))
    assert float(cm1.item_sizes[-1]) == 1.0


# -- GDS: device tree engine vs host oracle -------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("costs_mode", ["unit", "sizes", "dyadic"])
def test_gds_device_matches_host_oracle(seed, costs_mode):
    """Dyadic sizes/costs keep every H update exact in float32, so the
    device min-pair tree must replay the host GDS *bit-exactly* (same
    per-window hits, same byte accounting)."""
    trace, sizes, n, c = _sized_instance(seed, n=90, t=3000, c=9)
    rng = np.random.default_rng(seed + 100)
    if costs_mode == "unit":
        costs = None
    elif costs_mode == "sizes":
        costs = sizes.copy()
    else:
        costs = np.asarray([0.5, 1.0, 2.0, 4.0])[
            rng.integers(0, 4, size=n)
        ]
    w = 250
    r = api.run(
        api.policy_def("gds"), trace, n, c, window=w,
        sizes=sizes, costs=costs, track_opt=False,
    )
    host = GDS(n, c, sizes=sizes, costs=costs)
    ids = np.asarray(trace)
    hits_host, bytes_host = [], []
    for k in range(len(ids) // w):
        chunk = ids[k * w:(k + 1) * w]
        flags = [host.request(int(i)) for i in chunk]
        hits_host.append(sum(flags))
        bytes_host.append(float(np.sum(sizes[chunk][np.asarray(flags)])))
    np.testing.assert_array_equal(r.hits, hits_host)
    assert r.byte_hits is not None
    np.testing.assert_allclose(r.byte_hits, bytes_host, rtol=0, atol=0)
    assert r.bytes_total == pytest.approx(float(np.sum(sizes[ids])))
    assert 0.0 <= r.byte_hit_ratio <= 1.0


# -- ogb_sized: unit reduction + float64 oracle ---------------------------


def test_ogb_sized_scan_unit_sizes_bit_exact_vs_ogb():
    """With every size 1 the weighted machinery must vanish exactly:
    same normalization (sref=1), same bisection bracket, same floats."""
    trace, _, n, c = _sized_instance(7, n=150, t=4000, c=13)
    kw = dict(window=400, seed=5, eta=0.03, track_opt=False)
    rs = api.run(
        api.policy_def("ogb_sized", flavor="scan"), trace, n, c,
        sizes=np.ones(n), **kw,
    )
    ru = api.run(api.policy_def("ogb", projection="bisect"), trace, n, c, **kw)
    np.testing.assert_array_equal(np.asarray(rs.reward), np.asarray(ru.reward))
    np.testing.assert_array_equal(np.asarray(rs.hits), np.asarray(ru.hits))


def test_ogb_sized_tree_unit_sizes_bit_exact_vs_ogb_tree():
    trace, _, n, c = _sized_instance(8, n=150, t=4000, c=13)
    kw = dict(window=400, seed=5, eta=0.03, track_opt=False)
    rs = api.run(
        api.policy_def("ogb_sized", flavor="tree"), trace, n, c,
        sizes=np.ones(n), **kw,
    )
    ru = api.run(api.policy_def("ogb_tree"), trace, n, c, **kw)
    np.testing.assert_array_equal(np.asarray(rs.reward), np.asarray(ru.reward))
    np.testing.assert_array_equal(np.asarray(rs.hits), np.asarray(ru.hits))


def _f64_sized_oracle(trace, sizes, capacity, eta, window):
    """Float64 replay of the ogb_sized scan dynamics (the ground truth both
    device flavors are held to): mean-size normalization, byte-weighted
    ascent, exact weighted projection per chunk."""
    ids = np.asarray(trace)
    sref = float(np.mean(sizes))
    s = np.asarray(sizes, np.float64) / sref
    cap = float(capacity) / sref
    f = np.full(len(s), cap / float(np.sum(s)))
    reward = 0.0
    for k in range(len(ids) // window):
        chunk = ids[k * window:(k + 1) * window]
        reward += float(np.sum(s[chunk] * f[chunk]))  # w = s (byte reward)
        y = f.copy()
        np.add.at(y, chunk, eta * s[chunk])
        if float(np.sum(s * np.clip(y, 0.0, 1.0))) > cap:
            f = project_weighted(y, s, cap)
        else:
            f = np.clip(y, 0.0, 1.0)
    return reward * sref


@pytest.mark.parametrize("flavor", ["scan", "tree"])
def test_ogb_sized_tracks_float64_oracle(flavor):
    """Acceptance bound: fractional byte hit ratio within 5e-3 of the
    float64 weighted-projection oracle (slab sizes, so the tree's
    size-class quantization is lossless and only float32/bucketization
    error remains)."""
    trace, sizes, n, c = _sized_instance(11, n=200, t=6000, c=0)
    cap_bytes = 8.0 * float(np.mean(sizes))
    eta, w = 0.05, 500
    r = api.run(
        api.policy_def("ogb_sized", flavor=flavor), trace, n, cap_bytes,
        window=w, sizes=sizes, eta=eta, track_opt=False,
    )
    total_bytes = float(np.sum(sizes[np.asarray(trace)]))
    got = float(np.sum(np.asarray(r.reward, np.float64))) / total_bytes
    want = _f64_sized_oracle(trace, sizes, cap_bytes, eta, w) / total_bytes
    assert got == pytest.approx(want, abs=5e-3), (flavor, got, want)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ogb_sized_scan_oracle_sweep(seed):
    """Hypothesis sweep: random slab assignments/capacities, scan flavor
    vs the float64 oracle."""
    rng = np.random.default_rng(seed)
    n, t, w = 60, 1500, 250
    trace = jnp.asarray(rng.integers(0, n, size=t), jnp.int32)
    sizes = SLABS[rng.integers(0, len(SLABS), size=n)]
    cap_bytes = float(rng.uniform(4.0, 0.5 * float(np.sum(sizes))))
    eta = float(rng.uniform(0.01, 0.08))
    r = api.run(
        api.policy_def("ogb_sized", flavor="scan"), trace, n, cap_bytes,
        window=w, sizes=sizes, eta=eta, track_opt=False,
    )
    total_bytes = float(np.sum(sizes[np.asarray(trace)]))
    got = float(np.sum(np.asarray(r.reward, np.float64))) / total_bytes
    want = _f64_sized_oracle(trace, sizes, cap_bytes, eta, w) / total_bytes
    assert got == pytest.approx(want, abs=5e-3)


# -- byte accounting plumbing ---------------------------------------------


def test_sized_automaton_hits_unchanged_byte_ratio_differs():
    """Sizes never change a size-blind automaton's decisions — only the
    byte accounting. The unsized run must stay bit-identical."""
    trace, sizes, n, c = _sized_instance(21)
    for kind in ("lru", "lfu", "ftpl"):
        kw = dict(window=500, seed=2, horizon=len(trace), track_opt=False)
        r0 = api.run(api.policy_def(kind), trace, n, c, **kw)
        r1 = api.run(api.policy_def(kind), trace, n, c, sizes=sizes, **kw)
        np.testing.assert_array_equal(r0.hits, r1.hits)
        np.testing.assert_array_equal(r0.occupancy, r1.occupancy)
        assert r0.byte_hits is None and r0.bytes_total == 0.0
        assert r0.byte_hit_ratio == r0.hit_ratio  # unsized fallback
        assert r1.byte_hits is not None
        assert r1.bytes_total == pytest.approx(
            float(np.sum(sizes[np.asarray(trace)]))
        )
        assert 0.0 <= r1.byte_hit_ratio <= 1.0
        assert r1.byte_hit_ratio != pytest.approx(r1.hit_ratio, abs=1e-4)


def test_sized_lru_byte_hits_match_host_accounting():
    """Device byte accounting == host replay of the same (bit-exact) LRU
    decisions, window by window."""
    from repro.core.policies import LRU

    trace, sizes, n, c = _sized_instance(22)
    w = 400
    r = api.run(
        api.policy_def("lru"), trace, n, c, window=w, sizes=sizes,
        horizon=len(trace), track_opt=False,
    )
    host = LRU(n, c)
    ids = np.asarray(trace)
    want = []
    for k in range(len(ids) // w):
        chunk = ids[k * w:(k + 1) * w]
        flags = np.asarray([host.request(int(i)) for i in chunk])
        want.append(float(np.sum(sizes[chunk][flags])))
    np.testing.assert_allclose(r.byte_hits, want, rtol=0, atol=0)


def test_run_stream_sized_parity():
    """Chunked sized streaming == one-shot sized run, byte accounting
    included, bit for bit."""
    from repro.cachesim.tracelab.stream import run_stream

    trace, sizes, n, c = _sized_instance(23)
    w = 250
    one = api.run(
        api.policy_def("lru"), trace, n, c, window=w, sizes=sizes,
        horizon=len(trace), track_opt=False,
    )
    ids = np.asarray(trace)
    chunks = [ids[i:i + 707] for i in range(0, len(ids), 707)]
    sr = run_stream(
        api.policy_def("lru"), chunks, n, c, window=w, segment_len=1000,
        horizon=len(ids), sizes=sizes,
    )
    np.testing.assert_array_equal(one.hits, sr.hits)
    np.testing.assert_array_equal(one.byte_hits, sr.byte_hits)
    assert sr.bytes_total == pytest.approx(one.bytes_total)
    assert sr.byte_hit_ratio == pytest.approx(one.byte_hit_ratio)


def test_sweep_sized_byte_hit_ratios():
    trace, sizes, n, c = _sized_instance(24, n=80, t=2000, c=8)
    cap_bytes = int(round(c * float(np.mean(sizes))))
    res = api.sweep(
        api.policy_def("ogb_sized", flavor="scan"), trace, n,
        capacities=[cap_bytes], etas=[0.02, 0.05], window=250,
        sizes=sizes, track_opt=False,
    )
    assert len(res.byte_hit_ratios) == 2
    assert all(0.0 <= b <= 1.0 for b in res.byte_hit_ratios)


# -- unit policies reject what they cannot honor --------------------------


def test_unit_policies_reject_sizes_and_costs():
    trace, sizes, n, c = _sized_instance(25, n=40, t=1000, c=5)
    with pytest.raises(ValueError, match="unit-size"):
        api.run(
            api.policy_def("ogb"), trace, n, c, window=250, sizes=sizes,
            track_opt=False,
        )
    with pytest.raises(ValueError, match="costs"):
        api.run(
            api.policy_def("lru"), trace, n, c, window=250, sizes=sizes,
            costs=sizes, horizon=1000, track_opt=False,
        )
    with pytest.raises(ValueError, match="sizes"):
        api.run(
            api.policy_def("ogb_sized", flavor="scan"), trace, n, c,
            window=250, eta=0.05, track_opt=False,
        )


def test_run_rejects_bad_sizes():
    trace, _, n, c = _sized_instance(26, n=40, t=1000, c=5)
    for bad in (np.zeros(n), np.full(n, -1.0), np.full(n, np.nan),
                np.ones(n - 1)):
        with pytest.raises(ValueError):
            api.run(
                api.policy_def("lru"), trace, n, c, window=250,
                sizes=bad, horizon=1000, track_opt=False,
            )


# -- synthesizer size joint ------------------------------------------------


def test_synthesize_sizes_preserves_size_popularity_joint():
    """Fit on a trace whose sizes are anti-correlated with popularity;
    the synthesized catalog must reproduce the trend (popular small,
    tail large)."""
    from repro.cachesim.tracelab.synth import (
        fit_profile, synthesize_chunks, synthesize_sizes,
    )

    rng = np.random.default_rng(5)
    n = 400
    ranks = np.minimum(
        (rng.zipf(1.2, size=30_000) - 1), n - 1
    ).astype(np.int64)
    item_sizes = np.geomspace(1.0, 512.0, n)  # rank r -> bigger size
    prof = fit_profile(ranks, sizes=item_sizes[ranks])
    synth = np.concatenate(
        list(synthesize_chunks(prof, 30_000, seed=9))
    )
    szs = synthesize_sizes(prof, catalog=prof.catalog, seed=9)
    assert szs.shape == (prof.catalog,) and np.all(szs > 0)
    cnt = np.bincount(synth, minlength=prof.catalog)
    top = np.argsort(-cnt)[: max(prof.catalog // 10, 1)]
    tail = np.argsort(-cnt)[prof.catalog // 2:]
    assert float(np.median(szs[top])) < float(np.median(szs[tail]))
    # an unsized profile synthesizes unit sizes
    prof_u = fit_profile(ranks)
    np.testing.assert_array_equal(
        synthesize_sizes(prof_u, catalog=prof_u.catalog), 1.0
    )
