"""The async double-buffered pipeline: bit-exactness, faults, timing.

``run_stream``'s async mode (background ingest + non-blocking dispatch +
overlapped host OPT pass) must be a pure *scheduling* change: for every
trace-driven PolicyDef, over ragged prime-sized chunks, the async replay
equals the synchronous one bit for bit — hits, fractional reward, aux,
occupancy, dynamic-OPT windows, and every leaf of the final carry.  On
top of the differential sweep: the fault path (a loader that raises
mid-stream drains in-flight work and surfaces a position-pinned
:class:`StreamFault` with a *resumable* partial result), the stall path
(a slow source only idles the pipeline), and the split timing fields.
"""

import time

import numpy as np
import pytest

import jax

from repro.cachesim import api
from repro.cachesim.results import StreamResult
from repro.cachesim.tracelab import StreamFault, run_stream
from repro.cachesim.traces import zipf
from repro.core.regret import best_static_hits

STREAM_KINDS = tuple(
    k for k in api.policy_def_kinds() if api.policy_def(k).trace_driven
)

N, C, T = 311, 23, 6400
WINDOW = 16


def _kind_kwargs(kind):
    kw = {"eta": 0.03} if api.policy_def(kind).fractional else {}
    if kind == "ogb_sized":
        kw["sizes"] = np.asarray([1.0, 2.0, 4.0, 8.0])[np.arange(N) % 4]
    return kw


def _ragged(trace, size=997):
    return (trace[i : i + size] for i in range(0, len(trace), size))


@pytest.mark.parametrize("kind", STREAM_KINDS)
def test_async_bit_exact_vs_sync(kind):
    """prefetch=2 == prefetch=0 over ragged prime chunks, every kind."""
    trace = zipf(N, T, alpha=0.9, seed=3)
    pd = api.policy_def(kind)
    kw = _kind_kwargs(kind)
    runs = {}
    for prefetch in (0, 2):
        runs[prefetch] = run_stream(
            pd, _ragged(trace), N, C, window=WINDOW, seed=0, horizon=T,
            segment_len=2048, opt_window=704, prefetch=prefetch, **kw,
        )
    sync, asy = runs[0], runs[2]
    assert asy.prefetch == 2 and sync.prefetch == 0
    assert asy.T == sync.T and asy.n_segments == sync.n_segments
    np.testing.assert_array_equal(asy.hits, sync.hits)
    np.testing.assert_array_equal(asy.reward, sync.reward)
    np.testing.assert_array_equal(asy.aux, sync.aux)
    np.testing.assert_array_equal(asy.occupancy, sync.occupancy)
    np.testing.assert_array_equal(asy.dyn_opt_hits, sync.dyn_opt_hits)
    if sync.byte_hits is not None:
        np.testing.assert_array_equal(asy.byte_hits, sync.byte_hits)
    for a, b in zip(jax.tree.leaves(asy.carry), jax.tree.leaves(sync.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("prefetch", (0, 1, 2, 4))
def test_prefetch_depths_agree(prefetch):
    """Any pipeline depth replays the same dynamics (lfu as the automaton
    witness; the full kind sweep above covers depth 0 vs 2)."""
    trace = zipf(N, T, alpha=0.9, seed=7)
    sr = run_stream(
        api.policy_def("lfu"), _ragged(trace, 1013), N, C, window=WINDOW,
        horizon=T, segment_len=1024, prefetch=prefetch,
    )
    ref = api.run(
        api.policy_def("lfu"), trace, N, C, window=WINDOW, horizon=T,
        track_opt=False,
    )
    np.testing.assert_array_equal(sr.hits, ref.hits)
    np.testing.assert_array_equal(sr.reward, ref.reward)


@pytest.mark.parametrize("prefetch", (0, 2))
def test_source_fault_drains_and_pins_position(prefetch):
    """A loader that raises mid-stream: in-flight segments are drained,
    the StreamFault pins the position, and the partial result resumes
    bit-exactly into the rest of the trace."""
    trace = zipf(N, T, alpha=0.9, seed=11)
    cut = 4096  # fault lands exactly at a segment boundary

    def faulty():
        yield trace[:2048]
        yield trace[2048:cut]
        raise OSError("disk vanished")

    pd = api.policy_def("lru")
    with pytest.raises(StreamFault) as ei:
        run_stream(
            pd, faulty(), N, C, window=WINDOW, horizon=T,
            segment_len=2048, prefetch=prefetch,
        )
    fault = ei.value
    assert isinstance(fault.__cause__, OSError)
    assert fault.t_ingested == cut
    assert fault.t_replayed == cut  # both in-flight segments drained
    assert fault.n_segments == 2
    partial = fault.partial
    assert isinstance(partial, StreamResult)
    assert partial.T == cut and partial.prefetch == prefetch

    # the drained prefix + a resumed stream == the uninterrupted replay
    rest = run_stream(
        pd, trace[cut:], capacity=C, carry=partial.carry, window=WINDOW,
        segment_len=2048, prefetch=prefetch,
    )
    full = run_stream(
        pd, trace, N, C, window=WINDOW, horizon=T, segment_len=2048,
        prefetch=prefetch,
    )
    np.testing.assert_array_equal(
        np.concatenate([partial.hits, rest.hits]), full.hits
    )
    for a, b in zip(jax.tree.leaves(rest.carry), jax.tree.leaves(full.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_source_fault_before_first_window():
    """A fault before one full window replays: no partial, position 0."""

    def dead():
        raise RuntimeError("no data")
        yield  # pragma: no cover

    with pytest.raises(StreamFault) as ei:
        run_stream(
            api.policy_def("lru"), dead(), N, C, window=WINDOW, horizon=T,
        )
    assert ei.value.partial is None
    assert ei.value.t_replayed == 0 and ei.value.t_ingested == 0


def test_slow_source_stalls_gracefully():
    """A stalling chunk source just idles the pipeline — results are
    unchanged and the stall shows up as ingest time, not an error."""
    trace = zipf(N, 3200, alpha=0.9, seed=13)

    def slow():
        for i in range(0, 3200, 800):
            time.sleep(0.02)
            yield trace[i : i + 800]

    sr = run_stream(
        api.policy_def("ogb"), slow(), N, C, window=WINDOW, horizon=3200,
        segment_len=1024, eta=0.03, prefetch=2,
    )
    ref = run_stream(
        api.policy_def("ogb"), trace, N, C, window=WINDOW, horizon=3200,
        segment_len=1024, eta=0.03, prefetch=0,
    )
    np.testing.assert_array_equal(sr.hits, ref.hits)
    np.testing.assert_array_equal(sr.reward, ref.reward)
    assert sr.ingest_seconds > 0.05  # the four sleeps landed on the clock


def test_validation_error_is_not_wrapped():
    """Out-of-range ids are a caller bug, not a source fault: the async
    path must surface the same ValueError the sync path raises."""
    trace = zipf(N, 2000, seed=2)
    bad = trace.copy()
    bad[777] = N + 500
    for prefetch in (0, 2):
        with pytest.raises(ValueError, match=r"dense in \[0"):
            run_stream(
                api.policy_def("lru"), bad, N, C, window=WINDOW,
                horizon=2000, prefetch=prefetch,
            )


def test_timing_split_components():
    """wall_seconds stays the total; the component clocks are populated
    and non-negative in both modes."""
    trace = zipf(N, T, alpha=0.9, seed=5)
    for prefetch in (0, 2):
        sr = run_stream(
            api.policy_def("lfu"), _ragged(trace), N, C, window=WINDOW,
            horizon=T, segment_len=2048, opt_window=640, prefetch=prefetch,
        )
        assert sr.wall_seconds > 0
        assert sr.device_seconds > 0
        assert sr.ingest_seconds >= 0 and sr.host_seconds >= 0
        if prefetch == 0:
            # synchronous: the components partition the wall clock
            total = sr.ingest_seconds + sr.device_seconds + sr.host_seconds
            assert total <= sr.wall_seconds + 0.05


def test_dyn_opt_tail_flush_covers_every_replayed_request():
    """Regression (the residual dynamic-OPT buffer was dropped): windows
    now cover all t_used requests, the final shorter window included."""
    t = 5000  # 312 windows of 16 + 8 dropped; opt_window 704 leaves a tail
    trace = zipf(N, t, alpha=0.8, seed=9)
    sr = run_stream(
        api.policy_def("lfu"), trace, N, C, window=WINDOW, horizon=t,
        opt_window=704, segment_len=1024,
    )
    assert sr.t_dropped == t % WINDOW
    lens = sr.dyn_opt_lens
    assert int(lens.sum()) == sr.T  # full coverage, nothing discarded
    assert (lens[:-1] == sr.dyn_opt_window).all()
    assert 0 < lens[-1] <= sr.dyn_opt_window
    # each window (the partial tail included) is exactly the hindsight
    # static OPT of its own slice
    edges = np.concatenate([[0], np.cumsum(lens)])
    for k in range(len(lens)):
        blk = trace[edges[k] : edges[k + 1]]
        assert sr.dyn_opt_hits[k] == float(best_static_hits(blk, C))
    # dynamic_regret now compares over the whole replayed prefix
    assert sr.dynamic_regret == pytest.approx(
        sr.dynamic_opt_total - float(sr.reward.sum())
    )
    np.testing.assert_allclose(
        sr.dyn_opt_ratio(), sr.dyn_opt_hits / lens
    )


def test_opt_window_longer_than_stream_still_covered():
    """opt_window > T used to yield an empty comparator; now the whole
    (short) stream is one flushed window."""
    t = 1600
    trace = zipf(N, t, alpha=0.9, seed=21)
    sr = run_stream(
        api.policy_def("fifo"), trace, N, C, window=WINDOW, horizon=t,
        opt_window=10 * t,
    )
    assert len(sr.dyn_opt_hits) == 1
    assert sr.dyn_opt_hits[0] == float(best_static_hits(trace, C))
    assert int(sr.dyn_opt_lens.sum()) == sr.T


def test_fault_partial_preserves_dyn_opt_coverage():
    """The drained partial result's dynamic-OPT windows cover its own
    replayed prefix (the flush also runs on the fault path)."""
    trace = zipf(N, T, alpha=0.9, seed=15)

    def faulty():
        yield trace[:3000]
        raise RuntimeError("gone")

    with pytest.raises(StreamFault) as ei:
        run_stream(
            api.policy_def("lru"), faulty(), N, C, window=WINDOW,
            horizon=T, segment_len=1024, opt_window=704, prefetch=2,
        )
    partial = ei.value.partial
    assert partial is not None
    assert int(partial.dyn_opt_lens.sum()) == partial.T


def test_prefetch_env_default(monkeypatch):
    """REPRO_STREAM_PREFETCH is the process-wide fallback knob."""
    trace = zipf(N, 2000, seed=4)
    monkeypatch.setenv("REPRO_STREAM_PREFETCH", "0")
    sr = run_stream(
        api.policy_def("lru"), trace, N, C, window=WINDOW, horizon=2000
    )
    assert sr.prefetch == 0
    monkeypatch.setenv("REPRO_STREAM_PREFETCH", "3")
    sr = run_stream(
        api.policy_def("lru"), trace, N, C, window=WINDOW, horizon=2000
    )
    assert sr.prefetch == 3
