"""Fleet differential harness: run_fleet vs E independent api.run calls.

The whole fleet contract is that one vmapped dispatch is *exactly* E
independent replays — so every trace-driven kind is checked bit-exact on
hits/reward/aux/occupancy AND the final carry leaves, per tenant, against
``api.run`` with the same (capacity, seed, eta, horizon, n_slots).  Plus:
sweep==fleet parity on a shared trace, resume-mid-stream parity, the
per-tenant ``default_eta`` regression, streamed==in-memory parity, and
the edge->origin invariants.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.cachesim import api
from repro.cachesim.fleet import (
    run_edge_fleet,
    run_edge_fleet_scenario,
    run_fleet,
    run_fleet_stream,
)
from repro.cachesim.tracelab import (
    StreamFault,
    fit_profile,
    tenant_streams,
)
from repro.cachesim.traces import make_trace
from repro.core.ogb import theoretical_eta

N, W, T, E = 128, 50, 600, 3
CAPS = [8, 16, 12]
SEEDS = [3, 4, 5]
TRACE_KINDS = ("ogb", "ogb_tree", "omd", "lru", "lfu", "fifo", "ftpl", "gds")
SIZED_KINDS = ("gds", "ogb_sized")


@pytest.fixture(scope="module")
def traces():
    return np.stack(
        [make_trace("zipf", N, T, seed=7 + e, alpha=0.8) for e in range(E)]
    )


@pytest.fixture(scope="module")
def sizes():
    rng = np.random.default_rng(0)
    return rng.choice([1.0, 4.0, 16.0], size=N).astype(np.float64)


def _assert_rows_equal(fr, results):
    for e, r in enumerate(results):
        np.testing.assert_array_equal(fr.hits[e], r.hits)
        np.testing.assert_array_equal(fr.reward[e], r.reward)
        np.testing.assert_array_equal(fr.aux[e], r.aux)
        np.testing.assert_array_equal(fr.occupancy[e], r.occupancy)


def _assert_carry_rows_equal(fleet_carry, results):
    fleet_leaves = jax.tree.leaves(fleet_carry)
    for e, r in enumerate(results):
        ind_leaves = jax.tree.leaves(r.carry)
        assert jax.tree.structure(fleet_carry) == jax.tree.structure(r.carry)
        for fl, il in zip(fleet_leaves, ind_leaves):
            np.testing.assert_array_equal(
                np.asarray(fl)[e], np.asarray(il)
            )


@pytest.mark.parametrize("kind", TRACE_KINDS)
def test_fleet_matches_independent_runs(kind, traces):
    pd = api.policy_def(kind)
    fr = run_fleet(pd, traces, N, CAPS, window=W, seeds=SEEDS)
    results = [
        api.run(
            pd, traces[e], N, CAPS[e], window=W, seed=SEEDS[e],
            n_slots=max(CAPS),
        )
        for e in range(E)
    ]
    _assert_rows_equal(fr, results)
    _assert_carry_rows_equal(fr.carry, results)
    np.testing.assert_allclose(
        fr.opt_hits, [r.opt_hits for r in results]
    )


@pytest.mark.parametrize("kind", SIZED_KINDS)
def test_sized_fleet_matches_independent_runs(kind, traces, sizes):
    pd = api.policy_def(kind)
    fr = run_fleet(pd, traces, N, CAPS, window=W, seeds=SEEDS, sizes=sizes)
    results = [
        api.run(
            pd, traces[e], N, CAPS[e], window=W, seed=SEEDS[e],
            n_slots=max(CAPS), sizes=sizes,
        )
        for e in range(E)
    ]
    _assert_rows_equal(fr, results)
    assert fr.byte_hits is not None
    for e, r in enumerate(results):
        np.testing.assert_array_equal(fr.byte_hits[e], r.byte_hits)
        assert fr.bytes_total[e] == r.bytes_total


@pytest.mark.parametrize("kind", ("ogb", "lru"))
def test_fleet_matches_sweep_on_shared_trace(kind, traces):
    """Same trace fanned over capacities: fleet rows == sweep rows."""
    pd = api.policy_def(kind)
    caps = (4, 8, 16)
    sw = api.sweep(pd, traces[0], N, caps, seeds=(0,), window=W)
    fr = run_fleet(
        pd,
        np.stack([traces[0]] * len(caps)),
        N,
        list(caps),
        window=W,
        seeds=0,
        # sweep resolves eta at the shared trace horizon; match it so the
        # fractional combos agree bit-exactly
        horizons=T,
    )
    for i in range(len(caps)):
        j = sw.row(capacity=caps[i])
        np.testing.assert_array_equal(fr.hits[i], sw.hits[j])
        np.testing.assert_array_equal(fr.reward[i], sw.reward[j])


@pytest.mark.parametrize("kind", ("ogb", "lru"))
def test_fleet_resume_mid_stream(kind, traces):
    pd = api.policy_def(kind)
    half = T // 2
    full = run_fleet(
        pd, traces, N, CAPS, window=W, seeds=SEEDS, track_opt=False
    )
    r1 = run_fleet(
        pd, traces[:, :half], N, CAPS, window=W, seeds=SEEDS,
        # the one-shot run resolves default_eta at T; pin the same horizon
        horizons=T,
        track_opt=False,
    )
    r2 = run_fleet(
        pd, traces[:, half:], carry=r1.carry, capacities=CAPS,
        window=W, track_opt=False,
    )
    np.testing.assert_array_equal(
        np.concatenate([r1.hits, r2.hits], axis=1), full.hits
    )
    np.testing.assert_array_equal(
        np.concatenate([r1.reward, r2.reward], axis=1), full.reward
    )
    _assert_carry_rows_equal(
        full.carry,
        [
            type(
                "R", (), {"carry": jax.tree.map(lambda x: x[e], r2.carry)}
            )()
            for e in range(E)
        ],
    )


def test_fleet_resume_rejects_init_kwargs(traces):
    pd = api.policy_def("ogb")
    r = run_fleet(pd, traces, N, CAPS, window=W, track_opt=False)
    with pytest.raises(ValueError, match="resumes with"):
        run_fleet(pd, traces, window=W, carry=r.carry, seeds=SEEDS)


def test_fleet_rejects_ragged_traces():
    pd = api.policy_def("ogb")
    with pytest.raises(ValueError, match="equal length"):
        run_fleet(pd, [np.zeros(100, int), np.zeros(150, int)], N, 8,
                  window=W)


def test_fleet_rejects_non_trace_driven():
    with pytest.raises(ValueError, match="trace-driven"):
        run_fleet(
            api.policy_def("ogb_grad"), np.zeros((2, 100), int), N, 8,
            window=W,
        )


def test_default_eta_resolves_per_tenant(traces):
    """The satellite-3 regression: a tenant replaying a T-slice gets the
    Theorem-3.1 rate at ITS horizon, not at the fleet-aggregate E*T (nor
    any other shared horizon)."""
    pd = api.policy_def("ogb")
    fr = run_fleet(pd, traces, N, CAPS, window=W, track_opt=False)
    assert fr.etas is not None and fr.etas.shape == (E,)
    for e in range(E):
        expect = theoretical_eta(CAPS[e], N, T, 1)
        assert fr.etas[e] == pytest.approx(expect, rel=1e-12)
        # and it must NOT be the fleet-aggregate-horizon rate
        assert fr.etas[e] != pytest.approx(
            theoretical_eta(CAPS[e], N, E * T, 1), rel=1e-6
        )
    # heterogeneous horizons resolve each tenant at its own horizon
    hor = [T, 2 * T, 4 * T]
    fr2 = run_fleet(
        pd, traces, N, CAPS, window=W, horizons=hor, track_opt=False
    )
    for e in range(E):
        assert fr2.etas[e] == pytest.approx(
            theoretical_eta(CAPS[e], N, hor[e], 1), rel=1e-12
        )


@pytest.mark.parametrize("kind", ("ogb", "lru"))
@pytest.mark.parametrize("prefetch", (0, 2))
def test_fleet_stream_matches_in_memory(kind, prefetch, traces):
    """Ragged prime-sized source chunks re-batch to the same replay."""
    pd = api.policy_def(kind)
    fr = run_fleet(
        pd, traces, N, CAPS, window=W, seeds=SEEDS, track_opt=False
    )
    sources = [
        [traces[e][i : i + 97] for i in range(0, T, 97)] for e in range(E)
    ]
    fs = run_fleet_stream(
        pd, sources, N, CAPS, window=W, seeds=SEEDS, horizons=T,
        prefetch=prefetch, segment_len=200,
    )
    np.testing.assert_array_equal(fs.hits, fr.hits)
    np.testing.assert_array_equal(fs.reward, fr.reward)
    assert fs.n_segments == 3  # 600 per tenant / 200-aligned segments
    assert fs.t_dropped == 0


def test_fleet_stream_truncates_ragged_sources(traces):
    """Unequal tenants truncate to the shortest window-aligned length."""
    pd = api.policy_def("ogb")
    sources = [[traces[0][:500]], [traces[1][:350]], [traces[2][:600]]]
    fs = run_fleet_stream(
        pd, sources, N, CAPS, window=W, seeds=SEEDS, horizons=T, prefetch=0
    )
    assert fs.T == 350  # 350 -> floor to window multiple
    assert fs.t_dropped == (500 - 350) + 0 + (600 - 350)
    fr = run_fleet(
        pd, traces[:, :350], N, CAPS, window=W, seeds=SEEDS, horizons=T,
        track_opt=False,
    )
    np.testing.assert_array_equal(fs.hits, fr.hits)


def test_fleet_stream_synthesized_tenants():
    """tenant_streams sources replay identically to their materialization."""
    pd = api.policy_def("ogb")
    profile = fit_profile(make_trace("zipf", N, 4000, seed=11, alpha=0.8))
    t_s, e_s, cap = 300, 2, 12
    fs = run_fleet_stream(
        pd,
        tenant_streams(profile, e_s, t_s, catalog=N, base_seed=5),
        N,
        cap,
        window=W,
        horizons=t_s,
        track_opt=True,
    )
    mem = np.stack(
        [
            np.concatenate(
                list(
                    tenant_streams(profile, e_s, t_s, catalog=N,
                                   base_seed=5)[e]
                )
            )
            for e in range(e_s)
        ]
    )
    fr = run_fleet(pd, mem, N, cap, window=W, horizons=t_s)
    np.testing.assert_array_equal(fs.hits, fr.hits)
    np.testing.assert_allclose(fs.opt_hits, fr.opt_hits)


def test_fleet_stream_fault_carries_partial(traces):
    def bad_source():
        yield traces[0][:200]
        raise OSError("disk gone")

    sources = [bad_source(), [traces[1]], [traces[2]]]
    with pytest.raises(StreamFault) as ei:
        run_fleet_stream(
            api.policy_def("ogb"), sources, N, CAPS, window=W,
            horizons=T, prefetch=2, segment_len=100,
        )
    fault = ei.value
    assert isinstance(fault.__cause__, OSError)
    if fault.partial is not None:
        assert fault.partial.T > 0
        assert fault.partial.carry is not None


def test_edge_fleet_invariants(traces):
    ef = run_edge_fleet("lru", "ogb", traces, N, 8, 32, window=W)
    # edge rows are exactly independent per-edge replays
    pd = api.policy_def("lru")
    for e in range(E):
        r = api.run(pd, traces[e], N, 8, window=W, seed=e)
        np.testing.assert_array_equal(ef.edges.hits[e], r.hits)
    # conservation: every edge miss (and only those) reaches the origin
    assert ef.origin_requests == E * T - int(ef.edges.hits.sum())
    # the origin replays its window-aligned prefix of the miss stream
    assert ef.origin.T == (ef.origin_requests // W) * W
    assert 0.0 < ef.end_to_end_hit_ratio <= 1.0
    assert ef.end_to_end_hit_ratio >= ef.edge_hit_ratio
    # deterministic interleave -> bit-identical repeat
    ef2 = run_edge_fleet("lru", "ogb", traces, N, 8, 32, window=W)
    np.testing.assert_array_equal(ef.origin.hits, ef2.origin.hits)


def test_edge_fleet_scenario_mini_runs():
    ef = run_edge_fleet_scenario("edge_fleet_cdn", "mini")
    assert ef.edges.n_tenants >= 2
    assert 0.0 < ef.edges.hit_ratio_mean < 1.0
    assert ef.edges.hit_ratio_p5 <= ef.edges.hit_ratio_p95
    assert ef.origin.T > 0


def test_fleet_sharded_matches_unsharded():
    """Tenant axis over the data mesh axis: same results as unsharded."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.cachesim import api
from repro.cachesim.fleet import run_fleet
from repro.cachesim.traces import make_trace

N, W, T, E = 128, 50, 400, 4
traces = np.stack([make_trace("zipf", N, T, seed=e, alpha=0.8)
                   for e in range(E)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
for kind, exact in (("lru", True), ("ogb", False)):
    pd = api.policy_def(kind)
    ref = run_fleet(pd, traces, N, 12, window=W, track_opt=False)
    sh = run_fleet(pd, traces, N, 12, window=W, track_opt=False, mesh=mesh)
    if exact:
        np.testing.assert_array_equal(sh.hits, ref.hits)
    else:
        np.testing.assert_allclose(sh.reward, ref.reward, rtol=1e-5)
        np.testing.assert_allclose(
            sh.hits.astype(float), ref.hits.astype(float), atol=1.0
        )
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    assert "OK" in out.stdout, out.stderr[-3000:]
