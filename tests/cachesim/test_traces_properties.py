"""Hypothesis property tests for the trace generators (real-or-stub).

Uses the PR-2 conftest pattern: the real ``hypothesis`` when installed, the
vendored deterministic stub otherwise — either way these run in tier-1.

Invariants per generator: ids always in [0, N), exact length, int64 dtype,
determinism per seed; the adversarial round-robin covers the whole catalog
every round; ``trace_stats`` lifetime/max-hit identities hold for arbitrary
traces (they are exact combinatorial facts, not approximations).
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cachesim.traces import (
    TRACE_REGISTRY,
    adversarial,
    make_trace,
    reuse_distances,
    trace_stats,
)

GENERATOR_KINDS = sorted(set(TRACE_REGISTRY))


@given(
    kind=st.sampled_from(GENERATOR_KINDS),
    n=st.integers(8, 600),
    t=st.integers(1, 3000),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_ids_in_range_and_deterministic(kind, n, t, seed):
    a = make_trace(kind, n, t, seed=seed)
    b = make_trace(kind, n, t, seed=seed)
    assert a.dtype == np.int64
    assert len(a) == t
    assert a.min() >= 0 and a.max() < n
    np.testing.assert_array_equal(a, b)


@given(
    n=st.integers(4, 300),
    rounds=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_adversarial_covers_catalog_each_round(n, rounds, seed):
    tr = adversarial(n, rounds * n, seed=seed)
    for r in range(rounds):
        chunk = tr[r * n : (r + 1) * n]
        assert len(set(chunk.tolist())) == n  # a permutation: full coverage


@given(
    n=st.integers(4, 200),
    t=st.integers(1, 2000),
    kind=st.sampled_from(GENERATOR_KINDS),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_trace_stats_lifetime_invariants(n, t, kind, seed):
    tr = make_trace(kind, n, t, seed=seed)
    st_ = trace_stats(tr)
    assert st_.length == t
    assert st_.unique == len(np.unique(tr))
    assert st_.catalog == int(tr.max()) + 1
    # lifetimes: bounded by the horizon; zero iff the item appears once in a
    # single position-cluster sense (first == last)
    assert np.all(st_.lifetimes >= 0) and np.all(st_.lifetimes <= t - 1)
    counts = np.bincount(tr)
    counts = counts[counts > 0]
    np.testing.assert_array_equal(np.sort(st_.max_hits), np.sort(counts - 1))
    # total attainable (infinite-cache) hits = T - #unique items
    assert int(st_.max_hits.sum()) == t - st_.unique
    # a lifetime of L needs at least 2 requests, and at most L+1 distinct
    # positions fit in a window of L+1
    multi = st_.max_hits >= 1
    assert np.all(st_.lifetimes[multi] >= 1)
    assert np.all(st_.max_hits <= st_.lifetimes)
    # dict views agree with the array fast path
    assert st_.lifetime_by_item == dict(
        zip(st_.items.tolist(), st_.lifetimes.tolist())
    )


@given(
    n=st.integers(2, 50),
    t=st.integers(2, 800),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_reuse_distances_match_bruteforce(n, t, seed):
    rng = np.random.default_rng(seed)
    tr = rng.integers(0, n, size=t)
    got = reuse_distances(tr)
    lastpos, expect = {}, []
    for pos, j in enumerate(tr.tolist()):
        if j in lastpos:
            expect.append(pos - lastpos[j])
        lastpos[j] = pos
    np.testing.assert_array_equal(got, np.asarray(expect, np.int64))
    # every item with k requests contributes exactly k-1 distances
    assert len(got) == t - len(np.unique(tr))
