"""Scan-compiled replay == per-batch reference == float64 eager oracle.

The replay engine's whole point is that compiling the trace into one
``lax.scan`` with a warm-started projection changes *nothing* about the
replayed dynamics — every metric must match the per-batch
``ogb_batch_update`` driver and (within float32 tolerance) the exact float64
numpy oracle, on both random and adversarial traces.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cachesim.replay import replay_trace
from repro.cachesim.traces import adversarial, zipf
from repro.core.projection import capped_simplex_tau, project_capped_simplex
from repro.core.regret import best_static_hits
from repro.jaxcache.fractional import (
    FractionalState,
    capped_simplex_project,
    capped_simplex_project_warm,
    ogb_batch_update,
    ogb_batch_update_warm,
    permanent_random_numbers,
)

N, C, B = 301, 17, 16


def _per_batch_reference(trace, n, c, b, eta, seed=0):
    """The old driver: per-batch dispatch with identical Poisson sampling."""
    state = FractionalState.create(n, c)
    k_p, _ = jax.random.split(jax.random.key(seed))
    p = permanent_random_numbers(k_p, n)
    rewards, hits = [], []
    for i in range(len(trace) // b):
        ids = jnp.asarray(trace[i * b : (i + 1) * b], jnp.int32)
        fi = state.f[ids]
        rewards.append(float(jnp.sum(fi)))
        hits.append(int(jnp.sum(fi >= p[ids])))
        state, _ = ogb_batch_update(state, ids, jnp.float32(eta), c)
    return np.asarray(rewards), np.asarray(hits), np.asarray(state.f)


def _oracle_reference(trace, n, c, b, eta):
    """Float64 eager projection oracle (core/projection.py), batched."""
    f = np.full(n, c / n, dtype=np.float64)
    rewards = []
    for i in range(len(trace) // b):
        ids = trace[i * b : (i + 1) * b]
        rewards.append(f[ids].sum())
        y = f + eta * np.bincount(ids, minlength=n)
        f = project_capped_simplex(y, c)
    return np.asarray(rewards), f


@pytest.mark.parametrize(
    "make_trace",
    [
        lambda: zipf(N, 640, alpha=0.9, seed=3),
        lambda: adversarial(N, 640, seed=4),
    ],
    ids=["zipf", "adversarial"],
)
def test_scan_equals_per_batch_and_oracle(make_trace):
    trace = make_trace()
    eta = 0.03
    m = replay_trace(trace, N, C, batch=B, eta=eta, seed=0, keep_final_f=True)

    ref_rewards, ref_hits, ref_f = _per_batch_reference(trace, N, C, B, eta)
    np.testing.assert_allclose(m.frac_reward, ref_rewards, atol=1e-3)
    np.testing.assert_array_equal(m.hits, ref_hits)
    np.testing.assert_allclose(m.final_f, ref_f, atol=5e-6)

    orc_rewards, orc_f = _oracle_reference(trace, N, C, B, eta)
    np.testing.assert_allclose(m.frac_reward, orc_rewards, atol=5e-3)
    np.testing.assert_allclose(m.final_f, orc_f, atol=5e-5)


def test_warm_tau_equals_cold_bisection():
    """Single-digit warm sweeps must match 50-sweep cold bisection to 1e-6."""
    trace = zipf(N, 800, alpha=0.8, seed=7)
    eta = 0.05
    m_warm = replay_trace(trace, N, C, batch=B, eta=eta, projection="warm")
    m_cold = replay_trace(trace, N, C, batch=B, eta=eta, projection="bisect")
    assert m_warm.extras["sweeps"] <= 10
    np.testing.assert_allclose(m_warm.taus, m_cold.taus, atol=1e-6)
    np.testing.assert_allclose(
        m_warm.frac_reward, m_cold.frac_reward, atol=1e-3
    )
    # and both match the exact float64 tau step by step
    f = np.full(N, C / N, dtype=np.float64)
    for i, tau_w in enumerate(m_warm.taus):
        y = f + eta * np.bincount(
            trace[i * B : (i + 1) * B], minlength=N
        )
        tau_ref = capped_simplex_tau(y, C)
        assert abs(tau_w - tau_ref) < 2e-5, (i, tau_w, tau_ref)
        f = project_capped_simplex(y, C)


def test_warm_projection_single_call():
    """capped_simplex_project_warm == cold bisection == float64 oracle."""
    rng = np.random.default_rng(11)
    y = rng.normal(0.3, 0.5, size=1024).astype(np.float32)
    cap = 100.0
    f_cold, tau_cold = capped_simplex_project(jnp.asarray(y), cap)
    # a deliberately poor seed still converges inside the provable bracket
    f_warm, tau_warm = capped_simplex_project_warm(
        jnp.asarray(y),
        cap,
        jnp.float32(float(y.min()) - 1.0),
        jnp.float32(float(y.max())),
        jnp.float32(0.0),
        sweeps=8,
    )
    assert abs(float(tau_warm) - float(tau_cold)) < 1e-6
    np.testing.assert_allclose(np.asarray(f_warm), np.asarray(f_cold), atol=2e-6)
    tau_ref = capped_simplex_tau(y.astype(np.float64), cap)
    assert abs(float(tau_warm) - tau_ref) < 2e-5


def test_ogb_batch_update_warm_chains():
    """Chained warm updates track the cold per-batch driver exactly."""
    rng = np.random.default_rng(5)
    s_cold = FractionalState.create(N, C)
    s_warm = FractionalState.create(N, C)
    tau = jnp.float32(0.0)
    eta = jnp.float32(0.04)
    for _ in range(30):
        ids = jnp.asarray(rng.integers(0, N, size=B), jnp.int32)
        s_cold, _ = ogb_batch_update(s_cold, ids, eta, C)
        s_warm, _, tau = ogb_batch_update_warm(s_warm, ids, eta, C, tau)
        np.testing.assert_allclose(
            np.asarray(s_warm.f), np.asarray(s_cold.f), atol=2e-6
        )


def test_opt_and_regret_match_host_reference():
    trace = zipf(N, 960, alpha=1.0, seed=9)
    m = replay_trace(trace, N, C, batch=B, seed=1)
    assert m.opt_hits == best_static_hits(trace[: m.T], C)
    assert m.regret == pytest.approx(m.opt_hits - m.frac_reward.sum())
    # no-regret sanity: the fractional reward is within the paper's bound of
    # OPT for this short horizon (loose check, not the theorem constant)
    assert m.frac_reward.sum() > 0.25 * m.opt_hits


def test_madow_sampling_occupancy_exact():
    trace = zipf(N, 480, alpha=0.9, seed=13)
    m = replay_trace(trace, N, C, batch=B, sample="madow", seed=2)
    # Madow draws exactly C items each chunk (fp cumsum tolerance +-1)
    assert np.all(np.abs(m.occupancy - C) <= 1)
    assert 0.0 <= m.hit_ratio <= 1.0


def test_windowed_metrics_partition_totals():
    trace = zipf(N, 640, alpha=0.9, seed=17)
    m = replay_trace(trace, N, C, batch=B, seed=3)
    w = m.windowed_hit_ratio(160)
    assert w.shape == (4,)
    np.testing.assert_allclose(
        w.mean(), m.hits.sum() / m.T, atol=1e-12
    )


def test_sharded_warm_matches_unsharded():
    """8 fake XLA devices: warm sharded step == ogb_batch_update + same tau."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.jaxcache.fractional import FractionalState, ogb_batch_update
from repro.jaxcache.sharded import make_sharded_step

N, C, B, eta = 256, 32, 64, 0.04
mesh = jax.make_mesh((2, 4), ("data", "model"))
step, f_shard = make_sharded_step(mesh, N, C, B, eta, warm_start=True)
rng = np.random.default_rng(0)
f = jax.device_put(jnp.full((N,), C / N, jnp.float32), f_shard)
state = FractionalState.create(N, C)
tau = jnp.float32(0.0)
for i in range(4):
    ids = jnp.asarray(rng.integers(0, N, size=B), jnp.int32)
    f, reward_sh, tau = step(f, ids, tau)
    state, reward_un = ogb_batch_update(state, ids, jnp.float32(eta), C)
    np.testing.assert_allclose(np.asarray(f), np.asarray(state.f), atol=5e-5)
    np.testing.assert_allclose(float(reward_sh), float(reward_un), atol=1e-3)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    assert "OK" in out.stdout, out.stderr[-3000:]
