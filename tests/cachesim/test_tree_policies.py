"""Tree engines vs their dense oracles through the public policy API.

The lru/lfu/ftpl prefix-tree engines must be *bit-exact* against the dense
slot automata (same hit sequence, same occupancy); the lazy bucketized
``ogb_tree`` tracks dense ``ogb`` within its histogram quantization.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.cachesim import api
from repro.kernels.prefix_tree.ref import stack_distance_hits_ref

AUTOMATA = ["lru", "lfu", "ftpl"]


def _zipf_trace(rng, n, t, a=1.2):
    ranks = rng.zipf(a, size=t * 3) - 1
    ranks = ranks[ranks < n][:t]
    return jnp.asarray(rng.permutation(n)[ranks], jnp.int32)


def _traces():
    rng = np.random.default_rng(42)
    n, t = 400, 6000
    zipf = _zipf_trace(rng, n, t)
    cyclic = jnp.asarray(np.tile(np.arange(50), t // 50), jnp.int32)
    bursty = jnp.asarray(
        np.concatenate(
            [np.repeat(rng.integers(0, n, 40), 30) for _ in range(5)]
        ),
        jnp.int32,
    )
    return {"zipf": (zipf, n), "cyclic": (cyclic, n), "bursty": (bursty, n)}


TRACES = _traces()


@pytest.mark.parametrize("kind", AUTOMATA)
@pytest.mark.parametrize("trace_name", sorted(TRACES))
@pytest.mark.parametrize("window", [1, 16, 250])
def test_tree_bit_exact_vs_dense(kind, trace_name, window):
    trace, n = TRACES[trace_name]
    c = 23
    rt = api.run(api.policy_def(kind), trace, n, c, window=window, seed=3)
    rd = api.run(
        api.policy_def(kind, impl="dense"), trace, n, c, window=window, seed=3
    )
    np.testing.assert_array_equal(rt.hits, rd.hits)
    np.testing.assert_array_equal(rt.occupancy, rd.occupancy)


def test_tree_lru_matches_stack_distance_oracle():
    """The reuse-distance formulation IS exact LRU — check against the
    O(T*W) python oracle, not just the dense automaton."""
    rng = np.random.default_rng(0)
    trace, n, c = _zipf_trace(rng, 120, 1500), 120, 11
    r = api.run(api.policy_def("lru"), trace, n, c, window=50)
    oracle = stack_distance_hits_ref(np.asarray(trace), c)
    assert int(r.hits.sum()) == int(oracle.sum())


@pytest.mark.parametrize("kind", AUTOMATA)
def test_tree_resume_bit_exact(kind):
    trace, n = TRACES["zipf"]
    c, w = 23, 16
    # ftpl's noise scale depends on horizon, which defaults to the replayed
    # length — pin it so the split replay runs the same dynamics
    h = len(trace)
    full = api.run(api.policy_def(kind), trace, n, c, window=w, seed=1,
                   horizon=h)
    pd = api.policy_def(kind)
    half = len(trace) // (2 * w) * w
    r1 = api.run(pd, trace[:half], n, c, window=w, seed=1, horizon=h)
    r2 = api.run(pd, trace[half:], capacity=c, window=w, carry=r1.carry)
    np.testing.assert_array_equal(
        np.concatenate([r1.hits, r2.hits]), full.hits
    )


@pytest.mark.parametrize("kind", AUTOMATA)
def test_tree_sweep_matches_single_runs(kind):
    trace, n = TRACES["zipf"]
    caps = [5, 23, 64]
    sw = api.sweep(api.policy_def(kind), trace, n, caps, window=100)
    for combo, hits in zip(sw.combos, sw.hits):
        single = api.run(
            api.policy_def(kind), trace, n, combo["capacity"],
            window=100, n_slots=max(caps),
        )
        np.testing.assert_array_equal(hits, single.hits)


def test_tree_lru_small_ring_compaction_exact():
    """Force ring compactions (ring barely above 4*n_slots) and check the
    rank-compaction path stays bit-exact vs dense."""
    rng = np.random.default_rng(5)
    n, c, t, w = 600, 40, 8000, 100
    trace = _zipf_trace(rng, n, t, a=1.1)
    rt = api.run(api.policy_def("lru"), trace, n, c, window=w, ring=256)
    rd = api.run(api.policy_def("lru", impl="dense"), trace, n, c, window=w)
    np.testing.assert_array_equal(rt.hits, rd.hits)


@pytest.mark.parametrize("sample", ["poisson", "none"])
def test_ogb_tree_tracks_dense_ogb(sample):
    rng = np.random.default_rng(9)
    n, c, t, w = 1500, 75, 40000, 200
    trace = _zipf_trace(rng, n, t)
    rd = api.run(api.policy_def("ogb", sample=sample), trace, n, c,
                 window=w, seed=3)
    rt = api.run(api.policy_def("ogb_tree", sample=sample), trace, n, c,
                 window=w, seed=3)
    # fractional reward is sampling-free: a tight relative check
    assert float(rt.reward.sum()) == pytest.approx(
        float(rd.reward.sum()), rel=1e-2
    )
    if sample == "poisson":
        assert abs(rt.hit_ratio - rd.hit_ratio) <= 5e-3
        # occupancy stays near capacity (bucket-quantized estimate)
        assert abs(np.mean(rt.occupancy) - c) < 0.2 * c


def test_ogb_tree_reanchor_path():
    """A tiny value grid (batch_hint=1) forces frequent re-anchor rebuilds;
    accuracy must not degrade."""
    rng = np.random.default_rng(10)
    n, c, t, w = 800, 50, 30000, 100
    trace = _zipf_trace(rng, n, t, a=1.3)
    rd = api.run(api.policy_def("ogb"), trace, n, c, window=w, eta=0.01)
    rt = api.run(api.policy_def("ogb_tree", batch_hint=1), trace, n, c,
                 window=w, eta=0.01)
    assert abs(rt.hit_ratio - rd.hit_ratio) <= 5e-3


def test_ogb_tree_rejects_madow():
    with pytest.raises(ValueError, match="madow"):
        api.policy_def("ogb_tree", sample="madow")


def test_madow_tree_sampling_matches_dense_madow():
    """The O(C log N) tree-descent Madow draw through the dense OGB policy:
    same systematic sample up to f32 cumsum boundaries, so hit counts agree
    to a fraction of a percent."""
    rng = np.random.default_rng(11)
    n, c, t, w = 1000, 60, 20000, 200
    trace = _zipf_trace(rng, n, t)
    rm = api.run(api.policy_def("ogb", sample="madow", madow_capacity=c),
                 trace, n, c, window=w, seed=2)
    rt = api.run(api.policy_def("ogb", sample="madow_tree", madow_capacity=c),
                 trace, n, c, window=w, seed=2)
    assert abs(rt.hit_ratio - rm.hit_ratio) <= 2e-3
    np.testing.assert_array_equal(rt.occupancy, rm.occupancy)
