"""Tracelab ingestion: loaders, error paths, catalog remap, synthesizer fit.

The bundled fixtures under ``tests/cachesim/data/`` are a few KB of every
supported on-disk format over the *same* sparse raw-id stream (gappy
64-bit block-address-style ids), so cross-format agreement is asserted
directly; ``malformed.csv`` / ``truncated.u32`` / ``overflow.u64`` pin the
loader error paths.  The sparse-id regressions lock the
``trace_stats``/``reuse_distances`` fix: both must be correct (and not
OOM) on id sets nowhere near dense ``0..N-1``.
"""

import os

import numpy as np
import pytest

from repro.cachesim.tracelab import (
    CatalogRemap,
    fit_profile,
    load_trace,
    open_trace,
    sniff_format,
    synthesize,
    write_trace,
)
from repro.cachesim.tracelab.catalog import remap_trace
from repro.cachesim.traces import (
    bursty,
    make_trace,
    reuse_distances,
    shifting_zipf,
    trace_stats,
    zipf,
)

DATA = os.path.join(os.path.dirname(__file__), "data")


# ---------------------------------------------------------------------------
# loaders: the bundled fixtures all encode the same raw stream
# ---------------------------------------------------------------------------
def test_fixture_formats_agree():
    csv = load_trace(os.path.join(DATA, "sample.csv"))
    u64 = load_trace(os.path.join(DATA, "sample.u64"))
    assert len(csv) == 200
    np.testing.assert_array_equal(csv, u64)
    # the other fixtures are prefixes of the same stream
    np.testing.assert_array_equal(
        load_trace(os.path.join(DATA, "sample.tsv")), csv[:120]
    )
    np.testing.assert_array_equal(
        load_trace(os.path.join(DATA, "sample_cdn.log")), csv[:150]
    )
    np.testing.assert_array_equal(
        load_trace(os.path.join(DATA, "sample.u32")),
        csv[:200] % (1 << 31),
    )


def test_header_handling():
    path = os.path.join(DATA, "sample.csv")
    # auto (default) tolerates the header row; "skip" drops it explicitly;
    # "none" treats it as data and fails
    assert len(load_trace(path)) == 200
    assert len(load_trace(path, header="skip")) == 200
    with pytest.raises(ValueError, match="bad trace line"):
        load_trace(path, header="none")


def test_chunked_load_is_chunk_size_invariant():
    path = os.path.join(DATA, "sample.csv")
    want = load_trace(path)
    for chunk_size in (1, 7, 64, 10_000):
        got = np.concatenate(list(open_trace(path, chunk_size=chunk_size)))
        np.testing.assert_array_equal(got, want)
        sizes = [len(c) for c in open_trace(path, chunk_size=chunk_size)]
        assert all(s == chunk_size for s in sizes[:-1])
        assert 0 < sizes[-1] <= chunk_size


def test_malformed_lines_raise_with_position():
    path = os.path.join(DATA, "malformed.csv")
    with pytest.raises(ValueError, match=r"malformed\.csv:4"):
        load_trace(path)  # line 4 has one field


def test_malformed_lines_skip_policy():
    got = load_trace(os.path.join(DATA, "malformed.csv"), on_bad="skip")
    np.testing.assert_array_equal(got, [17, 4096, 9])


def test_hash_key_mode_rejects_header_auto():
    """hash mode parses every string, so a header row cannot be
    auto-detected — it would be ingested as a phantom first-seen item and
    shift every dense id; the combination must raise."""
    with pytest.raises(ValueError, match="auto-detected"):
        load_trace(os.path.join(DATA, "sample.csv"), key_mode="hash")


def test_hash_key_mode_loads_string_keys():
    got = load_trace(
        os.path.join(DATA, "malformed.csv"), key_mode="hash", on_bad="skip",
        header="skip",
    )
    # every id line hashes (including "not_an_id"); the 1-field line skips
    assert len(got) == 4
    assert got.min() >= 0  # digests folded into non-negative int64
    again = load_trace(
        os.path.join(DATA, "malformed.csv"), key_mode="hash", on_bad="skip",
        header="skip",
    )
    np.testing.assert_array_equal(got, again)  # stable digests


def test_truncated_binary_raises():
    with pytest.raises(ValueError, match="truncated"):
        list(open_trace(os.path.join(DATA, "truncated.u32")))


def test_id_overflow_raises():
    with pytest.raises(ValueError, match="overflows int64"):
        load_trace(os.path.join(DATA, "overflow.u64"))
    # text path: an overflowed id is not skippable even with on_bad="skip"
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "over.csv")
        with open(p, "w") as f:
            f.write(f"0,{1 << 70},1\n")
        with pytest.raises(ValueError, match="overflows int64"):
            load_trace(p, header="none", on_bad="skip")


def test_unknown_and_ambiguous_formats():
    with pytest.raises(ValueError, match="cannot infer"):
        sniff_format("trace.bin")  # .bin is ambiguous between u32/u64
    with pytest.raises(ValueError, match="unknown trace format"):
        load_trace(os.path.join(DATA, "sample.csv"), format="parquet")
    with pytest.raises(ValueError, match="chunk_size"):
        list(open_trace(os.path.join(DATA, "sample.csv"), chunk_size=0))


def test_write_trace_bin32_overflow():
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="uint32"):
            write_trace(os.path.join(d, "t.u32"), [1 << 40])


# ---------------------------------------------------------------------------
# catalog remap
# ---------------------------------------------------------------------------
def test_remap_first_seen_density():
    cr = CatalogRemap()
    out = np.concatenate(
        list(cr.remap(open_trace(os.path.join(DATA, "sample.csv"),
                                 chunk_size=13)))
    )
    raw = load_trace(os.path.join(DATA, "sample.csv"))
    # dense 0..N-1, first-seen monotone: a new dense id is always the next
    # integer, and the raw id behind dense d is raw[first occurrence of d]
    seen = {}
    want = np.empty_like(raw)
    for i, v in enumerate(raw.tolist()):
        if v not in seen:
            seen[v] = len(seen)
        want[i] = seen[v]
    np.testing.assert_array_equal(out, want)
    assert len(cr) == len(seen)
    np.testing.assert_array_equal(
        cr.raw_ids, sorted(seen, key=seen.get)
    )


def test_remap_overflow_raise():
    cr = CatalogRemap(max_items=2)
    with pytest.raises(ValueError, match="catalog overflow"):
        cr.apply(np.array([5, 6, 7]))


def test_remap_overflow_drop():
    cr = CatalogRemap(max_items=2, overflow="drop")
    out = cr.apply(np.array([5, 6, 7, 5, 7, 6]))
    np.testing.assert_array_equal(out, [0, 1, 0, 1])  # 7's requests removed
    assert cr.dropped == 2
    # a dropped id stays dropped in later chunks
    np.testing.assert_array_equal(cr.apply(np.array([7, 5])), [0])
    assert cr.dropped == 3


def test_remap_overflow_clamp():
    cr = CatalogRemap(max_items=3, overflow="clamp")
    out = cr.apply(np.array([5, 6, 7, 8, 5, 7]))
    # two real items + the shared bucket id 2
    np.testing.assert_array_equal(out, [0, 1, 2, 2, 0, 2])
    assert len(cr) == 3 and cr.clamped == 3
    assert cr.raw_ids[-1] == -1  # the bucket has no single raw id


def test_remap_trace_one_shot():
    out = remap_trace([10**15, 3, 10**15, 99])
    np.testing.assert_array_equal(out, [0, 1, 0, 2])


def test_remap_overflow_memory_stays_bounded():
    """drop/clamp must not record each distinct overflow id: on hashed
    out-of-core streams that dict would grow without bound — the exact
    case the bounded-catalog modes exist for."""
    for mode in ("drop", "clamp"):
        cr = CatalogRemap(max_items=4, overflow=mode)
        cr.apply(np.arange(10_000) * 17)
        assert len(cr._table) <= 4
        # behavior unchanged: later chunks still drop/clamp consistently
        out = cr.apply(np.asarray([17 * 9_999, 0]))
        if mode == "drop":
            np.testing.assert_array_equal(out, [0])
        else:
            np.testing.assert_array_equal(out, [3, 0])


# ---------------------------------------------------------------------------
# sparse-id regressions for trace_stats / reuse_distances
# ---------------------------------------------------------------------------
def test_trace_stats_sparse_ids_match_dense_relabeling():
    """Non-contiguous raw ids must give the same stats as their dense
    relabeling (the pre-fix code silently assumed dense 0..N-1 and would
    allocate max(id)+1 arrays)."""
    rng = np.random.default_rng(0)
    raw_ids = np.array([7, 10**9 + 33, 3, 10**14, 9_999_999_999], np.int64)
    trace = raw_ids[rng.integers(0, len(raw_ids), size=5000)]
    st_sparse = trace_stats(trace)  # must not OOM on max(id)+1 ~ 1e14
    assert st_sparse.catalog == 10**14 + 1
    assert st_sparse.unique == 5
    np.testing.assert_array_equal(st_sparse.items, np.sort(raw_ids))

    # dense relabeling preserving order-of-value (items are ascending)
    dense = np.searchsorted(np.sort(raw_ids), trace)
    st_dense = trace_stats(dense)
    np.testing.assert_array_equal(st_sparse.lifetimes, st_dense.lifetimes)
    np.testing.assert_array_equal(st_sparse.max_hits, st_dense.max_hits)
    assert st_sparse.hit_share_lifetime_below(100) == (
        st_dense.hit_share_lifetime_below(100)
    )


def test_trace_stats_dense_and_sparse_paths_agree():
    """The two internal paths must return identical results; force the
    sparse path by planting one huge id in an otherwise dense trace."""
    tr = zipf(500, 8000, seed=11)
    st_dense = trace_stats(tr)
    spread = tr * (10**10)  # same structure, ids now gappy
    st_sparse = trace_stats(spread)
    np.testing.assert_array_equal(st_sparse.items, st_dense.items * 10**10)
    np.testing.assert_array_equal(st_sparse.lifetimes, st_dense.lifetimes)
    np.testing.assert_array_equal(st_sparse.max_hits, st_dense.max_hits)
    assert st_sparse.unique == st_dense.unique


def test_trace_stats_negative_ids_raise():
    with pytest.raises(ValueError, match="negative"):
        trace_stats(np.array([1, -4, 2]))


def test_reuse_distances_sparse_ids():
    rd = reuse_distances(np.array([10**13, 5, 10**13, 5, 10**13]))
    np.testing.assert_array_equal(rd, [2, 2, 2])


# ---------------------------------------------------------------------------
# synthesizer calibration: the fitted statistics survive synthesis
# ---------------------------------------------------------------------------
def test_profile_matches_popularity_skew():
    src = zipf(2000, 60_000, alpha=0.9, seed=4)
    prof = fit_profile(src)
    syn = synthesize(prof, 60_000, catalog=2000, seed=9)

    def top_share(tr, k):
        c = np.sort(np.bincount(tr, minlength=2000))[::-1]
        return c[:k].sum() / len(tr)

    for k in (20, 200):
        assert abs(top_share(syn, k) - top_share(src, k)) < 0.1, k


def test_profile_matches_oneshot_and_burst_composition():
    src = bursty(4000, 60_000, burst_fraction=0.4, seed=5)
    prof = fit_profile(src)
    assert prof.burst_frac > 0.02  # the fit saw the short-lived overlay
    syn = synthesize(prof, 60_000, catalog=4000, seed=3)
    src_share = trace_stats(src).hit_share_lifetime_below(100)
    syn_share = trace_stats(syn).hit_share_lifetime_below(100)
    assert abs(syn_share - src_share) < 0.15
    syn_prof = fit_profile(syn)
    assert abs(syn_prof.oneshot_frac - prof.oneshot_frac) < 0.05
    assert abs(syn_prof.burst_frac - prof.burst_frac) < 0.1


def test_profile_matches_reuse_profile():
    src = zipf(1000, 50_000, alpha=0.8, seed=6)
    prof = fit_profile(src)
    syn = synthesize(prof, 50_000, catalog=1000, seed=2)
    med_src = np.median(reuse_distances(src))
    med_syn = np.median(reuse_distances(syn))
    assert 0.25 < med_syn / med_src < 4.0


def test_profile_detects_and_reproduces_drift():
    src = shifting_zipf(2000, 64_000, phase=8000, seed=7)
    assert fit_profile(src).drift_phase == 8000
    # a stationary source fits as stationary
    assert fit_profile(zipf(2000, 64_000, seed=7)).drift_phase == 0
    # synthesized drift: consecutive phases have (mostly) disjoint hot sets
    prof = fit_profile(src)
    syn = synthesize(prof, 32_000, catalog=2000, seed=1)
    c1 = np.bincount(syn[:8000], minlength=2000)
    c2 = np.bincount(syn[8000:16000], minlength=2000)
    top1 = set(np.argsort(c1)[-20:].tolist())
    top2 = set(np.argsort(c2)[-20:].tolist())
    assert len(top1 & top2) < 10


def test_fit_profile_empty_trace_raises():
    with pytest.raises(ValueError, match="empty"):
        fit_profile(np.empty(0, np.int64))


def test_real_like_generator_is_registered_and_deterministic():
    a = make_trace("real_like", 800, 12_000, seed=3, source="zipf", alpha=0.9)
    b = make_trace("real_like", 800, 12_000, seed=3, source="zipf", alpha=0.9)
    c = make_trace("real_like", 800, 12_000, seed=4, source="zipf", alpha=0.9)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.dtype == np.int64 and len(a) == 12_000
    assert a.min() >= 0 and a.max() < 800
