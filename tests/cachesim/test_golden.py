"""Golden-trace regression fixtures for the scenario registry.

Each comparison scenario is replayed at "mini" scale through the real
engines and checked against a committed snapshot
(``tests/cachesim/golden/<scenario>.json``), so an engine refactor cannot
silently shift hit ratios or regret.  The discrete automata are
deterministic and are pinned tightly; the fractional engines (OGB/OMD) get a
small float32 allowance for cross-XLA reduction-order drift.

To regenerate after an *intentional* behavior change::

    PYTHONPATH=src python -m pytest tests/cachesim/test_golden.py --update-golden

and commit the resulting JSON diff deliberately.
"""

import json
import os

import pytest

from repro.cachesim.scenarios import SCENARIOS, run_scenario

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

#: scenarios with a policy set (the fig11 entries are trace-stat only and are
#: covered by the fig11 benchmark's calibration assertions)
GOLDEN_SCENARIOS = sorted(
    name for name, sc in SCENARIOS.items() if sc.policies
)

# deterministic integer-hit automata: pinned to the stored value exactly;
# fractional float32 engines: small tolerance for reduction-order drift
EXACT_ATOL = 1e-12
FLOAT_ATOL = 5e-3
FLOAT_ROWS = ("OGB", "OMD", "OGB_sized_tree", "OGB_sized_scan")


def _golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def _snapshot(name: str) -> dict:
    res = run_scenario(name, scale="mini")
    rows = {}
    for policy, row in sorted(res.rows.items()):
        entry = {"hit_ratio": round(row["hit_ratio"], 10)}
        if "byte_hit_ratio" in row:
            entry["byte_hit_ratio"] = round(row["byte_hit_ratio"], 10)
        if "regret" in row:
            entry["regret"] = round(row["regret"], 6)
        if "byte_regret" in row:
            entry["byte_regret"] = round(row["byte_regret"], 6)
        rows[policy] = entry
    return {
        "scenario": name,
        "scale": "mini",
        "N": res.N,
        "T": res.T,
        "C": res.C,
        "rows": rows,
    }


def test_sized_cdn_golden_ranking_flip():
    """The committed sized_cdn fixture certifies the scenario's claim:
    byte hit ratio orders the policies differently than object hit ratio
    (size-blind frequency policies win on objects, the byte-weighted
    gradient policy wins on bytes)."""
    path = _golden_path("sized_cdn")
    assert os.path.exists(path), "missing sized_cdn golden (--update-golden)"
    with open(path) as f:
        rows = json.load(f)["rows"]
    pols = sorted(k for k in rows if k != "OPT(static)")
    assert all("byte_hit_ratio" in rows[k] for k in pols)
    by_obj = sorted(pols, key=lambda k: -rows[k]["hit_ratio"])
    by_byte = sorted(pols, key=lambda k: -rows[k]["byte_hit_ratio"])
    assert by_obj != by_byte, (by_obj, by_byte)
    # and the flip is not a hairline tie: the byte winner is an object loser
    assert by_byte[0] != by_obj[0]


@pytest.mark.parametrize("name", GOLDEN_SCENARIOS)
def test_golden_scenario(name, request):
    path = _golden_path(name)
    snap = _snapshot(name)
    if request.config.getoption("--update-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        pytest.skip(f"rewrote {path}")
    assert os.path.exists(path), (
        f"missing golden fixture {path}; run pytest with --update-golden "
        "and commit it"
    )
    with open(path) as f:
        golden = json.load(f)
    assert snap["rows"].keys() == golden["rows"].keys(), (
        snap["rows"].keys(),
        golden["rows"].keys(),
    )
    assert (snap["N"], snap["T"], snap["C"]) == (
        golden["N"],
        golden["T"],
        golden["C"],
    ), "scenario mini dims changed — regenerate the goldens deliberately"
    for policy, entry in golden["rows"].items():
        atol = FLOAT_ATOL if policy in FLOAT_ROWS else EXACT_ATOL
        got = snap["rows"][policy]
        for metric, want in entry.items():
            tol = atol if metric in ("hit_ratio", "byte_hit_ratio") else max(
                FLOAT_ATOL * golden["T"], abs(want) * 5e-3
            )
            assert got[metric] == pytest.approx(want, abs=tol), (
                name,
                policy,
                metric,
                got[metric],
                want,
            )
