"""Differential tests: scan automata == core/policies.py, bit for bit.

The engines' whole value is that compiling LRU/FIFO/LFU/FTPL into
``lax.scan`` slot automata changes *nothing* about the replayed dynamics —
the per-request hit sequence must equal the host policy's exactly (not in
distribution, not within tolerance) on every trace family, and OMD must
match a float64 numpy oracle within float32 headroom.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.cachesim.engines import (
    ENGINE_KINDS,
    engine_hit_sequence,
    init_engine_carry,
    make_engine_fn,
    run_engine,
    run_omd,
    sweep_engine,
)
from repro.cachesim.replay import replay_trace, sweep_replay
from repro.cachesim.traces import adversarial, bursty, zipf
from repro.core.omd import OMDClassic, project_capped_simplex_kl
from repro.core.policies import make_policy

N, C, T = 311, 23, 6000

TRACES = {
    "zipf": lambda: zipf(N, T, alpha=0.9, seed=3),
    "adversarial": lambda: adversarial(N, T, seed=4),
    "bursty": lambda: bursty(N, T, seed=5),
}


def _host_hits(kind, trace, n, c, **kw):
    pol = make_policy(kind, n, c, **kw)
    return np.fromiter(
        (pol.request(int(j)) for j in trace), dtype=bool, count=len(trace)
    )


@pytest.mark.parametrize("trace_name", sorted(TRACES))
@pytest.mark.parametrize("kind", ENGINE_KINDS)
def test_exact_hit_sequence_agreement(kind, trace_name):
    """Every automaton replays the exact host-policy hit sequence."""
    trace = TRACES[trace_name]()
    kw = {"horizon": T, "seed": 0} if kind == "ftpl" else {}
    dev = engine_hit_sequence(kind, trace, N, C, **kw)
    host = _host_hits(kind, trace, N, C, **kw)
    np.testing.assert_array_equal(dev, host)


def test_exact_agreement_at_issue_bounds():
    """The acceptance-criterion shape: N = 512, T = 20k, all automata."""
    n, c, t = 512, 31, 20_000
    trace = zipf(n, t, alpha=0.8, seed=11)
    for kind in ENGINE_KINDS:
        kw = {"horizon": t, "seed": 1} if kind == "ftpl" else {}
        dev = engine_hit_sequence(kind, trace, n, c, **kw)
        host = _host_hits(kind, trace, n, c, **kw)
        np.testing.assert_array_equal(dev, host, err_msg=kind)


def test_windowed_hits_partition_sequence():
    """Chunked replay (window > 1) sums the same per-request bits."""
    trace = TRACES["zipf"]()
    seq = engine_hit_sequence("lru", trace, N, C)
    res = run_engine("lru", trace, N, C, window=500)
    np.testing.assert_array_equal(
        res.hits, seq[: res.T].reshape(-1, 500).sum(axis=1)
    )
    assert res.occupancy[-1] == C  # zipf fills the cache


def test_lfu_admission_filter_matches_host():
    """Adversarial-for-LFU trace: a hot prefix then a cold scan — the scan
    must be rejected by the admission rule on both sides."""
    hot = np.repeat(np.arange(C), 5)
    scan = np.arange(C, N)
    trace = np.concatenate([hot, scan, hot])
    dev = engine_hit_sequence("lfu", trace, N, C)
    host = _host_hits("lfu", trace, N, C)
    np.testing.assert_array_equal(dev, host)
    # the cold scan got no admissions: the second hot pass hits everything
    assert dev[-len(hot) :].all()


def test_ftpl_noise_grid_identical():
    """Engine and host draw the same float32 noise (the bit-exactness root)."""
    from repro.core.ftpl import FTPL, ftpl_noise

    pol = FTPL(N, C, zeta=2.0, seed=7)
    carry = init_engine_carry("ftpl", N, C, zeta=2.0, seed=7)
    np.testing.assert_array_equal(np.asarray(carry.noise), pol._noise)
    assert set(np.asarray(carry.slots).tolist()) == set(pol.cached)
    assert ftpl_noise(N, 2.0, seed=7).dtype == np.float32


# ---------------------------------------------------------------------------
# OMD vs float64 oracle
# ---------------------------------------------------------------------------
def test_omd_engine_matches_float64_oracle_pointwise():
    """Short horizon (inside float32 headroom): the engine's fractional state
    tracks the exact float64 oracle coordinate by coordinate."""
    B, eta = 16, 0.05
    trace = TRACES["zipf"]()[: 100 * B]
    m = run_omd(
        trace, N, C, B, eta=eta, sample="none", keep_final_f=True,
        track_opt=True,
    )
    pol = OMDClassic(N, C, eta=eta, batch_size=B, integral=False)
    for j in trace[: m.T]:
        pol.request(int(j))
    np.testing.assert_allclose(m.final_f, pol.f, atol=5e-5)
    rewards = np.asarray(m.frac_reward)
    assert abs(rewards.sum() - pol.fractional_reward) < 1e-4 * max(
        pol.fractional_reward, 1.0
    )


def test_omd_per_step_threshold_matches_oracle():
    """Stepping the float64 oracle state: the float32 safeguarded-Newton
    threshold agrees with the exact water-filling lambda at every chunk
    (no compounding — this is the per-step contract)."""
    import jax.numpy as jnp

    from repro.cachesim.engines import _omd_project
    from repro.jaxcache.fractional import warm_bracket_hi

    B, eta = 16, 0.05
    trace = TRACES["zipf"]()
    pol = OMDClassic(N, C, eta=eta, batch_size=B, integral=False)
    for i in range(60):
        ids = trace[i * B : (i + 1) * B]
        pol.w = pol.w + eta * np.bincount(ids, minlength=N)
        f64, lam64 = project_capped_simplex_kl(pol.w, C, return_lam=True)
        lam32 = _omd_project(
            jnp.asarray(pol.w, jnp.float32),
            float(C),
            warm_bracket_hi(eta * B),
            10,
        )
        assert abs(float(lam32) - lam64) < 2e-6, (i, float(lam32), lam64)
        assert 0.0 <= lam64 <= eta * B  # the provable warm bracket
        pol.w -= lam64
        pol.f = f64


def test_omd_long_horizon_aggregates_and_feasibility():
    """Full horizon: float32 trajectories drift pointwise (mirror descent
    amplifies rounding multiplicatively) but the aggregate metrics, simplex
    feasibility and threshold bracket must all hold."""
    trace = TRACES["zipf"]()
    B, eta = 16, 0.05
    m = run_omd(
        trace, N, C, B, eta=eta, sample="none", keep_final_f=True,
        track_opt=True,
    )
    pol = OMDClassic(N, C, eta=eta, batch_size=B, integral=False)
    for j in trace[: m.T]:
        pol.request(int(j))
    rewards = np.asarray(m.frac_reward)
    assert abs(rewards.sum() - pol.fractional_reward) < 2e-3 * max(
        pol.fractional_reward, 1.0
    )
    # feasibility: the device state stays on the capped simplex
    assert abs(float(np.sum(m.final_f)) - C) < 1e-3
    assert np.all(m.final_f >= 0) and np.all(m.final_f <= 1 + 1e-6)
    # the KL thresholds stay in the provable [0, eta*B] bracket
    assert np.all(m.taus >= 0) and np.all(m.taus <= eta * B * (1 + 1e-4) + 1e-6)


def test_kl_projection_oracle_properties():
    rng = np.random.default_rng(2)
    w = rng.normal(-1.0, 2.0, size=400)
    for cap in (1, 17, 399):
        f, lam = project_capped_simplex_kl(w, cap, return_lam=True)
        assert abs(f.sum() - cap) < 1e-9 * max(cap, 1)
        assert np.all(f >= 0) and np.all(f <= 1 + 1e-12)
        # unsaturated coordinates keep the exact exponential-weights ratio
        interior = f < 1.0 - 1e-12
        np.testing.assert_allclose(
            f[interior], np.exp(w[interior] - lam), rtol=1e-10
        )


def test_omd_learns_on_skewed_traffic():
    """Sanity: mirror descent concentrates mass on the hot set."""
    trace = zipf(N, 20_000, alpha=1.2, seed=9)
    m = run_omd(trace, N, C, 100, sample="none", keep_final_f=True)
    hot = np.argsort(np.bincount(trace, minlength=N))[-C // 2 :]
    assert m.final_f[hot].mean() > 3.0 * (C / N)
    w = m.windowed_frac_ratio(m.T // 4)
    assert w[-1] > w[0]  # the transient moves the right way


# ---------------------------------------------------------------------------
# vmapped sweeps == stacked single replays
# ---------------------------------------------------------------------------
def test_sweep_engine_rows_match_single_runs():
    trace = TRACES["zipf"]()
    caps = [7, 23]
    sw = sweep_engine(
        "lru", trace, N, caps, seeds=(0,), window=500, track_opt=True
    )
    for cap in caps:
        single = run_engine("lru", trace, N, cap, window=500)
        r = sw.row(capacity=cap)
        np.testing.assert_array_equal(sw.hits[r], single.hits)
        np.testing.assert_array_equal(sw.occupancy[r], single.occupancy)
    assert sw.opt_hits[sw.row(capacity=23)] >= sw.opt_hits[sw.row(capacity=7)]


def test_sweep_engine_ftpl_seeds_differ():
    trace = TRACES["zipf"]()
    sw = sweep_engine(
        "ftpl", trace, N, [C], seeds=(0, 1), window=500, horizon=T
    )
    assert not np.array_equal(
        sw.hits[sw.row(seed=0)], sw.hits[sw.row(seed=1)]
    )
    # and each seed row matches its single replay exactly
    single = run_engine("ftpl", trace, N, C, window=500, seed=1, horizon=T)
    np.testing.assert_array_equal(sw.hits[sw.row(seed=1)], single.hits)


def test_sweep_replay_grid_matches_single():
    trace = TRACES["zipf"]()
    sw = sweep_replay(
        trace, N, capacities=[11, 23], etas=[0.03, None], seeds=(0,), batch=16
    )
    assert len(sw.combos) == 4
    single = replay_trace(trace, N, 23, batch=16, eta=0.03, seed=0)
    r = sw.row(capacity=23, eta=0.03)
    np.testing.assert_allclose(sw.frac_reward[r], single.frac_reward, atol=1e-3)
    np.testing.assert_array_equal(sw.hits[r], single.hits)
    assert sw.opt_hits[r] == single.opt_hits
    assert sw.regrets[r] == pytest.approx(single.regret, abs=1e-2)
    # eta=None rows must resolve to replay_trace's default tuning, so a
    # default-tuned sweep reproduces default-tuned single replays exactly
    default = replay_trace(trace, N, 11, batch=16, seed=0)
    r_def = sw.row(capacity=11, eta=default.extras["eta"])
    np.testing.assert_array_equal(sw.hits[r_def], default.hits)
    np.testing.assert_allclose(
        sw.frac_reward[r_def], default.frac_reward, atol=1e-3
    )


def test_engine_carry_capacity_padding_inert():
    """Padded (inactive) slots never cache anything: a padded sweep row
    equals the unpadded replay."""
    trace = TRACES["bursty"]()
    padded = init_engine_carry("lru", N, 7, n_slots=23)
    fn = make_engine_fn("lru")
    chunks = jnp.asarray(trace[:5000].reshape(-1, 100), jnp.int32)
    _carry, (hits_pad, occ_pad) = fn(padded, chunks)
    res = run_engine("lru", trace[:5000], N, 7, window=100)
    np.testing.assert_array_equal(np.asarray(hits_pad), res.hits)
    assert int(np.max(np.asarray(occ_pad))) <= 7
